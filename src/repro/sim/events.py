"""Discrete-event core: typed events and a deterministic event heap.

The cluster simulator (:mod:`repro.sim.clustersim`) advances simulated
time by popping events off an :class:`EventQueue`.  Two properties make
runs reproducible bit-for-bit:

* **Total order.**  Events sort by ``(time, type priority, sequence)``.
  The type priority resolves ties at equal timestamps with fixed
  semantics (see :class:`EventType`); the monotonically increasing
  sequence number resolves the remaining ties in insertion order, so two
  identical runs pop identical event streams.
* **Lazy invalidation.**  Events scheduled for a job attempt carry the
  attempt id; a consumer drops events whose attempt has since been
  superseded (e.g. the COMPLETE of an attempt that was aborted by a
  FAILURE) instead of searching the heap for them.

All times are simulated **seconds** on one global clock starting at 0.0.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Any, Iterator, Optional


class EventType(enum.IntEnum):
    """Event kinds, ordered by tie-break priority at equal timestamps.

    Lower value pops first.  The order encodes the simulator's
    simultaneity semantics:

    * ``COMPLETE`` before ``FAILURE``: a job that finishes at *t* is done
      before a node failing at the same instant can kill it (the benign
      reading; the paper's SimGrid platform makes the same call because a
      finished transmission cannot be varied to zero capacity).
    * ``FAILURE`` before ``RECOVER``: a zero-downtime blip still aborts
      the jobs it touches.
    * ``RECOVER`` and ``HEARTBEAT`` before ``SUBMIT``/``START``: a
      submission at a repair instant or heartbeat tick sees the freshest
      capacity and health estimate.
    * ``START`` last: scheduling decisions run after every state change
      at the same timestamp.
    """

    COMPLETE = 0
    FAILURE = 1
    RECOVER = 2
    HEARTBEAT = 3
    CHECKPOINT = 4
    SUBMIT = 5
    START = 6


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence: a timestamp, a kind, and a payload.

    ``seq`` is assigned by the queue at push time and makes the sort key
    ``(time, type, seq)`` unique.  ``data`` is an arbitrary payload dict
    owned by the producer (job ids, node arrays, attempt counters ...).
    """

    time: float
    type: EventType
    seq: int
    data: dict = dataclasses.field(default_factory=dict)

    def __getitem__(self, key: str) -> Any:
        return self.data[key]

    def get(self, key: str, default: Any = None) -> Any:
        return self.data.get(key, default)


class EventQueue:
    """Min-heap of :class:`Event` with the deterministic total order.

    ``push`` stamps the sequence number; ``pop`` returns the earliest
    event under ``(time, type priority, seq)``.  Pushing an event in the
    past (``time < last popped time``) raises ``ValueError`` — the loop
    never travels backwards.
    """

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, int, Event]] = []
        self._seq = itertools.count()
        self._now = 0.0
        self.pushed = 0
        self.popped = 0

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    @property
    def now(self) -> float:
        """Timestamp of the last popped event (0.0 before any pop)."""
        return self._now

    def push(self, time: float, type: EventType, **data: Any) -> Event:
        if time < self._now:
            raise ValueError(
                f"event at t={time} is in the past (clock at {self._now})")
        ev = Event(float(time), EventType(type), next(self._seq), data)
        heapq.heappush(self._heap, (ev.time, int(ev.type), ev.seq, ev))
        self.pushed += 1
        return ev

    def pop(self) -> Event:
        if not self._heap:
            raise IndexError("pop from empty EventQueue")
        _, _, _, ev = heapq.heappop(self._heap)
        self._now = ev.time
        self.popped += 1
        return ev

    def peek(self) -> Optional[Event]:
        return self._heap[0][3] if self._heap else None

    def drain(self) -> Iterator[Event]:
        """Pop until empty (mainly for tests)."""
        while self._heap:
            yield self.pop()
