"""Batch simulation — the paper's Section 5.2 experiment engine.

A *batch* is 100 instances of the same MPI application (paper).  Per batch:
a candidate faulty set ``N_f`` is fixed; per instance, each candidate enters
the failed state independently with ``p_f``.  A failed node kills any job
whose endpoints or routes touch it.  Without checkpointing (paper
assumption), every abort charges one full successful runtime and the
instance restarts from scratch:

    T_batch = sum_i T_success * (1 + aborts_i)
    abort_ratio = (# instances with >= 1 abort) / instances     [paper]
    abort_rate  = aborted attempts / total attempts             [diagnostic]

``checkpoint_interval`` enables the beyond-paper checkpoint/restart model:
an aborted attempt only charges the work since the last checkpoint plus
checkpoint-write overhead, bounding the restart cost.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster.failures import FailureModel
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.state import ClusterState
from repro.core.topology import TorusTopology
from repro.sim.jobsim import simulate_instance, successful_runtime
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import Workload


@dataclasses.dataclass
class BatchResult:
    policy: str
    completion_time: float
    abort_ratio: float          # paper metric: instances aborted >= once
    abort_rate: float           # attempts aborted / attempts
    n_instances: int
    n_aborted_attempts: int
    success_runtime: float      # per-instance successful runtime
    placement: np.ndarray
    faulty_nodes_used: int
    place_time_s: float = 0.0   # mapper wall-clock for this batch's placement


def run_batch(
    wl: Workload,
    policy: str,
    net: TorusNetwork,
    failure_model: FailureModel,
    known_p_f: np.ndarray | None,
    n_instances: int = 100,
    rng: np.random.Generator | None = None,
    checkpoint_interval: float | None = None,
    checkpoint_overhead: float = 0.0,
    max_attempts: int = 100,
    engine: PlacementEngine | None = None,
) -> BatchResult:
    """Simulate one batch under one placement policy.

    **The ``known_p_f`` contract** (truth vs estimate): the placement
    policy only ever sees ``known_p_f`` — what the scheduler *believes*,
    i.e. a heartbeat-derived estimate — while ``failure_model`` holds the
    ground truth used to sample actual failures.  Passing
    ``failure_model.outage_vector(...)`` models a perfectly converged
    estimator (the paper's setting); passing a
    :meth:`~repro.cluster.heartbeat.HeartbeatMonitor.outage_probabilities`
    vector models imperfect knowledge (see ``benchmarks/fault_ablation``);
    passing ``None`` models a fault-blind scheduler.  Eq. 1 only consults
    ``p_f > 0``, so any estimator that flags the right *set* of nodes is
    as good as the truth.

    Placement is computed once per batch, as in the paper (N_f is fixed
    per batch).  Pass a shared ``engine`` to reuse cached hop/weight
    matrices across batches and policies instead of recomputing full
    topology state per job.  ``rng`` drives both the per-attempt failure
    draws and any stochastic policy; one batch is a pure function of
    (workload, policy, rng state).
    """
    rng = rng or np.random.default_rng(0)
    topo = net.topo
    engine = engine or PlacementEngine()
    # the belief travels as a versioned ClusterState; from_arrays interns
    # by content, so every batch sharing one N_f shares one epoch (and
    # the engine's epoch-keyed weight matrices)
    state = ClusterState.from_arrays(topo.n_nodes, p_f=known_p_f)
    req = PlacementRequest(comm=wl.comm, topology=topo, state=state)
    res = engine.place(req, policy=policy, rng=rng)
    placement = res.placement
    t_ok = successful_runtime(wl, placement, net)

    total_time = 0.0
    aborted_instances = 0
    aborted_attempts = 0
    n_ckpts = int(t_ok // checkpoint_interval) if checkpoint_interval else 0
    for _ in range(n_instances):
        attempts = 0
        remaining = t_ok
        while True:
            attempts += 1
            failed = failure_model.sample_failed(rng, remaining)
            out = simulate_instance(wl, placement, net, failed,
                                    runtime=remaining)
            if out.completed or attempts >= max_attempts:
                # successful attempt pays checkpoint-write overhead too
                total_time += remaining + n_ckpts * checkpoint_overhead
                break
            aborted_attempts += 1
            if checkpoint_interval is None:
                # paper accounting: a full successful runtime is charged per
                # abort, then the job restarts from scratch
                total_time += t_ok
                remaining = t_ok
            else:
                # beyond paper: abort at a uniform point of the attempt;
                # work up to the last checkpoint is preserved (n_kept
                # writes were performed and are charged)
                fail_at = rng.uniform(0.0, remaining)
                n_kept = int(fail_at // checkpoint_interval)
                kept = n_kept * checkpoint_interval
                total_time += fail_at + n_kept * checkpoint_overhead
                remaining = remaining - kept
        if attempts > 1:
            aborted_instances += 1
    attempts_total = n_instances + aborted_attempts
    return BatchResult(
        policy=policy,
        completion_time=total_time,
        abort_ratio=aborted_instances / n_instances,
        abort_rate=aborted_attempts / attempts_total,
        n_instances=n_instances,
        n_aborted_attempts=aborted_attempts,
        success_runtime=t_ok,
        placement=placement,
        faulty_nodes_used=res.faulty_nodes_used,
        place_time_s=res.wall_time_s,
    )


@dataclasses.dataclass
class ScenarioResult:
    policy: str
    batches: list
    mean_completion: float
    mean_abort_ratio: float
    mean_place_time_s: float = 0.0  # placement overhead per batch (Section 5:
                                    # must stay negligible vs completion_time)

    def improvement_over(self, other: "ScenarioResult") -> float:
        return 1.0 - self.mean_completion / other.mean_completion


def run_scenario(
    wl_factory,
    policies,
    dims: tuple[int, ...] = (8, 8, 8),
    n_batches: int = 10,
    n_instances: int = 100,
    n_faulty: int = 16,
    p_f: float = 0.02,
    seed: int = 0,
    scheduler_knows_truth: bool = True,
    topology=None,
    network=None,
    **net_kw,
) -> dict[str, ScenarioResult]:
    """The full Fig. 4/5 protocol: ``n_batches`` batches x ``n_instances``
    instances; per batch a fresh random N_f (shared by all policies so the
    comparison is paired).

    Hosts: pass ``topology`` (any :class:`~repro.core.engine.Topology`
    implementation — fat-tree, TPU fabric, ...) to run on a non-torus
    platform; ``dims`` is the legacy torus shorthand used when ``topology``
    is omitted.  ``network`` overrides the performance model (default: the
    best in-tree model for the topology, see
    :func:`repro.sim.network.network_for`).
    """
    from repro.cluster.failures import BernoulliPerJob
    from repro.sim.network import network_for

    topo = topology if topology is not None else TorusTopology(dims)
    net = network if network is not None else network_for(topo, **net_kw)
    # one engine for the whole scenario: the torus hop matrix is derived
    # once, and each batch's Eq. 1 weight matrix once (shared by policies)
    engine = PlacementEngine()
    results: dict[str, list[BatchResult]] = {p: [] for p in policies}
    for b in range(n_batches):
        batch_rng = np.random.default_rng(seed * 1000 + b)
        candidates = batch_rng.choice(topo.n_nodes, n_faulty, replace=False)
        fm = BernoulliPerJob(candidates, p_f)
        known = fm.outage_vector(topo.n_nodes) if scheduler_knows_truth else None
        wl = wl_factory()
        for pol in policies:
            r = run_batch(wl, pol, net, fm, known, n_instances=n_instances,
                          rng=np.random.default_rng(seed * 7777 + b),
                          engine=engine)
            results[pol].append(r)
    out = {}
    for pol in policies:
        rs = results[pol]
        out[pol] = ScenarioResult(
            policy=pol,
            batches=rs,
            mean_completion=float(np.mean([r.completion_time for r in rs])),
            mean_abort_ratio=float(np.mean([r.abort_ratio for r in rs])),
            mean_place_time_s=float(np.mean([r.place_time_s for r in rs])),
        )
    return out
