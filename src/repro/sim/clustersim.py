"""Event-driven cluster simulator: many jobs, one shared cluster, real time.

:mod:`repro.sim.batchsim` reproduces the paper's Section 5.2 protocol with
closed-form accounting — one job at a time, failures sampled per attempt.
This module generalises it to a discrete-event simulation where **many
jobs share the cluster concurrently**: a :class:`~repro.cluster.scheduler.
Scheduler` queues and backfills jobs over free capacity, heartbeat rounds
drive the outage estimator, nodes fail and are repaired *over time*
(:class:`~repro.cluster.failures.FailureProcess`), and a mid-run failure
aborts the jobs holding the node, re-places them incrementally
(``engine.replace``) and restarts them from their latest checkpoint.

Every queue-drain tick (SUBMIT / COMPLETE / RECOVER / HEARTBEAT
handlers) places all runnable queued jobs with **one batched**
:meth:`~repro.core.engine.PlacementEngine.place_many` call in exclusive
mode, so a drain shares one backend scope and one set of cached
(topology, health) matrices across the jobs it starts; the cumulative
mapper wall-clock is reported as :attr:`SimResult.place_time_s`.

Event semantics (tie-breaks in :class:`~repro.sim.events.EventType`):

=========== ===============================================================
SUBMIT      a job enters the pending queue; the scheduler drains the queue
START       a (re)started attempt begins executing on its placement
CHECKPOINT  a running attempt preserves its work so far (time-based mode)
FAILURE     per-attempt doom (paper mode) or node(s) going down (time mode)
RECOVER     repaired nodes return; the queue drains onto them
HEARTBEAT   one poll round: replies sampled, estimates updated, drain/undrain
COMPLETE    an attempt finishes; capacity frees; chained jobs submit
=========== ===============================================================

**Two failure layers**, usable together:

* ``attempt_failures`` — the paper's per-attempt scenario model
  (:class:`~repro.cluster.failures.FailureModel`): at each attempt start
  a failed set is sampled for that attempt only; if the job's endpoints
  or routes touch it, the attempt is doomed and charged exactly as
  :func:`repro.sim.batchsim.run_batch` charges it (full remaining runtime
  without checkpointing; work-since-last-checkpoint plus write overhead
  with it).  With serial arrivals and a fixed per-batch placement this
  reproduces ``run_batch`` completion times *bit-for-bit* — the RNG draw
  order is identical (see ``tests/test_clustersim.py``).
* ``failure_process`` — time-based node lifecycles: FAILURE/RECOVER heap
  events from pre-generated traces.  A node failure aborts every running
  job whose placement holds it (endpoint fault form — see
  ``docs/SIMULATOR.md`` for why routes are only consulted in the
  per-attempt model); the scheduler re-places the survivors or requeues
  jobs the surviving capacity cannot hold.

**State ownership.**  Who knows what about node health is deliberately
split (see ``docs/ARCHITECTURE.md``): the *simulator* owns ground truth
(``_down_count`` — how many overlapping outages hold each node down —
plus ``registry.true_outage_p`` flakiness), the *failure layers* own the
injection processes, and the *scheduler* owns the single **belief**
artifact every placement consumes — a versioned
:class:`~repro.core.state.ClusterState` snapshot merged from registry
lifecycle and heartbeat estimates (``Scheduler.cluster_state()``).  The
simulator never hands truth to the mapper; it only shapes the heartbeat
replies the estimator sees.  Epochs advance only when the belief
actually changes, so long stretches of simulated time reuse one set of
engine caches.

Units: all times are simulated **seconds** on one clock from 0.0.  All
randomness flows through the single ``rng`` handed to :class:`ClusterSim`
(attempt dooms, checkpoint abort points, heartbeat replies), so a run is
a pure function of (job stream, cluster state, seed).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.cluster.failures import FailureModel, FailureProcess
from repro.cluster.scheduler import Job, JobRecord, Scheduler
from repro.sim.events import EventQueue, EventType
from repro.sim.jobsim import successful_runtime
from repro.workloads.arrivals import JobSpec


@dataclasses.dataclass
class SimConfig:
    """Knobs of one simulation run (all times in simulated seconds)."""

    heartbeat_interval: Optional[float] = None   # None = no heartbeat events
    checkpoint_interval: Optional[float] = None  # None = no checkpointing
    checkpoint_overhead: float = 0.0             # wall cost per ckpt write
    restart_delay: float = 0.0                   # relaunch latency per restart
    max_attempts: int = 100                      # per job, as in run_batch
    max_events: int = 500_000                    # hard event budget
    failure_horizon: Optional[float] = None      # trace length for processes
    trace: bool = False                          # keep an event trace


@dataclasses.dataclass
class _SimJob:
    """Internal per-job state (exposed summarised as :class:`JobStats`)."""

    idx: int
    spec: JobSpec
    rec: Optional[JobRecord] = None      # scheduler-managed jobs only
    state: str = "waiting"               # waiting|queued|running|done
    placement: Optional[np.ndarray] = None
    t_ok: float = 0.0                    # runtime under current placement
    remaining: float = 0.0               # work left, seconds @ current plcmt
    ckpt_in_attempt: float = 0.0         # work preserved within this attempt
    n_ckpts: int = 0                     # paper-mode success-charge count
    epoch: int = 0                       # invalidates stale heap events
    attempts: int = 0
    aborts: int = 0
    submit_time: float = -1.0
    first_start: float = -1.0
    finish_time: float = -1.0


@dataclasses.dataclass
class JobStats:
    name: str
    policy: str
    n_ranks: int
    submit_time: float
    first_start: float
    finish_time: float
    attempts: int
    aborts: int
    requeues: int

    @property
    def completion_time(self) -> float:
        """Sojourn: submit -> finish (queue wait + restarts included)."""
        return self.finish_time - self.submit_time

    @property
    def queue_wait(self) -> float:
        return self.first_start - self.submit_time


@dataclasses.dataclass
class SimResult:
    jobs: list[JobStats]
    makespan: float                 # last finish (clock starts at 0)
    n_events: int
    node_failures: int
    truncated: bool                 # hit max_events before all jobs finished
    trace: list[tuple[float, str, str]]
    place_time_s: float = 0.0       # mapper wall-clock the scheduler spent
                                    # placing/re-placing this run's jobs
                                    # (0 for fixed-placement streams)

    @property
    def finished_jobs(self) -> list[JobStats]:
        return [j for j in self.jobs if j.finish_time >= 0]

    @property
    def mean_completion(self) -> float:
        """Mean sojourn over *finished* jobs (unfinished jobs of a
        truncated run carry -1 sentinels and are excluded); 0.0 when
        nothing finished."""
        done = self.finished_jobs
        return float(np.mean([j.completion_time for j in done])) \
            if done else 0.0

    @property
    def mean_queue_wait(self) -> float:
        started = [j for j in self.jobs if j.first_start >= 0]
        return float(np.mean([j.queue_wait for j in started])) \
            if started else 0.0

    @property
    def aborted_attempts(self) -> int:
        return int(sum(j.aborts for j in self.jobs))


class ClusterSim:
    """One simulation: a job stream against one scheduler + cluster."""

    def __init__(
        self,
        scheduler: Scheduler,
        jobs: Sequence[JobSpec],
        *,
        attempt_failures: Optional[FailureModel] = None,
        failure_process: Optional[FailureProcess] = None,
        config: Optional[SimConfig] = None,
        rng: Optional[np.random.Generator] = None,
    ):
        self.sch = scheduler
        self.net = scheduler.net
        self.cfg = config or SimConfig()
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self.attempt_failures = attempt_failures
        self.failure_process = failure_process
        if failure_process is not None and not self.cfg.failure_horizon:
            raise ValueError(
                "failure_process needs config.failure_horizon > 0 "
                "(trace generation bound)")
        self.jobs = [_SimJob(i, spec) for i, spec in enumerate(jobs)]
        if failure_process is not None and any(
                s.fixed_placement is not None for s in jobs):
            raise ValueError(
                "fixed_placement streams model the paper protocol and do "
                "not interact with time-based node failures; use the "
                "scheduler-placed path instead")
        # serial chaining: spec i with after_previous submits when i-1 ends
        self._chain: dict[int, int] = {
            i - 1: i for i, s in enumerate(jobs) if s.after_previous}
        if self.jobs and self.jobs[0].spec.after_previous:
            raise ValueError("first job of a stream cannot chain")
        self._by_slurm: dict[int, _SimJob] = {}
        self._down_count = np.zeros(scheduler.topo.n_nodes, dtype=np.int64)
        self._place_time_t0 = scheduler.place_time_s   # shared-scheduler base
        self._done = 0
        self._node_failures = 0        # actual up -> down transitions
        self._trace: list[tuple[float, str, str]] = []

    # ----------------------------------------------------------------- run
    def run(self) -> SimResult:
        Q = self.Q = EventQueue()
        for j in self.jobs:
            if not j.spec.after_previous:
                Q.push(j.spec.submit_time, EventType.SUBMIT, job=j.idx)
        if self.failure_process is not None:
            for ev in self.failure_process.generate(
                    self.rng, self.cfg.failure_horizon):
                kind = (EventType.FAILURE if ev.kind == "fail"
                        else EventType.RECOVER)
                Q.push(ev.time, kind, nodes=np.asarray(ev.nodes,
                                                       dtype=np.int64))
        if self.cfg.heartbeat_interval:
            Q.push(self.cfg.heartbeat_interval, EventType.HEARTBEAT)

        truncated = False
        dispatch = {
            EventType.SUBMIT: self._on_submit,
            EventType.START: self._on_start,
            EventType.CHECKPOINT: self._on_checkpoint,
            EventType.COMPLETE: self._on_complete,
            EventType.FAILURE: self._on_failure,
            EventType.RECOVER: self._on_recover,
            EventType.HEARTBEAT: self._on_heartbeat,
        }
        while Q and self._done < len(self.jobs):
            if Q.popped >= self.cfg.max_events:
                truncated = True
                break
            ev = Q.pop()
            # the scheduler's admission-latency counters read this clock
            self.sch.clock = ev.time
            if self.cfg.trace:
                self._trace.append((ev.time, ev.type.name, repr(ev.data)))
            dispatch[ev.type](ev)

        stats = [JobStats(
            name=j.spec.label(), policy=j.spec.policy,
            n_ranks=j.spec.workload.n_ranks,
            submit_time=j.submit_time, first_start=j.first_start,
            finish_time=j.finish_time, attempts=j.attempts, aborts=j.aborts,
            requeues=(j.rec.requeues if j.rec is not None else 0),
        ) for j in self.jobs]
        finished = [s.finish_time for s in stats if s.finish_time >= 0]
        return SimResult(
            jobs=stats,
            makespan=max(finished) if finished else 0.0,
            n_events=Q.popped,
            node_failures=self._node_failures,
            truncated=truncated or self._done < len(self.jobs),
            trace=self._trace,
            place_time_s=self.sch.place_time_s - self._place_time_t0,
        )

    # ------------------------------------------------------------ handlers
    def _on_submit(self, ev) -> None:
        j = self.jobs[ev["job"]]
        j.submit_time = ev.time
        if j.spec.fixed_placement is not None:
            j.placement = np.asarray(j.spec.fixed_placement, dtype=np.int64)
            self._start_running(ev.time, j,
                                successful_runtime(j.spec.workload,
                                                   j.placement, self.net))
            return
        job = Job(j.spec.workload, distribution=j.spec.policy)
        j.rec = self.sch.enqueue(job)
        j.state = "queued"
        self._by_slurm[job.job_id] = j
        self._handle_started(ev.time, self.sch.schedule_pending())

    def _handle_started(self, t: float, records: list[JobRecord]) -> None:
        for rec in records:
            j = self._by_slurm[rec.job.job_id]
            self._start_running(t, j, rec.runtime,
                                np.asarray(rec.placement.placement,
                                           dtype=np.int64))

    def _start_running(self, t: float, j: _SimJob, t_ok: float,
                       placement: Optional[np.ndarray] = None) -> None:
        """(Re)entry to the running state: rescale remaining work to the
        new placement's runtime, then begin an attempt.  Restarts (a
        requeued job coming back from the queue) pay ``restart_delay``,
        like the incremental re-place path does."""
        restart = j.t_ok > 0
        if placement is not None:
            j.placement = placement
        if restart:             # preserve the work fraction done
            j.remaining = j.remaining * (t_ok / j.t_ok)
        else:                   # fresh job
            j.remaining = t_ok
            ci = self.cfg.checkpoint_interval
            j.n_ckpts = int(t_ok // ci) if ci else 0
        j.t_ok = t_ok
        j.state = "running"
        if j.first_start < 0:
            j.first_start = t
        self._begin_attempt(t + (self.cfg.restart_delay if restart else 0.0),
                            j)

    def _begin_attempt(self, t: float, j: _SimJob) -> None:
        j.attempts += 1
        j.epoch += 1
        j.ckpt_in_attempt = 0.0
        R = j.remaining
        ci = self.cfg.checkpoint_interval
        ov = self.cfg.checkpoint_overhead
        if self.attempt_failures is not None:
            # paper mode — mirror run_batch's accounting and RNG order
            # exactly: sample the attempt's failed set, then (only on the
            # abort path, with checkpointing) the uniform abort point
            failed = self.attempt_failures.sample_failed(self.rng, R)
            doomed = (len(failed) > 0
                      and j.attempts < self.cfg.max_attempts
                      and self.net.touches_failed(j.spec.workload.comm,
                                                  j.placement, failed))
            combined = bool(ci) and self.failure_process is not None
            if doomed:
                if ci is None:
                    # full successful runtime charged, restart from scratch
                    dur, new_remaining = R, R
                else:
                    fail_at = self.rng.uniform(0.0, R)
                    n_kept = int(fail_at // ci)
                    kept = n_kept * ci
                    dur = fail_at + n_kept * ov
                    new_remaining = R - kept
                self.Q.push(t + dur, EventType.FAILURE, job=j.idx,
                            epoch=j.epoch, remaining=new_remaining)
                if combined:
                    # a node FAILURE can interrupt before the doom fires;
                    # track checkpoints on the heap so it only loses work
                    # since the last one
                    self._push_checkpoints(t, j, R, ci, ov)
            elif combined:
                # charge write overhead for this attempt's actual
                # checkpoints — after a node-failure restart, R < t_ok and
                # the initial n_ckpts count would overcharge
                n_full = self._push_checkpoints(t, j, R, ci, ov)
                self.Q.push(t + R + n_full * ov, EventType.COMPLETE,
                            job=j.idx, epoch=j.epoch)
            else:
                # pure paper mode: run_batch parity — a successful attempt
                # pays the full-runtime checkpoint count as one lump
                self.Q.push(t + R + j.n_ckpts * ov, EventType.COMPLETE,
                            job=j.idx, epoch=j.epoch)
            return
        # time-based mode: periodic checkpoints, completion after the last
        n_full = self._push_checkpoints(t, j, R, ci, ov) if ci else 0
        self.Q.push(t + R + n_full * ov, EventType.COMPLETE,
                    job=j.idx, epoch=j.epoch)

    def _push_checkpoints(self, t: float, j: _SimJob, R: float,
                          ci: float, ov: float) -> int:
        """Schedule this attempt's CHECKPOINT events (one per full
        interval strictly inside ``R``, each write costing ``ov`` wall
        time); returns how many were scheduled."""
        n_full = max(0, int(np.ceil(R / ci)) - 1)
        for k in range(1, n_full + 1):
            self.Q.push(t + k * ci + k * ov, EventType.CHECKPOINT,
                        job=j.idx, epoch=j.epoch, work=k * ci)
        return n_full

    def _valid(self, ev, j: _SimJob) -> bool:
        return j.state == "running" and ev["epoch"] == j.epoch

    def _on_start(self, ev) -> None:
        j = self.jobs[ev["job"]]
        if not self._valid(ev, j):
            return
        self._begin_attempt(ev.time, j)

    def _on_checkpoint(self, ev) -> None:
        j = self.jobs[ev["job"]]
        if self._valid(ev, j):
            j.ckpt_in_attempt = ev["work"]

    def _on_complete(self, ev) -> None:
        j = self.jobs[ev["job"]]
        if not self._valid(ev, j):
            return
        j.state = "done"
        j.finish_time = ev.time
        j.remaining = 0.0
        self._done += 1
        if j.rec is not None:
            self._handle_started(ev.time,
                                 self.sch.complete(j.rec.job.job_id))
        nxt = self._chain.get(j.idx)
        if nxt is not None:
            self.Q.push(ev.time, EventType.SUBMIT, job=nxt)

    def _on_failure(self, ev) -> None:
        if "job" in ev.data:                 # per-attempt doom (paper mode)
            j = self.jobs[ev["job"]]
            if not self._valid(ev, j):
                return
            j.aborts += 1
            j.remaining = ev["remaining"]    # already checkpoint-adjusted
            j.ckpt_in_attempt = 0.0
            j.epoch += 1                     # invalidate the doomed attempt
            self.Q.push(ev.time + self.cfg.restart_delay, EventType.START,
                        job=j.idx, epoch=j.epoch)
            return
        # node(s) going down (time-based mode)
        nodes = ev["nodes"]
        newly_down = nodes[self._down_count[nodes] == 0]
        self._down_count[nodes] += 1
        if not newly_down.size:
            return    # overlapping outage: nothing newly transitioned
        self._node_failures += int(newly_down.size)
        affected = self.sch.handle_node_failure(newly_down)
        for rec in affected:
            j = self._by_slurm[rec.job.job_id]
            j.aborts += 1
            # work since the last checkpoint is lost
            j.remaining = j.remaining - j.ckpt_in_attempt
            j.ckpt_in_attempt = 0.0
            j.epoch += 1
            if rec.state == "running":       # incrementally re-placed
                j.placement = np.asarray(rec.placement.placement,
                                         dtype=np.int64)
                new_t_ok = rec.runtime
                j.remaining = j.remaining * (new_t_ok / j.t_ok)
                j.t_ok = new_t_ok
                self.Q.push(ev.time + self.cfg.restart_delay,
                            EventType.START, job=j.idx, epoch=j.epoch)
            else:                            # survivors can't hold it
                j.state = "queued"
        # a requeued job's freed allocation may make room for other
        # pending jobs (the scheduler is clock-free and does not drain
        # on failures itself)
        self._handle_started(ev.time, self.sch.schedule_pending())

    def _on_recover(self, ev) -> None:
        nodes = ev["nodes"]
        self._down_count[nodes] = np.maximum(self._down_count[nodes] - 1, 0)
        newly_up = nodes[self._down_count[nodes] == 0]
        if newly_up.size:
            self._handle_started(ev.time, self.sch.recover(newly_up))

    def _on_heartbeat(self, ev) -> None:
        # NodeState plugin semantics: a DOWN node never answers; a live
        # node misses a round with its ground-truth flakiness probability
        true_p = self.sch.registry.true_outage_vector()
        replies = (self._down_count == 0) \
            & (self.rng.random(len(true_p)) >= true_p)
        self._handle_started(ev.time, self.sch.heartbeat_round(
            replies, dt=self.cfg.heartbeat_interval))
        if self._done < len(self.jobs):
            self.Q.push(ev.time + self.cfg.heartbeat_interval,
                        EventType.HEARTBEAT)
