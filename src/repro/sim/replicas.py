"""Monte-Carlo replica engine: scenario presets across thousands of seeds.

Every gated claim of :mod:`benchmarks.clustersim` used to rest on a single
seed trajectory.  This module executes a scenario preset across many
independent seeds and aggregates the per-policy metric distributions into
bootstrap confidence intervals, so the repo's paper-claim verification
("tofa < linear") is a *statistical* statement instead of an anecdote::

    from repro.sim.replicas import run_replicas
    rs  = run_replicas("saturated-queue", n_replicas=1000, fast=True)
    cmp = rs.compare()                  # paired tofa-vs-linear statistics
    assert cmp.delta_ci_low > 0         # 95% CI of mean(linear - tofa)

**Seed streams.**  Replica ``k`` runs ``run_preset(name, seed=seeds[k])``
— presets derive every RNG they use from that one seed through fixed
formulas, so each replica is bit-identical to a standalone
``run_preset(seed=k)`` call (asserted per preset in
``tests/test_replicas.py``), and serial / process-pool / vectorized
execution all produce identical aggregates.

**Execution modes.**

* ``executor="serial"`` — one replica at a time in-process.
* ``executor="process"`` — a :class:`concurrent.futures.
  ProcessPoolExecutor` over the seeds; workers return flat metric dicts
  (floats only), so results are identical to serial by construction.
  Preset kwargs must be picklable in this mode.
* the **vectorized paper path** — for ``paper-fig4-5`` (the paper-mode
  batch protocol: fixed per-batch placement, per-attempt Bernoulli
  draws, no checkpointing) the per-attempt failure draws are consumed as
  one uniform block per (batch, policy) and the geometric attempt/abort
  accounting is evaluated arithmetically, skipping the event heap
  entirely.  The block is a prefix of the exact RNG stream the event
  simulator would consume, so the completion times are *bit-identical*
  (wall-clock fields excepted).

**Statistics.**  :func:`bootstrap_ci` is a percentile bootstrap
(configurable resample count ``B`` and level ``alpha``) of a sample
statistic (the mean by default); :func:`summarize` wraps one metric
vector into a :class:`SummaryStats`; :meth:`ReplicaSet.compare` forms the
*paired* per-seed deltas between two policies and reports the delta CI,
the per-seed win rate, and a one-sided bootstrap p-value — the quantities
the benchmark gate consumes.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import math
import os
from typing import Callable, Optional, Sequence

import numpy as np

from repro.sim.scenarios import SCENARIOS, run_preset

# wall-clock fields: nondeterministic across runs, excluded from the
# bit-reproducibility contract (still aggregated, never gated)
WALL_CLOCK_KEYS = ("place_time_s",)


# ------------------------------------------------------------------ stats
def _norm_ppf(p: float) -> float:
    """Standard-normal quantile (Acklam's rational approximation,
    |relative error| < 1.15e-9 — scipy-free)."""
    if not (0.0 < p < 1.0):
        raise ValueError(f"p must be in (0, 1), got {p}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    p_low = 0.02425
    if p < p_low:
        q = math.sqrt(-2.0 * math.log(p))
        return ((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                 * q + c[5])
                / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1.0))
    if p > 1.0 - p_low:
        q = math.sqrt(-2.0 * math.log(1.0 - p))
        return -((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                  * q + c[5])
                 / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1.0))
    q = p - 0.5
    r = q * q
    return ((((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
             * r + a[5]) * q
            / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4])
               * r + 1.0))


def _norm_cdf(z: float) -> float:
    """Standard-normal CDF via ``math.erf``."""
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


def _jackknife(x: np.ndarray, stat: Callable) -> np.ndarray:
    """Leave-one-out statistic values (vectorized for the mean — the
    replica engine's default — generic np.delete loop otherwise)."""
    n = x.size
    if stat is np.mean:
        return (x.sum() - x) / (n - 1)
    return np.array([float(stat(np.delete(x, i), axis=0))
                     for i in range(n)])


def bootstrap_ci(samples, B: int = 2000, alpha: float = 0.05,
                 seed: int = 0, stat: Callable = np.mean,
                 method: str = "percentile") -> tuple[float, float]:
    """Bootstrap confidence interval of ``stat(samples)``.

    Resamples ``samples`` with replacement ``B`` times and applies
    ``stat`` along the resample axis (``stat(x, axis=1)``).
    ``method="percentile"`` (default) returns the ``(alpha/2,
    1 - alpha/2)`` quantiles of the bootstrap distribution;
    ``method="bca"`` returns the bias-corrected-and-accelerated (BCa)
    interval — the same bootstrap sample read at quantile levels
    adjusted by the median-bias correction ``z0`` (normal quantile of
    the fraction of bootstrap values below the observed statistic) and
    the jackknife acceleration ``a`` (skewness of the leave-one-out
    statistics), which restores second-order-correct coverage on the
    small, skewed paired-delta samples the percentile interval
    under-covers (see the coverage test in ``tests/test_beliefs.py``).
    Degenerate inputs short-circuit for both methods: a single
    observation or an all-equal sample has a zero-width interval at the
    observed value.
    """
    x = np.asarray(samples, dtype=np.float64)
    if x.ndim != 1:
        raise ValueError(f"samples must be 1-D, got shape {x.shape}")
    n = x.size
    if n == 0:
        raise ValueError("bootstrap_ci needs at least one sample")
    if not (0.0 < alpha < 1.0):
        raise ValueError(f"alpha must be in (0, 1), got {alpha}")
    if B < 1:
        raise ValueError(f"B must be >= 1, got {B}")
    if method not in ("percentile", "bca"):
        raise ValueError(f"unknown bootstrap method {method!r}; "
                         "use 'percentile' or 'bca'")
    if n == 1 or np.ptp(x) == 0.0:
        v = float(stat(x, axis=0))
        return (v, v)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(B, n))
    boot = np.asarray(stat(x[idx], axis=1), dtype=np.float64)
    if method == "percentile":
        lo, hi = np.quantile(boot, [alpha / 2.0, 1.0 - alpha / 2.0])
        return (float(lo), float(hi))
    # BCa: bias correction from the bootstrap distribution's position
    # relative to the observed statistic, acceleration from the
    # jackknife skewness
    theta = float(stat(x, axis=0))
    frac_below = float((boot < theta).mean())
    frac_below = min(max(frac_below, 1.0 / (B + 1)), B / (B + 1.0))
    z0 = _norm_ppf(frac_below)
    jack = _jackknife(x, stat)
    dev = jack.mean() - jack
    denom = 6.0 * (dev ** 2).sum() ** 1.5
    accel = float((dev ** 3).sum() / denom) if denom > 0 else 0.0
    levels = []
    for z_a in (_norm_ppf(alpha / 2.0), _norm_ppf(1.0 - alpha / 2.0)):
        adj = z0 + (z0 + z_a) / (1.0 - accel * (z0 + z_a))
        levels.append(min(max(_norm_cdf(adj), 0.0), 1.0))
    lo, hi = np.quantile(boot, levels)
    return (float(lo), float(hi))


@dataclasses.dataclass(frozen=True)
class SummaryStats:
    """Distribution summary of one metric across replicas."""

    metric: str
    n: int
    mean: float
    std: float                  # sample std (ddof=1; 0.0 when n == 1)
    ci_low: float               # bootstrap CI of the mean (see ``method``)
    ci_high: float
    p05: float
    p50: float
    p95: float
    method: str = "percentile"  # bootstrap CI flavor: percentile | bca


def summarize(samples, metric: str = "", B: int = 2000,
              alpha: float = 0.05, seed: int = 0,
              method: str = "percentile") -> SummaryStats:
    """One metric vector -> :class:`SummaryStats` (bootstrap CI of the
    mean plus sample quantiles).  ``method="bca"`` opts into the
    bias-corrected-and-accelerated interval."""
    x = np.asarray(samples, dtype=np.float64)
    lo, hi = bootstrap_ci(x, B=B, alpha=alpha, seed=seed, method=method)
    q05, q50, q95 = np.quantile(x, [0.05, 0.50, 0.95])
    return SummaryStats(
        metric=metric, n=int(x.size), mean=float(x.mean()),
        std=float(x.std(ddof=1)) if x.size > 1 else 0.0,
        ci_low=lo, ci_high=hi,
        p05=float(q05), p50=float(q50), p95=float(q95), method=method)


@dataclasses.dataclass(frozen=True)
class PairedComparison:
    """Paired per-seed comparison of two policies on one metric.

    ``delta`` is ``mean(b - a)`` over seeds (positive == ``a`` smaller ==
    ``a`` better on completion-style metrics); ``delta_ci_low/high`` is
    the percentile-bootstrap CI of that paired mean; ``win_rate`` the
    fraction of seeds with ``a < b`` strictly; ``p_value`` the one-sided
    bootstrap p-value of ``mean(b - a) <= 0`` with the standard
    ``(k + 1) / (B + 1)`` small-sample correction.
    """

    metric: str
    a: str                      # the policy claimed better (smaller)
    b: str                      # the baseline
    n: int
    mean_a: float
    mean_b: float
    delta: float
    delta_ci_low: float
    delta_ci_high: float
    win_rate: float
    p_value: float
    method: str = "percentile"  # bootstrap CI flavor: percentile | bca

    @property
    def significant(self) -> bool:
        """The gate predicate: the whole delta CI is above zero."""
        return self.delta_ci_low > 0.0


def paired_compare(a_samples, b_samples, *, metric: str = "",
                   a: str = "a", b: str = "b", B: int = 2000,
                   alpha: float = 0.05, seed: int = 0,
                   method: str = "percentile") -> PairedComparison:
    """Paired bootstrap comparison: is ``mean(a) < mean(b)`` (same seeds)?

    ``method="bca"`` applies the BCa correction to the delta CI — small
    paired-delta samples are exactly where the percentile interval's
    coverage gets shaky (skewed deltas pull its endpoints inward)."""
    xa = np.asarray(a_samples, dtype=np.float64)
    xb = np.asarray(b_samples, dtype=np.float64)
    if xa.shape != xb.shape or xa.ndim != 1:
        raise ValueError(
            f"paired samples need matching 1-D shapes, got {xa.shape} vs "
            f"{xb.shape}")
    delta = xb - xa
    lo, hi = bootstrap_ci(delta, B=B, alpha=alpha, seed=seed, method=method)
    # one-sided p-value: bootstrap mass at or below zero
    if delta.size == 1 or np.ptp(delta) == 0.0:
        k = B if float(delta.mean()) <= 0.0 else 0
    else:
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, delta.size, size=(B, delta.size))
        k = int((delta[idx].mean(axis=1) <= 0.0).sum())
    return PairedComparison(
        metric=metric, a=a, b=b, n=int(xa.size),
        mean_a=float(xa.mean()), mean_b=float(xb.mean()),
        delta=float(delta.mean()), delta_ci_low=lo, delta_ci_high=hi,
        win_rate=float((xa < xb).mean()),
        p_value=(k + 1) / (B + 1), method=method)


# ------------------------------------------------------- replica execution
def _flat_policy_rows(out: dict) -> dict[str, dict[str, float]]:
    """Flatten one preset result into ``{policy_key: {metric: value}}``.

    Nested presets (drain-sweep's per-threshold rows) flatten to
    ``"policy/th=<t>"`` keys; only scalar numerics survive (lists like
    ``batch_completions`` and booleans are summarised or dropped).
    """
    flat: dict[str, dict[str, float]] = {}

    def scalars(row: dict) -> dict[str, float]:
        vals = {}
        for k, v in row.items():
            if isinstance(v, bool):
                vals[k] = float(v)
            elif isinstance(v, (int, float, np.integer, np.floating)):
                vals[k] = float(v)
        return vals

    for pol, row in out["policies"].items():
        if "mean_completion" in row:
            flat[pol] = scalars(row)
        else:                       # nested (threshold-keyed) rows
            for th, r in row.items():
                flat[f"{pol}/th={th}"] = scalars(r)
    return flat


def _replica_worker(args) -> dict[str, dict[str, float]]:
    """Module-level so ProcessPoolExecutor can pickle it."""
    name, seed, policies, fast, preset_kw = args
    out = run_preset(name, seed=seed, policies=policies, fast=fast,
                     **preset_kw)
    return _flat_policy_rows(out)


@dataclasses.dataclass
class ReplicaSet:
    """Per-seed metric distributions of one preset across policies.

    ``metrics[policy_key][metric]`` is an (n_replicas,) array ordered as
    ``seeds`` — paired across policies, so per-seed deltas are meaningful.
    """

    preset: str
    fast: bool
    seeds: tuple[int, ...]
    policies: tuple[str, ...]
    metrics: dict[str, dict[str, np.ndarray]]

    @property
    def n_replicas(self) -> int:
        return len(self.seeds)

    def samples(self, policy: str, metric: str = "mean_completion"
                ) -> np.ndarray:
        try:
            return self.metrics[policy][metric]
        except KeyError:
            raise KeyError(
                f"no samples for policy={policy!r} metric={metric!r}; have "
                f"policies {sorted(self.metrics)} with metrics "
                f"{sorted(next(iter(self.metrics.values())))}") from None

    def summary(self, policy: str, metric: str = "mean_completion",
                B: int = 2000, alpha: float = 0.05, seed: int = 0,
                method: str = "percentile") -> SummaryStats:
        return summarize(self.samples(policy, metric), metric=metric,
                         B=B, alpha=alpha, seed=seed, method=method)

    def compare(self, a: str = "tofa", b: str = "linear",
                metric: str = "mean_completion", B: int = 2000,
                alpha: float = 0.05, seed: int = 0,
                method: str = "percentile") -> PairedComparison:
        """Paired per-seed comparison (default: tofa vs. linear)."""
        return paired_compare(
            self.samples(a, metric), self.samples(b, metric),
            metric=metric, a=a, b=b, B=B, alpha=alpha, seed=seed,
            method=method)


class _StreamingCollector:
    """Streams per-replica flat rows straight into preallocated
    per-policy metric arrays.

    The old collector held every replica's flat result dict alive until
    the end of the run — O(n_replicas * policies * metrics) Python
    floats, dict and string overhead included, which at 1k seeds
    dominated the resident set of the replica engine.  This one
    allocates the final (n_replicas,) float64 arrays from the first row
    and writes each subsequent row into its seed slot as it arrives, so
    at any instant only one flat row is alive regardless of replica
    count.  Execution modes that yield rows in seed order (serial,
    ``pool.map``, the vectorized path) stream through :meth:`add`
    unchanged.
    """

    def __init__(self, n_replicas: int):
        self._n = n_replicas
        self._metrics: Optional[dict[str, dict[str, np.ndarray]]] = None

    def add(self, k: int, row: dict[str, dict[str, float]]) -> None:
        """Record replica ``k``'s flat ``{policy: {metric: value}}``."""
        if self._metrics is None:
            self._metrics = {
                pol: {m: np.empty(self._n, dtype=np.float64) for m in vals}
                for pol, vals in row.items()}
        for pol, vals in row.items():
            dest = self._metrics[pol]
            for m, v in vals.items():
                dest[m][k] = v

    def result(self) -> dict[str, dict[str, np.ndarray]]:
        return self._metrics if self._metrics is not None else {}


def run_replicas(
    name: str,
    *,
    n_replicas: Optional[int] = None,
    seeds: Optional[Sequence[int]] = None,
    base_seed: int = 0,
    policies: Sequence[str] = ("linear", "tofa"),
    fast: bool = False,
    executor: str = "auto",
    max_workers: Optional[int] = None,
    vectorize: str = "auto",
    **preset_kw,
) -> ReplicaSet:
    """Execute preset ``name`` across independent seeds and collect the
    per-policy metric distributions.

    ``seeds`` gives the replica seeds explicitly; otherwise
    ``base_seed + arange(n_replicas)``.  ``executor`` is ``"serial"``,
    ``"process"`` (seed-parallel worker pool, ``max_workers`` processes)
    or ``"auto"`` (process pool when it can help: > 1 CPU and enough
    replicas to amortise worker startup).  ``max_workers=None`` or ``0``
    auto-detects ``os.cpu_count()``.  ``vectorize`` enables the
    bit-identical closed-form paper-mode path for ``paper-fig4-5``
    (``"auto"``/``"always"``/``"never"``).

    Results stream into preallocated per-metric arrays as replicas
    finish (:class:`_StreamingCollector`) — memory is O(n_replicas)
    floats per metric, never n_replicas live result dicts.

    Replica ``k`` is bit-identical to ``run_preset(name, seed=seeds[k])``
    regardless of the execution mode (wall-clock fields excepted).
    """
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(SCENARIOS)}")
    if (n_replicas is None) == (seeds is None):
        raise ValueError("pass exactly one of n_replicas / seeds")
    if seeds is None:
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        seeds = range(base_seed, base_seed + n_replicas)
    seeds = tuple(int(s) for s in seeds)
    policies = tuple(policies)
    if executor not in ("auto", "serial", "process"):
        raise ValueError(f"unknown executor {executor!r}")
    if vectorize not in ("auto", "always", "never"):
        raise ValueError(f"unknown vectorize {vectorize!r}")

    use_vector = (name == "paper-fig4-5" and vectorize != "never")
    if vectorize == "always" and name != "paper-fig4-5":
        raise ValueError(
            f"vectorized execution only covers 'paper-fig4-5', not {name!r}")

    collect = _StreamingCollector(len(seeds))
    if use_vector:
        for k, s in enumerate(seeds):
            collect.add(k, _flat_policy_rows(
                paper_replica_vector(seed=s, policies=policies, fast=fast,
                                     **preset_kw)))
        return ReplicaSet(name, fast, seeds, policies, collect.result())

    workers = max_workers or (os.cpu_count() or 1)
    pooled = (executor == "process"
              or (executor == "auto" and workers > 1 and len(seeds) >= 8))
    args = [(name, s, policies, fast, preset_kw) for s in seeds]
    if pooled and workers > 1:
        with concurrent.futures.ProcessPoolExecutor(workers) as pool:
            # pool.map yields in seed order, so rows stream straight
            # into their slots without buffering the full result list
            for k, row in enumerate(
                    pool.map(_replica_worker, args,
                             chunksize=max(1, len(seeds) // (4 * workers)))):
                collect.add(k, row)
    else:
        for k, a in enumerate(args):
            collect.add(k, _replica_worker(a))
    return ReplicaSet(name, fast, seeds, policies, collect.result())


# -------------------------------------------- vectorized paper-mode path
def paper_replica_vector(
    seed: int = 0,
    policies: Sequence[str] = ("linear", "tofa"),
    fast: bool = False,
    wl_factory=None,
    dims: tuple[int, ...] = (8, 8, 8),
    n_batches: int = 10,
    n_instances: int = 100,
    n_faulty: int = 16,
    p_f: float = 0.02,
    scheduler_knows_truth: bool = True,
    topology=None,
    max_attempts: int = 100,
) -> dict:
    """One ``paper-fig4-5`` replica via block-drawn failure uniforms.

    Mirrors :func:`repro.sim.scenarios.paper_fig4_5` **bit-for-bit** on
    every stochastic output: the placement call consumes the per-(batch,
    policy) RNG exactly as the preset does, then the per-attempt
    Bernoulli draws are taken as one ``rng.random((K, n_faulty))`` block
    — row ``r`` of the block is byte-identical to the ``r``-th sequential
    ``sample_failed`` draw, so doom decisions, attempt counts, abort
    counts, event counts and (sequentially accumulated) makespans all
    match the event simulator.  Only ``place_time_s`` (wall-clock)
    differs run to run, as it does between any two event-sim runs.
    """
    from repro.core.engine import PlacementEngine, PlacementRequest
    from repro.core.state import ClusterState
    from repro.core.topology import TorusTopology
    from repro.cluster.failures import BernoulliPerJob
    from repro.sim.jobsim import successful_runtime
    from repro.sim.network import network_for
    from repro.workloads.patterns import npb_dt_like

    if fast:
        dims, n_batches, n_instances, n_faulty = (4, 4, 4), 2, 20, 8
        wl_factory = wl_factory or (lambda: npb_dt_like(24))
    wl_factory = wl_factory or (lambda: npb_dt_like(85))
    topo = topology if topology is not None else TorusTopology(dims)
    net = network_for(topo)
    engine = PlacementEngine()
    comps: dict[str, list[float]] = {p: [] for p in policies}
    aborts: dict[str, int] = {p: 0 for p in policies}
    events: dict[str, int] = {p: 0 for p in policies}
    place_time: dict[str, float] = {p: 0.0 for p in policies}
    for b in range(n_batches):
        batch_rng = np.random.default_rng(seed * 1000 + b)
        candidates = batch_rng.choice(topo.n_nodes, n_faulty, replace=False)
        fm = BernoulliPerJob(candidates, p_f)
        known = (fm.outage_vector(topo.n_nodes)
                 if scheduler_knows_truth else None)
        wl = wl_factory()
        known_state = ClusterState.from_arrays(topo.n_nodes, p_f=known)
        for pol in policies:
            rng = np.random.default_rng(seed * 7777 + b)
            plan = engine.place(
                PlacementRequest(comm=wl.comm, topology=topo,
                                 state=known_state),
                policy=pol, rng=rng)
            place_time[pol] += plan.wall_time_s
            t_ok = successful_runtime(wl, plan.placement, net)
            # which candidates doom an attempt at all: monotone
            # union-of-singletons form of touches_failed
            touch = np.array([
                net.touches_failed(wl.comm, plan.placement,
                                   np.array([c], dtype=np.int64))
                for c in candidates])
            n_att, n_ab = _walk_attempts(rng, touch, p_f, n_instances,
                                         max_attempts)
            t = 0.0                  # sequential accumulation, as the
            for _ in range(n_att):   # event heap adds one t_ok per attempt
                t += t_ok
            comps[pol].append(t)
            aborts[pol] += n_ab
            events[pol] += 2 * n_instances + 2 * n_ab
    rows = {
        pol: {
            "mean_completion": float(np.mean(comps[pol])),
            "batch_completions": comps[pol],
            "aborted_attempts": int(aborts[pol]),
            "n_events": int(events[pol]),
            "place_time_s": place_time[pol],
        } for pol in policies}
    return {"name": "paper-fig4-5",
            "params": {"dims": getattr(topo, "dims", None),
                       "n_batches": n_batches, "n_instances": n_instances,
                       "n_faulty": n_faulty, "p_f": p_f, "seed": seed},
            "policies": rows}


def _walk_attempts(rng: np.random.Generator, touch: np.ndarray,
                   p_f: float, n_instances: int, max_attempts: int
                   ) -> tuple[int, int]:
    """Consume per-attempt failure uniforms in blocks and walk the serial
    instance chain: returns (total attempts, total aborted attempts).

    Every row of every drawn block corresponds 1:1 to one sequential
    ``BernoulliPerJob.sample_failed`` call (numpy Generators fill arrays
    from the stream in row-major order), so the doom sequence is exactly
    the event simulator's.  Over-drawn rows past the last consumed
    attempt are never used by anyone — the RNG is not consumed again.
    """
    C = touch.size
    q = p_f * float(touch.sum())          # rough per-attempt doom rate
    block = max(32, int(math.ceil(n_instances * (1.0 + 3.0 * q))))
    doom = np.zeros(0, dtype=bool)
    cursor = 0
    aborted = 0
    for _ in range(n_instances):
        attempts = 0
        while True:
            if cursor >= doom.size:
                u = rng.random((block, C))
                fresh = (u < p_f) & touch[None, :]
                doom = np.concatenate([doom, fresh.any(axis=1)])
            attempts += 1
            doomed = doom[cursor] and attempts < max_attempts
            cursor += 1
            if not doomed:
                break
            aborted += 1
    return cursor, aborted
