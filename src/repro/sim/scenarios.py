"""Named scenario presets for the event-driven cluster simulator.

Each preset assembles a full experiment — topology, network model, job
stream, failure layer, scheduler knobs — and runs it once per placement
policy so the comparison is paired (same seeds, same traces).  Presets
are registered in :data:`SCENARIOS`; run one with::

    from repro.sim.scenarios import run_preset
    out = run_preset("saturated-queue", policies=("linear", "tofa"), seed=0)

Every preset returns ``{"name", "params", "policies": {policy: row}}``
where a row carries ``mean_completion``, ``makespan``,
``aborted_attempts``, ``mean_queue_wait``, ``n_events`` and
``node_failures`` (see :class:`~repro.sim.clustersim.SimResult`).
``fast=True`` shrinks every preset to a seconds-scale smoke run (CI).

The ``paper-fig4-5`` preset reproduces the paper's Section 5.2 protocol
as a special case of the event simulator — serial arrivals, placement
computed once per batch, per-batch Bernoulli ``N_f`` — with the *same
RNG draw order* as :func:`repro.sim.batchsim.run_batch`, so its
completion times match the closed-form engine bit-for-bit (asserted in
``tests/test_clustersim.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.cluster.failures import (BernoulliPerJob, CascadingOutages,
                                    CompositeProcess, CorrelatedOutages,
                                    ExponentialLifetimes, MaintenanceWindow,
                                    contiguous_racks)
from repro.cluster.nodes import NodeState
from repro.cluster.scheduler import Scheduler
from repro.core.dragonfly import DragonflyTopology
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.fattree import FatTreeTopology
from repro.core.state import ClusterState
from repro.core.topology import TorusTopology
from repro.sim.clustersim import ClusterSim, SimConfig, SimResult
from repro.sim.network import network_for
from repro.workloads.arrivals import (burst_stream, mixed_size_factory,
                                      poisson_stream, serial_stream)
from repro.workloads.patterns import npb_dt_like


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    description: str
    fn: Callable


SCENARIOS: dict[str, Preset] = {}


def register_preset(name: str, description: str):
    def deco(fn):
        SCENARIOS[name] = Preset(name, description, fn)
        return fn
    return deco


def list_presets() -> list[Preset]:
    return list(SCENARIOS.values())


def run_preset(name: str, **kw) -> dict:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    return SCENARIOS[name].fn(**kw)


def _row(res: SimResult) -> dict:
    return {
        "mean_completion": res.mean_completion,
        "makespan": res.makespan,
        "aborted_attempts": res.aborted_attempts,
        "mean_queue_wait": res.mean_queue_wait,
        "n_events": res.n_events,
        "node_failures": res.node_failures,
        "truncated": res.truncated,
        "place_time_s": res.place_time_s,
    }


def _converged_monitor(sch: Scheduler, truth: np.ndarray, seed: int,
                       rounds: int = 400) -> None:
    """Warm the heartbeat estimator to convergence on the ground truth —
    the `known_p_f` contract's 'perfect estimator' end (the paper's
    setting).  In-sim HEARTBEAT events keep it fresh afterwards."""
    sch.registry.set_outage_probabilities(np.flatnonzero(truth > 0),
                                          float(truth.max()))
    sch.monitor.simulate_rounds(np.random.default_rng(seed ^ 0x5eed),
                                truth, rounds)


BELIEF_MODES = ("monitor", "oracle", "learned", "learned-node", "static",
                "adversarial")


def _attach_belief(sch: Scheduler, mode: str, proc, groups, seed: int, *,
                   horizon: float = 1.0,
                   train_horizon: Optional[float] = None,
                   fast: bool = False) -> dict:
    """Attach a belief tracker to ``sch`` per the preset's ``belief_mode``.

    The belief-error axis of ``benchmarks/belief_sweep.py``:

    * ``"monitor"`` (default) — no tracker; the scheduler keeps reading
      the converged heartbeat estimate.  Bit-identical to the pre-belief
      presets.
    * ``"oracle"`` — the failure process's :meth:`expected_p_f` handed
      straight to placement (zero belief error).
    * ``"learned"`` — :class:`~repro.beliefs.RackPooledBayes` pre-trained
      on a ``train_horizon``-long trace generated from a seed-derived
      training RNG (disjoint from every sim stream), then updated online
      from the live failure/repair events.  ``"learned-node"`` is the
      un-pooled :class:`~repro.beliefs.ExponentialBayes` ablation.
    * ``"static"`` — a uniform positive prior (mean of the truth's
      nonzero entries): under the Eq. 1 ``p_f > 0`` pattern this
      penalizes every route equally, i.e. fault-*blind* placement — the
      baseline a learned belief must beat.
    * ``"adversarial"`` — the truth vector reversed in id order: belief
      mass on healthy nodes, none on the flaky set.

    Drain/degrade decisions stay monitor-driven in every mode, so the
    only thing that varies across modes is the belief Eq. 1 consumes.
    Returns belief-quality scalars for the result row (empty for
    ``"monitor"``).
    """
    if mode == "monitor":
        return {}
    from repro.beliefs import (AdversarialBeliefs, BeliefTracker,
                               ExponentialBayes, OracleBeliefs,
                               RackPooledBayes, StaticPrior, belief_mse,
                               pattern_confusion)
    n = sch.topo.n_nodes
    truth = proc.expected_p_f(n)
    if mode == "oracle":
        model = OracleBeliefs(truth)
    elif mode == "static":
        pos = truth[truth > 0]
        model = StaticPrior(float(pos.mean()) if pos.size else 0.1)
    elif mode == "adversarial":
        model = AdversarialBeliefs(truth)
    elif mode == "learned":
        model = RackPooledBayes([np.asarray(g) for g in groups])
    elif mode == "learned-node":
        model = ExponentialBayes()
    else:
        raise ValueError(f"unknown belief_mode {mode!r}; "
                         f"have {BELIEF_MODES}")
    tracker = BeliefTracker(n, model, horizon=horizon)
    if mode in ("learned", "learned-node"):
        if train_horizon is None:
            train_horizon = 60.0 if fast else 240.0
        rng_train = np.random.default_rng(seed * 9901 + 97)
        tracker.ingest_events(proc.generate(rng_train, train_horizon),
                              t_end=train_horizon)
        tracker.rebase(0.0)
    sch.tracker = tracker
    p0 = tracker.p_f_vector(now=0.0)
    pat = pattern_confusion(p0, truth)
    return {"belief_err": belief_mse(p0, truth),
            "belief_pattern_precision": pat["precision"],
            "belief_pattern_recall": pat["recall"]}


# ---------------------------------------------------------------- presets
@register_preset(
    "paper-fig4-5",
    "The paper's Section 5.2 protocol through the event simulator: serial "
    "arrivals, one placement per batch, per-batch Bernoulli N_f; matches "
    "batchsim.run_scenario bit-for-bit.")
def paper_fig4_5(policies: Sequence[str] = ("linear", "tofa"),
                 seed: int = 0, fast: bool = False,
                 wl_factory: Optional[Callable] = None,
                 dims: tuple[int, ...] = (8, 8, 8),
                 n_batches: int = 10, n_instances: int = 100,
                 n_faulty: int = 16, p_f: float = 0.02,
                 scheduler_knows_truth: bool = True,
                 topology=None) -> dict:
    if fast:
        dims, n_batches, n_instances, n_faulty = (4, 4, 4), 2, 20, 8
        wl_factory = wl_factory or (lambda: npb_dt_like(24))
    wl_factory = wl_factory or (lambda: npb_dt_like(85))
    topo = topology if topology is not None else TorusTopology(dims)
    net = network_for(topo)
    engine = PlacementEngine()
    per_batch: dict[str, list[SimResult]] = {p: [] for p in policies}
    place_time: dict[str, float] = {p: 0.0 for p in policies}
    for b in range(n_batches):
        # identical draw structure to batchsim.run_scenario: candidates
        # from the batch RNG, one attempt/placement RNG per (batch, policy)
        batch_rng = np.random.default_rng(seed * 1000 + b)
        candidates = batch_rng.choice(topo.n_nodes, n_faulty, replace=False)
        fm = BernoulliPerJob(candidates, p_f)
        known = (fm.outage_vector(topo.n_nodes)
                 if scheduler_knows_truth else None)
        wl = wl_factory()
        known_state = ClusterState.from_arrays(topo.n_nodes, p_f=known)
        for pol in policies:
            rng = np.random.default_rng(seed * 7777 + b)
            plan = engine.place(
                PlacementRequest(comm=wl.comm, topology=topo,
                                 state=known_state),
                policy=pol, rng=rng)
            place_time[pol] += plan.wall_time_s
            sch = Scheduler(topo, net=net, engine=engine)
            sim = ClusterSim(
                sch,
                serial_stream([wl] * n_instances, policy=pol,
                              fixed_placement=plan.placement),
                attempt_failures=fm, rng=rng)
            per_batch[pol].append(sim.run())
    rows = {}
    for pol in policies:
        rs = per_batch[pol]
        rows[pol] = {
            "mean_completion": float(np.mean([r.makespan for r in rs])),
            "batch_completions": [r.makespan for r in rs],
            "aborted_attempts": int(sum(r.aborted_attempts for r in rs)),
            "n_events": int(sum(r.n_events for r in rs)),
            "place_time_s": place_time[pol],
        }
    return {"name": "paper-fig4-5",
            "params": {"dims": getattr(topo, "dims", None),
                       "n_batches": n_batches, "n_instances": n_instances,
                       "n_faulty": n_faulty, "p_f": p_f, "seed": seed},
            "policies": rows}


def _flaky_cluster(topo, net, engine, seed: int, candidates, p_f: float
                   ) -> tuple[Scheduler, BernoulliPerJob]:
    """A cluster with a known flaky set: Bernoulli per-attempt failures,
    heartbeat estimator pre-converged on the truth."""
    fm = BernoulliPerJob(np.asarray(candidates), p_f)
    sch = Scheduler(topo, net=net, engine=engine, seed=seed)
    _converged_monitor(sch, fm.outage_vector(topo.n_nodes), seed)
    return sch, fm


@register_preset(
    "saturated-queue",
    "Every job submitted at t=0 against bounded capacity: queueing, "
    "backfill and abort rework dominate the makespan.")
def saturated_queue(policies: Sequence[str] = ("linear", "tofa"),
                    seed: int = 0, fast: bool = False) -> dict:
    dims = (4, 4, 4) if fast else (8, 8, 8)
    n_jobs = 12 if fast else 48
    n_flaky = 16 if fast else 96
    p_f = 0.3
    topo = TorusTopology(dims)
    net = network_for(topo)
    engine = PlacementEngine()
    rng0 = np.random.default_rng(seed * 101 + 7)
    candidates = rng0.choice(topo.n_nodes, n_flaky, replace=False)
    factory = mixed_size_factory(sizes=(8, 12, 18) if fast
                                 else (16, 27, 64))
    wls = [factory(np.random.default_rng(seed * 31 + i))
           for i in range(n_jobs)]
    rows = {}
    for pol in policies:
        sch, fm = _flaky_cluster(topo, net, engine, seed, candidates, p_f)
        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol), attempt_failures=fm,
            config=SimConfig(heartbeat_interval=0.5),
            rng=np.random.default_rng(seed * 997 + 13))
        rows[pol] = _row(sim.run())
    return {"name": "saturated-queue",
            "params": {"dims": dims, "n_jobs": n_jobs, "n_flaky": n_flaky,
                       "p_f": p_f, "seed": seed},
            "policies": rows}


@register_preset(
    "mixed-stream",
    "Open Poisson arrivals of a mixed-width job stream — steady-state "
    "sojourn time and queue wait per policy.")
def mixed_stream(policies: Sequence[str] = ("linear", "tofa"),
                 seed: int = 0, fast: bool = False) -> dict:
    dims = (4, 4, 4) if fast else (8, 8, 8)
    n_jobs = 15 if fast else 60
    rate = 8.0          # jobs/second: comfortably above service capacity
    topo = TorusTopology(dims)
    net = network_for(topo)
    engine = PlacementEngine()
    rng0 = np.random.default_rng(seed * 211 + 3)
    candidates = rng0.choice(topo.n_nodes,
                             16 if fast else 96, replace=False)
    stream_rng = np.random.default_rng(seed * 47 + 1)
    jobs = poisson_stream(mixed_size_factory(sizes=(8, 12) if fast
                                             else (16, 27, 64)),
                          rate=rate, n_jobs=n_jobs, rng=stream_rng)
    rows = {}
    for pol in policies:
        for spec in jobs:
            spec.policy = pol
        sch, fm = _flaky_cluster(topo, net, engine, seed, candidates, 0.25)
        sim = ClusterSim(
            sch, jobs, attempt_failures=fm,
            config=SimConfig(heartbeat_interval=0.5),
            rng=np.random.default_rng(seed * 613 + 5))
        rows[pol] = _row(sim.run())
    return {"name": "mixed-stream",
            "params": {"dims": dims, "n_jobs": n_jobs, "rate": rate,
                       "seed": seed},
            "policies": rows}


@register_preset(
    "fat-tree",
    "The saturated mix on a k-ary Clos fabric instead of a torus — "
    "exercises the Topology protocol + HopNetwork end of the simulator.")
def fat_tree(policies: Sequence[str] = ("linear", "tofa"),
             seed: int = 0, fast: bool = False) -> dict:
    k = 4 if fast else 8                      # 16 / 128 hosts
    topo = FatTreeTopology(k)
    net = network_for(topo)
    engine = PlacementEngine()
    n_jobs = 8 if fast else 24
    rng0 = np.random.default_rng(seed * 307 + 11)
    candidates = rng0.choice(topo.n_nodes,
                             max(4, topo.n_nodes // 4), replace=False)
    factory = mixed_size_factory(sizes=(4, 6) if fast else (8, 16, 32))
    wls = [factory(np.random.default_rng(seed * 59 + i))
           for i in range(n_jobs)]
    rows = {}
    for pol in policies:
        sch, fm = _flaky_cluster(topo, net, engine, seed, candidates, 0.3)
        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol), attempt_failures=fm,
            config=SimConfig(heartbeat_interval=0.5),
            rng=np.random.default_rng(seed * 811 + 17))
        rows[pol] = _row(sim.run())
    return {"name": "fat-tree",
            "params": {"k": k, "n_hosts": topo.n_nodes, "n_jobs": n_jobs,
                       "seed": seed},
            "policies": rows}


@register_preset(
    "correlated-failures",
    "Time-correlated rack outages with repair: flaky racks miss heartbeats "
    "and actually go down mid-run; restarts charge from the last "
    "checkpoint and engine.replace moves the displaced processes.")
def correlated_failures(policies: Sequence[str] = ("linear", "tofa"),
                        seed: int = 0, fast: bool = False,
                        belief_mode: str = "monitor",
                        p_f_atol: Optional[float] = None,
                        train_horizon: Optional[float] = None,
                        checkpointing: bool = True,
                        engine: Optional[PlacementEngine] = None) -> dict:
    # full scale stays at a 216-node torus: every distinct failed set
    # costs one Eq. 1 weight-matrix derivation (route enumeration, ~1 s
    # at 6x6x6 vs ~5 s at 8x8x8), and a time-based run visits many
    dims = (4, 4, 4) if fast else (6, 6, 6)
    topo = TorusTopology(dims)
    net = network_for(topo)
    # ``engine`` lets instrumentation (the belief-sweep churn row) read
    # the cache counters; ``belief_mode`` selects the p_f source the
    # placements consume (see _attach_belief) and ``p_f_atol`` overrides
    # the scheduler's interning tolerance (None keeps its default)
    engine = engine if engine is not None else PlacementEngine()
    rack_size = 16 if fast else 36
    racks = contiguous_racks(topo.n_nodes, rack_size)
    flaky_racks = racks[:1] if fast else racks[:2]
    flaky_ids = np.concatenate(flaky_racks)
    n_jobs = 10 if fast else 24
    factory = mixed_size_factory(sizes=(8, 12) if fast else (16, 27))
    wls = [factory(np.random.default_rng(seed * 83 + i))
           for i in range(n_jobs)]
    horizon = 500.0
    proc = CompositeProcess([
        CorrelatedOutages(flaky_racks, mtbf=1.0 if fast else 3.0,
                          mttr=0.3),
        ExponentialLifetimes(flaky_ids, mtbf=4.0 if fast else 12.0,
                             mttr=0.5),
    ])
    rows = {}
    for pol in policies:
        sch_kw = {} if p_f_atol is None else {"p_f_atol": p_f_atol}
        sch = Scheduler(topo, net=net, engine=engine, seed=seed,
                        drain_threshold=0.6, **sch_kw)
        truth = np.zeros(topo.n_nodes)
        truth[flaky_ids] = 0.25          # flaky racks also miss heartbeats
        _converged_monitor(sch, truth, seed)
        binfo = _attach_belief(sch, belief_mode, proc, racks, seed,
                               train_horizon=train_horizon, fast=fast)
        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol), failure_process=proc,
            config=SimConfig(heartbeat_interval=0.25,
                             checkpoint_interval=(0.05 if checkpointing
                                                  else None),
                             checkpoint_overhead=(0.002 if checkpointing
                                                  else 0.0),
                             restart_delay=0.01,
                             failure_horizon=horizon),
            rng=np.random.default_rng(seed * 1213 + 29))
        rows[pol] = _row(sim.run())
        rows[pol].update(binfo)
    return {"name": "correlated-failures",
            "params": {"dims": dims, "rack_size": rack_size,
                       "n_flaky_racks": len(flaky_racks), "n_jobs": n_jobs,
                       "belief_mode": belief_mode, "seed": seed},
            "policies": rows}


@register_preset(
    "drain-sweep",
    "Sweep the drain threshold on a cluster whose flaky nodes both miss "
    "heartbeats and genuinely die: eager draining protects fault-blind "
    "policies (linear) at a capacity cost, lax draining keeps scheduling "
    "onto nodes about to fail.")
def drain_sweep(policies: Sequence[str] = ("linear", "tofa"), seed: int = 0,
                fast: bool = False,
                thresholds: Sequence[float] = (0.1, 0.5, 1.01),
                engine: Optional[PlacementEngine] = None) -> dict:
    dims = (4, 4, 4) if fast else (6, 6, 6)     # see correlated-failures
    topo = TorusTopology(dims)
    net = network_for(topo)
    # ``engine`` lets instrumentation (benchmarks/state_churn.py) read
    # the cache counters the sweep produced
    engine = engine if engine is not None else PlacementEngine()
    n_flaky = 12 if fast else 40
    rng0 = np.random.default_rng(seed * 401 + 19)
    flaky = rng0.choice(topo.n_nodes, n_flaky, replace=False)
    n_jobs = 8 if fast else 16
    factory = mixed_size_factory(sizes=(8, 12) if fast else (16, 27))
    wls = [factory(np.random.default_rng(seed * 71 + i))
           for i in range(n_jobs)]
    proc = ExponentialLifetimes(flaky, mtbf=2.0 if fast else 6.0, mttr=0.5)
    truth = np.zeros(topo.n_nodes)
    truth[flaky] = 0.3
    rows: dict = {}
    for pol in policies:
        rows[pol] = {}
        for th in thresholds:
            sch = Scheduler(topo, net=net, engine=engine, seed=seed,
                            drain_threshold=th)
            # converged estimator + heartbeats running before the burst
            # arrives at t=1.0, so draining happens ahead of placement
            _converged_monitor(sch, truth, seed)
            sim = ClusterSim(
                sch, burst_stream(wls, policy=pol, at=1.0),
                failure_process=proc,
                config=SimConfig(heartbeat_interval=0.1,
                                 checkpoint_interval=0.05,
                                 checkpoint_overhead=0.002,
                                 failure_horizon=500.0),
                rng=np.random.default_rng(seed * 1709 + 31))
            rows[pol][th] = _row(sim.run())
    return {"name": "drain-sweep",
            "params": {"dims": dims, "n_flaky": n_flaky, "n_jobs": n_jobs,
                       "thresholds": list(thresholds), "seed": seed},
            "policies": rows}


@register_preset(
    "dragonfly",
    "The saturated mix on a dragonfly (groups of all-to-all routers joined "
    "by global links) — the high-radix host family: exercises the Topology "
    "protocol + HopNetwork on a 3-level hierarchy with gateway detours.")
def dragonfly(policies: Sequence[str] = ("linear", "tofa"),
              seed: int = 0, fast: bool = False) -> dict:
    topo = (DragonflyTopology(p=2, a=4, h=2)          # 9 groups, 72 hosts
            if fast else
            DragonflyTopology(p=4, a=8, h=4, g=9))    # 9 groups, 288 hosts
    net = network_for(topo)
    engine = PlacementEngine()
    n_jobs = 8 if fast else 24
    rng0 = np.random.default_rng(seed * 613 + 11)
    candidates = rng0.choice(topo.n_nodes,
                             max(4, topo.n_nodes // 4), replace=False)
    factory = mixed_size_factory(sizes=(4, 6) if fast else (8, 16, 32))
    wls = [factory(np.random.default_rng(seed * 67 + i))
           for i in range(n_jobs)]
    rows = {}
    for pol in policies:
        sch, fm = _flaky_cluster(topo, net, engine, seed, candidates, 0.3)
        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol), attempt_failures=fm,
            config=SimConfig(heartbeat_interval=0.5),
            rng=np.random.default_rng(seed * 947 + 17))
        rows[pol] = _row(sim.run())
    return {"name": "dragonfly",
            "params": {"p": topo.p, "a": topo.a, "h": topo.h, "g": topo.g,
                       "n_hosts": topo.n_nodes, "n_jobs": n_jobs,
                       "seed": seed},
            "policies": rows}


@register_preset(
    "cascading-racks",
    "Cascading rack failures: outages on two flaky racks spread to "
    "adjacent racks by contagion — the scheduler's belief covers the "
    "seeds, but the healthy-looking neighbours fail too.  Checkpointed "
    "restarts + engine.replace under correlated, spreading faults.")
def cascading_racks(policies: Sequence[str] = ("linear", "tofa"),
                    seed: int = 0, fast: bool = False,
                    belief_mode: str = "monitor",
                    p_f_atol: Optional[float] = None,
                    train_horizon: Optional[float] = None,
                    checkpointing: bool = True,
                    engine: Optional[PlacementEngine] = None) -> dict:
    dims = (4, 4, 4) if fast else (6, 6, 6)   # see correlated-failures
    topo = TorusTopology(dims)
    net = network_for(topo)
    engine = engine if engine is not None else PlacementEngine()
    rack_size = 16 if fast else 27
    racks = contiguous_racks(topo.n_nodes, rack_size)
    seed_racks = (0, 1)                       # spontaneous-outage racks
    proc = CascadingOutages(racks, mtbf=2.0 if fast else 6.0, mttr=0.4,
                            spread_p=0.5, spread_delay=0.05,
                            seed_groups=seed_racks)
    n_jobs = 8 if fast else 16
    factory = mixed_size_factory(sizes=(8, 12) if fast else (16, 27))
    wls = [factory(np.random.default_rng(seed * 151 + i))
           for i in range(n_jobs)]
    truth = proc.expected_p_f(topo.n_nodes)
    rows = {}
    for pol in policies:
        sch_kw = {} if p_f_atol is None else {"p_f_atol": p_f_atol}
        sch = Scheduler(topo, net=net, engine=engine, seed=seed,
                        drain_threshold=0.6, **sch_kw)
        _converged_monitor(sch, truth, seed)
        binfo = _attach_belief(sch, belief_mode, proc, racks, seed,
                               train_horizon=train_horizon, fast=fast)
        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol, at=1.0),
            failure_process=proc,
            config=SimConfig(heartbeat_interval=0.25,
                             checkpoint_interval=(0.05 if checkpointing
                                                  else None),
                             checkpoint_overhead=(0.002 if checkpointing
                                                  else 0.0),
                             restart_delay=0.01,
                             failure_horizon=500.0),
            rng=np.random.default_rng(seed * 1327 + 19))
        rows[pol] = _row(sim.run())
        rows[pol].update(binfo)
    return {"name": "cascading-racks",
            "params": {"dims": dims, "rack_size": rack_size,
                       "seed_racks": list(seed_racks), "n_jobs": n_jobs,
                       "belief_mode": belief_mode, "seed": seed},
            "policies": rows}


@register_preset(
    "maintenance-burst",
    "A maintenance window takes a whole rack out of service just before "
    "an adversarial burst of wide jobs lands on the shrunken cluster; "
    "flaky nodes elsewhere keep dying.  Fault-aware placement must thread "
    "tight capacity around the elevated-p_f nodes until the rack returns.")
def maintenance_burst(policies: Sequence[str] = ("linear", "tofa"),
                      seed: int = 0, fast: bool = False,
                      belief_mode: str = "monitor",
                      p_f_atol: Optional[float] = None,
                      train_horizon: Optional[float] = None,
                      checkpointing: bool = True,
                      engine: Optional[PlacementEngine] = None) -> dict:
    dims = (4, 4, 4) if fast else (6, 6, 6)
    topo = TorusTopology(dims)
    net = network_for(topo)
    engine = engine if engine is not None else PlacementEngine()
    rack_size = 16 if fast else 36
    racks = contiguous_racks(topo.n_nodes, rack_size)
    maintenance = racks[-1]
    n_flaky = 10 if fast else 32
    rng0 = np.random.default_rng(seed * 733 + 29)
    pool = np.setdiff1d(np.arange(topo.n_nodes), maintenance)
    flaky = rng0.choice(pool, n_flaky, replace=False)
    # adversarial burst: wide jobs only, sized against the shrunken
    # capacity, all at t=1.0 — inside the maintenance window
    n_jobs = 8 if fast else 14
    factory = mixed_size_factory(sizes=(12, 16) if fast else (27, 64))
    wls = [factory(np.random.default_rng(seed * 173 + i))
           for i in range(n_jobs)]
    proc = CompositeProcess([
        MaintenanceWindow(maintenance, start=0.5, duration=4.0),
        ExponentialLifetimes(flaky, mtbf=0.8 if fast else 2.5, mttr=0.5),
    ])
    truth = np.zeros(topo.n_nodes)
    truth[flaky] = 0.3
    rows = {}
    for pol in policies:
        sch_kw = {} if p_f_atol is None else {"p_f_atol": p_f_atol}
        sch = Scheduler(topo, net=net, engine=engine, seed=seed,
                        drain_threshold=0.6, **sch_kw)
        _converged_monitor(sch, truth, seed)
        binfo = _attach_belief(sch, belief_mode, proc, racks, seed,
                               train_horizon=train_horizon, fast=fast)
        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol, at=1.0),
            failure_process=proc,
            config=SimConfig(heartbeat_interval=0.1,
                             checkpoint_interval=(0.05 if checkpointing
                                                  else None),
                             checkpoint_overhead=(0.002 if checkpointing
                                                  else 0.0),
                             restart_delay=0.01,
                             failure_horizon=500.0),
            rng=np.random.default_rng(seed * 2539 + 41))
        rows[pol] = _row(sim.run())
        rows[pol].update(binfo)
    return {"name": "maintenance-burst",
            "params": {"dims": dims, "rack_size": rack_size,
                       "n_flaky": n_flaky, "n_jobs": n_jobs,
                       "window": [0.5, 4.5], "belief_mode": belief_mode,
                       "seed": seed},
            "policies": rows}


@register_preset(
    "degraded-drain",
    "Nodes pass through DEGRADED (allocatable, elevated p_f) before dying, "
    "while a maintenance rack sits DRAINED: exercises the four-state "
    "lifecycle the boolean up/down model cannot express.  Fault-aware "
    "policies route around degraded nodes they are still allowed to use; "
    "fault-blind ones keep landing on them.")
def degraded_drain(policies: Sequence[str] = ("linear", "tofa"),
                   seed: int = 0, fast: bool = False) -> dict:
    dims = (4, 4, 4) if fast else (6, 6, 6)
    topo = TorusTopology(dims)
    net = network_for(topo)
    engine = PlacementEngine()
    rack_size = 8 if fast else 27
    racks = contiguous_racks(topo.n_nodes, rack_size)
    maintenance = racks[-1]               # administratively drained rack
    n_flaky = 10 if fast else 32
    rng0 = np.random.default_rng(seed * 521 + 23)
    pool = np.setdiff1d(np.arange(topo.n_nodes), maintenance)
    flaky = rng0.choice(pool, n_flaky, replace=False)
    n_jobs = 8 if fast else 16
    factory = mixed_size_factory(sizes=(8, 12) if fast else (16, 27))
    wls = [factory(np.random.default_rng(seed * 131 + i))
           for i in range(n_jobs)]
    # flaky nodes degrade (miss ~30% of heartbeats) and genuinely die
    # over time; the degraded band keeps them allocatable, so only
    # fault-aware policies avoid the elevated-p_f capacity
    proc = ExponentialLifetimes(flaky, mtbf=0.8 if fast else 2.5, mttr=0.5)
    truth = np.zeros(topo.n_nodes)
    truth[flaky] = 0.3
    rows = {}
    for pol in policies:
        sch = Scheduler(topo, net=net, engine=engine, seed=seed,
                        drain_threshold=0.9,       # degrade, don't drain
                        degraded_threshold=0.1)
        _converged_monitor(sch, truth, seed)
        # one heartbeat round promotes the flaky set into DEGRADED and
        # maintenance puts a whole rack administratively out of service
        sch.heartbeat_round(np.ones(topo.n_nodes, dtype=bool))
        sch.registry.mark(maintenance, NodeState.DRAINED)
        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol, at=1.0),
            failure_process=proc,
            config=SimConfig(heartbeat_interval=0.1,
                             checkpoint_interval=0.05,
                             checkpoint_overhead=0.002,
                             failure_horizon=500.0),
            rng=np.random.default_rng(seed * 2311 + 37))
        res = sim.run()
        rows[pol] = _row(res)
        rows[pol]["degraded_nodes"] = int(
            (sch.registry.health_codes() == 1).sum())
    return {"name": "degraded-drain",
            "params": {"dims": dims, "n_flaky": n_flaky,
                       "rack_size": rack_size, "n_jobs": n_jobs,
                       "seed": seed},
            "policies": rows}
