"""Flow-level torus network model — the SimGrid platform analogue.

The paper simulates an 8x8x8 torus in SimGrid with 6 Gflops nodes, 10 Gbps
/ 1 usec links, and emulates a failed node by setting the capacity of all
its links to zero (killing any transmission routed through it).  This module
reproduces that platform at flow level:

* traffic between placed ranks follows the same dimension-ordered routes the
  topology graph uses (the platform description "lists the route for each
  pair of nodes ... matches exactly the topology assumed for deriving the
  mapping");
* per-link loads are accumulated over routes; the bandwidth term of a
  communication round is the *bottleneck* link serialization (max over
  links), the latency term charges per-message hop latency on the heaviest
  pair;
* a failed node zeroes all of its links: any job whose traffic or endpoints
  touch it aborts, exactly like SimGrid's zero-capacity variation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.comm_graph import CommGraph
from repro.core.topology import TorusTopology

GBPS = 1e9 / 8.0  # bytes/sec per Gbit/s


@dataclasses.dataclass
class TorusNetwork:
    topo: TorusTopology
    link_bandwidth: float = 10 * GBPS   # paper: 10 Gbps
    link_latency: float = 1e-6          # paper: 1 usec
    node_flops: float = 6e9             # paper: 6 Gflops

    def __post_init__(self):
        self._route_cache: dict[tuple[int, int], list] = {}

    def _route(self, u: int, v: int):
        key = (u, v)
        r = self._route_cache.get(key)
        if r is None:
            r = self.topo.route(u, v)
            self._route_cache[key] = r
        return r

    # ------------------------------------------------------------- loads
    def link_loads(self, comm: CommGraph, placement: np.ndarray
                   ) -> dict[tuple[int, int], float]:
        """Bytes per directed physical link, routing G_v over the placement."""
        loads: dict[tuple[int, int], float] = {}
        n = comm.n
        G = comm.G_v
        p = np.asarray(placement)
        for i in range(n):
            for j in range(i + 1, n):
                b = G[i, j]
                if b <= 0:
                    continue
                # symmetric convention: G[i,j] already holds both directions;
                # split evenly over the two directed routes
                for (u, v), frac in (((int(p[i]), int(p[j])), 0.5),
                                     ((int(p[j]), int(p[i])), 0.5)):
                    for link in self._route(u, v):
                        key = (link.src, link.dst)
                        loads[key] = loads.get(key, 0.0) + b * frac
        return loads

    def touches_failed(self, comm: CommGraph, placement: np.ndarray,
                       failed: np.ndarray) -> bool:
        """True if any endpoint or any routed hop touches a failed node."""
        failed_set = set(int(f) for f in np.asarray(failed).ravel())
        if not failed_set:
            return False
        p = np.asarray(placement)
        if any(int(x) in failed_set for x in p):
            return True
        n = comm.n
        G = comm.G_v
        for i in range(n):
            for j in range(i + 1, n):
                if G[i, j] <= 0:
                    continue
                for u, v in ((int(p[i]), int(p[j])), (int(p[j]), int(p[i]))):
                    for link in self._route(u, v):
                        if link.dst in failed_set or link.src in failed_set:
                            return True
        return False

    # -------------------------------------------------------------- times
    def comm_time(self, comm: CommGraph, placement: np.ndarray) -> float:
        """Time to drain the job's whole communication volume.

        bandwidth term: bottleneck link serialization (congestion);
        latency term:   per-message hop latency of the chattiest pair.
        """
        loads = self.link_loads(comm, placement)
        t_bw = max(loads.values()) / self.link_bandwidth if loads else 0.0
        p = np.asarray(placement)
        t_lat = 0.0
        n = comm.n
        for i in range(n):
            for j in range(i + 1, n):
                m = comm.G_m[i, j]
                if m <= 0:
                    continue
                hops = len(self._route(int(p[i]), int(p[j])))
                t_lat = max(t_lat, m * hops * self.link_latency)
        return t_bw + t_lat

    def compute_time(self, flops_per_rank: float, rounds: float) -> float:
        return flops_per_rank * rounds / self.node_flops
