"""Network performance models — the SimGrid platform analogue.

:class:`TorusNetwork` is the flow-level torus model below;
:class:`HopNetwork` is a distance-level fallback that makes any
``Topology`` implementation (fat-tree, TPU fabric) a simulation host.

The paper simulates an 8x8x8 torus in SimGrid with 6 Gflops nodes, 10 Gbps
/ 1 usec links, and emulates a failed node by setting the capacity of all
its links to zero (killing any transmission routed through it).  This module
reproduces that platform at flow level:

* traffic between placed ranks follows the same dimension-ordered routes the
  topology graph uses (the platform description "lists the route for each
  pair of nodes ... matches exactly the topology assumed for deriving the
  mapping");
* per-link loads are accumulated over routes; the bandwidth term of a
  communication round is the *bottleneck* link serialization (max over
  links), the latency term charges per-message hop latency on the heaviest
  pair;
* a failed node zeroes all of its links: any job whose traffic or endpoints
  touch it aborts, exactly like SimGrid's zero-capacity variation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.comm_graph import CommGraph
from repro.core.topology import TorusTopology

GBPS = 1e9 / 8.0  # bytes/sec per Gbit/s


@dataclasses.dataclass
class TorusNetwork:
    topo: TorusTopology
    link_bandwidth: float = 10 * GBPS   # paper: 10 Gbps
    link_latency: float = 1e-6          # paper: 1 usec
    node_flops: float = 6e9             # paper: 6 Gflops

    def __post_init__(self):
        self._route_cache: dict[tuple[int, int], list] = {}

    def _route(self, u: int, v: int):
        key = (u, v)
        r = self._route_cache.get(key)
        if r is None:
            r = self.topo.route(u, v)
            self._route_cache[key] = r
        return r

    # ------------------------------------------------------------- loads
    def link_loads(self, comm: CommGraph, placement: np.ndarray
                   ) -> dict[tuple[int, int], float]:
        """Bytes per directed physical link, routing G_v over the placement."""
        loads: dict[tuple[int, int], float] = {}
        n = comm.n
        G = comm.G_v
        p = np.asarray(placement)
        for i in range(n):
            for j in range(i + 1, n):
                b = G[i, j]
                if b <= 0:
                    continue
                # symmetric convention: G[i,j] already holds both directions;
                # split evenly over the two directed routes
                for (u, v), frac in (((int(p[i]), int(p[j])), 0.5),
                                     ((int(p[j]), int(p[i])), 0.5)):
                    for link in self._route(u, v):
                        key = (link.src, link.dst)
                        loads[key] = loads.get(key, 0.0) + b * frac
        return loads

    def touches_failed(self, comm: CommGraph, placement: np.ndarray,
                       failed: np.ndarray) -> bool:
        """True if any endpoint or any routed hop touches a failed node."""
        failed_set = set(int(f) for f in np.asarray(failed).ravel())
        if not failed_set:
            return False
        p = np.asarray(placement)
        if any(int(x) in failed_set for x in p):
            return True
        n = comm.n
        G = comm.G_v
        for i in range(n):
            for j in range(i + 1, n):
                if G[i, j] <= 0:
                    continue
                for u, v in ((int(p[i]), int(p[j])), (int(p[j]), int(p[i]))):
                    for link in self._route(u, v):
                        if link.dst in failed_set or link.src in failed_set:
                            return True
        return False

    # -------------------------------------------------------------- times
    def comm_time(self, comm: CommGraph, placement: np.ndarray) -> float:
        """Time to drain the job's whole communication volume.

        bandwidth term: bottleneck link serialization (congestion);
        latency term:   per-message hop latency of the chattiest pair.
        """
        loads = self.link_loads(comm, placement)
        t_bw = max(loads.values()) / self.link_bandwidth if loads else 0.0
        p = np.asarray(placement)
        t_lat = 0.0
        n = comm.n
        for i in range(n):
            for j in range(i + 1, n):
                m = comm.G_m[i, j]
                if m <= 0:
                    continue
                hops = len(self._route(int(p[i]), int(p[j])))
                t_lat = max(t_lat, m * hops * self.link_latency)
        return t_bw + t_lat

    def compute_time(self, flops_per_rank: float, rounds: float) -> float:
        return flops_per_rank * rounds / self.node_flops


@dataclasses.dataclass
class HopNetwork:
    """Distance-level network model for any :class:`~repro.core.engine.
    Topology` implementation (fat-tree, TPU fabric, ...).

    Where :class:`TorusNetwork` routes every flow over explicit links and
    takes the bottleneck link as the bandwidth term, ``HopNetwork`` only
    has the topology's hop-distance matrix to work with.  It charges:

    * bandwidth: total *byte-hops* (``sum G_v[i,j] * hops(p_i, p_j)``)
      spread over the job's ``n`` injection links — placement-sensitive
      (proportional to the hop-bytes objective the mappers minimise) and
      equal to the torus model's serialization in the uniform-load limit;
    * latency: per-message hop latency of the chattiest pair, as in
      :class:`TorusNetwork`.

    The fault model is *endpoint form*, matching
    :meth:`~repro.core.fattree.FatTreeTopology.weight_matrix`: multi-path
    fabrics route around interior failures, so only a failed node that is
    itself a job endpoint aborts the job.
    """

    topo: "object"                      # any Topology (hop_matrix + n_nodes)
    link_bandwidth: float = 10 * GBPS
    link_latency: float = 1e-6
    node_flops: float = 6e9

    def __post_init__(self):
        self._hops: np.ndarray | None = None

    def hop_matrix(self) -> np.ndarray:
        if self._hops is None:
            self._hops = self.topo.hop_matrix()
        return self._hops

    def touches_failed(self, comm: CommGraph, placement: np.ndarray,
                       failed: np.ndarray) -> bool:
        """Endpoint fault form: abort iff a failed node hosts a process."""
        failed = np.asarray(failed).ravel()
        if not failed.size:
            return False
        return bool(np.isin(np.asarray(placement), failed).any())

    def comm_time(self, comm: CommGraph, placement: np.ndarray) -> float:
        p = np.asarray(placement)
        D = self.hop_matrix()
        hops = D[np.ix_(p, p)]
        byte_hops = float((comm.G_v * hops).sum()) / 2.0  # symmetric G
        t_bw = byte_hops / (self.link_bandwidth * max(comm.n, 1))
        t_lat = float((comm.G_m * hops).max()) * self.link_latency
        return t_bw + t_lat

    def compute_time(self, flops_per_rank: float, rounds: float) -> float:
        return flops_per_rank * rounds / self.node_flops


def network_for(topo, **kw):
    """Pick the highest-fidelity in-tree network model for a topology:
    flow-level :class:`TorusNetwork` for tori, distance-level
    :class:`HopNetwork` for everything else."""
    if isinstance(topo, TorusTopology):
        return TorusNetwork(topo, **kw)
    return HopNetwork(topo, **kw)
