"""Single-job simulation: completion time + abort decision for one instance."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.network import TorusNetwork
from repro.workloads.patterns import Workload


@dataclasses.dataclass
class JobOutcome:
    completed: bool
    time: float                # successful runtime (time charged on abort too)
    aborted_by: np.ndarray     # failed nodes that killed it (empty if ok)


def successful_runtime(wl: Workload, placement: np.ndarray,
                       net: TorusNetwork) -> float:
    """Runtime with no failures: compute + communication (no overlap — the
    conservative model; overlap is a serving-framework concern, not the
    placement paper's)."""
    return net.compute_time(wl.flops_per_rank, wl.rounds) \
        + net.comm_time(wl.comm, placement)


def simulate_instance(
    wl: Workload,
    placement: np.ndarray,
    net: TorusNetwork,
    failed: np.ndarray,
    runtime: float | None = None,
) -> JobOutcome:
    """One scenario: if any failed node is an endpoint or on a used route,
    the MPI job aborts (paper fault model: failed nodes neither compute nor
    forward; communication errors abort the job)."""
    t = successful_runtime(wl, placement, net) if runtime is None else runtime
    if len(failed) and net.touches_failed(wl.comm, placement, failed):
        return JobOutcome(False, t, np.asarray(failed))
    return JobOutcome(True, t, np.array([], dtype=np.int64))
