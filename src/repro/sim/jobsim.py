"""Single-job simulation: completion time + abort decision for one instance.

**Units.**  All returned times are *simulated seconds* under the network
model's platform constants (the paper's SimGrid platform: 6 Gflops
nodes, 10 Gbps / 1 usec links).  They are physical only to the extent
those constants are; relative comparisons between placements are the
meaningful output.  Byte and flop inputs come from
:class:`~repro.workloads.patterns.Workload` and are totals per run.

**Determinism.**  Nothing here draws randomness: an instance outcome is
a pure function of (workload, placement, network, failed set).  All
stochastic choice — which nodes fail, where an attempt aborts — lives in
the callers (:mod:`repro.sim.batchsim`, :mod:`repro.sim.clustersim`) and
flows through their explicit ``numpy.random.Generator`` arguments, so a
batch or event-sim run is reproducible from its seed.

**Truth vs estimate.**  ``failed`` is *ground truth* (sampled from a
:class:`~repro.cluster.failures.FailureModel`).  The scheduler-side
belief (``known_p_f`` in :func:`repro.sim.batchsim.run_batch`) never
reaches this module: placement quality is decided upstream, the physics
here only ask "did a truly-failed node touch the job?".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.network import TorusNetwork
from repro.workloads.patterns import Workload


@dataclasses.dataclass
class JobOutcome:
    completed: bool
    time: float                # successful runtime (time charged on abort too)
    aborted_by: np.ndarray     # failed nodes that killed it (empty if ok)


def successful_runtime(wl: Workload, placement: np.ndarray,
                       net: TorusNetwork) -> float:
    """Failure-free runtime in simulated seconds: compute + communication
    (no overlap — the conservative model; overlap is a serving-framework
    concern, not the placement paper's).  ``net`` may be any network
    model exposing ``compute_time`` / ``comm_time``
    (:class:`~repro.sim.network.TorusNetwork`,
    :class:`~repro.sim.network.HopNetwork`)."""
    return net.compute_time(wl.flops_per_rank, wl.rounds) \
        + net.comm_time(wl.comm, placement)


def simulate_instance(
    wl: Workload,
    placement: np.ndarray,
    net: TorusNetwork,
    failed: np.ndarray,
    runtime: float | None = None,
) -> JobOutcome:
    """One scenario: if any failed node is an endpoint or on a used route,
    the MPI job aborts (paper fault model: failed nodes neither compute nor
    forward; communication errors abort the job).

    ``failed`` holds ground-truth failed node ids for this one attempt.
    ``runtime`` (seconds) overrides the charged time when the caller
    tracks partial progress (checkpoint/restart accounting in
    ``run_batch``); default is the full :func:`successful_runtime`.
    """
    t = successful_runtime(wl, placement, net) if runtime is None else runtime
    if len(failed) and net.touches_failed(wl.comm, placement, failed):
        return JobOutcome(False, t, np.asarray(failed))
    return JobOutcome(True, t, np.array([], dtype=np.int64))
