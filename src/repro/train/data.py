"""Deterministic synthetic data pipeline.

Generates reproducible token streams (seeded per step, host-sliceable for
multi-process data loading) with enough structure that the loss actually
falls: a k-gram Markov chain over the vocabulary, so next-token prediction
is learnable.  ``input_specs`` builds the ShapeDtypeStruct stand-ins used by
the dry-run for every (arch x shape) cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticDataset:
    """Markov-chain token stream; next token = f(prev token) + noise."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # deterministic successor table: makes sequences predictable
        self._succ = rng.permutation(self.vocab)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, B)
        noise_mask = rng.random((B, S)) < self.noise
        noise_tok = rng.integers(0, self.vocab, (B, S))
        for t in range(S):
            nxt = self._succ[toks[:, t]]
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}


def extra_inputs(cfg: ModelConfig, batch_size: int, dtype=jnp.float32,
                 abstract: bool = False, seq_len: int | None = None) -> dict:
    """Modality-frontend STUBS (assignment): precomputed patch / frame
    embeddings for [vlm] / [audio] archs."""
    out = {}
    if cfg.family == "vlm":
        shp = (batch_size, cfg.n_vision_tokens, cfg.d_model)
        out["vision_embed"] = (jax.ShapeDtypeStruct(shp, dtype) if abstract
                               else jnp.zeros(shp, dtype))
    if cfg.family == "encdec":
        # speech frames scale with the text length when not pinned
        src = cfg.n_audio_frames or seq_len or 512
        shp = (batch_size, src, cfg.d_model)
        out["enc_embed"] = (jax.ShapeDtypeStruct(shp, dtype) if abstract
                            else jnp.zeros(shp, dtype))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) dry-run cell
    (train/prefill kinds; decode cells add caches via serve.kvcache)."""
    B, S = shape.global_batch, shape.seq_len
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch = {"tokens": toks}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch.update(extra_inputs(cfg, B, dtype=dtype, abstract=True, seq_len=S))
    return batch
