"""Loss and train step (grad + AdamW update), microbatch accumulation."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import NULL_CTX, forward
from repro.parallel.sharding import ShardingCtx
from repro.train.optimizer import AdamW, AdamWState


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; computed in f32 over the (possibly vocab-sharded)
    logits — GSPMD turns the logsumexp into a psum over the vocab axis."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()


def loss_fn(cfg: ModelConfig, params, batch: dict,
            ctx: ShardingCtx = NULL_CTX) -> jax.Array:
    logits = forward(cfg, params, batch, ctx)
    return cross_entropy(logits, batch["labels"])


def make_train_step(cfg: ModelConfig, opt: AdamW,
                    ctx: ShardingCtx = NULL_CTX,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``microbatches > 1`` accumulates gradients over a scan of
    batch slices (activation memory / global-batch decoupling)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, ctx))(params)

    def train_step(params, opt_state: AdamWState, batch: dict):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def acc_body(carry, i):
                acc, loss_acc = carry
                mb = {k: slice_mb(v, i) for k, v in batch.items()}
                l, g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        new_params, new_state, gnorm = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": new_state.step}
        return new_params, new_state, metrics

    return train_step
