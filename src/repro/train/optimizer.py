"""AdamW in plain JAX pytrees (no optax dependency), with ZeRO-style
sharded optimizer state: m/v inherit each parameter's sharding, so state
memory divides across the same axes the parameter does.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    state_dtype: Any = jnp.float32   # bf16 halves optimizer HBM (§Perf knob)

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def _schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        return self.lr * warm

    def update(self, grads, state: AdamWState, params):
        # global-norm clip
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        step = state.step + 1
        lr = self._schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mh = m_new / b1c
            vh = v_new / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(self.state_dtype),
                    v_new.astype(self.state_dtype))

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        p_new = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return p_new, AdamWState(step=step, m=m_new, v=v_new), gnorm
