"""Sharded checkpoint / restore — the fault-tolerance substrate.

The paper assumes *no* checkpointing (abort => restart from scratch) and we
reproduce that accounting in the batch simulator; this module is the
beyond-paper piece that the elastic scheduler and the training driver use:

* every array leaf is saved as a raw ``.npy`` plus a JSON manifest with the
  pytree structure, dtypes, and the training step;
* save is atomic (write to ``<dir>.tmp``, fsync, rename) so a node failure
  mid-checkpoint never corrupts the latest good checkpoint;
* ``keep`` rotation bounds disk usage;
* restore validates shapes against the expected tree and re-places leaves
  onto the current mesh (device order may have changed after a TOFA
  re-placement — exactly the elastic-restart path).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, params, opt_state=None,
                    keep: int = 3, extra: dict | None = None) -> str:
    """Atomic save; returns the final checkpoint path."""
    base = os.path.join(directory, f"step_{step:08d}")
    tmp = base + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    for name, tree in trees.items():
        for key, leaf in _flatten_with_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"{name}__{key.replace('/', '__')}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][f"{name}/{key}"] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(base):
        shutil.rmtree(base)
    os.rename(tmp, base)
    _rotate(directory, keep)
    return base


def _rotate(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def restore_checkpoint(path: str, params_like, opt_like=None,
                       shardings=None):
    """Restore into the structure of ``params_like`` (+ ``opt_like``).

    ``shardings``: optional matching tree of NamedSharding to re-place
    leaves on the current (possibly re-ordered) mesh."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def load_tree(name, like, shard_tree=None):
        flat = _flatten_with_paths(like)
        shards = _flatten_with_paths(shard_tree) if shard_tree is not None \
            else [(k, None) for k, _ in flat]
        leaves = []
        for (key, leaf), (_, sh) in zip(flat, shards):
            meta = manifest["leaves"][f"{name}/{key}"]
            arr = np.load(os.path.join(path, meta["file"]))
            expect = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != expect:
                raise ValueError(
                    f"checkpoint leaf {key}: shape {arr.shape} != {expect}")
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jnp.asarray(arr))
        treedef = jax.tree.structure(like)
        return jax.tree.unflatten(treedef, leaves)

    params = load_tree("params", params_like,
                       shardings[0] if shardings else None)
    out = {"step": manifest["step"], "params": params,
           "extra": manifest.get("extra", {})}
    if opt_like is not None:
        out["opt"] = load_tree("opt", opt_like,
                               shardings[1] if shardings else None)
    return out
