"""Mamba2 — state-space duality (SSD) blocks, pure-JAX chunked algorithm.

Implements the SSD "chunked dual" form of arXiv:2405.21060: the sequence is
split into chunks; within a chunk the quadratic (attention-like) form runs
on the MXU, between chunks an O(S/Q) state recurrence propagates.  This file
is the *reference*; ``repro.kernels.ssd_scan`` is the Pallas TPU kernel with
the same contract (tested against this module).

Shapes (mamba2 conventions):
  x   (B, S, H, P)   heads x head_dim, H*P = expand * d_model
  dt  (B, S, H)      softplus-positive step sizes
  A   (H,)           negative decay rates (A = -exp(a_log))
  B,C (B, S, G, N)   input/output projections, G groups, N = d_state
State: (B, H, P, N)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef, rmsnorm


# --------------------------------------------------------------------------
# schema
# --------------------------------------------------------------------------

def mamba2_schema(cfg: ModelConfig, layers: int) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    G, N = s.n_groups, s.d_state
    L = (layers,)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    # fused in_proj: [z, x, B, C, dt]
    proj = 2 * d_in + 2 * G * N + H
    return {
        "in_proj": ParamDef(L + (d, proj), ("layers", "embed", "ssm_inner")),
        "conv_w": ParamDef(L + (s.d_conv, d_in + 2 * G * N),
                           ("layers", None, "ssm_inner")),
        "conv_b": ParamDef(L + (d_in + 2 * G * N,), ("layers", "ssm_inner"),
                           init="zeros"),
        "a_log": ParamDef(L + (H,), ("layers", "heads"), init="ones"),
        "dt_bias": ParamDef(L + (H,), ("layers", "heads"), init="zeros"),
        "d_skip": ParamDef(L + (H,), ("layers", "heads"), init="ones"),
        "norm_w": ParamDef(L + (d_in,), ("layers", "ssm_inner"), init="ones"),
        "out_proj": ParamDef(L + (d_in, d), ("layers", "ssm_inner", "embed"),
                             scale=out_scale),
    }


# --------------------------------------------------------------------------
# SSD core (chunked scan) — reference implementation
# --------------------------------------------------------------------------

def _segsum(x):
    """(..., Q) -> (..., Q, Q) with out[..., i, j] = sum_{j < k <= i} x_k,
    -inf above the diagonal (lower-triangular cumulative sums)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD forward.  Returns (y (B,S,H,P), final_state (B,H,P,N)).

    Discretisation: dA = dt * A;  dB = dt * B (ZOH-simplified, as mamba2).
    """
    with jax.named_scope("ssd_chunked"):
        return _ssd_chunked_impl(x, dt, A, B, C, chunk, init_state)


def _ssd_chunked_impl(x, dt, A, B, C, chunk: int, init_state=None):
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    nc = S // chunk
    # heads per group replication
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2) if rep > 1 else B   # (b,S,H,N)
    Ch = jnp.repeat(C, rep, axis=2) if rep > 1 else C

    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = Bh.reshape(b, nc, chunk, H, N)
    Cc = Ch.reshape(b, nc, chunk, H, N)

    dA = dtc * A[None, None, None, :]              # (b,nc,Q,H), negative
    dA_hc = jnp.moveaxis(dA, -1, 1)                # (b,H,nc,Q)
    dA_cs = jnp.cumsum(dA_hc, axis=-1)             # cumulative within chunk

    # 1) intra-chunk (quadratic) term
    Lmat = jnp.exp(_segsum(dA_hc))                 # (b,H,nc,Q,Q)
    scores = jnp.einsum("bclhn,bcshn->bhcls", Cc, Bc)
    y_diag = jnp.einsum("bhcls,bhcls,bcshp,bcsh->bclhp",
                        scores, Lmat, xc, dtc)

    # 2) chunk states: contribution of each chunk to its final state
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)      # (b,H,nc,Q)
    states = jnp.einsum("bcshn,bhcs,bcsh,bcshp->bchpn",
                        Bc, decay_states, dtc, xc)       # (b,nc,H,P,N)
    states = states.astype(jnp.float32)                  # recurrence in f32

    # 3) inter-chunk recurrence over chunk-final states
    chunk_decay = dA_cs[..., -1].astype(jnp.float32)      # (b,H,nc)
    if init_state is None:
        init_state = jnp.zeros((b, H, P, N), jnp.float32)
    init_state = init_state.astype(jnp.float32)

    def scan_fn(carry, inp):
        st, dec = inp                                     # (b,H,P,N),(b,H)
        new = carry * jnp.exp(dec)[..., None, None] + st
        return new, carry                                 # emit state BEFORE

    sts = jnp.moveaxis(states, 1, 0)                      # (nc,b,H,P,N)
    decs = jnp.moveaxis(chunk_decay, -1, 0)               # (nc,b,H)
    final, prev_states = jax.lax.scan(scan_fn, init_state, (sts, decs))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (b,nc,H,P,N)

    # 4) inter-chunk output term: carry-in state read by each position
    state_decay = jnp.exp(dA_cs)                          # (b,H,nc,Q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, S, H, P).astype(x.dtype)
    return y, final


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrence: state' = state*exp(dt*A) + dt * B (x) outer;
    y = C . state' + skip handled by caller.  x (B,H,P), dt (B,H),
    B/C (B,G,N)."""
    b, H, P = x.shape
    G, N = B.shape[1], B.shape[2]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1) if rep > 1 else B    # (b,H,N)
    Ch = jnp.repeat(C, rep, axis=1) if rep > 1 else C
    dA = jnp.exp(dt * A[None, :])                        # (b,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x, Bh)
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    return y, new_state


# --------------------------------------------------------------------------
# full mamba2 block
# --------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    G, N = s.n_groups, s.d_state
    H = d_in // s.head_dim
    z, xi, Bf, Cf, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N],
        axis=-1)
    return z, xi, Bf, Cf, dt


def mamba2_block(p: dict, h: jax.Array, cfg: ModelConfig,
                 conv_state=None, ssm_state=None):
    """One mamba2 mixer. Train/prefill: conv via sliding window; decode:
    single-step with cached conv tail + state.  Returns (out, new_caches)."""
    s = cfg.ssm
    B_, S, D = h.shape
    d_in = s.expand * D
    G, N = s.n_groups, s.d_state
    H = d_in // s.head_dim

    zxbcdt = jnp.einsum("bsd,dp->bsp", h, p["in_proj"])
    z, xi, Bf, Cf, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    conv_in = jnp.concatenate([xi, Bf, Cf], axis=-1)     # (B,S,conv_ch)
    new_conv_state = None
    if conv_state is not None:
        # decode: cached last (d_conv-1) inputs
        window = jnp.concatenate([conv_state, conv_in], axis=1)
        new_conv_state = window[:, -(s.d_conv - 1):]
        conv = jnp.einsum("bwc,wc->bc", window[:, -s.d_conv:],
                          p["conv_w"]) + p["conv_b"]
        conv = conv[:, None, :]
    else:
        pad = jnp.pad(conv_in, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        windows = jnp.stack(
            [pad[:, i:i + S] for i in range(s.d_conv)], axis=2)  # (B,S,W,C)
        conv = jnp.einsum("bswc,wc->bsc", windows, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)
    xi = conv[..., :d_in]
    Bf = conv[..., d_in:d_in + G * N]
    Cf = conv[..., d_in + G * N:]

    xh = xi.reshape(B_, -1, H, s.head_dim)
    Bg = Bf.reshape(B_, -1, G, N)
    Cg = Cf.reshape(B_, -1, G, N)

    new_ssm_state = None
    if ssm_state is not None:
        y, new_ssm_state = ssd_decode_step(
            ssm_state.astype(jnp.float32), xh[:, 0], dt[:, 0], A,
            Bg[:, 0], Cg[:, 0])
        y = y[:, None].astype(h.dtype)
        # cache dtype is stable across steps (f32 leaf, see kvcache)
        new_ssm_state = new_ssm_state.astype(ssm_state.dtype)
    else:
        y, final = ssd_chunked(xh, dt, A, Bg, Cg, chunk=min(s.chunk, S))
        new_ssm_state = final
    y = y + xh * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, -1, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = jnp.einsum("bsp,pd->bsd", y, p["out_proj"]).astype(h.dtype)
    return out, (new_conv_state, new_ssm_state)
