"""Unified model builder: every assigned architecture behind one API.

    schema(cfg)                      -> nested dict of ParamDef
    init(cfg, key, dtype)            -> params pytree
    forward(cfg, params, batch, ctx) -> logits  (train / prefill)
    init_cache(cfg, B, S_max)        -> cache schema (ParamDef tree)
    decode_step(cfg, params, caches, tokens, pos, ctx) -> logits, caches

Layers are STACKED and SCANNED (``lax.scan``): HLO size and compile time
are O(1) in depth — a 96-layer nemotron compiles as fast as a 4-layer toy.
Heterogeneous interleaves (VLM cross-attention, Zamba2 shared blocks,
DeepSeek leading dense layer) are expressed as group-scans.

``ctx`` (ShardingCtx) injects sharding constraints and the MoE EP wrapper;
``ctx=None`` is the single-device test path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (ParamDef, apply_rope, gqa_attention,
                                 gqa_schema, init_params, mla_attention,
                                 mla_schema, mlp, mlp_schema, rmsnorm,
                                 rope_freqs)
from repro.parallel.sharding import ShardingCtx

NULL_CTX = ShardingCtx(mesh=None)


# --------------------------------------------------------------------------
# schema
# --------------------------------------------------------------------------

def _norms_schema(cfg: ModelConfig, layers: int, n: int = 2) -> dict:
    return {f"ln{i+1}": ParamDef((layers, cfg.d_model),
                                 ("layers", "act_embed"), init="ones")
            for i in range(n)}


def _attn_schema(cfg: ModelConfig, layers: int) -> dict:
    if cfg.attn_type == "mla":
        return mla_schema(cfg, layers)
    return gqa_schema(cfg, layers)


def _ffn_schema(cfg: ModelConfig, layers: int) -> dict:
    if cfg.family == "moe" and cfg.moe:
        return moe_mod.moe_schema(cfg, layers)
    return mlp_schema(cfg, layers)


def schema(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    sch: dict = {
        "tok_emb": ParamDef((V, d), ("vocab", "embed")),
        "final_norm": ParamDef((d,), ("act_embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        sch["unembed"] = ParamDef((V, d), ("vocab", "embed"))

    fam = cfg.family
    if fam in ("dense", "moe"):
        L = cfg.n_layers
        if fam == "moe" and cfg.moe and cfg.moe.first_dense:
            Ld = cfg.moe.first_dense
            Lm = L - Ld
            sch["dense0"] = {**_attn_schema(cfg, Ld),
                            **mlp_schema(cfg, Ld, d_ff=cfg.moe.d_ff_first or cfg.d_ff),
                            **_norms_schema(cfg, Ld)}
            sch["blocks"] = {**_attn_schema(cfg, Lm), **_ffn_schema(cfg, Lm),
                             **_norms_schema(cfg, Lm)}
        else:
            sch["blocks"] = {**_attn_schema(cfg, L), **_ffn_schema(cfg, L),
                             **_norms_schema(cfg, L)}
    elif fam == "ssm":
        sch["blocks"] = {**ssm_mod.mamba2_schema(cfg, cfg.n_layers),
                         **_norms_schema(cfg, cfg.n_layers, n=1)}
    elif fam == "hybrid":
        G, k, trail = _hybrid_split(cfg)
        sch["blocks"] = {**ssm_mod.mamba2_schema(cfg, G * k),
                         **_norms_schema(cfg, G * k, n=1)}
        if trail:
            sch["trailing"] = {**ssm_mod.mamba2_schema(cfg, trail),
                               **_norms_schema(cfg, trail, n=1)}
        # ONE shared attention block (true weight sharing, zamba2-style)
        sch["shared"] = {**_attn_schema(cfg, 1), **mlp_schema(cfg, 1),
                         **_norms_schema(cfg, 1)}
    elif fam == "vlm":
        G, k = _vlm_split(cfg)
        sch["blocks"] = {**_attn_schema(cfg, G * k), **_ffn_schema(cfg, G * k),
                         **_norms_schema(cfg, G * k)}
        sch["cross"] = {**_attn_schema(cfg, G), **_ffn_schema(cfg, G),
                        **_norms_schema(cfg, G, n=3)}
    elif fam == "encdec":
        Le, Ld = cfg.n_enc_layers, cfg.n_layers
        sch["encoder"] = {**_attn_schema(cfg, Le), **mlp_schema(cfg, Le),
                          **_norms_schema(cfg, Le)}
        sch["enc_norm"] = ParamDef((d,), ("act_embed",), init="ones")
        sch["decoder"] = {
            "self": _attn_schema(cfg, Ld),
            "cross": _attn_schema(cfg, Ld),
            **mlp_schema(cfg, Ld),
            **_norms_schema(cfg, Ld, n=3),
        }
    else:
        raise ValueError(f"unknown family {fam}")
    return sch


def _hybrid_split(cfg: ModelConfig) -> tuple[int, int, int]:
    k = cfg.hybrid_every or 6
    G = cfg.n_layers // k
    return G, k, cfg.n_layers - G * k


def _vlm_split(cfg: ModelConfig) -> tuple[int, int]:
    """n_layers = G groups of (k self layers + 1 cross layer)."""
    k = cfg.cross_attn_every or 4
    G = cfg.n_layers // (k + 1)
    assert G * (k + 1) == cfg.n_layers, \
        f"vlm layers {cfg.n_layers} must be divisible by {k + 1}"
    return G, k


def init(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(schema(cfg), key, dtype)


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _ffn_apply(p, x, cfg: ModelConfig, ctx: ShardingCtx):
    if cfg.family == "moe" and "router" in p:
        if ctx.mesh is None or ctx.moe_impl == "auto" \
                or ctx.model_axis_size == 1 \
                or cfg.moe.n_experts % ctx.model_axis_size != 0:
            return moe_mod.moe_ffn_local(p, x, cfg)
        if ctx.moe_impl == "alltoall":
            return _moe_a2a_shardmap(p, x, cfg, ctx)
        return _moe_ep_shardmap(p, x, cfg, ctx)
    return mlp(p, x, cfg.act)


# keys of the MoE FFN proper — only these enter the shard_map (the block
# dict also carries attention weights and rank-1 norms)
_MOE_KEYS = ("router", "w_up", "w_down", "w_gate",
             "shared_up", "shared_gate", "shared_down")


def _moe_ep_shardmap(p, x, cfg, ctx: ShardingCtx):
    mesh = ctx.mesh
    pm = {k: p[k] for k in _MOE_KEYS if k in p}
    specs_in = {}
    for name in pm:
        if name in ("w_up", "w_down", "w_gate"):
            specs_in[name] = P("model", None, None)
        elif name in ("shared_up", "shared_gate"):
            specs_in[name] = P(None, "model")
        elif name == "shared_down":
            specs_in[name] = P("model", None)
        else:  # router replicated
            specs_in[name] = P(None, None)
    x_spec = P(ctx.batch_axes() or None, None, None)
    f = jax.shard_map(
        functools.partial(moe_mod.moe_ffn_ep, cfg=cfg, axis="model"),
        mesh=mesh, in_specs=(specs_in, x_spec), out_specs=x_spec,
        check_vma=False)
    return f(pm, x)


def _moe_a2a_shardmap(p, x, cfg, ctx: ShardingCtx):
    mesh = ctx.mesh
    pm = {k: p[k] for k in _MOE_KEYS if k in p}
    specs_in = {}
    for name in pm:
        if name in ("w_up", "w_down", "w_gate"):
            specs_in[name] = P("model", None, None)
        else:  # router + shared experts replicated (x is sequence-sharded)
            specs_in[name] = P(*([None] * pm[name].ndim))
    # batch-wise dispatch sharding over the model axis: narrowing the batch
    # dim is a local slice (no resharding collective), unlike seq-sharding
    # which GSPMD reshards via full replication (measured: 2.5 TB/step of
    # all-gather on phi3.5 train — see EXPERIMENTS §Perf-C)
    ba = ctx.batch_axes()
    if x.shape[0] % (int(np.prod([ctx.mesh.shape[a] for a in ba]))
                     * ctx.mesh.shape["model"]) == 0:
        x_spec = P((*ba, "model"), None, None)
    else:
        x_spec = P(ba or None, "model", None)
    f = jax.shard_map(
        functools.partial(moe_mod.moe_ffn_a2a, cfg=cfg, axis="model"),
        mesh=mesh, in_specs=(specs_in, x_spec), out_specs=x_spec,
        check_vma=False)
    return f(pm, x)


def _attn_apply(p, x, cfg, cos, sin, ctx, cache=None, pos=None,
                kv_override=None, causal=True):
    if cfg.attn_type == "mla":
        return mla_attention(p, x, cos, sin, mla=cfg.mla,
                             n_heads=cfg.n_heads, cache=cache,
                             cache_pos=pos, causal=causal)
    if cache is not None and ctx.flash_decode and ctx.mesh is not None \
            and "model" in ctx.mesh.shape:
        from repro.models.layers import flash_decode_gqa
        return flash_decode_gqa(p, x, cache, pos, n_heads=cfg.n_heads,
                                n_kv_heads=cfg.n_kv_heads, cos=cos, sin=sin,
                                mesh=ctx.mesh, batch_axes=ctx.batch_axes())
    return gqa_attention(p, x, cos, sin, n_heads=cfg.n_heads,
                         n_kv_heads=cfg.n_kv_heads, cache=cache,
                         cache_pos=pos, kv_override=kv_override,
                         causal=causal)


def _dense_block(p, h, cfg, cos, sin, ctx, cache=None, pos=None):
    a, kc = _attn_apply(p, rmsnorm(h, p["ln1"]), cfg, cos, sin, ctx,
                        cache=cache, pos=pos)
    h = h + a
    h = h + _ffn_apply(p, rmsnorm(h, p["ln2"]), cfg, ctx)
    h = ctx.constrain(h, "batch", "seq", "act_embed")
    return h, kc


def _mamba_layer(p, h, cfg, conv_state=None, ssm_state=None):
    o, caches = ssm_mod.mamba2_block(p, rmsnorm(h, p["ln1"]), cfg,
                                     conv_state=conv_state,
                                     ssm_state=ssm_state)
    return h + o, caches


def _shared_attn_block(p, h, cfg, cos, sin, ctx, cache=None, pos=None):
    p1 = jax.tree.map(lambda a: a[0], p)  # single stacked entry
    a, kc = _attn_apply(p1, rmsnorm(h, p1["ln1"]), cfg, cos, sin, ctx,
                        cache=cache, pos=pos)
    h = h + a
    h = h + mlp(p1, rmsnorm(h, p1["ln2"]), cfg.act)
    return h, kc


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _maybe_remat(fn, ctx: ShardingCtx):
    return jax.checkpoint(fn) if ctx.remat else fn


def _rope(cfg: ModelConfig, S: int, offset=0):
    pos = jnp.arange(S) + offset
    hd = cfg.mla.qk_rope_head_dim if cfg.attn_type == "mla" else cfg.head_dim_
    return rope_freqs(hd, cfg.rope_theta, pos)


def forward(cfg: ModelConfig, params, batch: dict,
            ctx: ShardingCtx = NULL_CTX) -> jax.Array:
    """Token logits for train/prefill.  ``batch``: tokens (B,S) [+
    vision_embed (B,Nv,D) | enc_embed (B,Ss,D)]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = jnp.take(params["tok_emb"], tokens, axis=0)
    h = ctx.constrain(h, "batch", "seq", "act_embed")
    cos, sin = _rope(cfg, S)
    fam = cfg.family

    if fam in ("dense", "moe"):
        if "dense0" in params:
            def body0(carry, p):
                return _dense_block(p, carry, cfg, cos, sin, ctx)[0], None
            h, _ = jax.lax.scan(_maybe_remat(body0, ctx), h, params["dense0"])

        def body(carry, p):
            return _dense_block(p, carry, cfg, cos, sin, ctx)[0], None
        h, _ = jax.lax.scan(_maybe_remat(body, ctx), h, params["blocks"])

    elif fam == "ssm":
        def body(carry, p):
            return _mamba_layer(p, carry, cfg)[0], None
        h, _ = jax.lax.scan(_maybe_remat(body, ctx), h, params["blocks"])

    elif fam == "hybrid":
        G, k, trail = _hybrid_split(cfg)
        grouped = jax.tree.map(
            lambda a: a.reshape((G, k) + a.shape[1:]), params["blocks"])

        def group_body(carry, pg):
            def inner(c, p):
                return _mamba_layer(p, c, cfg)[0], None
            c, _ = jax.lax.scan(inner, carry, pg)
            c, _ = _shared_attn_block(params["shared"], c, cfg, cos, sin, ctx)
            return c, None
        h, _ = jax.lax.scan(_maybe_remat(group_body, ctx), h, grouped)
        if trail:
            def body(carry, p):
                return _mamba_layer(p, carry, cfg)[0], None
            h, _ = jax.lax.scan(_maybe_remat(body, ctx), h,
                                params["trailing"])

    elif fam == "vlm":
        G, k = _vlm_split(cfg)
        vis = batch["vision_embed"].astype(h.dtype)
        grouped = jax.tree.map(
            lambda a: a.reshape((G, k) + a.shape[1:]), params["blocks"])

        def group_body(carry, ps):
            pg, pc = ps

            def inner(c, p):
                return _dense_block(p, c, cfg, cos, sin, ctx)[0], None
            c, _ = jax.lax.scan(inner, carry, pg)
            # cross-attention to the (stubbed) vision embeddings
            a, _ = _attn_apply(pc, rmsnorm(c, pc["ln1"]), cfg, cos, sin, ctx,
                               kv_override=(vis,), causal=False)
            c = c + a
            c = c + _ffn_apply(pc, rmsnorm(c, pc["ln2"]), cfg, ctx)
            return c, None
        h, _ = jax.lax.scan(_maybe_remat(group_body, ctx), h,
                            (grouped, params["cross"]))

    elif fam == "encdec":
        enc = batch["enc_embed"].astype(h.dtype)
        Se = enc.shape[1]
        cos_e, sin_e = _rope(cfg, Se)

        def enc_body(carry, p):
            a, _ = _attn_apply(p, rmsnorm(carry, p["ln1"]), cfg, cos_e, sin_e,
                               ctx, causal=False)
            c = carry + a
            c = c + mlp(p, rmsnorm(c, p["ln2"]), cfg.act)
            return c, None
        enc, _ = jax.lax.scan(_maybe_remat(enc_body, ctx), enc,
                              params["encoder"])
        enc = rmsnorm(enc, params["enc_norm"])

        dec_p = params["decoder"]

        def dec_body(carry, p):
            a, _ = _attn_apply(p["self"], rmsnorm(carry, p["ln1"]), cfg,
                               cos, sin, ctx)
            c = carry + a
            a, _ = _attn_apply(p["cross"], rmsnorm(c, p["ln2"]), cfg,
                               cos, sin, ctx, kv_override=(enc,),
                               causal=False)
            c = c + a
            c = c + mlp(p, rmsnorm(c, p["ln3"]), cfg.act)
            return c, None
        h, _ = jax.lax.scan(_maybe_remat(dec_body, ctx), h, dec_p)
    else:
        raise ValueError(fam)

    h = rmsnorm(h, params["final_norm"])
    unembed = params["tok_emb"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", h, unembed)
    logits = ctx.constrain(logits, "batch", "seq", "vocab")
    return logits
