"""Mixture-of-Experts FFN: top-k routing, sorted ragged-matmul dispatch, EP.

TPU adaptation notes (DESIGN.md §2): GPU MoE kernels (grouped GEMM on SMs)
map to ``jax.lax.ragged_dot`` on TPU, which XLA lowers onto the MXU.  Expert
parallelism uses the *replicated-activation* scheme: activations between
blocks are replicated across the ``model`` axis under tensor parallelism, so
each model shard can route its (replicated) tokens to the experts it owns
locally and a single ``psum`` over ``model`` combines contributions — no
all-to-all and no token dropping.  The ``alltoall`` variant (sequence-
sharded dispatch with fixed capacity, GShard-style) is implemented for the
§Perf comparison.

Two entry points:
  moe_ffn_local   single-shard / GSPMD-auto reference (all experts local)
  moe_ffn_ep      shard_map expert-parallel version (see model.py wiring)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef, act_fn


def moe_schema(cfg: ModelConfig, layers: int) -> dict:
    mo = cfg.moe
    d, fe = cfg.d_model, mo.d_ff_expert
    E = mo.n_experts
    L = (layers,)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    sch = {
        "router": ParamDef(L + (d, E), ("layers", "embed", None)),
        "w_up": ParamDef(L + (E, d, fe), ("layers", "experts", "embed", None)),
        "w_down": ParamDef(L + (E, fe, d),
                           ("layers", "experts", None, "embed"),
                           scale=out_scale),
    }
    if cfg.act == "silu_glu":
        sch["w_gate"] = ParamDef(L + (E, d, fe),
                                 ("layers", "experts", "embed", None))
    if mo.n_shared:
        fs = mo.n_shared * fe
        sch["shared_up"] = ParamDef(L + (d, fs), ("layers", "embed", "mlp"))
        sch["shared_down"] = ParamDef(L + (fs, d), ("layers", "mlp", "embed"),
                                      scale=out_scale)
        if cfg.act == "silu_glu":
            sch["shared_gate"] = ParamDef(L + (d, fs),
                                          ("layers", "embed", "mlp"))
    return sch


def route(logits: jax.Array, top_k: int):
    """softmax -> top-k -> renormalise.  Returns (probs (T,k), ids (T,k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i


def _expert_mlp_sorted(xs, p, act, lo_e=None, n_local=None):
    """ragged-matmul over tokens sorted by expert; params may be the local
    expert slice already."""
    w_up, w_down = p["w_up"], p["w_down"]
    gs = xs["group_sizes"]
    h = jax.lax.ragged_dot(xs["tokens"], w_up, gs)
    if "w_gate" in p:
        g = jax.lax.ragged_dot(xs["tokens"], p["w_gate"], gs)
        h = h * act_fn(act)(g)
    else:
        h = act_fn(act)(h)
    return jax.lax.ragged_dot(h, w_down, gs)


def moe_ffn_local(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """All experts resident: the reference path (smoke tests, 1 device) and
    the GSPMD-auto ablation path."""
    mo = cfg.moe
    B, S, D = x.shape
    T = B * S
    k = mo.top_k
    xf = x.reshape(T, D)
    logits = xf @ p["router"]
    probs, ids = route(logits, k)               # (T,k)

    flat_e = ids.reshape(-1)                    # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)       # token of each choice
    order = jnp.argsort(flat_e)
    inv = jnp.argsort(order)
    xs = jnp.take(xf, flat_t[order], axis=0)    # (T*k, D) sorted by expert
    group_sizes = jnp.bincount(flat_e, length=mo.n_experts)
    ys = _expert_mlp_sorted({"tokens": xs, "group_sizes": group_sizes},
                            p, cfg.act)
    ys = jnp.take(ys, inv, axis=0).reshape(T, k, D)
    y = (ys * probs[..., None].astype(ys.dtype)).sum(axis=1)

    if "shared_up" in p:
        h = xf @ p["shared_up"]
        if "shared_gate" in p:
            h = h * act_fn(cfg.act)(xf @ p["shared_gate"])
        else:
            h = act_fn(cfg.act)(h)
        y = y + h @ p["shared_down"]
    return y.reshape(B, S, D)


def moe_ffn_ep(p: dict, x: jax.Array, cfg: ModelConfig, axis: str = "model"
               ) -> jax.Array:
    """Expert-parallel body — call *inside* shard_map.

    ``p`` holds the local expert slice: w_up (E_local, D, F) etc.; shared-
    expert weights arrive sliced on the hidden dim (dense TP).  ``x`` is the
    local batch shard, replicated across ``axis``.  One psum over ``axis``
    combines routed + shared partial outputs (the same collective a dense
    TP MLP needs — EP rides for free)."""
    mo = cfg.moe
    B, S, D = x.shape
    T = B * S
    k = mo.top_k
    E = mo.n_experts
    n_shards = jax.lax.axis_size(axis)
    e_local = E // n_shards
    shard = jax.lax.axis_index(axis)
    lo = shard * e_local

    xf = x.reshape(T, D)
    logits = xf @ p["router"]                   # router replicated
    probs, ids = route(logits, k)               # identical on every shard

    flat_e = ids.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    local = (flat_e >= lo) & (flat_e < lo + e_local)
    # non-local choices sort into a trailing trash group (id e_local)
    e_l = jnp.where(local, flat_e - lo, e_local)
    order = jnp.argsort(e_l)
    inv = jnp.argsort(order)
    xs = jnp.take(xf, flat_t[order], axis=0)
    group_sizes = jnp.bincount(e_l, length=e_local)  # trash group excluded

    ys = _expert_mlp_sorted({"tokens": xs, "group_sizes": group_sizes},
                            p, cfg.act)
    # rows past sum(group_sizes) (trash) are unspecified -> mask them out
    ys = jnp.take(ys, inv, axis=0).reshape(T, k, D)
    w = probs * local.reshape(T, k)
    y = (ys * w[..., None].astype(ys.dtype)).sum(axis=1)

    if "shared_up" in p:                        # hidden dim sliced over axis
        h = xf @ p["shared_up"]
        if "shared_gate" in p:
            h = h * act_fn(cfg.act)(xf @ p["shared_gate"])
        else:
            h = act_fn(cfg.act)(h)
        y = y + h @ p["shared_down"]
    y = jax.lax.psum(y, axis)
    return y.reshape(B, S, D)


def moe_ffn_a2a(p: dict, x: jax.Array, cfg: ModelConfig, axis: str = "model"
                ) -> jax.Array:
    """All-to-all EP (GShard-style, fixed capacity) — §Perf variant.

    Call inside shard_map with the *sequence* sharded over ``axis``: each
    shard routes its T_local tokens, packs per-destination-shard buffers of
    fixed capacity, exchanges them with one all-to-all, computes its local
    experts, and reverses the exchange.  Token dropping occurs beyond
    capacity (counted and minimised by the capacity factor)."""
    mo = cfg.moe
    B, S, D = x.shape
    T = B * S
    k = mo.top_k
    E = mo.n_experts
    n_shards = jax.lax.axis_size(axis)
    e_local = E // n_shards
    cap = int(mo.capacity_factor * T * k / n_shards) or 1

    xf = x.reshape(T, D)
    logits = xf @ p["router"]
    probs, ids = route(logits, k)

    flat_e = ids.reshape(-1)                     # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    dest = flat_e // e_local                     # destination shard
    # slot within the destination buffer (position among same-dest choices)
    one_hot = jax.nn.one_hot(dest, n_shards, dtype=jnp.int32)
    slot = jnp.take_along_axis(jnp.cumsum(one_hot, axis=0) - 1,
                               dest[:, None], axis=1)[:, 0]
    keep = slot < cap
    slot = jnp.minimum(slot, cap - 1)            # dropped slots write garbage
                                                 # then get masked by `keep`
    send = jnp.zeros((n_shards, cap, D), xf.dtype)
    send_e = jnp.full((n_shards, cap), e_local, jnp.int32)  # pad = trash
    send = send.at[dest, slot].set(jnp.where(keep[:, None],
                                             jnp.take(xf, flat_t, axis=0), 0))
    send_e = send_e.at[dest, slot].set(
        jnp.where(keep, flat_e % e_local, e_local))

    recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, axis, 0, 0, tiled=False)
    rt = recv.reshape(n_shards * cap, D)
    re = recv_e.reshape(n_shards * cap)
    order = jnp.argsort(re)
    inv = jnp.argsort(order)
    xs = jnp.take(rt, order, axis=0)
    group_sizes = jnp.bincount(re, length=e_local)
    ys = _expert_mlp_sorted({"tokens": xs, "group_sizes": group_sizes},
                            p, cfg.act)
    ys = jnp.take(ys, inv, axis=0)
    ys = jnp.where((re < e_local)[:, None], ys, 0)
    back = jax.lax.all_to_all(ys.reshape(n_shards, cap, D), axis, 0, 0,
                              tiled=False)
    # gather each choice's result back to its token
    y_choice = back[dest, slot] * keep[:, None]
    y = jnp.zeros((T, D), ys.dtype).at[flat_t].add(
        y_choice * probs.reshape(-1)[:, None].astype(ys.dtype))

    if "shared_up" in p:
        # a2a mode: x is sequence-sharded, so shared-expert weights must be
        # passed in REPLICATED (model.py wires in_specs accordingly)
        h = xf @ p["shared_up"]
        if "shared_gate" in p:
            h = h * act_fn(cfg.act)(xf @ p["shared_gate"])
        else:
            h = act_fn(cfg.act)(h)
        y = y + h @ p["shared_down"]
    return y.reshape(B, S, D)
