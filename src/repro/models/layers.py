"""Core NN building blocks: norms, RoPE, attention (GQA / MLA), MLPs.

Everything is functional: params are plain dicts built from ``ParamDef``
schemas, so the partition-spec tree (``parallel/sharding.py``) is generated
from the same schema and can never drift from the arrays.

Conventions:
  activations  (B, S, D)  — batch, sequence, d_model
  GQA caches   (B, Hkv, S, Dh)
  MLA caches   (B, S, kv_lora + rope_dim)   (compressed latent, per layer)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig

# sequences at or above this length use the flash (online-softmax) attention
# path: O(S * block) memory instead of the O(S^2) score matrix
FLASH_MIN_SEQ = 2048

# --------------------------------------------------------------------------
# param schema
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple              # logical axis name per dim (None = unsharded)
    init: str = "normal"     # normal | zeros | ones
    scale: float = 0.02
    dtype: object = None     # None = container default; else pinned (e.g.
                             # f32 SSM states that must not decay in bf16)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_params(schema, key: jax.Array, dtype=jnp.float32):
    """Materialise a (nested dict) schema of ParamDef into arrays."""
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            out.append(jax.random.normal(k, d.shape, dtype) * d.scale)
    return jax.tree.unflatten(treedef, out)


def abstract_params(schema, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        schema, is_leaf=lambda x: isinstance(x, ParamDef))


# --------------------------------------------------------------------------
# norms / activations / rope
# --------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def act_fn(name: str) -> Callable:
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    if name in ("silu", "silu_glu"):
        return jax.nn.silu
    raise ValueError(name)


def rope_freqs(head_dim: int, theta: float, positions: jax.Array):
    """(S,) positions -> cos/sin of shape (S, head_dim // 2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                      dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, Dh); cos/sin: (S, Dh//2). Rotate-half convention."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    shape = (1,) * (x.ndim - 2) + cos.shape
    c, s = cos.reshape(shape), sin.reshape(shape)
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA)
# --------------------------------------------------------------------------

def gqa_schema(cfg: ModelConfig, layers: int) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    L = (layers,)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "wq": ParamDef(L + (d, h, hd), ("layers", "embed", "heads", None)),
        "wk": ParamDef(L + (d, kv, hd), ("layers", "embed", "kv_heads", None)),
        "wv": ParamDef(L + (d, kv, hd), ("layers", "embed", "kv_heads", None)),
        "wo": ParamDef(L + (h, hd, d), ("layers", "heads", None, "embed"),
                       scale=out_scale),
    }


def gqa_attention(
    p: dict, x: jax.Array, cos, sin, *,
    n_heads: int, n_kv_heads: int,
    cache: Optional[tuple] = None,       # (k, v) (B, Hkv, S_max, Dh)
    cache_pos: Optional[jax.Array] = None,
    causal: bool = True,
    kv_override: Optional[tuple] = None,  # cross-attention K/V inputs
):
    """Grouped-query attention; returns (out, new_cache)."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
        if cos is not None:
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        src = kv_override[0]
        k = jnp.einsum("bsd,dhk->bhsk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bhsk", src, p["wv"])
        causal = False

    new_cache = None
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, 0, cache_pos, 0))
        k, v = ck, cv
        new_cache = (ck, cv)
        causal = False  # masking handled by length below

    if cache is None and causal and kv_override is None \
            and S >= FLASH_MIN_SEQ:
        # long-context prefill/train: O(S*block) online-softmax attention
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=True)
        out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
        return out, None

    groups = n_heads // max(k.shape[1], 1)
    if groups > 1:
        k = jnp.repeat(k, groups, axis=1)
        v = jnp.repeat(v, groups, axis=1)

    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bhsk,bhtk->bhst", q, k).astype(jnp.float32) * scale
    if cache is not None:
        # decode: mask positions beyond the write point
        t = jnp.arange(k.shape[2])
        mask = t[None, None, None, :] <= (cache_pos + jnp.arange(S))[None, None, :, None]
        scores = jnp.where(mask, scores, -1e30)
    elif causal:
        t = jnp.arange(S)
        mask = t[None, :] <= t[:, None]
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bhtk->bhsk", probs, v)
    out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    return out, new_cache


def flash_decode_gqa(p: dict, x: jax.Array, cache: tuple, cache_pos,
                     *, n_heads: int, n_kv_heads: int, cos, sin,
                     mesh, batch_axes: tuple):
    """Decode attention over a *sequence-sharded* KV cache (flash-decoding).

    Baseline GSPMD handles a model-sharded cache by gathering scores or KV
    across the model axis (GBs per step at 32k context).  Here each shard
    computes a partial softmax over its local KV slice and the combine is
    one psum of (out, max, denom) — O(B*H*Dh) bytes instead of O(B*H*S).
    The token's K/V write lands only on the shard owning ``cache_pos``.
    """
    from jax.sharding import PartitionSpec as P

    B, S1, D = x.shape
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)

    ck, cv = cache
    bspec = tuple(batch_axes) if batch_axes else None
    kv_spec = P(bspec, None, "model", None)
    q_spec = P(bspec, None, None, None)
    scalar = P()

    def body(q_, kn, vn, ck_, cv_, pos_):
        i = jax.lax.axis_index("model")
        S_loc = ck_.shape[2]
        local_pos = pos_ - i * S_loc
        in_range = (local_pos >= 0) & (local_pos < S_loc)
        lp = jnp.clip(local_pos, 0, S_loc - 1)
        ck2 = jax.lax.dynamic_update_slice(ck_, kn.astype(ck_.dtype),
                                           (0, 0, lp, 0))
        cv2 = jax.lax.dynamic_update_slice(cv_, vn.astype(cv_.dtype),
                                           (0, 0, lp, 0))
        ck_ = jnp.where(in_range, ck2, ck_)
        cv_ = jnp.where(in_range, cv2, cv_)

        kk, vv = ck_, cv_
        Hkv = kk.shape[1]
        groups = q_.shape[1] // max(Hkv, 1)
        # GQA-native: group the q heads instead of materialising repeated
        # K/V (a repeat gathers+rewrites the whole cache every layer —
        # ~4x cache traffic at groups=4)
        B_, H_, S1_, Dh_ = q_.shape
        qg = q_.reshape(B_, Hkv, groups * S1_, Dh_)
        scale = 1.0 / math.sqrt(Dh_)
        s = jnp.einsum("bhsk,bhtk->bhst", qg, kk).astype(jnp.float32) * scale
        t = i * S_loc + jnp.arange(S_loc)
        mask = t[None, None, None, :] <= pos_
        s = jnp.where(mask, s, -1e30)
        m = s.max(axis=-1)                                  # (B,Hkv,g*S1)
        pr = jnp.exp(s - m[..., None])
        den = pr.sum(axis=-1)
        num = jnp.einsum("bhst,bhtk->bhsk", pr.astype(vv.dtype), vv)
        M = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - M)
        num = jax.lax.psum(num * corr[..., None].astype(num.dtype), "model")
        den = jax.lax.psum(den * corr, "model")
        out = num / jnp.maximum(den, 1e-30)[..., None].astype(num.dtype)
        out = out.reshape(B_, H_, S1_, Dh_)
        return out.astype(q_.dtype), ck_, cv_

    f = jax.shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, q_spec, q_spec, kv_spec, kv_spec, scalar),
        out_specs=(q_spec, kv_spec, kv_spec), check_vma=False)
    out, ck, cv = f(q, k_new, v_new, ck, cv, cache_pos)
    out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    return out, (ck, cv)


# --------------------------------------------------------------------------
# attention (MLA — multi-head latent attention, deepseek-v2 / minicpm3)
# --------------------------------------------------------------------------

def mla_schema(cfg: ModelConfig, layers: int) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    L = (layers,)
    qdim = m.qk_nope_head_dim + m.qk_rope_head_dim
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    sch = {
        # KV compression: d -> latent (+ decoupled rope key)
        "w_dkv": ParamDef(L + (d, m.kv_lora_rank + m.qk_rope_head_dim),
                          ("layers", "embed", None)),
        # latent -> per-head K(nope) and V
        "w_uk": ParamDef(L + (m.kv_lora_rank, h, m.qk_nope_head_dim),
                         ("layers", None, "heads", None)),
        "w_uv": ParamDef(L + (m.kv_lora_rank, h, m.v_head_dim),
                         ("layers", None, "heads", None)),
        "wo": ParamDef(L + (h, m.v_head_dim, d),
                       ("layers", "heads", None, "embed"), scale=out_scale),
    }
    if m.q_lora_rank:
        sch["w_dq"] = ParamDef(L + (d, m.q_lora_rank),
                               ("layers", "embed", "lora"))
        sch["w_uq"] = ParamDef(L + (m.q_lora_rank, h, qdim),
                               ("layers", "lora", "heads", None))
    else:
        sch["wq"] = ParamDef(L + (d, h, qdim),
                             ("layers", "embed", "heads", None))
    return sch


def mla_attention(
    p: dict, x: jax.Array, cos, sin, *, mla: MLAConfig, n_heads: int,
    cache: Optional[jax.Array] = None,    # (B, S_max, lora+rope)
    cache_pos: Optional[jax.Array] = None,
    causal: bool = True,
):
    """MLA in the *absorbed* form: scores are computed in latent space, so
    decode touches only the (B, S, lora+rope) compressed cache."""
    B, S, D = x.shape
    r = mla.qk_rope_head_dim
    if "w_dq" in p:
        q = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
        q = jnp.einsum("bsr,rhk->bhsk", q, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    q_nope, q_rope = q[..., : mla.qk_nope_head_dim], q[..., mla.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, cos[:, : r // 2], sin[:, : r // 2])

    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # (B,S,lora+rope)
    c_lat, k_rope = ckv[..., : mla.kv_lora_rank], ckv[..., mla.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, None], cos[:, : r // 2],
                        sin[:, : r // 2])[:, 0]
    ckv = jnp.concatenate([c_lat, k_rope], axis=-1)

    new_cache = None
    if cache is not None:
        cache = jax.lax.dynamic_update_slice(
            cache, ckv.astype(cache.dtype), (0, cache_pos, 0))
        ckv = cache
        new_cache = cache
    c_lat = ckv[..., : mla.kv_lora_rank]
    k_rope = ckv[..., mla.kv_lora_rank:]

    if cache is None and causal and S >= FLASH_MIN_SEQ:
        # prefill: expand per-head K/V (naive MLA form) + flash attention
        from repro.kernels.flash_attention.ops import flash_attention
        k_nope = jnp.einsum("btr,rhk->bhtk", c_lat, p["w_uk"])
        v = jnp.einsum("btr,rhk->bhtk", c_lat, p["w_uv"])
        kr = jnp.broadcast_to(k_rope[:, None], k_nope.shape[:3] + (r,))
        k_full = jnp.concatenate([k_nope, kr], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad V to the K head-dim for the shared kernel, trim after
        pad = q_full.shape[-1] - v.shape[-1]
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad else v
        out = flash_attention(q_full, k_full, v_p, causal=True)
        out = out[..., : mla.v_head_dim]
        out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
        return out, None

    # absorbed: q' = q_nope @ W_uk -> latent space
    q_lat = jnp.einsum("bhsk,rhk->bhsr", q_nope, p["w_uk"])
    scores = jnp.einsum("bhsr,btr->bhst", q_lat, c_lat) \
        + jnp.einsum("bhsk,btk->bhst", q_rope, k_rope)
    scale = 1.0 / math.sqrt(mla.qk_nope_head_dim + r)
    scores = scores.astype(jnp.float32) * scale
    T = ckv.shape[1]
    if cache is not None:
        t = jnp.arange(T)
        mask = t[None, None, None, :] <= (cache_pos + jnp.arange(S))[None, None, :, None]
        scores = jnp.where(mask, scores, -1e30)
    elif causal:
        t = jnp.arange(S)
        mask = t[None, :] <= t[:, None]
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    # out = probs @ (c_lat @ W_uv)  — absorb into latent, then lift per head
    ctx = jnp.einsum("bhst,btr->bhsr", probs, c_lat)
    out = jnp.einsum("bhsr,rhk->bhsk", ctx, p["w_uv"])
    out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    return out, new_cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig, layers: int, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    L = (layers,)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    sch = {
        "w_up": ParamDef(L + (d, f), ("layers", "embed", "mlp")),
        "w_down": ParamDef(L + (f, d), ("layers", "mlp", "embed"),
                           scale=out_scale),
    }
    if cfg.act == "silu_glu":
        sch["w_gate"] = ParamDef(L + (d, f), ("layers", "embed", "mlp"))
    return sch


def mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        h = h * act_fn(act)(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    else:
        h = act_fn(act)(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
