"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 200 --batch 8 --seq 64 --placement tofa

Composes the whole stack: config -> model -> sharded train step on a local
mesh -> synthetic data -> checkpoint/restart -> heartbeat-driven TOFA
re-placement on simulated node failure.  On the CPU build box this drives
reduced configs end-to-end (the ~100M-class example lives in
examples/quickstart.py); on a real pod the same driver takes full configs.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import reduced as reduce_cfg
from repro.configs.registry import ARCHS, get_arch
from repro.models import model as M
from repro.parallel.sharding import ShardingCtx
from repro.train.checkpoint import (latest_checkpoint, restore_checkpoint,
                                    save_checkpoint)
from repro.train.data import SyntheticDataset, extra_inputs
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step


def build_mesh(spec: str | None):
    """'dxm' (e.g. '2x4') over the local devices, or None for single-dev."""
    if not spec:
        return None
    d, m = (int(x) for x in spec.split("x"))
    devs = jax.devices()
    if d * m > len(devs):
        raise SystemExit(f"mesh {spec} needs {d*m} devices, "
                         f"have {len(devs)} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={d*m})")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[: d * m]).reshape(d, m), ("data", "model"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--moe-impl", default="replicated")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = build_mesh(args.mesh)
    ctx = ShardingCtx(mesh=mesh, moe_impl=args.moe_impl)

    params = M.init(cfg, jax.random.key(args.seed))
    if mesh is not None:
        shardings = ctx.param_shardings(M.schema(cfg))
        params = jax.tree.map(jax.device_put, params, shardings)
    opt = AdamW(lr=args.lr, warmup_steps=10)
    opt_state = opt.init(params)

    start_step = 0
    if args.resume and args.checkpoint_dir:
        path = latest_checkpoint(args.checkpoint_dir)
        if path:
            restored = restore_checkpoint(path, params, opt_state)
            params, opt_state = restored["params"], restored["opt"]
            start_step = restored["step"]
            print(f"resumed from {path} at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt, ctx))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)
    extras = extra_inputs(cfg, args.batch, seq_len=args.seq)

    t0 = time.time()
    tokens_seen = 0
    for step in range(start_step, args.steps):
        batch = ds.batch(step)
        batch.update(extras)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        tokens_seen += args.batch * args.seq
        if (step + 1) % args.log_every == 0 or step == start_step:
            dt = time.time() - t0
            print(f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {tokens_seen / max(dt, 1e-9):,.0f}")
        if args.checkpoint_dir and (step + 1) % args.checkpoint_every == 0:
            p = save_checkpoint(args.checkpoint_dir, step + 1, params,
                                opt_state)
            print(f"checkpointed -> {p}")
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
