import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (the two lines above MUST run before any jax-importing module: jax locks
# the host platform device count at first initialisation)

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, shape_cells            # noqa: E402
from repro.configs.registry import ARCHS, get_arch            # noqa: E402
from repro.core.profiler import profile_hlo                   # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.roofline import (HBM_BW, Roofline,  # noqa: E402
                                   ideal_attention_bytes, model_flops_for,
                                   placement_terms)
from repro.models import model as M                           # noqa: E402
from repro.models.layers import abstract_params               # noqa: E402
from repro.parallel.sharding import ShardingCtx               # noqa: E402
from repro.serve.decode import decode_step                    # noqa: E402
from repro.serve.kvcache import abstract_cache, cache_schema  # noqa: E402
from repro.train.data import input_specs                      # noqa: E402
from repro.train.optimizer import AdamW, AdamWState           # noqa: E402
from repro.train.train_step import make_train_step            # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. jits the cell's step function (train_step / forward-prefill /
     decode_step) with explicit in/out shardings over ShapeDtypeStruct
     stand-ins — no arrays are ever allocated;
  3. ``.lower().compile()`` — any sharding mismatch, unsupported
     collective, or spec bug fails HERE, which is the point;
  4. prints ``memory_analysis()`` (does it fit per-device HBM?),
     ``cost_analysis()``, the loop-corrected profiler numbers, the three
     roofline terms, and the placement-aware hop-bytes (linear vs TOFA).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out dryrun.json
"""

V5E_HBM = 16e9  # bytes per chip


def _metric_shardings(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def build_cell(cfg, shape_cfg, mesh, *, moe_impl="replicated", remat=True,
               state_dtype=jnp.float32, param_dtype=jnp.bfloat16,
               rules_override=None, pad_shard_axes=(), flash_decode=False,
               layout="tp"):
    """-> (fn, example_args, in_shardings, out_shardings)"""
    from repro.parallel.sharding import LAYOUTS
    ctx = ShardingCtx(mesh=mesh, moe_impl=moe_impl, remat=remat,
                      pad_shard_axes=tuple(pad_shard_axes),
                      flash_decode=flash_decode,
                      rules=dict(LAYOUTS[layout]))
    if rules_override:
        ctx.rules.update(rules_override)
    sch = M.schema(cfg)
    params = abstract_params(sch, dtype=param_dtype)
    params_sh = ctx.param_shardings(sch)
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    tok_sh = NamedSharding(mesh, ctx.spec_for(("batch", "seq"), (B, S)))

    if shape_cfg.kind == "train":
        opt = AdamW(state_dtype=state_dtype)
        step_fn = make_train_step(cfg, opt, ctx)
        opt_abs = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape,
                                                          state_dtype),
                           params),
            v=jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape,
                                                          state_dtype),
                           params))
        opt_sh = AdamWState(step=NamedSharding(mesh, P()),
                            m=params_sh, v=params_sh)
        batch = input_specs(cfg, shape_cfg, dtype=param_dtype)
        batch_sh = {k: tok_sh if v.ndim == 2 else NamedSharding(
            mesh, ctx.spec_for(("batch", "seq", "act_embed"), v.shape))
            for k, v in batch.items()}
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P()),
                      "step": NamedSharding(mesh, P())}
        return (step_fn, (params, opt_abs, batch),
                (params_sh, opt_sh, batch_sh),
                (params_sh, opt_sh, metrics_sh))

    if shape_cfg.kind == "prefill":
        def fwd(p, b):
            return M.forward(cfg, p, b, ctx)
        batch = input_specs(cfg, shape_cfg, dtype=param_dtype)
        batch_sh = {k: tok_sh if v.ndim == 2 else NamedSharding(
            mesh, ctx.spec_for(("batch", "seq", "act_embed"), v.shape))
            for k, v in batch.items()}
        logits_sh = NamedSharding(
            mesh, ctx.spec_for(("batch", "seq", "vocab"),
                               (B, S, cfg.vocab)))
        return fwd, (params, batch), (params_sh, batch_sh), logits_sh

    # decode: one new token against a seq_len-deep cache
    src_len = cfg.n_vision_tokens if cfg.family == "vlm" else \
        (cfg.n_audio_frames or 512 if cfg.family == "encdec" else None)
    caches = abstract_cache(cfg, B, S, dtype=param_dtype, src_len=src_len)
    csch = cache_schema(cfg, B, S, src_len=src_len)
    caches_sh = ctx.param_shardings(csch)

    def dec(p, c, tok, pos):
        return decode_step(cfg, p, c, tok, pos, ctx)

    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tok1_sh = NamedSharding(mesh, ctx.spec_for(("batch", None), (B, 1)))
    pos_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(
        mesh, ctx.spec_for(("batch", None, "vocab"), (B, 1, cfg.vocab)))
    return (dec, (params, caches, tok, pos),
            (params_sh, caches_sh, tok1_sh, pos_sh),
            (logits_sh, caches_sh))


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             placement_analysis: bool = True, verbose: bool = True,
             **build_kw) -> dict:
    cfg = get_arch(arch)
    shape_cfg = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    t0 = time.time()
    fn, args, in_sh, out_sh = build_cell(cfg, shape_cfg, mesh, **build_kw)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    prof = profile_hlo(hlo)

    per_dev_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    rf = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_dev,
        flops=prof.flops, bytes_accessed=prof.bytes_accessed,
        collective_bytes=prof.collective_bytes,
        xla_flops=float(ca.get("flops", 0.0)),
        model_flops=model_flops_for(cfg, shape_cfg, n_dev))

    row = rf.row()
    # kernel-adjusted memory term: the Pallas flash/SSD kernels keep their
    # block intermediates in VMEM; substitute ideal q/k/v/o traffic for the
    # HLO-tagged reference-path traffic (see roofline.ideal_attention_bytes)
    tagged = sum(prof.bytes_by_tag.values())
    bpd = shape_cfg.global_batch
    for ax in ("pod", "data"):
        if ax in mesh.shape and bpd % mesh.shape[ax] == 0:
            bpd //= mesh.shape[ax]
    hpd = cfg.n_heads or 1
    if "model" in mesh.shape and hpd and hpd % mesh.shape["model"] == 0:
        hpd //= mesh.shape["model"]
    ideal = ideal_attention_bytes(cfg, shape_cfg, bpd, hpd)
    mem_kernel_s = max(prof.bytes_accessed - tagged + ideal, 0.0) / HBM_BW
    row.update({
        "ok": True,
        "bytes_tagged_kernelizable": tagged,
        "bytes_kernel_ideal": ideal,
        "memory_s_kernel": mem_kernel_s,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "arg_bytes_per_dev": ma.argument_size_in_bytes,
        "temp_bytes_per_dev": ma.temp_size_in_bytes,
        "total_bytes_per_dev": per_dev_bytes,
        "fits_hbm": bool(per_dev_bytes <= V5E_HBM),
        "collectives_by_kind": prof.collective_bytes_by_kind(),
        "moe_impl": build_kw.get("moe_impl", "replicated"),
    })
    if placement_analysis:
        try:
            pt = placement_terms(prof, multi_pod)
            if pt:
                row["placement"] = {k: {"hop_bytes": v["hop_bytes"],
                                        "avg_dilation": v["avg_dilation"]}
                                    for k, v in pt.items()}
        except Exception as e:  # pragma: no cover
            row["placement_error"] = str(e)

    if verbose:
        print(f"[{arch} x {shape} @ {mesh_name}] "
              f"compile={t_compile:.1f}s "
              f"mem/dev={per_dev_bytes/1e9:.2f}GB "
              f"fits_hbm={row['fits_hbm']} "
              f"compute={rf.compute_s*1e3:.2f}ms "
              f"memory={rf.memory_s*1e3:.2f}ms "
              f"collective={rf.collective_s*1e3:.2f}ms "
              f"mem_kernel={mem_kernel_s*1e3:.2f}ms "
              f"dominant={rf.dominant} "
              f"useful={rf.useful_flops_ratio:.2f} "
              f"roofline={rf.roofline_fraction:.1%}")
        print("  memory_analysis:", ma)
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (ca.get("flops", 0), ca.get("bytes accessed", 0)))
    return row


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="sweep every live (arch x shape) cell")
    ap.add_argument("--multi-pod", choices=("on", "off", "both"),
                    default="off")
    ap.add_argument("--moe-impl", default="replicated",
                    choices=("replicated", "alltoall", "auto"))
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--state-dtype", default="float32",
                    choices=("float32", "bfloat16"))
    ap.add_argument("--pad-heads", action="store_true",
                    help="allow padded head sharding (40 heads over 16 "
                         "shards pads to 48 instead of replicating)")
    ap.add_argument("--flash-decode", action="store_true",
                    help="shard_map flash-decoding over the model-sharded "
                         "KV cache (decode cells)")
    ap.add_argument("--layout", default="tp", choices=("tp", "fsdp"),
                    help="sharding layout: tp (TP+FSDP default) or pure fsdp")
    ap.add_argument("--tag", default=None,
                    help="experiment tag recorded in the output rows")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args()

    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]
    if args.all:
        cells = [(a, s) for a in sorted(ARCHS)
                 for s in shape_cells(get_arch(a))]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    build_kw = dict(moe_impl=args.moe_impl, remat=not args.no_remat,
                    state_dtype=jnp.dtype(args.state_dtype),
                    pad_shard_axes=(("heads", "kv_heads")
                                    if args.pad_heads else ()),
                    flash_decode=args.flash_decode, layout=args.layout)
    failures = 0
    rows = []
    for arch, shape in cells:
        if shape not in shape_cells(get_arch(arch)):
            print(f"[{arch} x {shape}] SKIPPED (cell not live for family)")
            continue
        for mp in pods:
            try:
                row = run_cell(arch, shape, multi_pod=mp, **build_kw)
                if args.tag:
                    row["tag"] = args.tag
                rows.append(row)
            except Exception:
                failures += 1
                print(f"[{arch} x {shape} @ multi_pod={mp}] FAILED")
                traceback.print_exc()
                rows.append({"arch": arch, "shape": shape,
                             "mesh": "2x16x16" if mp else "16x16",
                             "ok": False,
                             "error": traceback.format_exc(limit=1)})
    if args.out:
        with open(args.out, "a") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
    print(f"\n{len(rows) - failures}/{len(rows)} cells compiled OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
