"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute    = HLO_FLOPs / peak_FLOPs            (per device)
    memory     = HLO_bytes / HBM_bw                (per device)
    collective = collective_bytes / link_bw        (per device)

HLO_FLOPs / HLO_bytes come from ``core.profiler`` (loop-corrected — XLA's
``cost_analysis`` counts a scanned body once; see profiler docstring).
The placement-aware term decomposes every collective over the physical
torus under {linear, tofa} device assignment — the paper's objective
surfaced as a roofline quantity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops: float                 # per device, loop-corrected
    bytes_accessed: float        # per device
    collective_bytes: float      # per device
    xla_flops: float             # raw cost_analysis (body-once) for reference
    model_flops: float           # 6ND (train) / 2ND (fwd) per device
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time bound: the max term (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the step would hit: useful compute time over
        the bounding term."""
        bound = self.step_s
        return (self.model_flops / self.peak_flops) / bound if bound else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "devices": self.n_devices,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "xla_flops": self.xla_flops,
        }


def model_flops_for(cfg, shape_cfg, n_devices: int) -> float:
    """Per-device MODEL_FLOPS: 6·N·D for training, 2·N·D forward-only,
    2·N_active·B for one decode step (D = tokens processed)."""
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        total = 6.0 * cfg.n_active_params * tokens
    elif shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        total = 2.0 * cfg.n_active_params * tokens
    else:  # decode: one token per sequence
        total = 2.0 * cfg.n_active_params * shape_cfg.global_batch
    return total / n_devices


def ideal_attention_bytes(cfg, shape_cfg, batch_per_dev: float,
                          heads_per_dev: float) -> float:
    """Per-device HBM bytes of the Pallas flash/SSD kernels for one step.

    The XLA-lowered online-softmax reference writes its block intermediates
    to HBM (the profiler tags that traffic 'flash'/'ssd'); the Pallas TPU
    kernel keeps them in VMEM, touching only q/k/v/o (+ O(S) stats):

      fwd:        (q + k + v + o)           = 4*T*Dh per head
      remat fwd:  + 4*T*Dh
      bwd:        reads q,k,v,dout + writes dq,dk,dv  ~ 8*T*Dh

    -> 16*T*Dh per head per layer for training, 4 for inference.  SSM archs
    use the analogous xdt/dA/B/C/y (+state) ~ 6*T*P per head.
    """
    S = shape_cfg.seq_len if shape_cfg.kind != "decode" else 1
    T = batch_per_dev * S
    dtype_bytes = 2.0
    passes = 16.0 if shape_cfg.kind == "train" else 4.0
    if cfg.family in ("ssm", "hybrid") and cfg.ssm:
        d_in = cfg.ssm.expand * cfg.d_model
        per_layer = passes / 16 * 6 * T * d_in * dtype_bytes
        n_layers = cfg.n_layers
        attn_layers = (cfg.n_layers // (cfg.hybrid_every or 6)
                       if cfg.family == "hybrid" else 0)
        attn = passes * T * cfg.head_dim_ * heads_per_dev * dtype_bytes \
            * attn_layers
        return per_layer * n_layers + attn
    hd = cfg.head_dim_
    n_attn = cfg.n_layers + (cfg.n_enc_layers or 0)
    return passes * T * hd * heads_per_dev * dtype_bytes * n_attn


def placement_terms(profile, multi_pod: bool, policies=("linear", "tofa"),
                    p_f: np.ndarray | None = None) -> dict:
    """Hop-weighted collective cost per placement policy (paper tie-in)."""
    from repro.core.placement import Fabric, compare_policies
    from repro.core.profiler import comm_graph_from_profile

    n = profile.num_partitions
    fabric = Fabric(pod_dims=(16, 16), n_pods=2 if multi_pod else 1)
    if fabric.n_chips != n:
        return {}
    comm = comm_graph_from_profile(profile)
    return compare_policies(comm, fabric, policies=policies, p_f=p_f)
