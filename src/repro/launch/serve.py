"""Serving driver: prefill a batch of requests, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced as reduce_cfg
from repro.configs.registry import ARCHS, get_arch
from repro.models import model as M
from repro.serve.decode import decode_step, encode, prefill_cross_cache
from repro.serve.kvcache import init_cache
from repro.train.data import SyntheticDataset, extra_inputs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = M.init(cfg, jax.random.key(args.seed))
    B, S = args.batch, args.prompt_len
    max_seq = S + args.gen

    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=S, global_batch=B,
                          seed=args.seed)
    batch = ds.batch(0)
    batch.update(extra_inputs(cfg, B, seq_len=S))
    prompts = batch["tokens"]

    src_len = (batch["enc_embed"].shape[1]
               if cfg.family == "encdec" else None)
    caches = init_cache(cfg, B, max_seq, src_len=src_len)
    if cfg.family == "vlm":
        caches["cross"] = prefill_cross_cache(cfg, params,
                                              batch["vision_embed"])
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, batch["enc_embed"])
        caches["cross"] = prefill_cross_cache(cfg, params, enc_out,
                                              which="decoder")
    step = jax.jit(lambda c, t, p: decode_step(cfg, params, c, t, p))

    # prefill token-by-token through the decode path (simple; a production
    # deployment jits the chunked prefill in launch/dryrun.py's prefill fn)
    t0 = time.time()
    logits = None
    for t in range(S):
        logits, caches = step(caches, prompts[:, t:t + 1], jnp.int32(t))
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(tok)
        logits, caches = step(caches, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t_dec = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill: {S} tokens x {B} seqs in {t_prefill:.2f}s")
    print(f"decode:  {args.gen} tokens x {B} seqs in {t_dec:.2f}s "
          f"({args.gen * B / max(t_dec, 1e-9):.1f} tok/s)")
    print("generated token ids (first sequence):",
          [int(x) for x in gen[0]])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
