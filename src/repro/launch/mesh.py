"""Production meshes + TOFA device assignment.

``make_production_mesh`` builds the logical mesh (a FUNCTION, never a
module-level constant — importing this module must not touch jax device
state).  ``make_tofa_mesh`` is `srun --distribution=TOFA` for XLA: it
profiles the compiled step's collectives, runs TOFA against the physical
fabric + node health, and hands ``Mesh`` a permuted device array.  The
compiled program is identical; only which physical chip owns which logical
coordinate changes.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_with_devices(devices, shape, axes):
    """Mesh from an explicit (possibly permuted) device list."""
    import jax
    from jax.sharding import Mesh
    devs = np.asarray(devices).reshape(shape)
    return Mesh(devs, axes)


def make_tofa_mesh(
    hlo_text: str,
    *,
    multi_pod: bool = False,
    p_f: Optional[np.ndarray] = None,
    state=None,
    policy: str = "tofa",
    engine=None,
):
    """Device-permuted production mesh.

    1. ``core.profiler`` extracts the per-shard traffic matrix from the
       compiled HLO (the paper's LoadMatrix input);
    2. the requested registry policy runs through the
       :class:`~repro.core.engine.PlacementEngine` against the v5e fabric
       model (FATT input) and chip health — pass ``state`` (a versioned
       :class:`~repro.core.state.ClusterState` over chips) so repeated
       mesh builds against one health epoch reuse the engine's cached
       fabric matrices; the raw ``p_f`` kwarg remains as a shim;
    3. the permutation is applied to ``jax.devices()``.

    Returns (mesh, DeviceAssignment) — the assignment carries hop-bytes
    before/after for the §Roofline placement term.
    """
    import jax

    from repro.core.engine import default_engine
    from repro.core.placement import Fabric, assign_devices
    from repro.core.profiler import comm_graph_from_hlo

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    fabric = Fabric(pod_dims=(16, 16), n_pods=2 if multi_pod else 1)
    comm = comm_graph_from_hlo(hlo_text, n_devices=n)
    assignment = assign_devices(comm, fabric, policy=policy, p_f=p_f,
                                state=state,
                                engine=engine or default_engine())
    devs = np.asarray(jax.devices()[:n])
    # logical shard k runs on physical chip assignment.permutation[k]; on
    # real hardware jax.devices() is coordinate-ordered, so indexing by
    # chip id == physical position.
    mesh = make_mesh_with_devices(devs[assignment.permutation], shape, axes)
    return mesh, assignment
