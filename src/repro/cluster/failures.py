"""Fault injection models: per-attempt scenarios and time-based processes.

Two layers, consumed by different simulators:

**Per-attempt models** (:class:`FailureModel`) — the paper's Section 5.2
semantics: a fixed candidate set ``N_f`` of nodes each enters the failed
state independently with probability ``p_f`` *per simulated scenario*
(= per job attempt).  A failed node can neither compute nor forward
traffic; restart is instantaneous; no checkpointing.  The draw is local
to one attempt — it does not change cluster state for other jobs.  Used
by :func:`repro.sim.batchsim.run_batch` and by the event simulator's
paper-equivalence mode.

**Time-based processes** (:class:`FailureProcess`) — beyond-paper node
*lifecycles* over continuous simulated time: a node is UP until its
lifetime expires, DOWN until repaired, and so on.  ``generate`` expands a
process into a sorted trace of :class:`NodeEvent` (fail/repair, possibly
correlated across a rack) that the event simulator replays as FAILURE /
RECOVER heap events; a mid-run failure aborts every job whose placement
holds the node.  Lifetime distributions follow the LANL-trace analysis
the paper cites [34]: exponential and Weibull (shape < 1 ==
infant-mortality-heavy).

All times are simulated seconds; every stochastic draw takes an explicit
``numpy.random.Generator`` so traces are reproducible from a seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np


class FailureModel:
    def sample_failed(self, rng: np.random.Generator, duration: float
                      ) -> np.ndarray:
        """Node ids in the failed state for one job instance."""
        raise NotImplementedError


@dataclasses.dataclass
class NoFailures(FailureModel):
    def sample_failed(self, rng, duration) -> np.ndarray:
        return np.array([], dtype=np.int64)


@dataclasses.dataclass
class BernoulliPerJob(FailureModel):
    """The paper's model: each candidate fails w.p. ``p_f`` per instance."""

    candidates: np.ndarray
    p_f: float

    def sample_failed(self, rng, duration) -> np.ndarray:
        cand = np.asarray(self.candidates)
        mask = rng.random(len(cand)) < self.p_f
        return cand[mask]

    def outage_vector(self, n_nodes: int) -> np.ndarray:
        """Ground-truth p_f vector (what a converged heartbeat estimator
        reports to the placement policy)."""
        p = np.zeros(n_nodes)
        p[np.asarray(self.candidates)] = self.p_f
        return p


@dataclasses.dataclass
class WeibullArrival(FailureModel):
    """Failures arrive per node as a Weibull renewal process (shape < 1:
    infant-mortality-heavy, per LANL data); a node hit during the job's
    window is failed for that instance."""

    candidates: np.ndarray
    mtbf: float            # mean time between failures per candidate node
    shape: float = 0.7

    def sample_failed(self, rng, duration) -> np.ndarray:
        cand = np.asarray(self.candidates)
        # P(>=1 failure within the job window) for the renewal process;
        # exponential bound is exact for shape == 1 and a good approximation
        # in the duration << mtbf regime the simulator operates in
        p = 1.0 - np.exp(-(duration / self.mtbf) ** self.shape)
        mask = rng.random(len(cand)) < p
        return cand[mask]

    def outage_vector(self, n_nodes: int) -> np.ndarray:
        p = np.zeros(n_nodes)
        p[np.asarray(self.candidates)] = min(1.0, 1.0 / max(self.mtbf, 1e-9))
        return p


# --------------------------------------------------------------------------
# Time-based failure processes (event-simulator layer)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeEvent:
    """One state transition in a failure trace.

    ``kind`` is ``"fail"`` or ``"repair"``; ``nodes`` is the (possibly
    correlated) group that transitions together at ``time`` seconds.
    """

    time: float
    kind: str
    nodes: tuple[int, ...]


class FailureProcess:
    """Base: a generator of node fail/repair traces over [0, horizon]."""

    def generate(self, rng: np.random.Generator, horizon: float
                 ) -> list[NodeEvent]:
        """Sorted fail/repair events up to ``horizon`` (exclusive).

        The trace is *open-loop*: it does not know what the simulator does
        with the nodes.  A ``fail`` for a node already down (e.g. a rack
        outage overlapping a node outage) is legal; the simulator treats
        node state as a counter, not a boolean.
        """
        raise NotImplementedError

    def expected_p_f(self, n_nodes: int) -> np.ndarray:
        """Steady-state per-node unavailability (fraction of time down) —
        what a long-converged heartbeat estimator would report.  Used by
        scenarios that hand the scheduler the ground truth instead of
        simulating heartbeat convergence."""
        raise NotImplementedError


def _renewal_trace(rng: np.random.Generator, node: int, horizon: float,
                   draw_life, draw_repair) -> list[NodeEvent]:
    """Alternating up/down renewal sequence for one node."""
    out: list[NodeEvent] = []
    t = float(draw_life(rng))
    while t < horizon:
        out.append(NodeEvent(t, "fail", (node,)))
        if draw_repair is None:           # permanent failure
            break
        t += float(draw_repair(rng))
        if t >= horizon:
            break
        out.append(NodeEvent(t, "repair", (node,)))
        t += float(draw_life(rng))
    return out


class _RenewalLifetimes(FailureProcess):
    """Shared machinery for per-node alternating-renewal lifecycles.

    Subclasses are dataclasses declaring ``candidates``, ``mtbf`` and
    ``mttr`` (``None`` = permanent failures) and implement ``_draw_life``
    — the up-time distribution.  Repairs are exponential with mean
    ``mttr``; steady-state unavailability is ``mttr / (mtbf + mttr)``.
    """

    def _draw_life(self, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def generate(self, rng, horizon) -> list[NodeEvent]:
        rep = None if self.mttr is None else (
            lambda r: r.exponential(self.mttr))
        out: list[NodeEvent] = []
        for node in np.asarray(self.candidates, dtype=np.int64):
            out += _renewal_trace(rng, int(node), horizon,
                                  self._draw_life, rep)
        return sorted(out, key=lambda e: e.time)

    def expected_p_f(self, n_nodes) -> np.ndarray:
        p = np.zeros(n_nodes)
        frac = (1.0 if self.mttr is None
                else self.mttr / (self.mtbf + self.mttr))
        p[np.asarray(self.candidates, dtype=np.int64)] = frac
        return p


@dataclasses.dataclass
class ExponentialLifetimes(_RenewalLifetimes):
    """Memoryless per-node lifetimes: up ~ Exp(``mtbf``), down ~
    Exp(``mttr``); ``mttr=None`` makes failures permanent."""

    candidates: Sequence[int]
    mtbf: float                         # mean time between failures, seconds
    mttr: Optional[float] = None        # mean time to repair; None = no repair

    def _draw_life(self, rng) -> float:
        return rng.exponential(self.mtbf)


@dataclasses.dataclass
class WeibullLifetimes(_RenewalLifetimes):
    """Weibull per-node lifetimes with mean ``mtbf`` and shape ``shape``
    (< 1 == infant-mortality-heavy, the LANL-trace regime [34]); repairs
    are exponential with mean ``mttr``."""

    candidates: Sequence[int]
    mtbf: float
    shape: float = 0.7
    mttr: Optional[float] = None

    def __post_init__(self):
        if self.shape <= 0:
            raise ValueError(f"Weibull shape must be > 0, got {self.shape}")

    @property
    def scale(self) -> float:
        """Weibull scale lambda such that the mean equals ``mtbf``."""
        return self.mtbf / math.gamma(1.0 + 1.0 / self.shape)

    def _draw_life(self, rng) -> float:
        return self.scale * rng.weibull(self.shape)


@dataclasses.dataclass
class CorrelatedOutages(FailureProcess):
    """Rack/switch-level outages: whole node groups fail and repair
    together — the shared-PDU / top-of-rack-switch failure mode that
    per-node models cannot express.  Per group, an alternating renewal:
    up-time to the next outage ~ Exp(``mtbf``) measured from the previous
    repair, outage duration ~ Exp(``mttr``) (mean cycle ``mtbf + mttr``,
    steady-state unavailability ``mttr / (mtbf + mttr)``; outages never
    overlap within a group)."""

    groups: Sequence[Sequence[int]]
    mtbf: float
    mttr: float

    def generate(self, rng, horizon) -> list[NodeEvent]:
        out: list[NodeEvent] = []
        for grp in self.groups:
            nodes = tuple(int(x) for x in np.asarray(grp, dtype=np.int64))
            t = float(rng.exponential(self.mtbf))
            while t < horizon:
                out.append(NodeEvent(t, "fail", nodes))
                dt = float(rng.exponential(self.mttr))
                if t + dt < horizon:
                    out.append(NodeEvent(t + dt, "repair", nodes))
                t += dt + float(rng.exponential(self.mtbf))
        return sorted(out, key=lambda e: e.time)

    def expected_p_f(self, n_nodes) -> np.ndarray:
        p = np.zeros(n_nodes)
        frac = self.mttr / (self.mtbf + self.mttr)
        for grp in self.groups:
            p[np.asarray(grp, dtype=np.int64)] = frac
        return p


@dataclasses.dataclass
class CascadingOutages(FailureProcess):
    """Cascading rack failures: an outage spreads to adjacent racks.

    Seed outages follow the :class:`CorrelatedOutages` renewal per group
    (up-time ~ Exp(``mtbf``) from the previous repair, outage duration ~
    Exp(``mttr``)), but every outage — seed or induced — additionally
    *cascades*: each adjacent group (neighbours in ``groups`` list order,
    the shared-aisle/PDU adjacency of contiguous racks) fails with
    probability ``spread_p`` after an Exp(``spread_delay``) lag.  Induced
    outages repair after Exp(``mttr``) and can cascade onward; within one
    cascade tree each group fails at most once, so trees terminate.

    ``seed_groups`` restricts *spontaneous* outages to the given group
    indices (default: all groups seed) — the others only ever fail by
    contagion, which is the stress case for a fault-aware scheduler whose
    belief covers the flaky racks but not their healthy-looking
    neighbours.
    """

    groups: Sequence[Sequence[int]]
    mtbf: float
    mttr: float
    spread_p: float = 0.5
    spread_delay: float = 0.1
    seed_groups: Optional[Sequence[int]] = None

    def __post_init__(self):
        if not (0.0 <= self.spread_p <= 1.0):
            raise ValueError(f"spread_p must be in [0, 1], got {self.spread_p}")
        if self.spread_delay <= 0 or self.mttr <= 0 or self.mtbf <= 0:
            raise ValueError("mtbf, mttr and spread_delay must be > 0")

    def generate(self, rng, horizon) -> list[NodeEvent]:
        nodes = [tuple(int(x) for x in np.asarray(g, dtype=np.int64))
                 for g in self.groups]
        n_groups = len(nodes)
        seeds = (range(n_groups) if self.seed_groups is None
                 else [int(s) for s in self.seed_groups])
        out: list[NodeEvent] = []

        def emit(gi: int, t: float) -> float:
            """One outage of group ``gi`` at ``t``; returns repair time."""
            out.append(NodeEvent(t, "fail", nodes[gi]))
            dt = float(rng.exponential(self.mttr))
            if t + dt < horizon:
                out.append(NodeEvent(t + dt, "repair", nodes[gi]))
            return t + dt

        def cascade(gi: int, t: float, visited: set[int]) -> None:
            """Spread from an outage of ``gi`` at ``t`` to its neighbours
            (FIFO over the adjacency, deterministic draw order)."""
            frontier = [(gi, t)]
            while frontier:
                g0, t0 = frontier.pop(0)
                for nb in (g0 - 1, g0 + 1):
                    if nb < 0 or nb >= n_groups or nb in visited:
                        continue
                    if rng.random() >= self.spread_p:
                        continue
                    visited.add(nb)
                    t1 = t0 + float(rng.exponential(self.spread_delay))
                    if t1 >= horizon:
                        continue
                    emit(nb, t1)
                    frontier.append((nb, t1))

        # deterministic draw order: group-major over seeds, then each seed
        # outage's full cascade tree before the next outage of that seed
        for gi in seeds:
            t = float(rng.exponential(self.mtbf))
            while t < horizon:
                repaired = emit(gi, t)
                cascade(gi, t, {gi})
                t = repaired + float(rng.exponential(self.mtbf))
        return sorted(out, key=lambda e: e.time)

    def expected_p_f(self, n_nodes) -> np.ndarray:
        """Steady-state unavailability, one-hop cascade approximation:
        a group's outage rate is its own seed rate plus ``spread_p`` times
        each neighbouring seed's rate (deeper contagion terms dropped)."""
        n_groups = len(self.groups)
        seeds = (set(range(n_groups)) if self.seed_groups is None
                 else set(int(s) for s in self.seed_groups))
        lam_seed = 1.0 / self.mtbf
        p = np.zeros(n_nodes)
        for gi, grp in enumerate(self.groups):
            lam = lam_seed if gi in seeds else 0.0
            lam += self.spread_p * lam_seed * sum(
                1 for nb in (gi - 1, gi + 1)
                if 0 <= nb < n_groups and nb in seeds)
            frac = (lam * self.mttr) / (1.0 + lam * self.mttr)
            p[np.asarray(grp, dtype=np.int64)] = frac
        return p


@dataclasses.dataclass
class MaintenanceWindow(FailureProcess):
    """A scheduled maintenance drain: ``nodes`` leave service at ``start``
    and return at ``start + duration`` — one deterministic fail/repair
    pair (no RNG draw), so the window composes with stochastic processes
    without perturbing their draw order.  Jobs running on the nodes at
    ``start`` are aborted, exactly like a real drain deadline expiring.
    """

    nodes: Sequence[int]
    start: float
    duration: float

    def __post_init__(self):
        if self.start < 0 or self.duration <= 0:
            raise ValueError(
                f"need start >= 0 and duration > 0, got ({self.start}, "
                f"{self.duration})")

    def generate(self, rng, horizon) -> list[NodeEvent]:
        nodes = tuple(int(x) for x in np.asarray(self.nodes, dtype=np.int64))
        out: list[NodeEvent] = []
        if self.start < horizon:
            out.append(NodeEvent(self.start, "fail", nodes))
            end = self.start + self.duration
            if end < horizon:
                out.append(NodeEvent(end, "repair", nodes))
        return out

    def expected_p_f(self, n_nodes) -> np.ndarray:
        # a planned window is not a hazard the estimator should bake into
        # p_f; lifecycle (DRAINED/DOWN) carries it instead
        return np.zeros(n_nodes)


@dataclasses.dataclass
class CompositeProcess(FailureProcess):
    """Superposition of several processes (e.g. per-node Weibull churn +
    rack-level correlated outages) merged into one sorted trace."""

    processes: Sequence[FailureProcess]

    def generate(self, rng, horizon) -> list[NodeEvent]:
        out: list[NodeEvent] = []
        for p in self.processes:
            out += p.generate(rng, horizon)
        return sorted(out, key=lambda e: e.time)

    def expected_p_f(self, n_nodes) -> np.ndarray:
        # union bound on unavailability, clamped — processes overlap rarely
        # in the regimes the scenarios use
        p = np.zeros(n_nodes)
        for proc in self.processes:
            p = 1.0 - (1.0 - p) * (1.0 - proc.expected_p_f(n_nodes))
        return p


def contiguous_racks(n_nodes: int, rack_size: int) -> list[np.ndarray]:
    """Partition node ids into contiguous racks of ``rack_size``.

    Node ids follow resource-manager order in every in-tree topology
    (torus row-major, fat-tree (pod, edge, host)), so contiguous id
    blocks are physically co-located — a contiguous slice is the natural
    rack/chassis unit for correlated outages."""
    if rack_size <= 0:
        raise ValueError(f"rack_size must be positive, got {rack_size}")
    ids = np.arange(n_nodes)
    return [ids[i:i + rack_size] for i in range(0, n_nodes, rack_size)]
