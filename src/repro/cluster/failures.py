"""Fault injection models for batch simulation.

The paper's model (Section 5.2): a fixed candidate set ``N_f`` of nodes each
enters the failed state independently with probability ``p_f`` *per
simulated scenario* (= per job instance).  A failed node can neither compute
nor forward traffic; restart is instantaneous; no checkpointing.

``WeibullArrival`` is a beyond-paper model in which failures arrive as a
renewal process over continuous time (the LANL-trace shape cited by the
paper [34]) so exposure scales with job duration.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class FailureModel:
    def sample_failed(self, rng: np.random.Generator, duration: float
                      ) -> np.ndarray:
        """Node ids in the failed state for one job instance."""
        raise NotImplementedError


@dataclasses.dataclass
class NoFailures(FailureModel):
    def sample_failed(self, rng, duration) -> np.ndarray:
        return np.array([], dtype=np.int64)


@dataclasses.dataclass
class BernoulliPerJob(FailureModel):
    """The paper's model: each candidate fails w.p. ``p_f`` per instance."""

    candidates: np.ndarray
    p_f: float

    def sample_failed(self, rng, duration) -> np.ndarray:
        cand = np.asarray(self.candidates)
        mask = rng.random(len(cand)) < self.p_f
        return cand[mask]

    def outage_vector(self, n_nodes: int) -> np.ndarray:
        """Ground-truth p_f vector (what a converged heartbeat estimator
        reports to the placement policy)."""
        p = np.zeros(n_nodes)
        p[np.asarray(self.candidates)] = self.p_f
        return p


@dataclasses.dataclass
class WeibullArrival(FailureModel):
    """Failures arrive per node as a Weibull renewal process (shape < 1:
    infant-mortality-heavy, per LANL data); a node hit during the job's
    window is failed for that instance."""

    candidates: np.ndarray
    mtbf: float            # mean time between failures per candidate node
    shape: float = 0.7

    def sample_failed(self, rng, duration) -> np.ndarray:
        cand = np.asarray(self.candidates)
        # P(>=1 failure within the job window) for the renewal process;
        # exponential bound is exact for shape == 1 and a good approximation
        # in the duration << mtbf regime the simulator operates in
        p = 1.0 - np.exp(-(duration / self.mtbf) ** self.shape)
        mask = rng.random(len(cand)) < p
        return cand[mask]

    def outage_vector(self, n_nodes: int) -> np.ndarray:
        p = np.zeros(n_nodes)
        p[np.asarray(self.candidates)] = min(1.0, 1.0 / max(self.mtbf, 1e-9))
        return p
