"""FANS — Fault Aware Node Selection: the Slurm-integration layer.

Composes the pieces the paper wires into Slurm as five plugins:

* ``NodeRegistry``      <- FATT topology plugin (coords + routing input)
* ``HeartbeatMonitor``  <- Fault Aware Slurmctld + per-node NodeState
* ``Job.comm``          <- LoadMatrix plugin (the profiled communication
                           graph travels with the job submission)
* ``Scheduler.submit``  <- srun --distribution={linear,random,greedy,topo,
                           tofa,...}; FANS builds a PlacementRequest and the
                           shared PlacementEngine overrides the default task
                           layout

The scheduler owns one :class:`~repro.core.engine.PlacementEngine`, so hop
and Eq. 1 weight matrices are derived once per (topology, health) state
instead of once per submission.  Beyond the paper, it also supports
*draining* (administratively removing nodes whose estimated outage crosses
a threshold) and *elastic re-placement*: when a running job's node goes
down, ``engine.replace`` moves only the displaced processes onto surviving
healthy nodes and the job restarts (from the latest checkpoint if the
checkpoint model is enabled in the batch simulator).
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.cluster.heartbeat import HeartbeatMonitor, MovingAverage
from repro.cluster.nodes import NodeRegistry, NodeState
from repro.core.engine import PlacementEngine, PlacementPlan, PlacementRequest
from repro.core.topology import TorusTopology
from repro.sim.jobsim import successful_runtime
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import Workload

_job_ids = itertools.count(1)


@dataclasses.dataclass
class Job:
    workload: Workload
    distribution: str = "tofa"          # srun --distribution=
    job_id: int = dataclasses.field(default_factory=lambda: next(_job_ids))


@dataclasses.dataclass
class JobRecord:
    job: Job
    placement: PlacementPlan
    state: str = "pending"              # pending | running | done | failed
    runtime: float = 0.0
    restarts: int = 0


class Scheduler:
    """slurmctld with the TOFA plugin set."""

    def __init__(
        self,
        topo: TorusTopology,
        net: TorusNetwork | None = None,
        estimator=None,
        drain_threshold: float = 0.5,
        seed: int = 0,
        engine: PlacementEngine | None = None,
    ):
        self.registry = NodeRegistry(topo)
        self.topo = topo
        self.net = net or TorusNetwork(topo)
        self.monitor = HeartbeatMonitor(topo.n_nodes,
                                        estimator or MovingAverage())
        self.drain_threshold = drain_threshold
        self.rng = np.random.default_rng(seed)
        self.engine = engine or PlacementEngine()
        self.records: dict[int, JobRecord] = {}
        self.queue: list[Job] = []

    # -------------------------------------------------------------- health
    def heartbeat_round(self, replies: np.ndarray,
                        latencies: np.ndarray | None = None) -> None:
        self.monitor.poll(replies, latencies)
        p = self.monitor.outage_probabilities()
        for i in np.flatnonzero(p >= self.drain_threshold):
            if self.registry[int(i)].state == NodeState.UP:
                self.registry.mark([int(i)], NodeState.DRAINED)

    def estimated_outage(self) -> np.ndarray:
        """p_f as FANS sees it: heartbeat estimate, drained nodes pinned."""
        p = self.monitor.outage_probabilities()
        for n in self.registry.nodes:
            if n.state != NodeState.UP:
                p[n.node_id] = 1.0
        return p

    # ---------------------------------------------------------- placement
    def placement_request(self, job: Job) -> PlacementRequest:
        """FANS inputs: G from LoadMatrix, H from FATT, p_f from the
        heartbeat history, availability from the node registry."""
        return PlacementRequest(
            comm=job.workload.comm,
            topology=self.topo,
            p_f=self.estimated_outage(),
            available=self.registry.up_ids(),
        )

    def select_nodes_for(self, job: Job) -> PlacementPlan:
        return self.engine.place(self.placement_request(job),
                                 policy=job.distribution, rng=self.rng)

    # ------------------------------------------------------------- running
    def submit(self, job: Job) -> JobRecord:
        plan = self.select_nodes_for(job)
        rec = JobRecord(job=job, placement=plan, state="running",
                        runtime=successful_runtime(job.workload,
                                                   plan.placement, self.net))
        self.records[job.job_id] = rec
        return rec

    def handle_node_failure(self, node_ids) -> list[JobRecord]:
        """Elastic re-placement (beyond paper): nodes went down; any running
        job touching them is incrementally re-placed on surviving nodes —
        only the displaced processes move — and restarted."""
        node_ids = [int(x) for x in np.atleast_1d(node_ids)]
        self.registry.mark(node_ids, NodeState.DOWN)
        replaced = []
        for rec in self.records.values():
            if rec.state != "running":
                continue
            used = set(int(x) for x in rec.placement.placement)
            if used & set(node_ids):
                # pass the *current* registry/heartbeat view — the plan's
                # request carries the submit-time snapshot, stale once other
                # nodes failed or drained after submission
                rec.placement = self.engine.replace(
                    rec.placement, node_ids, rng=self.rng,
                    p_f=self.estimated_outage(),
                    available=self.registry.up_ids())
                rec.restarts += 1
                rec.runtime = successful_runtime(rec.job.workload,
                                                 rec.placement.placement,
                                                 self.net)
                replaced.append(rec)
        return replaced

    def complete(self, job_id: int) -> None:
        self.records[job_id].state = "done"
