"""FANS — Fault Aware Node Selection: the Slurm-integration layer.

Composes the pieces the paper wires into Slurm as five plugins:

* ``NodeRegistry``      <- FATT topology plugin (coords + routing input)
* ``HeartbeatMonitor``  <- Fault Aware Slurmctld + per-node NodeState
* ``Job.comm``          <- LoadMatrix plugin (the profiled communication
                           graph travels with the job submission)
* ``Scheduler.submit``  <- srun --distribution={linear,random,greedy,topo,
                           tofa,...}; FANS builds a PlacementRequest and the
                           shared PlacementEngine overrides the default task
                           layout

The scheduler is the **owner of the cluster's health state**: it merges
the registry's administrative lifecycle (UP / DEGRADED / DRAINED / DOWN)
with the heartbeat monitor's outage estimates into one versioned
:class:`~repro.core.state.ClusterState` snapshot
(:meth:`Scheduler.cluster_state`).  A new epoch is minted **only when
health actually changes** — lifecycle transitions or an estimate moving
beyond ``p_f_atol`` (or flipping the ``p_f > 0`` pattern Eq. 1
consults) — so estimator jitter between heartbeat rounds never produces
a fresh engine cache key, and thousands of placements against a stable
cluster stay warm.  Placement requests carry the snapshot plus a cheap
*overlay* masking nodes allocated to running jobs.

Beyond the paper, the scheduler also supports *degrading* (a flaky node
whose estimate crosses ``degraded_threshold`` stays allocatable but is
marked DEGRADED so Eq. 1 steers placements around it), *draining*
(administratively removing nodes whose estimated outage crosses
``drain_threshold``, with hysteresis so recovered nodes return to
service) and *elastic re-placement*: when a running job's node goes
down, ``engine.replace`` moves only the displaced processes onto
surviving healthy nodes and the job restarts (from the latest checkpoint
if the checkpoint model is enabled in the simulator).

**Queueing.**  Nodes are allocated exclusively per running job (Slurm's
default exclusive node allocation).  ``submit`` enqueues; the pending
queue is drained FIFO against free allocatable capacity whenever
capacity changes (submit / complete / recover / undrain).  With
``backfill=True`` (default) a job behind a blocked queue head may start
early when it fits in currently-free capacity.  This is *greedy*
capacity backfill: the scheduler is clock-free, has no runtime
estimates, and makes no reservations, so — unlike EASY backfill — a
backfilled job *can* delay the blocked head (it holds nodes the head
would have received at the next completion).  Use ``backfill=False`` for
strict FIFO when head-of-line fairness matters more than utilisation.
The simulated-time event loop that drives this queue lives in
:mod:`repro.sim.clustersim`.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from repro.cluster.heartbeat import HeartbeatMonitor, MovingAverage
from repro.cluster.nodes import NodeRegistry, NodeState
from repro.core.engine import PlacementEngine, PlacementPlan, PlacementRequest
from repro.core.state import ClusterState
from repro.core.topology import TorusTopology
from repro.sim.jobsim import successful_runtime
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import Workload

_job_ids = itertools.count(1)


@dataclasses.dataclass
class Job:
    workload: Workload
    distribution: str = "tofa"          # srun --distribution=
    job_id: int = dataclasses.field(default_factory=lambda: next(_job_ids))


@dataclasses.dataclass
class JobRecord:
    job: Job
    placement: Optional[PlacementPlan] = None   # None while pending
    state: str = "pending"              # pending | running | done | failed
    runtime: float = 0.0
    restarts: int = 0
    requeues: int = 0                   # times bounced back to the queue
    enqueue_time: float = 0.0           # scheduler clock at enqueue
    start_time: float = -1.0            # scheduler clock at first start


class Scheduler:
    """slurmctld with the TOFA plugin set."""

    def __init__(
        self,
        topo: TorusTopology,
        net: TorusNetwork | None = None,
        estimator=None,
        drain_threshold: float = 0.5,
        undrain_threshold: float | None = None,
        degraded_threshold: float | None = None,
        p_f_atol: float = 0.15,
        seed: int = 0,
        engine: PlacementEngine | None = None,
        backfill: bool = True,
        tracker=None,
    ):
        self.registry = NodeRegistry(topo)
        self.topo = topo
        self.net = net or TorusNetwork(topo)
        self.monitor = HeartbeatMonitor(topo.n_nodes,
                                        estimator or MovingAverage())
        self.drain_threshold = drain_threshold
        # hysteresis: a DRAINED node returns to service only once its
        # estimate falls well below the drain trigger (default half)
        self.undrain_threshold = (drain_threshold / 2.0
                                  if undrain_threshold is None
                                  else undrain_threshold)
        # optional middle band: estimates in [degraded_threshold,
        # drain_threshold) mark a node DEGRADED — still allocatable, but
        # its elevated p_f makes Eq. 1 steer placements around it.
        # None (default) disables the band: UP <-> DRAINED only.
        self.degraded_threshold = degraded_threshold
        # belief-staleness bound of the published ClusterState: estimate
        # drift within +-p_f_atol (and an unchanged p_f > 0 pattern)
        # re-uses the current epoch instead of minting a new one.  Every
        # in-tree policy reads only the pattern, so sub-atol drift can
        # never change a placement — it only would have cold-started the
        # engine caches on every heartbeat round.  The 0.15 default is
        # the tightest value at which epochs track genuine failures only
        # under raw monitor jitter (full-mode serving loop: 44 epochs =
        # churn + initial at 0.15/0.25, 47 at 0.1, 107 and an 0.893 hit
        # rate at 0.05 — below the >=95% floor gated in BENCH_state;
        # a learned BeliefTracker's exposure-only drift stays at the
        # floor at every grid point, see benchmarks/belief_sweep.py
        # --atol-sweep); configurable here and through the scenario
        # presets' ``p_f_atol=`` kwarg.
        self.p_f_atol = p_f_atol
        # optional BeliefTracker (repro.beliefs): when attached, the
        # published ClusterState carries the tracker's learned hazard
        # belief instead of the raw heartbeat estimate, and failure /
        # repair events are forwarded so the belief updates online.
        # Drain/degrade decisions stay monitor-driven either way — the
        # tracker only changes what Eq. 1 placements believe.
        self.tracker = tracker
        self.backfill = backfill
        self.rng = np.random.default_rng(seed)
        self.engine = engine or PlacementEngine()
        self.records: dict[int, JobRecord] = {}
        self.queue: list[Job] = []              # pending jobs, FIFO order
        self.allocated: dict[int, np.ndarray] = {}   # job_id -> node ids
        self._state = ClusterState.healthy(topo.n_nodes)
        # cumulative mapper wall-clock this scheduler has spent, across
        # queue drains and fault-driven re-placements (benchmarked per
        # scenario in benchmarks/clustersim.py)
        self.place_time_s: float = 0.0
        # simulated-seconds clock, advanced by the event simulator before
        # each handler (direct callers may leave it at 0.0 — admission
        # waits then read as abstract rounds).  Feeds the queue-depth and
        # admission-latency counters reported by :meth:`stats`.
        self.clock: float = 0.0
        self.peak_queue_depth: int = 0
        self.n_enqueued: int = 0
        self.n_started: int = 0
        self._wait_total_s: float = 0.0
        self._wait_max_s: float = 0.0

    # -------------------------------------------------------------- health
    def cluster_state(self) -> ClusterState:
        """The current versioned health snapshot (FANS's world view).

        Merges registry lifecycle codes with the heartbeat belief; a new
        epoch is minted only when either actually changed (see
        ``p_f_atol``), so callers can use ``state.key`` — and the engine
        does — as a cache token that is stable across no-op heartbeat
        rounds.  With a belief tracker attached the belief is the
        tracker's learned ``p_f`` (queried at the scheduler clock so
        censored exposure stays current); otherwise the raw heartbeat
        estimate."""
        codes = self.registry.health_codes()
        if self.tracker is not None:
            p = self.tracker.p_f_vector(now=self.clock)
        else:
            p = self.monitor.outage_probabilities()
        # a non-allocatable node's belief is pinned to 1.0 in every view
        # placements consume, so its raw estimate drifting (a dead node's
        # miss fraction climbing toward 1.0) must not mint epochs
        p = np.where(codes <= np.int8(1), p, 1.0)   # 1 == DEGRADED
        self._state = self._state.evolve(health=codes, p_f=p,
                                         atol=self.p_f_atol)
        return self._state

    def heartbeat_round(self, replies: np.ndarray,
                        latencies: np.ndarray | None = None,
                        dt: float = 1.0) -> list[JobRecord]:
        """One heartbeat poll: update estimates, degrade/drain/undrain,
        and drain the pending queue if capacity came back.  Returns newly
        started records (draining never kills running jobs — Slurm
        semantics).  ``dt`` is the poll interval in simulated seconds,
        forwarded to the monitor's clock (the event simulator passes its
        ``heartbeat_interval``; the default 1.0 reads as one abstract
        round for direct callers)."""
        self.monitor.poll(replies, latencies, dt=dt)
        if self.tracker is not None:
            self.tracker.observe_heartbeat(self.clock)
        p = self.monitor.outage_probabilities()
        deg = self.degraded_threshold
        freed = False
        for i in range(self.topo.n_nodes):
            state = self.registry[i].state
            if state.allocatable and p[i] >= self.drain_threshold:
                self.registry.mark([i], NodeState.DRAINED)
            elif state == NodeState.DRAINED and p[i] < self.undrain_threshold:
                back = (NodeState.DEGRADED
                        if deg is not None and p[i] >= deg else NodeState.UP)
                self.registry.mark([i], back)
                freed = True
            elif deg is not None:
                if state == NodeState.UP and p[i] >= deg:
                    self.registry.mark([i], NodeState.DEGRADED)
                elif state == NodeState.DEGRADED and p[i] < deg / 2.0:
                    # same hysteresis shape as undrain: recover only once
                    # the evidence has clearly faded
                    self.registry.mark([i], NodeState.UP)
        return self.schedule_pending() if freed else []

    def estimated_outage(self) -> np.ndarray:
        """p_f as FANS sees it: the current state's pinned outage vector —
        heartbeat belief for allocatable nodes (DEGRADED keeps its
        elevated estimate), DRAINED/DOWN pinned to certain outage."""
        return self.cluster_state().outage_vector()

    # ----------------------------------------------------------- capacity
    def free_ids(self) -> np.ndarray:
        """Allocatable (UP/DEGRADED) nodes not held by any running job,
        in id order."""
        ok = self.registry.allocatable_ids()
        if not self.allocated:
            return ok
        busy = np.concatenate(list(self.allocated.values()))
        return ok[~np.isin(ok, busy)]

    # ---------------------------------------------------------- placement
    def placement_request(self, job: Job,
                          available: np.ndarray | None = None
                          ) -> PlacementRequest:
        """FANS inputs: G from LoadMatrix, H from FATT, and one versioned
        ClusterState carrying p_f (heartbeat belief) and availability —
        busy allocations enter as a cheap overlay on the snapshot, so the
        epoch (and every engine cache keyed on it) survives until health
        actually changes.

        An explicit ``available`` that is an id-ordered subset of the
        allocatable set (what :meth:`free_ids` produces) rides the
        overlay; anything else — a custom order, or a what-if list
        naming drained/down nodes — is passed verbatim through the
        legacy request path so the caller's intent is honored exactly."""
        state = self.cluster_state()
        if available is None:
            available = self.free_ids()
        else:
            available = np.asarray(available, dtype=np.int64)
            alloc = state.available_ids()
            ordered_subset = np.isin(available, alloc).all() and \
                np.array_equal(available, alloc[np.isin(alloc, available)])
            if not ordered_subset:
                return PlacementRequest(
                    comm=job.workload.comm, topology=self.topo,
                    p_f=state.outage_vector(), available=available)
        unavailable = np.setdiff1d(state.available_ids(), available)
        return PlacementRequest(
            comm=job.workload.comm,
            topology=self.topo,
            state=state.overlay(unavailable=unavailable),
        )

    # ------------------------------------------------------------- running
    def enqueue(self, job: Job) -> JobRecord:
        """Append to the pending queue without draining it — for callers
        (the event simulator) that need :meth:`schedule_pending`'s list
        of started records themselves."""
        rec = JobRecord(job=job, enqueue_time=self.clock)
        self.records[job.job_id] = rec
        self.queue.append(job)
        self.n_enqueued += 1
        self.peak_queue_depth = max(self.peak_queue_depth, len(self.queue))
        return rec

    def submit(self, job: Job) -> JobRecord:
        """Enqueue and try to start.  The returned record is ``running``
        (with a placement) if capacity allowed, else ``pending``; other
        queued jobs may start too as a side effect."""
        rec = self.enqueue(job)
        self.schedule_pending()
        return rec

    def schedule_pending(self) -> list[JobRecord]:
        """Drain the pending queue FIFO against free capacity.

        Without backfill, scanning stops at the first job that does not
        fit (strict FIFO).  With backfill, later jobs are still tried —
        a small job can slip past a blocked wide head into currently-free
        nodes.  Greedy, reservation-free: the backfilled job may hold
        nodes the head would have received at the next completion, so
        wide jobs can be delayed by a stream of small ones (no starvation
        bound; use ``backfill=False`` for strict FIFO fairness).

        Admission is decided first by capacity *count* (each job takes
        exactly ``n_ranks`` exclusive nodes, so which jobs start is
        placement-independent), then every admitted job is placed with
        **one** :meth:`PlacementEngine.place_many` call in exclusive
        mode — the whole drain shares one backend scope, one set of
        epoch-keyed (topology, state) matrices, and the shrinking
        availability mask is threaded through the batch as state
        overlays exactly as the old per-job loop did (bit-identical
        placements and RNG draws).
        """
        remaining: list[Job] = []
        admitted: list[Job] = []
        free = self.free_ids()
        free_count = len(free)
        blocked = False
        for job in self.queue:
            if blocked and not self.backfill:
                remaining.append(job)
                continue
            if free_count < job.workload.n_ranks:
                remaining.append(job)
                blocked = True
                continue
            admitted.append(job)
            free_count -= job.workload.n_ranks
        self.queue = remaining
        if not admitted:
            return []

        plans = self.engine.place_many(
            [self.placement_request(job, free) for job in admitted],
            policy=[job.distribution for job in admitted],
            rng=self.rng, exclusive=True)
        started: list[JobRecord] = []
        for job, plan in zip(admitted, plans):
            rec = self.records[job.job_id]
            rec.placement = plan
            rec.state = "running"
            if rec.start_time < 0:
                rec.start_time = self.clock
                wait = max(0.0, self.clock - rec.enqueue_time)
                self.n_started += 1
                self._wait_total_s += wait
                self._wait_max_s = max(self._wait_max_s, wait)
            rec.runtime = successful_runtime(job.workload, plan.placement,
                                             self.net)
            self.allocated[job.job_id] = np.asarray(plan.placement,
                                                    dtype=np.int64).copy()
            self.place_time_s += plan.wall_time_s
            started.append(rec)
        return started

    def handle_node_failure(self, node_ids) -> list[JobRecord]:
        """Elastic re-placement (beyond paper): nodes went down; any running
        job holding them is incrementally re-placed on surviving nodes —
        only the displaced processes move — and restarted.  A job the
        survivors cannot hold goes back to the head of the pending queue
        (``state="pending"``).  Returns every affected record.

        This method does *not* drain the pending queue, so the caller can
        distinguish affected records from newly started ones: if a
        requeued job released capacity another pending job fits in, call
        :meth:`schedule_pending` afterwards (the event simulator does)."""
        node_ids = [int(x) for x in np.atleast_1d(node_ids)]
        if self.tracker is not None:
            self.tracker.observe_failure(node_ids, self.clock)
        self.registry.mark(node_ids, NodeState.DOWN)
        affected = []
        requeued: list[Job] = []
        for rec in self.records.values():
            if rec.state != "running":
                continue
            used = set(int(x) for x in rec.placement.placement)
            if not (used & set(node_ids)):
                continue
            affected.append(rec)
            # free this job's own allocation before re-placing so its
            # surviving nodes remain usable by the replacement
            del self.allocated[rec.job.job_id]
            try:
                # pass the *current* snapshot (busy allocations overlaid)
                # — the plan's request carries the submit-time state,
                # stale once other nodes failed or drained after
                # submission
                state = self.cluster_state()
                busy = np.setdiff1d(state.available_ids(), self.free_ids())
                rec.placement = self.engine.replace(
                    rec.placement, node_ids, rng=self.rng,
                    state=state.overlay(unavailable=busy))
            except ValueError:
                # survivors cannot hold the job: back to the queue head
                rec.placement = None
                rec.state = "pending"
                rec.requeues += 1
                requeued.append(rec.job)
                continue
            rec.restarts += 1
            self.place_time_s += rec.placement.wall_time_s
            rec.runtime = successful_runtime(rec.job.workload,
                                             rec.placement.placement,
                                             self.net)
            self.allocated[rec.job.job_id] = np.asarray(
                rec.placement.placement, dtype=np.int64).copy()
        if requeued:
            self.queue = requeued + self.queue
        return affected

    def recover(self, node_ids) -> list[JobRecord]:
        """Repaired nodes return to service; returns newly started records.

        A repaired node whose heartbeat estimate still sits at or above
        ``drain_threshold`` comes back DRAINED, not UP — repair fixes the
        outage, not the flakiness evidence, so the undrain hysteresis in
        :meth:`heartbeat_round` keeps gating its return to placements.
        With the degraded band enabled, an estimate in [degraded, drain)
        brings the node back DEGRADED."""
        if self.tracker is not None:
            self.tracker.observe_repair(
                [int(x) for x in np.atleast_1d(node_ids)], self.clock)
        p = self.monitor.outage_probabilities()
        deg = self.degraded_threshold
        for i in (int(x) for x in np.atleast_1d(node_ids)):
            if p[i] >= self.drain_threshold:
                state = NodeState.DRAINED
            elif deg is not None and p[i] >= deg:
                state = NodeState.DEGRADED
            else:
                state = NodeState.UP
            self.registry.mark([i], state)
        return self.schedule_pending()

    def complete(self, job_id: int) -> list[JobRecord]:
        """Mark done, release nodes, and drain the queue onto the freed
        capacity; returns newly started records."""
        self.records[job_id].state = "done"
        self.allocated.pop(job_id, None)
        return self.schedule_pending()

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Queueing and admission-latency counters of this scheduler.

        Waits are measured on :attr:`clock` (simulated seconds when the
        event simulator drives it, abstract otherwise) from enqueue to
        *first* start — requeues after a failure do not reset the clock,
        matching how users experience time-to-start."""
        return {
            "queue_depth": len(self.queue),
            "peak_queue_depth": self.peak_queue_depth,
            "n_enqueued": self.n_enqueued,
            "n_started": self.n_started,
            "admission_wait_total_s": self._wait_total_s,
            "admission_wait_max_s": self._wait_max_s,
            "admission_wait_mean_s": (self._wait_total_s / self.n_started
                                      if self.n_started else 0.0),
            "place_time_s": self.place_time_s,
        }
