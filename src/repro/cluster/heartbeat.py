"""Heartbeat collection and outage-probability estimation.

The paper's *Fault Aware Slurmctld* plugin polls every node each interval
``t`` (``Hb(t, i)``); a missing reply marks an outage sample.  Node outage
probability is inferred by post-processing each node's heartbeat history
``HB(i)`` — the paper explicitly calls out moving / weighted-moving averages
as candidate policies.  Both are implemented here, plus the latency-based
straggler score used by the beyond-paper soft penalty.

**Units.**  The monitor's ``clock`` advances by ``dt`` simulated seconds
per ``poll`` (default 1.0 — one abstract round); sample timestamps and
reply latencies are in the same seconds.  Estimates are probabilities in
``[0, 1]`` per *round*: a node with ``p = 0.3`` misses ~30% of polls.

**Truth vs estimate.**  What ``poll`` records is *observed* replies; the
ground truth lives in the fault-injection layer
(:mod:`repro.cluster.failures`) or
``NodeRegistry.true_outage_p``.  ``outage_probabilities()`` is therefore
the scheduler's *belief* — exactly the ``known_p_f`` side of the
contract documented on :func:`repro.sim.batchsim.run_batch`:
``simulate_rounds`` with enough rounds converges that belief to the
truth (the paper's setting), few rounds model a cold or lagging
estimator.

**Determinism.**  The monitor itself never draws randomness;
``simulate_rounds`` draws reply misses from the explicit ``rng``
argument, so a heartbeat history is reproducible from its seed.

**Deprecation note.**  The :class:`OutageEstimator` hierarchy here
(:class:`MovingAverage` / :class:`EWMA`) predates the belief subsystem
in :mod:`repro.beliefs` and survives as the monitor's default
post-processing only.  New estimation code should implement the
:class:`repro.beliefs.BeliefModel` protocol — which is horizon-aware
and learns from lifetime statistics rather than per-round miss
fractions — and these legacy estimators are available behind it via
:class:`repro.beliefs.HeartbeatBeliefAdapter` so the monitor and the
:class:`repro.beliefs.BeliefTracker` share one interface.  No removal
is scheduled (drain/degrade thresholds are calibrated against per-round
miss fractions), but the hierarchy is frozen: grow ``repro.beliefs``
instead.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class HeartbeatSample:
    t: float
    ok: bool
    latency: float = 0.0   # reply latency (straggler signal), seconds


class OutageEstimator:
    """Base: estimate p_f from a heartbeat history."""

    def estimate(self, history: "deque[HeartbeatSample]") -> float:
        raise NotImplementedError


class MovingAverage(OutageEstimator):
    """p_f = fraction of missed heartbeats over the last ``window`` samples."""

    def __init__(self, window: int = 100):
        self.window = window

    def estimate(self, history) -> float:
        if not history:
            return 0.0
        recent = list(history)[-self.window:]
        return sum(0.0 if s.ok else 1.0 for s in recent) / len(recent)


class EWMA(OutageEstimator):
    """Exponentially weighted moving average of the miss indicator."""

    def __init__(self, alpha: float = 0.05):
        self.alpha = alpha

    def estimate(self, history) -> float:
        p = 0.0
        for s in history:
            p = (1 - self.alpha) * p + self.alpha * (0.0 if s.ok else 1.0)
        return p


class HeartbeatMonitor:
    """Fault Aware Slurmctld: maintains HB(i) per node, infers p_f vector."""

    def __init__(self, n_nodes: int, estimator: OutageEstimator | None = None,
                 max_history: int = 1000):
        self.n_nodes = n_nodes
        self.estimator = estimator or MovingAverage()
        self.history: list[deque] = [deque(maxlen=max_history)
                                     for _ in range(n_nodes)]
        self.clock = 0.0

    def poll(self, replies: np.ndarray, latencies: np.ndarray | None = None,
             dt: float = 1.0) -> None:
        """One heartbeat round: ``replies[i]`` True if node i answered.

        ``dt`` is the poll interval in simulated seconds (the paper's
        ``t``); ``latencies`` are per-node reply latencies in seconds
        (straggler signal), ignored for missing replies."""
        self.clock += dt
        for i in range(self.n_nodes):
            lat = float(latencies[i]) if latencies is not None else 0.0
            self.history[i].append(
                HeartbeatSample(self.clock, bool(replies[i]), lat))

    def outage_probabilities(self) -> np.ndarray:
        return np.array([self.estimator.estimate(h) for h in self.history])

    def straggler_scores(self, baseline_latency: float = 1e-3) -> np.ndarray:
        """Relative slowdown per node from heartbeat reply latency."""
        out = np.zeros(self.n_nodes)
        for i, h in enumerate(self.history):
            lats = [s.latency for s in h if s.ok and s.latency > 0]
            if lats:
                med = float(np.median(lats))
                out[i] = max(0.0, med / baseline_latency - 1.0)
        return out

    def simulate_rounds(
        self, rng: np.random.Generator, true_p: np.ndarray,
        n_rounds: int, slowdown: np.ndarray | None = None,
        baseline_latency: float = 1e-3,
    ) -> None:
        """Drive the monitor with synthetic heartbeats: node i misses each
        round with its true outage probability (the NodeState plugin simply
        does not answer while a node is down).

        ``true_p`` is the *ground-truth* per-round miss probability; all
        draws come from ``rng``, so the resulting estimate trajectory is
        reproducible from the seed.  ~400 rounds converge a default
        ``MovingAverage`` to within a few percent of ``true_p`` (see
        ``tests/test_cluster.py``); the event simulator instead issues
        live HEARTBEAT events for the same effect over simulated time."""
        for _ in range(n_rounds):
            replies = rng.random(self.n_nodes) >= true_p
            lat = np.full(self.n_nodes, baseline_latency)
            if slowdown is not None:
                lat = baseline_latency * (1.0 + slowdown)
            self.poll(replies, lat)
