"""Node registry — slurmd-side state for every compute node.

Mirrors the information the paper's *FATT* plugin reads from the topology
file (node id + torus coordinates) and the state that *NodeState* /
*Fault Aware Slurmctld* maintain per node (up/down, outage statistics).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.state import NodeHealth
from repro.core.topology import TorusTopology


class NodeState(enum.Enum):
    UP = "up"
    DOWN = "down"
    DRAINED = "drained"   # administratively removed (beyond paper: elastic)
    DEGRADED = "degraded"  # allocatable but flaky: elevated outage estimate

    @property
    def health(self) -> NodeHealth:
        """The :class:`~repro.core.state.NodeHealth` lifecycle code this
        administrative state maps onto."""
        return _HEALTH[self]

    @property
    def allocatable(self) -> bool:
        return self in (NodeState.UP, NodeState.DEGRADED)


_HEALTH = {
    NodeState.UP: NodeHealth.UP,
    NodeState.DEGRADED: NodeHealth.DEGRADED,
    NodeState.DRAINED: NodeHealth.DRAINED,
    NodeState.DOWN: NodeHealth.DOWN,
}


@dataclasses.dataclass
class NodeInfo:
    node_id: int
    coords: tuple[int, ...]
    state: NodeState = NodeState.UP
    true_outage_p: float = 0.0      # ground truth used by fault injection
    slowdown: float = 0.0           # straggler factor (beyond paper)


class NodeRegistry:
    """All nodes of the platform, keyed by id (id order == Slurm order)."""

    def __init__(self, topo: TorusTopology):
        self.topo = topo
        self.nodes = [NodeInfo(i, topo.coords(i)) for i in range(topo.n_nodes)]

    def __len__(self) -> int:
        return len(self.nodes)

    def __getitem__(self, i: int) -> NodeInfo:
        return self.nodes[i]

    def set_outage_probabilities(self, ids, p: float) -> None:
        for i in ids:
            self.nodes[int(i)].true_outage_p = p

    def true_outage_vector(self) -> np.ndarray:
        return np.array([n.true_outage_p for n in self.nodes])

    def up_ids(self) -> np.ndarray:
        return np.array([n.node_id for n in self.nodes
                         if n.state == NodeState.UP])

    def allocatable_ids(self) -> np.ndarray:
        """Nodes placements may use: UP or DEGRADED, in id order."""
        return np.array([n.node_id for n in self.nodes
                         if n.state.allocatable], dtype=np.int64)

    def health_codes(self) -> np.ndarray:
        """(n,) int8 :class:`~repro.core.state.NodeHealth` codes — the
        lifecycle vector a :class:`~repro.core.state.ClusterState`
        snapshot is minted from."""
        return np.array([int(n.state.health) for n in self.nodes],
                        dtype=np.int8)

    def mark(self, ids, state: NodeState) -> None:
        for i in ids:
            self.nodes[int(i)].state = state

    def topology_file(self) -> str:
        """The FATT plugin's input format: 'id x y z' per line."""
        return "\n".join(
            f"{n.node_id} " + " ".join(str(c) for c in n.coords)
            for n in self.nodes)

    @classmethod
    def from_topology_file(cls, text: str, dims: tuple[int, ...]
                           ) -> "NodeRegistry":
        topo = TorusTopology(dims)
        reg = cls(topo)
        for line in text.strip().splitlines():
            parts = line.split()
            nid, coords = int(parts[0]), tuple(int(c) for c in parts[1:])
            assert reg.nodes[nid].coords == coords, "topology file mismatch"
        return reg
