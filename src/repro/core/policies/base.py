"""Policy registry + protocol for the :class:`~repro.core.engine.PlacementEngine`.

A placement *policy* is a strategy object that maps a prepared
:class:`PolicyContext` (guest graph, host matrices, health, availability,
RNG) to a placement array.  Policies self-register by name with
``@register_policy("name")`` and are looked up with :func:`get_policy`, so
string dispatch lives in the registry — never in call sites.  This is the
extension point that lets Scotch-style mappers, grid/torus-specialised
mappers, and fault-aware mappers coexist behind one interface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, Optional, Protocol, runtime_checkable

import numpy as np


class PolicyError(ValueError):
    """Base class for registry errors (a ``ValueError`` so legacy callers
    that caught the old string-dispatch error keep working)."""


class UnknownPolicyError(PolicyError):
    """Requested policy name is not registered."""


class DuplicatePolicyError(PolicyError):
    """A policy with this name is already registered."""


@dataclasses.dataclass
class PolicyContext:
    """Inputs prepared (and cached) by the engine for one placement call.

    ``weights`` — the Eq. 1 fault/straggler-weighted route matrix — is
    computed lazily: baseline policies that only need hop distances never
    pay for route weighting, and fault-aware policies hit the engine's
    per-(topology, health) cache.
    """

    request: object                 # the originating PlacementRequest
    G_w: np.ndarray                 # guest edge weights under request.metric
    coords: np.ndarray              # (N, ndim) host coordinates
    hops: np.ndarray                # healthy hop-distance matrix (cached)
    p_f: np.ndarray                 # outage probs, unavailable pinned to 1.0
    available: np.ndarray           # allocatable node ids (order-preserving)
    rng: np.random.Generator
    _weights_fn: Optional[Callable[[], np.ndarray]] = None
    _weights: Optional[np.ndarray] = None
    # engine-owned memo dict scoped to one (topology, health) state: policies
    # stash guest-independent intermediates (e.g. TOFA's window/ball node-set
    # candidates) here so repeated placements against the same health
    # snapshot skip re-deriving them.  None when no engine cache backs the
    # call (ad-hoc contexts in tests).
    shared: Optional[dict] = None
    # disambiguates availability inside a shared dict: the engine scopes
    # shared dicts per *route* health key (so busy-overlay churn reuses one
    # dict per epoch), and every memo entry is namespaced by this token —
    # the request state's full key — because candidate node sets depend on
    # which nodes are currently selectable, not just on route weights.
    avail_token: Optional[tuple] = None

    def memo(self, key, fn: Callable[[], object]):
        """Return ``fn()`` memoised under ``(key, avail_token)`` in the
        engine-scoped ``shared`` dict (or uncached when no dict was
        provided).  The availability namespace keeps entries correct when
        one shared dict serves many busy-overlay views of one epoch."""
        if self.shared is None:
            return fn()
        key = (key, self.avail_token)
        if key not in self.shared:
            self.shared[key] = fn()
        return self.shared[key]

    @property
    def n_procs(self) -> int:
        return self.G_w.shape[0]

    @property
    def weights(self) -> np.ndarray:
        if self._weights is None:
            self._weights = (self._weights_fn() if self._weights_fn is not None
                             else self.hops)
        return self._weights

    @property
    def weights_computed(self) -> bool:
        return self._weights is not None


@dataclasses.dataclass
class PolicyOutput:
    """What a policy returns: the placement plus policy-specific diagnostics."""

    placement: np.ndarray
    used_consecutive_window: bool = False   # TOFA step 10 succeeded?


@runtime_checkable
class PlacementPolicy(Protocol):
    """The protocol every registered policy class implements."""

    name: ClassVar[str]
    fault_aware: ClassVar[bool]

    def place(self, ctx: PolicyContext) -> PolicyOutput: ...


_REGISTRY: dict[str, type] = {}


def register_policy(name: str):
    """Class decorator: register a :class:`PlacementPolicy` under ``name``."""
    def deco(cls):
        if name in _REGISTRY:
            raise DuplicatePolicyError(
                f"policy {name!r} already registered by "
                f"{_REGISTRY[name].__name__}")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_policy(name: str) -> PlacementPolicy:
    """Instantiate the policy registered under ``name``."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise UnknownPolicyError(
            f"unknown policy {name!r}; registered: "
            f"{', '.join(_REGISTRY) or '(none)'}") from None
    return cls()


def unregister_policy(name: str) -> None:
    """Remove a registered policy (tests / plugin teardown)."""
    if name not in _REGISTRY:
        raise UnknownPolicyError(f"unknown policy {name!r}")
    del _REGISTRY[name]


def available_policies() -> tuple[str, ...]:
    """Registered policy names, in registration order."""
    return tuple(_REGISTRY)
