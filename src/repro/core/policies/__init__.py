"""Pluggable placement policies.

Importing this package registers the five seed policies — ``linear``,
``random``, ``greedy``, ``topo``, ``tofa`` — in that order.  Third-party
policies register the same way:

    from repro.core.policies import PolicyOutput, register_policy

    @register_policy("mine")
    class MinePolicy:
        fault_aware = True
        def place(self, ctx):
            return PolicyOutput(...)
"""
from repro.core.policies.base import (DuplicatePolicyError, PlacementPolicy,
                                      PolicyContext, PolicyError,
                                      PolicyOutput, UnknownPolicyError,
                                      available_policies, get_policy,
                                      register_policy, unregister_policy)
# import order == registration order == legacy POLICIES tuple order
from repro.core.policies import baselines as _baselines  # noqa: E402,F401
from repro.core.policies import scotch as _scotch        # noqa: E402,F401
from repro.core.policies import tofa as _tofa            # noqa: E402,F401
from repro.core.policies.tofa import FAULT_BLOCK

__all__ = [
    "DuplicatePolicyError", "PlacementPolicy", "PolicyContext", "PolicyError",
    "PolicyOutput", "UnknownPolicyError", "available_policies", "get_policy",
    "register_policy", "unregister_policy", "FAULT_BLOCK",
]
