"""``topo`` — topology-aware but fault-blind mapping (paper Section 5.1).

The Scotch-analogue run of the paper's comparison: dual recursive
bipartitioning onto the healthy hop metric, ignoring ``p_f`` entirely.
"""
from __future__ import annotations

import numpy as np

from .. import mapping
from .base import PolicyContext, PolicyOutput, register_policy


@register_policy("topo")
class ScotchPolicy:
    """Fault-blind Scotch mapping: window + compact-ball candidates."""

    fault_aware = False

    def place(self, ctx: PolicyContext) -> PolicyOutput:
        n, avail = ctx.n_procs, ctx.available
        subsets = [avail[:n]]
        if n < len(avail) and not mapping.is_lazy(ctx.hops):
            # the restricted-matrix ball needs a dense metric; above the
            # lazy threshold the sequential window candidate stands alone
            Wa = ctx.hops[np.ix_(avail, avail)]
            subsets.append(avail[mapping.select_nodes(Wa, n)])
        placement = mapping.best_map(ctx.G_w, subsets, ctx.coords, ctx.hops, ctx.rng)
        return PolicyOutput(placement)
