"""TOFA — TOpology and Fault-Aware process placement (paper Listing 1.1).

    procedure TOFA(G, H):
        S = find |V_G| consecutive nodes s.t. p_f = 0
        if S != {}:
            H_s := ScotchExtract(H, S)
            T   := ScotchMap(G, H_s)
        else:
            T   := ScotchMap(G, H)     # H fault-weighted per Eq. (1)

``map_graph`` (our Scotch analogue) plays ScotchMap; extraction is matrix
restriction.  When no consecutive fault-free window exists, the guest is
mapped onto a compact subset grown under the Eq. 1-weighted metric, which is
how the 100x penalty steers placement away from failing nodes while
tolerating them if unavoidable (the trade-off discussed in Section 3).
"""
from __future__ import annotations

import numpy as np

from .. import mapping
from ..topology import find_consecutive_healthy
from .base import PolicyContext, PolicyOutput, register_policy

# additive weight that makes a node effectively unselectable (used to mask
# faulty nodes out of ball extraction during TOFA step 14)
FAULT_BLOCK = 1e9


def _healthy_window_starts(p_f: np.ndarray, count: int) -> list[int]:
    """Start ids of all length->=count runs of healthy nodes (non-overlapping
    step count//2 within a run, to bound candidate count)."""
    healthy = p_f == 0
    starts: list[int] = []
    i, n = 0, len(p_f)
    while i + count <= n:
        if healthy[i:i + count].all():
            starts.append(i)
            i += max(count // 2, 1)
        else:
            # jump past the first unhealthy node in the window
            bad = i + int(np.argmax(~healthy[i:i + count]))
            i = bad + 1
    return starts


@register_policy("tofa")
class TofaPolicy:
    """Listing 1.1: consecutive-healthy window first, Eq. 1 fallback."""

    fault_aware = True

    def place(self, ctx: PolicyContext) -> PolicyOutput:
        n = ctx.n_procs
        G_w = ctx.G_w
        coords = ctx.coords
        rng = ctx.rng
        W = ctx.weights                       # Eq. 1 weights on H (cached)

        # Candidate node-set generation depends only on (health, n) — never
        # on the guest traffic — so it is memoised in the engine's
        # per-(topology, health) shared cache: batch simulations placing
        # hundreds of same-size jobs against one health snapshot grow the
        # window/ball candidates once.
        used_window, candidates = ctx.memo(
            ("tofa-candidates", n), lambda: self._candidates(ctx, W))

        if used_window:
            placement = mapping.best_map(G_w, candidates, coords, W, rng)
            return PolicyOutput(placement, used_consecutive_window=True)
        placement = mapping.map_graph(G_w, candidates[0], coords, D=W, rng=rng)
        return PolicyOutput(placement, used_consecutive_window=False)

    @staticmethod
    def _candidates(ctx: PolicyContext, W: np.ndarray
                    ) -> tuple[bool, list[np.ndarray]]:
        """Candidate node subsets: (found_consecutive_window, node sets)."""
        n = ctx.n_procs
        p_f = ctx.p_f
        S = find_consecutive_healthy(p_f, n)
        if S is not None:
            # steps 14-15: extract sub-topology, map onto it.  Listing 1.1's
            # H carries Eq. 1 weights *before* extraction, so mapping quality
            # is still judged fault-aware: a window placement whose internal
            # routes cross a faulty node is priced at 100x and avoided.
            # Several extraction shapes are tried (ScotchExtract is free to
            # return any sub-arch): consecutive-id windows (slabs — ideal for
            # banded guests) and compact balls grown from seeds spread across
            # the healthy region; more candidates raise the odds of a region
            # whose internal routes are entirely fault-free, which keeps full
            # mapping quality *and* zero abort exposure.
            W_sel = W + (FAULT_BLOCK * ((p_f[:, None] > 0) | (p_f[None, :] > 0)))
            candidates = [S]
            healthy = np.flatnonzero(p_f == 0)
            # additional healthy windows beyond the first
            run_starts = _healthy_window_starts(p_f, n)
            for s0 in run_starts[1:4]:
                candidates.append(np.arange(s0, s0 + n))
            # balls from diverse seeds: default (cheapest region) + the
            # healthy nodes farthest from any fault
            candidates.append(mapping.select_nodes(W_sel, n))
            if (p_f > 0).any():
                dist_to_fault = W[:, p_f > 0].min(axis=1)
                far = healthy[np.argsort(dist_to_fault[healthy])[::-1]]
                for seed_node in far[:3]:
                    candidates.append(
                        mapping.select_nodes(W_sel, n, seed=int(seed_node)))
            return True, candidates

        # step 12: map onto the full fault-weighted topology.  Weighted
        # selection grows the cheapest (healthiest, most compact) subset.
        # Improvement over plain Eq. 1 (see DESIGN.md): when >= n healthy
        # nodes exist, restrict selection to them outright — Eq. 1 alone can
        # tie a directly-faulty node with healthy nodes whose routes merely
        # *pass through* faults, and lose that tie.  Faulty nodes are used
        # only when the job cannot fit on healthy ones (the paper's
        # tolerance trade-off).
        healthy = np.flatnonzero(p_f == 0)
        if len(healthy) >= n:
            sub = mapping.select_nodes(W[np.ix_(healthy, healthy)], n)
            nodes = healthy[sub]
        else:
            nodes = mapping.select_nodes(W, n)
        return False, [nodes]
