"""TOFA — TOpology and Fault-Aware process placement (paper Listing 1.1).

    procedure TOFA(G, H):
        S = find |V_G| consecutive nodes s.t. p_f = 0
        if S != {}:
            H_s := ScotchExtract(H, S)
            T   := ScotchMap(G, H_s)
        else:
            T   := ScotchMap(G, H)     # H fault-weighted per Eq. (1)

``map_graph`` (our Scotch analogue) plays ScotchMap; extraction is matrix
restriction.  When no consecutive fault-free window exists, the guest is
mapped onto a compact subset grown under the Eq. 1-weighted metric, which is
how the 100x penalty steers placement away from failing nodes while
tolerating them if unavoidable (the trade-off discussed in Section 3).

Two registrations share this module: flat ``tofa`` (the paper listing,
full-graph DRB) and ``tofa-ml`` (the same candidate search with the
multilevel coarsen->map->refine mapper of :mod:`repro.core.multilevel`).
Above the engine's lazy-distance threshold both run the multilevel /
hierarchical path — the flat mapper's full-matrix operations are
undefined on a :class:`~repro.core.lazydist.LazyDistance` metric.
"""
from __future__ import annotations

import numpy as np

from .. import mapping, multilevel
from ..topology import find_consecutive_healthy
from .base import PolicyContext, PolicyOutput, register_policy

# additive weight that makes a node effectively unselectable (used to mask
# faulty nodes out of ball extraction during TOFA step 14)
FAULT_BLOCK = 1e9


def _healthy_window_starts(p_f: np.ndarray, count: int) -> list[int]:
    """Start ids of all length->=count runs of healthy nodes (non-overlapping
    step count//2 within a run, to bound candidate count)."""
    healthy = p_f == 0
    starts: list[int] = []
    i, n = 0, len(p_f)
    while i + count <= n:
        if healthy[i:i + count].all():
            starts.append(i)
            i += max(count // 2, 1)
        else:
            # jump past the first unhealthy node in the window
            bad = i + int(np.argmax(~healthy[i:i + count]))
            i = bad + 1
    return starts


@register_policy("tofa")
class TofaPolicy:
    """Listing 1.1: consecutive-healthy window first, Eq. 1 fallback."""

    fault_aware = True

    def place(self, ctx: PolicyContext) -> PolicyOutput:
        n = ctx.n_procs
        G_w = ctx.G_w
        coords = ctx.coords
        rng = ctx.rng
        W = ctx.weights                       # Eq. 1 weights on H (cached)

        if mapping.is_lazy(W):
            # above the lazy threshold the flat candidate search (full-
            # matrix select_nodes / np.ix_ restrictions) cannot run —
            # the multilevel policy's hierarchical path serves "tofa"
            return TofaMultilevelPolicy._place_lazy(ctx, W)

        # Candidate node-set generation depends only on (health, n) — never
        # on the guest traffic — so it is memoised in the engine's
        # per-(topology, health) shared cache: batch simulations placing
        # hundreds of same-size jobs against one health snapshot grow the
        # window/ball candidates once.
        used_window, candidates = ctx.memo(
            ("tofa-candidates", n), lambda: self._candidates(ctx, W))

        if used_window:
            placement = mapping.best_map(G_w, candidates, coords, W, rng)
            return PolicyOutput(placement, used_consecutive_window=True)
        placement = mapping.map_graph(G_w, candidates[0], coords, D=W, rng=rng)
        return PolicyOutput(placement, used_consecutive_window=False)

    @staticmethod
    def _candidates(ctx: PolicyContext, W: np.ndarray
                    ) -> tuple[bool, list[np.ndarray]]:
        """Candidate node subsets: (found_consecutive_window, node sets)."""
        n = ctx.n_procs
        p_f = ctx.p_f
        S = find_consecutive_healthy(p_f, n)
        if S is not None:
            # steps 14-15: extract sub-topology, map onto it.  Listing 1.1's
            # H carries Eq. 1 weights *before* extraction, so mapping quality
            # is still judged fault-aware: a window placement whose internal
            # routes cross a faulty node is priced at 100x and avoided.
            # Several extraction shapes are tried (ScotchExtract is free to
            # return any sub-arch): consecutive-id windows (slabs — ideal for
            # banded guests) and compact balls grown from seeds spread across
            # the healthy region; more candidates raise the odds of a region
            # whose internal routes are entirely fault-free, which keeps full
            # mapping quality *and* zero abort exposure.
            W_sel = W + (FAULT_BLOCK * ((p_f[:, None] > 0) | (p_f[None, :] > 0)))
            candidates = [S]
            healthy = np.flatnonzero(p_f == 0)
            # additional healthy windows beyond the first
            run_starts = _healthy_window_starts(p_f, n)
            for s0 in run_starts[1:4]:
                candidates.append(np.arange(s0, s0 + n))
            # balls from diverse seeds: default (cheapest region) + the
            # healthy nodes farthest from any fault
            candidates.append(mapping.select_nodes(W_sel, n))
            if (p_f > 0).any():
                dist_to_fault = W[:, p_f > 0].min(axis=1)
                far = healthy[np.argsort(dist_to_fault[healthy])[::-1]]
                for seed_node in far[:3]:
                    candidates.append(
                        mapping.select_nodes(W_sel, n, seed=int(seed_node)))
            return True, candidates

        # step 12: map onto the full fault-weighted topology.  Weighted
        # selection grows the cheapest (healthiest, most compact) subset.
        # Improvement over plain Eq. 1 (see DESIGN.md): when >= n healthy
        # nodes exist, restrict selection to them outright — Eq. 1 alone can
        # tie a directly-faulty node with healthy nodes whose routes merely
        # *pass through* faults, and lose that tie.  Faulty nodes are used
        # only when the job cannot fit on healthy ones (the paper's
        # tolerance trade-off).
        healthy = np.flatnonzero(p_f == 0)
        if len(healthy) >= n:
            sub = mapping.select_nodes(W[np.ix_(healthy, healthy)], n)
            nodes = healthy[sub]
        else:
            nodes = mapping.select_nodes(W, n)
        return False, [nodes]


@register_policy("tofa-ml")
class TofaMultilevelPolicy(TofaPolicy):
    """TOFA candidate search + multilevel coarsen->map->refine mapper.

    Below ``COARSE_TARGET`` processes, coarsening is a no-op and the
    policy delegates to flat :class:`TofaPolicy` outright — placements
    are bit-identical (the parity anchor of ``tests/test_multilevel.py``).
    With a lazy metric (engine above its size threshold) the candidate
    search itself goes hierarchical: the consecutive-healthy window scan
    is O(N), and the fallback ball is grown rack-first over
    ``Topology.hierarchy_groups`` representatives
    (:func:`repro.core.multilevel.hierarchical_select`).
    """

    fault_aware = True
    COARSE_TARGET = 160

    def place(self, ctx: PolicyContext) -> PolicyOutput:
        n = ctx.n_procs
        W = ctx.weights
        if mapping.is_lazy(W):
            return self._place_lazy(ctx, W)
        if n <= self.COARSE_TARGET:
            # coarsening would be a no-op: run the flat policy unchanged
            return TofaPolicy.place(self, ctx)
        used_window, candidates = ctx.memo(
            ("tofa-candidates", n), lambda: self._candidates(ctx, W))
        placements = np.stack([
            multilevel.multilevel_map(ctx.G_w, nodes, ctx.coords, D=W,
                                      rng=ctx.rng,
                                      coarse_target=self.COARSE_TARGET)
            for nodes in candidates])
        scores = mapping.hop_bytes_batch(ctx.G_w, W, placements)
        return PolicyOutput(placements[int(np.argmin(scores))],
                            used_consecutive_window=used_window)

    @classmethod
    def _place_lazy(cls, ctx: PolicyContext, W) -> PolicyOutput:
        n = ctx.n_procs
        used_window, candidates = ctx.memo(
            ("tofa-ml-candidates", n), lambda: cls._candidates_lazy(ctx))
        placements = np.stack([
            multilevel.multilevel_map(ctx.G_w, nodes, ctx.coords, D=W,
                                      rng=ctx.rng,
                                      coarse_target=cls.COARSE_TARGET)
            for nodes in candidates])
        scores = mapping.hop_bytes_batch(ctx.G_w, W, placements)
        return PolicyOutput(placements[int(np.argmin(scores))],
                            used_consecutive_window=used_window)

    @staticmethod
    def _candidates_lazy(ctx: PolicyContext) -> tuple[bool, list[np.ndarray]]:
        """O(N)-memory candidate node sets: the first consecutive-healthy
        window plus a hierarchical (rack-first) compact ball."""
        n = ctx.n_procs
        p_f = ctx.p_f
        W = ctx.weights
        N = W.shape[0]
        S = find_consecutive_healthy(p_f, n)
        candidates: list[np.ndarray] = []
        if S is not None:
            candidates.append(S)
            # further healthy windows — the scan is O(N), and window
            # diversity is what closes the quality gap to the dense
            # candidate search under sparse faults
            for s0 in _healthy_window_starts(p_f, n)[1:4]:
                candidates.append(np.arange(s0, s0 + n))
        topo = getattr(ctx.request, "topology", None)
        if hasattr(topo, "hierarchy_groups"):
            groups = topo.hierarchy_groups(max(64, N // 256))
            healthy = p_f == 0
            hmask = healthy if healthy.sum() >= n else None
            ball = multilevel.hierarchical_select(W, groups, n, healthy=hmask)
            if len(ball) >= n:
                candidates.append(ball)
            faulty = np.flatnonzero(p_f > 0)
            if faulty.size and hmask is not None:
                # a second ball grown from the rack farthest from any
                # fault — the lazy analogue of the dense path's
                # far-seeded select_nodes candidates.  Rep-to-fault
                # distances touch #groups x #faults entries only.
                ng = int(groups.max()) + 1
                first = np.full(ng, -1, dtype=np.int64)
                hid = np.flatnonzero(healthy)
                first[groups[hid[::-1]]] = hid[::-1]
                live = np.flatnonzero(first >= 0)
                reps = first[live]
                dist_to_fault = np.asarray(
                    W[reps[:, None], faulty[None, :]], np.float64).min(axis=1)
                far_group = int(live[np.argmax(dist_to_fault)])
                ball2 = multilevel.hierarchical_select(
                    W, groups, n, healthy=hmask, seed_group=far_group)
                if len(ball2) >= n:
                    candidates.append(ball2)
        if not candidates:
            # last resort: lazy-aware frontier growth (blocked seed scan)
            candidates.append(mapping.select_nodes(W, n))
        return S is not None, candidates
