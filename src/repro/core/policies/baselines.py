"""Baseline placement policies of the paper's Section 5.1.

``linear`` (default-slurm), ``random``, and ``greedy`` — all fault-blind:
they see only the availability mask (Slurm never schedules onto
DOWN/DRAINED nodes, independent of fault-awareness) and the healthy hop
metric.
"""
from __future__ import annotations

from .. import mapping
from .base import PolicyContext, PolicyOutput, register_policy


@register_policy("linear")
class LinearPolicy:
    """default-slurm: iterate available nodes sequentially."""

    fault_aware = False

    def place(self, ctx: PolicyContext) -> PolicyOutput:
        return PolicyOutput(mapping.linear_placement(ctx.n_procs, ctx.available))


@register_policy("random")
class RandomPolicy:
    """Uniform random draw without replacement from the available nodes."""

    fault_aware = False

    def place(self, ctx: PolicyContext) -> PolicyOutput:
        return PolicyOutput(
            mapping.random_placement(ctx.n_procs, ctx.available, ctx.rng))


@register_policy("greedy")
class GreedyPolicy:
    """Heaviest-traffic pairs placed as close as possible (paper baseline)."""

    fault_aware = False

    def place(self, ctx: PolicyContext) -> PolicyOutput:
        return PolicyOutput(
            mapping.greedy_placement(ctx.G_w, ctx.available, ctx.hops))
