"""Multilevel graph mapping: coarsen -> map -> uncoarsen-with-refinement.

The flat dual-recursive-bipartitioning mapper (:func:`mapping.map_graph`)
bisects the full guest graph at every recursion level — O(n^2) work per
level on dense guests, and its refinement sees all n processes at once.
This module implements the multilevel scheme of the process-mapping
literature (Schulz & Woydt, "Shared-Memory Hierarchical Process Mapping";
Schulz & Träff, "Better Process Mapping and Sparse Quadratic
Assignment"):

1. **Coarsen** the communication graph by heavy-edge matching (HEM)
   until at most ``coarse_target`` super-vertices remain.  Matching is
   deterministic: vertices are visited in descending weighted-degree
   order (ties by index) and matched to their heaviest unmatched
   neighbour (ties to the lowest index).
2. **Map the coarse graph** with weighted dual recursive bipartitioning:
   the super-vertex split is count-balanced FM bisection
   (:func:`mapping.bisect_graph`), and the *node-set* split adapts to
   whatever vertex weight falls on each side
   (:func:`mapping.bisect_nodes` at the exact weighted boundary) — every
   super-vertex ends up with a compact contiguous chunk of exactly its
   size in nodes.
3. **Uncoarsen**: expand each super-vertex into its children and
   recursively map them *within the parent's chunk*, then run per-level
   local delta-swap refinement (:func:`mapping._pairwise_refine` on the
   chunk subproblem) followed by a global
   :func:`mapping.refine_batch` pass over the final candidates.

Mapping work per level is proportional to the level's vertex count, so
total work is a geometric series dominated by the finest level — the
flat mapper's repeated full-graph bisections disappear.  Combined with a
:class:`~repro.core.lazydist.LazyDistance` host metric, placements at
64k nodes never materialise an O(N^2) object.

``hierarchical_select`` is the companion node-subset search for lazy
metrics: it picks candidate regions group-first (racks / sub-tori from
``Topology.hierarchy_groups``), touching only a #groups x #groups
representative distance block instead of the full matrix.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from . import mapping


class Level(NamedTuple):
    """One coarsening step: ``match`` maps each vertex of the *fine*
    graph ``G`` (with vertex weights ``sizes``) to its coarse vertex."""

    match: np.ndarray   # (n_fine,) fine vertex -> coarse vertex id
    G: np.ndarray       # (n_fine, n_fine) fine guest graph
    sizes: np.ndarray   # (n_fine,) fine vertex weights (original procs)


def coarsen_level(G: np.ndarray, sizes: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One heavy-edge-matching pass: returns (match, G_coarse, sizes_c).

    Deterministic: descending weighted-degree visit order with
    index tie-break, heaviest-unmatched-neighbour matching with
    lowest-index tie-break (``argmax`` keeps the first maximum).
    Unmatchable vertices (no positive edge to an unmatched neighbour)
    become singletons.
    """
    n = G.shape[0]
    deg = G.sum(axis=1)
    order = np.lexsort((np.arange(n), -deg))
    mate = np.full(n, -1, dtype=np.int64)
    for v in order:
        if mate[v] >= 0:
            continue
        row = G[v].copy()
        row[v] = 0.0
        row[mate >= 0] = 0.0
        u = int(np.argmax(row))
        if row[u] > 0.0:
            mate[v] = u
            mate[u] = v
        else:
            mate[v] = v
    match = np.full(n, -1, dtype=np.int64)
    nc = 0
    for v in range(n):
        if match[v] < 0:
            match[v] = nc
            u = mate[v]
            if u != v:
                match[u] = nc
            nc += 1
    flat = match[:, None] * nc + match[None, :]
    Gc = np.bincount(flat.ravel(), weights=G.ravel(),
                     minlength=nc * nc).reshape(nc, nc)
    np.fill_diagonal(Gc, 0.0)
    sizes_c = np.bincount(match, weights=sizes.astype(np.float64),
                          minlength=nc).astype(np.int64)
    return match, Gc, sizes_c


def coarsen(G_w: np.ndarray, target: int
            ) -> tuple[list[Level], np.ndarray, np.ndarray]:
    """Repeated HEM until <= ``target`` vertices or matching stalls
    (< 5% shrink).  Returns (levels, G_coarse, sizes_coarse); an empty
    level list means coarsening was a no-op (n <= target already)."""
    G = np.asarray(G_w, dtype=np.float64)
    sizes = np.ones(G.shape[0], dtype=np.int64)
    levels: list[Level] = []
    while G.shape[0] > target:
        match, Gc, sizes_c = coarsen_level(G, sizes)
        if Gc.shape[0] > 0.95 * G.shape[0]:
            break
        levels.append(Level(match, G, sizes))
        G, sizes = Gc, sizes_c
    return levels, G, sizes


def uncoarsen_map(levels: list[Level], placement_like=None):
    """Compose the per-level matchings: returns ``labels`` where
    ``labels[k][p]`` is the coarse-vertex id of original process ``p``
    after ``k+1`` coarsening steps (used by round-trip tests)."""
    labels = []
    cur = None
    for lvl in levels:
        cur = lvl.match if cur is None else lvl.match[cur]
        labels.append(cur)
    return labels


def _children_lists(match: np.ndarray, nc: int) -> list[np.ndarray]:
    """Per-coarse-vertex fine-vertex id arrays, one argsort per level."""
    order = np.argsort(match, kind="stable")
    bounds = np.searchsorted(match[order], np.arange(nc + 1))
    return [order[bounds[v]:bounds[v + 1]] for v in range(nc)]


def _weighted_drb(G: np.ndarray, sizes: np.ndarray, navail: np.ndarray,
                  coords: np.ndarray, D, rng) -> list[np.ndarray]:
    """Weighted dual recursive bipartitioning: assign each vertex a
    contiguous node chunk of exactly ``sizes[v]`` nodes.  The vertex
    split is count-balanced; the node split lands on the weighted
    boundary the vertex split produced."""
    chunks: list[Optional[np.ndarray]] = [None] * len(sizes)

    def rec(verts: np.ndarray, nodes: np.ndarray) -> None:
        if len(verts) == 1:
            chunks[int(verts[0])] = nodes
            return
        half = len(verts) // 2
        in0 = mapping.bisect_graph(G[np.ix_(verts, verts)], half, rng=rng)
        w0 = int(sizes[verts[in0]].sum())
        n0, n1 = mapping.bisect_nodes(nodes, coords, w0, D=D)
        rec(verts[in0], n0)
        rec(verts[~in0], n1)

    rec(np.arange(len(sizes)), np.asarray(navail))
    return chunks


# chunk-local refinement window: chunks smaller than this refine as one
# dense subproblem during uncoarsening; larger chunks are left to their
# children's own refinement (their subgraph gather would dominate)
_LOCAL_REFINE_MAX = 1024


def multilevel_map(G_w: np.ndarray, nodes: np.ndarray, coords: np.ndarray,
                   D=None, rng: np.random.Generator | None = None,
                   coarse_target: int = 160,
                   refine: bool = True) -> np.ndarray:
    """Multilevel analogue of :func:`mapping.map_graph`.

    Coarsening a guest already at/below ``coarse_target`` is a no-op, and
    the call degrades to exactly ``map_graph`` — the bit-identity anchor
    the parity tests pin.
    """
    n = G_w.shape[0]
    nodes = np.asarray(nodes)
    assert len(nodes) >= n, "not enough nodes"
    rng = rng or np.random.default_rng(0)
    if len(nodes) > n:
        nodes = mapping.snake_order(nodes, coords)[:n]

    levels, Gc, sizes_c = coarsen(G_w, coarse_target)
    if not levels:
        return mapping.map_graph(G_w, nodes, coords, D=D, rng=rng,
                                 refine=refine)

    placement = np.full(n, -1, dtype=np.int64)

    def descend(li: int, members: np.ndarray, chunk: np.ndarray) -> None:
        """Map ``members`` (vertices of levels[li].G) onto ``chunk``."""
        lvl = levels[li]
        if len(members) == 1:
            sub_chunks = [np.asarray(chunk)]
        else:
            sub_chunks = _weighted_drb(
                lvl.G[np.ix_(members, members)], lvl.sizes[members],
                chunk, coords, D, rng)
        if li == 0:
            for local, m in enumerate(members):
                placement[m] = sub_chunks[local][0]
            return
        kids = _children_by_level[li - 1]
        for local, m in enumerate(members):
            descend(li - 1, kids[int(m)], sub_chunks[local])
        # local uncoarsening refinement: the original processes under
        # ``members`` now occupy ``chunk``; polish their arrangement
        # against the *global* metric restricted to this subproblem
        if refine and D is not None:
            procs = _procs_by_level[li - 1]
            F = np.concatenate([procs[int(m)] for m in members]) \
                if len(members) > 1 else procs[int(members[0])]
            if 4 <= len(F) <= _LOCAL_REFINE_MAX:
                refiner = mapping.__dict__["_pairwise_refine"]
                placement[F] = refiner(
                    G_w[np.ix_(F, F)], D, placement[F])

    # children of a level-li coarse vertex (vertices of levels[li].G),
    # and the original processes each level-li vertex represents
    _children_by_level = [
        _children_lists(lvl.match, int(lvl.match.max()) + 1)
        for lvl in levels]
    labels = uncoarsen_map(levels)
    _procs_by_level = [
        _children_lists(lab, int(lab.max()) + 1) for lab in labels]

    top_chunks = _weighted_drb(Gc, sizes_c, nodes, coords, D, rng)
    top_kids = _children_by_level[-1]
    for v in range(Gc.shape[0]):
        descend(len(levels) - 1, top_kids[v], top_chunks[v])

    assert (placement >= 0).all()
    if D is None:
        return placement

    # final global polish + snake portfolio — same candidate contract as
    # the flat mapper, so multilevel can never lose to the sequential
    # seed it would otherwise have skipped
    candidates = np.stack([placement,
                           mapping.snake_order(nodes, coords)[:n]])
    if refine:
        candidates = mapping.refine_batch(G_w, D, candidates)
    scores = mapping.hop_bytes_batch(G_w, D, candidates)
    return candidates[int(np.argmin(scores))]


# --------------------------------------------------------------------------
# hierarchical node-subset selection (lazy metrics)
# --------------------------------------------------------------------------

def hierarchical_select(D, groups: np.ndarray, count: int,
                        healthy: np.ndarray | None = None,
                        seed_group: int | None = None) -> np.ndarray:
    """Grow a compact ``count``-node subset group-first.

    ``groups`` is the (N,) rack/sub-torus id vector from
    ``Topology.hierarchy_groups``; ``healthy`` an optional (N,) bool
    mask.  Only a (#groups, #groups) representative distance block and
    per-node rows of ``D`` are ever materialised — the full-matrix
    ``select_nodes`` seed search is O(N^2) and off the table for lazy
    metrics.  ``seed_group`` forces growth to start from a specific
    *group id* (e.g. the rack farthest from any fault) instead of the
    cheapest-ball search.  Returns sorted node ids.
    """
    groups = np.asarray(groups)
    N = len(groups)
    if healthy is None:
        healthy = np.ones(N, dtype=bool)
    count = min(count, int(healthy.sum()))
    ng = int(groups.max()) + 1
    cap = np.bincount(groups[healthy], minlength=ng)
    live = np.flatnonzero(cap > 0)
    # lowest healthy id represents each live group
    first = np.full(ng, -1, dtype=np.int64)
    hid = np.flatnonzero(healthy)
    # reversed so the lowest id wins the final write
    first[groups[hid[::-1]]] = hid[::-1]
    reps = first[live]
    R = np.asarray(D[reps[:, None], reps[None, :]], dtype=np.float64)

    if seed_group is not None:
        hits = np.flatnonzero(live == seed_group)
        gseed = int(hits[0]) if hits.size else 0
    else:
        # seed group: cheapest capacity-weighted ball over group reps
        order = np.argsort(R, axis=1, kind="stable")
        cap_o = cap[live][order]
        cum = np.cumsum(cap_o, axis=1)
        need = np.argmax(cum >= count, axis=1)
        costs = np.where(
            cum[:, -1] >= count,
            np.take_along_axis(
                np.cumsum(R[np.arange(len(live))[:, None], order]
                          * cap_o, axis=1),
                need[:, None], axis=1)[:, 0],
            np.inf)
        gseed = int(np.argmin(costs))

    # frontier growth over groups; overshoot by ~1/2 so the node-granular
    # finish below has real boundary slack to carve a compact ball from
    # (the dense finish is O(|sup|^2) = O(count^2) either way)
    target = min(count + max(count // 2, 8), int(cap[live].sum()))
    chosen = np.zeros(len(live), dtype=bool)
    chosen[gseed] = True
    got = int(cap[live[gseed]])
    cost = R[gseed].copy()
    cost[gseed] = np.inf
    picks = [gseed]
    while got < target and len(picks) < len(live):
        nxt = int(np.argmin(cost))
        chosen[nxt] = True
        got += int(cap[live[nxt]])
        cost += R[nxt]
        cost[nxt] = np.inf
        picks.append(nxt)

    sup = np.sort(np.concatenate(
        [np.flatnonzero(healthy & (groups == live[g])) for g in picks]))
    if len(sup) == count:
        return sup
    # node-granular finish: compact growth *within* the group superset —
    # a (|sup|, |sup|) dense subproblem, |sup| <= count + one group, so
    # cost is O(count^2) like the guest matrix itself, never O(N^2)
    Dsub = np.asarray(D[np.ix_(sup, sup)], dtype=np.float64)
    seed_id = int(first[live[gseed]])
    local_seed = int(np.searchsorted(sup, seed_id))
    sel = mapping.select_nodes(Dsub, count, seed=local_seed)
    return np.sort(sup[sel])
