"""Dragonfly host topology — the high-radix group/global-link fabric.

A dragonfly (Kim et al., ISCA 2008) arranges routers into ``g`` groups of
``a`` routers each; every router serves ``p`` hosts and owns ``h`` global
links.  Routers within a group are all-to-all connected; each ordered pair
of groups is joined by exactly one global link whose endpoints follow the
standard consecutive assignment (group ``i``'s global-link slot ``m``
— slots enumerated router-major — lands on the ``m``-th *other* group).
The balanced configuration is ``a = 2p = 2h`` with ``g = a*h + 1`` groups;
smaller ``g`` is allowed as long as every pair of groups still has a
dedicated slot (``g - 1 <= a*h``).

Compute nodes are the hosts; switches appear only in the distance model.
Counting switch-level link traversals (as :class:`~repro.core.fattree.
FatTreeTopology` does):

    same host                              0 hops
    same router                            2 hops  (host-router-host)
    same group, different router           3 hops  (host-r-r-host)
    different groups                       3 + [src detour] + [dst detour]
                                           in {3, 4, 5}: one local hop on
                                           either side iff the endpoint's
                                           router is not the gateway owning
                                           that group pair's global link

Host ids are ordered (group, router, host), so *consecutive ids are
maximally co-located* — the property TOFA's consecutive-healthy-window
search and the resource-manager ordering assume, same as the fat-tree.

Fault weighting follows Eq. (1) in **endpoint form**: dragonflies are
multi-path fabrics (Valiant / adaptive routing detours around interior
failures), so only a faulty compute node that is itself a job endpoint
penalises a path — identical semantics to the fat-tree model.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import FAULT_PENALTY


@dataclasses.dataclass(frozen=True)
class DragonflyTopology:
    """Dragonfly of ``g`` groups x ``a`` routers x ``p`` hosts.

    ``p``  hosts per router, ``a`` routers per group, ``h`` global links
    per router, ``g`` groups (default the balanced maximum ``a*h + 1``).
    """

    p: int = 2
    a: int = 4
    h: int = 2
    g: int | None = None

    def __post_init__(self):
        if min(self.p, self.a, self.h) < 1:
            raise ValueError(
                f"dragonfly needs p, a, h >= 1, got ({self.p}, {self.a}, "
                f"{self.h})")
        g = self.a * self.h + 1 if self.g is None else self.g
        if g < 2:
            raise ValueError(f"dragonfly needs >= 2 groups, got {g}")
        if g - 1 > self.a * self.h:
            raise ValueError(
                f"g={g} groups need {g - 1} global-link slots per group "
                f"but a*h={self.a * self.h}; increase a or h")
        object.__setattr__(self, "g", g)

    # ------------------------------------------------------------------ basics
    @property
    def hosts_per_group(self) -> int:
        return self.a * self.p

    @property
    def n_groups(self) -> int:
        return self.g

    @property
    def n_nodes(self) -> int:
        return self.g * self.hosts_per_group

    def coords(self, node: int) -> tuple[int, int, int]:
        """Host id -> (group, router, host slot)."""
        grp, rest = divmod(node, self.hosts_per_group)
        router, host = divmod(rest, self.p)
        return (grp, router, host)

    def coords_array(self) -> np.ndarray:
        """(n_nodes, 3) (group, router, host) coordinates, id-ordered."""
        ids = np.arange(self.n_nodes)
        grp, rest = np.divmod(ids, self.hosts_per_group)
        router, host = np.divmod(rest, self.p)
        return np.stack([grp, router, host], axis=1)

    # ----------------------------------------------------------------- gateway
    def gateway_router(self, src_group: int, dst_group: int) -> int:
        """Router of ``src_group`` owning the global link to ``dst_group``.

        Slot ``m`` (the rank of ``dst_group`` among the other groups) lives
        on router ``m // h`` — the consecutive assignment, deterministic
        and consistent for both directions of a group pair.
        """
        if src_group == dst_group:
            raise ValueError("no global link within a group")
        m = dst_group - (dst_group > src_group)
        return m // self.h

    # --------------------------------------------------------------- distances
    def hop_matrix(self) -> np.ndarray:
        """(n, n) switch-level hop distances in {0, 2, 3, 4, 5}.

        Memoised on first use so topology construction stays O(1) and
        repeat callers share one dense matrix.
        """
        cached = self.__dict__.get("_hop_matrix")
        if cached is not None:
            return cached
        c = self.coords_array()
        grp, router = c[:, 0], c[:, 1]
        same_grp = grp[:, None] == grp[None, :]
        same_router = same_grp & (router[:, None] == router[None, :])
        # gateway detours for inter-group pairs: src side needs a local
        # hop iff its router does not own the slot toward the dst group
        # (and symmetrically on the dst side)
        dst_rank = grp[None, :] - (grp[None, :] > grp[:, None])  # m per pair
        src_rank = grp[:, None] - (grp[:, None] > grp[None, :])
        src_gw = dst_rank // self.h     # gateway router in the src group
        dst_gw = src_rank // self.h     # gateway router in the dst group
        hops = (3.0
                + (router[:, None] != src_gw)
                + (router[None, :] != dst_gw))
        hops[same_grp] = 3.0
        hops[same_router] = 2.0
        np.fill_diagonal(hops, 0.0)
        object.__setattr__(self, "_hop_matrix", hops)
        return hops

    def hierarchy_groups(self, target_groups: int = 64) -> np.ndarray:
        """(n,) group ids for hierarchical mapping.

        The dragonfly group is the natural "rack" (one electrical/global
        domain); when the caller wants finer granularity than ``g``
        groups, fall back to one group per router.
        """
        c = self.coords_array()
        if target_groups <= self.g:
            return c[:, 0].astype(np.int64)
        return (c[:, 0] * self.a + c[:, 1]).astype(np.int64)

    def weight_matrix(
        self,
        p_f: np.ndarray | None = None,
        c: float = 1.0,
        straggler: np.ndarray | None = None,
    ) -> np.ndarray:
        """Eq. (1) path weights in endpoint form.

        A path's only compute-node contacts are its two endpoints, so the
        weight is ``c * hops`` plus ``c * 100`` per faulty endpoint and
        ``c * s`` per straggling endpoint (slowdown factor ``s``) —
        identical semantics to the fat-tree model.
        """
        n = self.n_nodes
        w = c * self.hop_matrix()
        penalty = np.zeros(n)
        if p_f is not None:
            penalty += c * FAULT_PENALTY * (np.asarray(p_f, np.float64) > 0)
        if straggler is not None:
            penalty += c * np.asarray(straggler, dtype=np.float64)
        if (penalty > 0).any():
            extra = penalty[:, None] + penalty[None, :]
            np.fill_diagonal(extra, 0.0)
            w = w + extra
        return w

    def weight_matrix_update(
        self,
        W_prev: np.ndarray,
        changed,
        p_f: np.ndarray | None = None,
        c: float = 1.0,
        straggler: np.ndarray | None = None,
    ) -> np.ndarray:
        """Row-wise delta refresh of :meth:`weight_matrix`.

        Endpoint form: a node's health only enters through its own
        penalty term, so a change at node x invalidates exactly row x and
        column x (bit-identical to a full derivation).
        """
        changed = np.atleast_1d(np.asarray(changed, dtype=np.int64))
        if changed.size == 0:
            return W_prev
        n = self.n_nodes
        penalty = np.zeros(n)
        if p_f is not None:
            penalty += c * FAULT_PENALTY * (np.asarray(p_f, np.float64) > 0)
        if straggler is not None:
            penalty += c * np.asarray(straggler, dtype=np.float64)
        extra = penalty[:, None] + penalty[None, :]
        np.fill_diagonal(extra, 0.0)
        ref = c * self.hop_matrix() + extra
        W = W_prev.copy()
        W[changed, :] = ref[changed, :]
        W[:, changed] = ref[:, changed]
        return W
