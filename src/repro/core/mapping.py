"""Graph mapping: the Scotch dual-recursive-bipartitioning analogue.

The paper delegates the topology-mapping problem to the Scotch library
(``ScotchMap``).  This module implements the same class of algorithm from
scratch so the framework has no external solver dependency:

* ``bisect_graph``     weighted graph bisection of the guest (communication)
                       graph via greedy graph growing + Fiduccia–Mattheyses
                       (FM) boundary refinement.
* ``bisect_nodes``     bisection of the host (topology) node set.  For
                       contiguous torus windows this is a geometric split
                       along the longest bounding-box dimension (what Scotch's
                       architecture decomposition does for ``tleaf``/mesh
                       targets); for arbitrary weighted node sets it is a
                       distance-based sweep from a peripheral seed.
* ``map_graph``        dual recursive bipartitioning: recursively co-bisect
                       (processes, nodes) and assign at the leaves.
* ``select_nodes``     when |V_H| > |V_G|, greedily grow a compact,
                       low-weight (== healthy, per Eq. 1 weighting) node
                       subset — the mechanism by which the 100x fault penalty
                       steers the mapping away from failing nodes.

Quality metric: ``hop_bytes`` = sum_{i<j} G_v[i,j] * d(place_i, place_j) —
the standard dilation-volume objective these mappers minimise.
"""
from __future__ import annotations

import numpy as np


# --------------------------------------------------------------------------
# quality metrics
# --------------------------------------------------------------------------

def hop_bytes(G_v: np.ndarray, D: np.ndarray, placement: np.ndarray) -> float:
    """0.5 * sum_{ij} G_v[i,j] * D[place(i), place(j)] — lower is better.

    With the symmetric G_v convention (both directions accumulated into both
    entries) this equals sum over unordered pairs of bytes * distance; an
    asymmetric route-weight matrix D is implicitly symmetrised.
    """
    p = np.asarray(placement)
    return float(0.5 * (G_v * D[np.ix_(p, p)]).sum())


def avg_dilation(G_v: np.ndarray, D: np.ndarray, placement: np.ndarray) -> float:
    """Traffic-weighted mean hop distance."""
    tot = np.triu(G_v, 1).sum()
    if tot == 0:
        return 0.0
    return hop_bytes(G_v, D, placement) / float(tot)


# --------------------------------------------------------------------------
# guest graph bisection (greedy growing + FM refinement)
# --------------------------------------------------------------------------

def bisect_graph(
    W: np.ndarray,
    size0: int,
    rng: np.random.Generator | None = None,
    fm_passes: int = 4,
) -> np.ndarray:
    """Bisect vertices {0..n-1} of weighted graph W into parts of size
    (size0, n - size0), minimising cut weight.  Returns a bool array
    ``in_part0`` of length n."""
    n = W.shape[0]
    assert 0 <= size0 <= n
    if size0 == 0:
        return np.zeros(n, dtype=bool)
    if size0 == n:
        return np.ones(n, dtype=bool)
    rng = rng or np.random.default_rng(0)

    # --- greedy graph growing from a peripheral (weakly connected) vertex
    deg = W.sum(axis=1)
    seed = int(np.argmin(deg))  # peripheral vertex
    in0 = np.zeros(n, dtype=bool)
    in0[seed] = True
    # connection weight of every vertex to part 0
    conn = W[seed].copy()
    for _ in range(size0 - 1):
        conn_masked = np.where(in0, -np.inf, conn)
        nxt = int(np.argmax(conn_masked))
        if not np.isfinite(conn_masked[nxt]):
            nxt = int(rng.choice(np.flatnonzero(~in0)))
        in0[nxt] = True
        conn += W[nxt]

    # --- FM refinement: swap boundary pairs with positive combined gain.
    # gain(v) = (external weight) - (internal weight); moving v from its
    # part to the other changes the cut by -gain(v).  We do balanced *pair*
    # swaps (one from each side) so sizes stay exact.
    for _ in range(fm_passes):
        int0 = W[:, in0].sum(axis=1)       # weight to part 0
        int1 = W[:, ~in0].sum(axis=1)      # weight to part 1
        gain = np.where(in0, int1 - int0, int0 - int1)
        # candidate movers: top-k positive-gain vertices on each side
        side0 = np.flatnonzero(in0)
        side1 = np.flatnonzero(~in0)
        if side0.size == 0 or side1.size == 0:
            break
        a = side0[np.argsort(gain[side0])[::-1][:8]]
        b = side1[np.argsort(gain[side1])[::-1][:8]]
        best, pair = 0.0, None
        for u in a:
            for v in b:
                # swapping u<->v: delta_cut = -(gain_u + gain_v) + 2*W[u,v]
                d = gain[u] + gain[v] - 2.0 * W[u, v]
                if d > best + 1e-12:
                    best, pair = d, (u, v)
        if pair is None:
            break
        u, v = pair
        in0[u], in0[v] = False, True
    return in0


# --------------------------------------------------------------------------
# host node-set bisection
# --------------------------------------------------------------------------

def bisect_nodes(
    nodes: np.ndarray,
    coords: np.ndarray,
    size0: int,
    D: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Split ``nodes`` into (size0, rest) keeping each half compact.

    Geometric split: order nodes along the longest bounding-box dimension of
    their coordinates (lexicographic within), take the first ``size0``.
    Falls back to a distance sweep from a peripheral node when ``D`` is
    given and coordinates are degenerate (e.g. fault-weighted selection).
    """
    nodes = np.asarray(nodes)
    if size0 <= 0:
        return nodes[:0], nodes
    if size0 >= len(nodes):
        return nodes, nodes[:0]
    sub = coords[nodes]  # (m, ndim)
    spans = sub.max(axis=0) - sub.min(axis=0)
    dim = int(np.argmax(spans))
    if spans[dim] == 0 and D is not None:
        # all nodes co-located geometrically: sweep by weighted distance
        seed_local = 0
        order = np.argsort(D[nodes[seed_local]][nodes], kind="stable")
    else:
        key = [sub[:, dim]]
        for k in range(sub.shape[1]):
            if k != dim:
                key.append(sub[:, k])
        order = np.lexsort(tuple(reversed(key)))
    ordered = nodes[order]
    return ordered[:size0], ordered[size0:]


def snake_order(nodes: np.ndarray, coords: np.ndarray) -> np.ndarray:
    """Order ``nodes`` along a boustrophedon (snake) curve of their coords.

    Consecutive nodes in the returned order are (on full grids) one hop
    apart, which makes a sequential seed near-optimal for banded guests —
    the regular-pattern regime where the paper observes default-slurm
    winning (Section 5.1, LAMMPS 256).
    """
    nodes = np.asarray(nodes)
    sub = coords[nodes].astype(np.int64)
    eff = sub.copy()
    ndim = sub.shape[1]
    for d in range(1, ndim):
        parity = sub[:, :d].sum(axis=1) % 2
        hi = sub[:, d].max() if len(sub) else 0
        eff[:, d] = np.where(parity == 1, hi - sub[:, d], sub[:, d])
    order = np.lexsort(tuple(eff[:, d] for d in reversed(range(ndim))))
    return nodes[order]


# --------------------------------------------------------------------------
# node subset selection (|V_H| > |V_G|)
# --------------------------------------------------------------------------

def select_nodes(D: np.ndarray, count: int, seed: int | None = None) -> np.ndarray:
    """Greedily grow a compact low-weight subset of ``count`` nodes.

    ``D`` is the (fault-aware) pairwise weight matrix of the full topology.
    Start from the node with the lowest total weight to its ``count``
    nearest peers (cheapest healthy region) and repeatedly add the node with
    minimum total weight to the chosen set.  The Eq. 1 fault penalty (100x)
    makes faulty nodes effectively unselectable unless unavoidable.
    """
    n = D.shape[0]
    count = min(count, n)
    if seed is None:
        # cost of the best `count`-node ball centred at each node
        part = np.partition(D, count - 1, axis=1)[:, :count]
        seed = int(np.argmin(part.sum(axis=1)))
    chosen = np.zeros(n, dtype=bool)
    chosen[seed] = True
    cost = D[seed].copy()
    for _ in range(count - 1):
        masked = np.where(chosen, np.inf, cost)
        nxt = int(np.argmin(masked))
        chosen[nxt] = True
        cost += D[nxt]
    return np.flatnonzero(chosen)


def best_map(G_w, node_sets, coords, D, rng) -> np.ndarray:
    """Map onto each candidate node subset, keep the lowest hop-bytes."""
    best, best_hb = None, np.inf
    for nodes in node_sets:
        pl = map_graph(G_w, np.asarray(nodes), coords, D=D, rng=rng)
        hb = hop_bytes(G_w, D, pl)
        if hb < best_hb:
            best, best_hb = pl, hb
    return best


# --------------------------------------------------------------------------
# dual recursive bipartitioning
# --------------------------------------------------------------------------

def map_graph(
    G_w: np.ndarray,
    nodes: np.ndarray,
    coords: np.ndarray,
    D: np.ndarray | None = None,
    rng: np.random.Generator | None = None,
    refine: bool = True,
    portfolio: bool = True,
) -> np.ndarray:
    """ScotchMap analogue: map processes {0..n-1} onto ``nodes``.

    ``G_w``    (n, n) guest edge weights (bytes, typically CommGraph.G_v)
    ``nodes``  host node ids available (len >= n)
    ``coords`` (N, ndim) coordinates of *all* host nodes (for geometric
               bisection)
    ``D``      optional (N, N) weight matrix for refinement + degenerate
               splits

    Like Scotch, runs a small strategy *portfolio*: dual recursive
    bipartitioning AND a sequential seed (which is near-optimal for banded /
    regular patterns — cf. the paper's LAMMPS discussion in Section 5.1),
    refines each with pairwise swaps, and keeps the best by hop-bytes.

    Returns placement: array of node ids, one per process.
    """
    n = G_w.shape[0]
    nodes = np.asarray(nodes)
    assert len(nodes) >= n, "not enough nodes"
    rng = rng or np.random.default_rng(0)
    placement = np.full(n, -1, dtype=np.int64)

    def rec(procs: np.ndarray, navail: np.ndarray) -> None:
        if len(procs) == 0:
            return
        if len(procs) == 1:
            # put the single proc on the first node (splits kept compact)
            placement[procs[0]] = navail[0]
            return
        half_nodes = len(navail) // 2
        # processes split proportionally to the node halves, but never more
        # procs than nodes on either side
        p0 = min(max(len(procs) * half_nodes // len(navail),
                     len(procs) - (len(navail) - half_nodes)), half_nodes)
        sub = G_w[np.ix_(procs, procs)]
        in0 = bisect_graph(sub, p0, rng=rng)
        n0, n1 = bisect_nodes(navail, coords, half_nodes, D=D)
        rec(procs[in0], n0)
        rec(procs[~in0], n1)

    rec(np.arange(n), nodes)

    if D is None:
        return placement

    candidates = [placement]
    if portfolio:
        # sequential seed: process i -> i-th node along a snake curve of the
        # available nodes (near-optimal chain for banded guests)
        candidates.append(snake_order(nodes, coords)[:n].copy())
    if refine:
        candidates = [_pairwise_refine(G_w, D, c) for c in candidates]
    scores = [hop_bytes(G_w, D, c) for c in candidates]
    return candidates[int(np.argmin(scores))]


def _pairwise_refine(
    G_w: np.ndarray, D: np.ndarray, placement: np.ndarray,
    max_passes: int = 3,
) -> np.ndarray:
    """Greedy pairwise-swap refinement of a full placement under hop-bytes.

    After recursive bipartitioning, try swapping the node assignments of
    process pairs when it lowers sum_ij G_w[i,j] * D[p_i, p_j].  This is the
    mapping-level counterpart of Scotch's recursive refinement and typically
    shaves another few percent of hop-bytes.
    """
    p = placement.copy()
    n = len(p)
    for _ in range(max_passes):
        improved = False
        # cost contribution of each process: c_i = sum_j G_w[i,j] D[p_i, p_j]
        Dp = D[np.ix_(p, p)]
        contrib = (G_w * Dp).sum(axis=1)
        order = np.argsort(contrib)[::-1][: min(n, 64)]  # worst offenders
        for i in order:
            best_d, best_j = 0.0, -1
            mask = np.ones(n, dtype=bool)
            mask[i] = False
            for j in range(n):
                if j == i:
                    continue
                mask[j] = False
                pi, pj = p[j], p[i]  # candidate swapped assignments
                # cost with i@pi, j@pj vs current, others fixed
                new = float(G_w[i, mask] @ D[pi][p[mask]]) \
                    + float(G_w[j, mask] @ D[pj][p[mask]]) \
                    + G_w[i, j] * D[pi, pj]
                old = float(G_w[i, mask] @ D[p[i]][p[mask]]) \
                    + float(G_w[j, mask] @ D[p[j]][p[mask]]) \
                    + G_w[i, j] * D[p[i], p[j]]
                mask[j] = True
                d = old - new
                if d > best_d + 1e-9:
                    best_d, best_j = d, j
            if best_j >= 0:
                p[i], p[best_j] = p[best_j], p[i]
                improved = True
        if not improved:
            break
    return p


# --------------------------------------------------------------------------
# baseline placement policies of Section 5.1
# --------------------------------------------------------------------------

def linear_placement(n_procs: int, nodes: np.ndarray) -> np.ndarray:
    """default-slurm: iterate available nodes sequentially."""
    nodes = np.asarray(nodes)
    return nodes[:n_procs].copy()


def random_placement(
    n_procs: int, nodes: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    nodes = np.asarray(nodes)
    return rng.choice(nodes, size=n_procs, replace=False)


def greedy_placement(
    G_w: np.ndarray, nodes: np.ndarray, D: np.ndarray,
) -> np.ndarray:
    """The paper's Greedy baseline: sort process pairs by traffic, place the
    heaviest pairs as close as possible (starting from one hop)."""
    n = G_w.shape[0]
    nodes = np.asarray(nodes)
    iu = np.triu_indices(n, 1)
    order = np.argsort(G_w[iu])[::-1]
    pairs = list(zip(iu[0][order], iu[1][order]))

    placement = np.full(n, -1, dtype=np.int64)
    used = np.zeros(D.shape[0], dtype=bool)
    avail_mask = np.zeros(D.shape[0], dtype=bool)
    avail_mask[nodes] = True

    def nearest_free(anchor: int) -> int:
        cand = np.where(~used & avail_mask, D[anchor], np.inf)
        return int(np.argmin(cand))

    def first_free() -> int:
        free = np.flatnonzero(~used & avail_mask)
        return int(free[0])

    for i, j in pairs:
        if G_w[i, j] <= 0:
            break
        pi, pj = placement[i], placement[j]
        if pi < 0 and pj < 0:
            a = first_free()
            placement[i] = a
            used[a] = True
            b = nearest_free(a)
            placement[j] = b
            used[b] = True
        elif pi < 0:
            a = nearest_free(pj)
            placement[i] = a
            used[a] = True
        elif pj < 0:
            b = nearest_free(pi)
            placement[j] = b
            used[b] = True
    # any untouched processes (no traffic): fill linearly
    for i in range(n):
        if placement[i] < 0:
            a = first_free()
            placement[i] = a
            used[a] = True
    return placement
