"""Graph mapping: the Scotch dual-recursive-bipartitioning analogue.

The paper delegates the topology-mapping problem to the Scotch library
(``ScotchMap``).  This module implements the same class of algorithm from
scratch so the framework has no external solver dependency:

* ``bisect_graph``     weighted graph bisection of the guest (communication)
                       graph via greedy graph growing + Fiduccia–Mattheyses
                       (FM) boundary refinement.
* ``bisect_nodes``     bisection of the host (topology) node set.  For
                       contiguous torus windows this is a geometric split
                       along the longest bounding-box dimension (what Scotch's
                       architecture decomposition does for ``tleaf``/mesh
                       targets); for arbitrary weighted node sets it is a
                       distance-based sweep from a peripheral seed.
* ``map_graph``        dual recursive bipartitioning: recursively co-bisect
                       (processes, nodes) and assign at the leaves.
* ``select_nodes``     when |V_H| > |V_G|, greedily grow a compact,
                       low-weight (== healthy, per Eq. 1 weighting) node
                       subset — the mechanism by which the 100x fault penalty
                       steers the mapping away from failing nodes.

Quality metric: ``hop_bytes`` = sum_{i<j} G_v[i,j] * d(place_i, place_j) —
the standard dilation-volume objective these mappers minimise.

Performance: the hot kernels (``_pairwise_refine``, ``bisect_graph``,
``select_nodes``, ``greedy_placement``) are array-level NumPy
implementations in the style of high-performance mapping codes (cf. Schulz
& Träff, "Better Process Mapping and Sparse Quadratic Assignment"):
per-process cost contributions are precomputed once, every candidate swap
gain for a mover is evaluated with one matvec over the gathered distance
matrix, and contributions are updated incrementally in O(n) after each
accepted move instead of re-gathered per pass.  The original scalar-loop
versions are retained as ``*_reference`` — they define the quality floor
the vectorized kernels are differentially tested against
(``tests/test_mapping_diff.py``) and the baseline ``benchmarks/refine_scale``
measures speedups from.

Backends: the hot kernels dispatch through :mod:`repro.core.backend`.
The default ``numpy`` backend runs the implementations in this file,
pinned to float64.  With the optional ``jax`` backend active
(``backend.use("jax")`` / ``REPRO_BACKEND=jax`` /
``PlacementEngine(backend="jax")``), ``hop_bytes``/``hop_bytes_batch``,
``_pairwise_refine``, ``select_nodes`` and ``greedy_placement`` run the
jit-compiled kernels of :mod:`repro.core.mapping_jax` — decision-identical
at float64 (bit-identical placements for the integer-weighted in-tree
workloads), with all candidate refinements of one mapping call batched
into a single device dispatch.  Asymmetric guest matrices (outside the
CommGraph convention) silently fall back to the NumPy kernels.  Inside
``use_reference_impl`` the retained scalar loops always run, regardless
of backend — they are the fixed baseline.
"""
from __future__ import annotations

import contextlib

import numpy as np

from . import backend as _backend
from .lazydist import is_lazy


def _jax_kernels(G_w: np.ndarray | None = None, D=None):
    """The jitted kernel module when the jax backend should serve this
    call, else None (numpy path).  ``G_w`` adds the symmetric-guest
    check for guest-dependent kernels; ``D`` adds the lazy-distance
    check — a lazy adapter is served only when the backend can compute
    its entries in-kernel (implicit torus), otherwise the NumPy kernels
    run against the adapter's ``__getitem__``."""
    be = _backend.active()
    if not getattr(be, "is_jax", False):
        return None
    from . import mapping_jax
    if G_w is not None and not mapping_jax.guest_supported(G_w):
        return None
    if D is not None and is_lazy(D) and not mapping_jax.lazy_supported(D):
        return None
    return mapping_jax


# --------------------------------------------------------------------------
# quality metrics
# --------------------------------------------------------------------------

def hop_bytes(G_v: np.ndarray, D: np.ndarray, placement: np.ndarray) -> float:
    """0.5 * sum_{ij} G_v[i,j] * D[place(i), place(j)] — lower is better.

    With the symmetric G_v convention (both directions accumulated into both
    entries) this equals sum over unordered pairs of bytes * distance; an
    asymmetric route-weight matrix D is implicitly symmetrised.
    """
    jx = _jax_kernels(G_v, D)
    if jx is not None:
        return jx.hop_bytes(G_v, D, placement)
    p = np.asarray(placement)
    return float(0.5 * (G_v * D[np.ix_(p, p)]).sum())


def hop_bytes_batch(
    G_v: np.ndarray, D: np.ndarray, placements: np.ndarray,
    max_block_elems: int = 64_000_000,
) -> np.ndarray:
    """Score a stack of candidate placements in one batched gather.

    ``placements`` is (k, n); returns (k,) hop-bytes.  The D gather is
    blocked so at most ``max_block_elems`` distance entries are materialised
    at once (the k*n*n intermediate would otherwise dominate memory for
    many candidates at large n).
    """
    P = np.asarray(placements)
    if P.ndim == 1:
        return np.array([hop_bytes(G_v, D, P)])
    jx = _jax_kernels(G_v, D)
    if jx is not None:
        return jx.hop_bytes_batch(G_v, D, P)
    k, n = P.shape
    out = np.empty(k, dtype=np.float64)
    step = max(1, int(max_block_elems // max(n * n, 1)))
    for s in range(0, k, step):
        blk = P[s:s + step]
        gathered = D[blk[:, :, None], blk[:, None, :]]   # (b, n, n)
        out[s:s + step] = 0.5 * np.einsum("ij,kij->k", G_v, gathered)
    return out


def avg_dilation(G_v: np.ndarray, D: np.ndarray, placement: np.ndarray) -> float:
    """Traffic-weighted mean hop distance."""
    tot = np.triu(G_v, 1).sum()
    if tot == 0:
        return 0.0
    return hop_bytes(G_v, D, placement) / float(tot)


# --------------------------------------------------------------------------
# guest graph bisection (greedy growing + FM refinement)
# --------------------------------------------------------------------------

def bisect_graph(
    W: np.ndarray,
    size0: int,
    rng: np.random.Generator | None = None,
    fm_passes: int | None = None,
) -> np.ndarray:
    """Bisect vertices {0..n-1} of weighted graph W into parts of size
    (size0, n - size0), minimising cut weight.  Returns a bool array
    ``in_part0`` of length n.

    Vectorized kernel: greedy growing keeps the part-0 connection vector
    masked in place (chosen entries pinned to -inf, no fresh ``np.where``
    allocation per step) and FM refinement maintains per-vertex gains
    incrementally — a swap updates ``int0`` by ``±W[:, moved]`` rows
    instead of re-summing ``W[:, in0]`` each pass — and evaluates all
    top-k x top-k pair deltas as one broadcast matrix.

    ``fm_passes`` caps FM refinement passes (one swap each); ``None``
    (default) runs until no improving pair remains — incremental gains
    make extra passes nearly free, and deeper descent keeps this kernel
    equal-or-better than the 4-pass scalar reference.
    """
    n = W.shape[0]
    assert 0 <= size0 <= n
    if size0 == 0:
        return np.zeros(n, dtype=bool)
    if size0 == n:
        return np.ones(n, dtype=bool)
    rng = rng or np.random.default_rng(0)

    # --- greedy graph growing from a peripheral (weakly connected) vertex
    deg = W.sum(axis=1)
    seed = int(np.argmin(deg))  # peripheral vertex
    in0 = np.zeros(n, dtype=bool)
    in0[seed] = True
    # connection weight of every vertex to part 0; chosen vertices are kept
    # pinned at -inf so the running argmax needs no per-step re-mask
    conn = W[seed].astype(np.float64, copy=True)
    conn[seed] = -np.inf
    for _ in range(size0 - 1):
        nxt = int(np.argmax(conn))
        if not np.isfinite(conn[nxt]):
            nxt = int(rng.choice(np.flatnonzero(~in0)))
        in0[nxt] = True
        conn += W[nxt]           # -inf entries stay -inf
        conn[nxt] = -np.inf

    # --- FM refinement: swap boundary pairs with positive combined gain.
    # gain(v) = (external weight) - (internal weight); moving v from its
    # part to the other changes the cut by -gain(v).  We do balanced *pair*
    # swaps (one from each side) so sizes stay exact.  ``int0`` (weight to
    # part 0) is maintained incrementally across passes; each pass applies
    # one swap, so n bounds the useful pass count.
    int0 = W @ in0
    max_passes = n if fm_passes is None else fm_passes
    for _ in range(max_passes):
        gain = np.where(in0, deg - 2.0 * int0, 2.0 * int0 - deg)
        side0 = np.flatnonzero(in0)
        side1 = np.flatnonzero(~in0)
        if side0.size == 0 or side1.size == 0:
            break
        a = side0[np.argsort(gain[side0])[::-1][:8]]
        b = side1[np.argsort(gain[side1])[::-1][:8]]
        # swapping u<->v: delta_cut = -(gain_u + gain_v) + 2*W[u,v]
        d = gain[a][:, None] + gain[b][None, :] - 2.0 * W[np.ix_(a, b)]
        flat = int(np.argmax(d))
        if d.flat[flat] <= 1e-12:
            break
        u, v = int(a[flat // len(b)]), int(b[flat % len(b)])
        in0[u], in0[v] = False, True
        int0 += W[:, v] - W[:, u]
    return in0


def bisect_graph_reference(
    W: np.ndarray,
    size0: int,
    rng: np.random.Generator | None = None,
    fm_passes: int = 4,
) -> np.ndarray:
    """Retained scalar-loop bisection (quality floor for differential tests)."""
    n = W.shape[0]
    assert 0 <= size0 <= n
    if size0 == 0:
        return np.zeros(n, dtype=bool)
    if size0 == n:
        return np.ones(n, dtype=bool)
    rng = rng or np.random.default_rng(0)

    deg = W.sum(axis=1)
    seed = int(np.argmin(deg))
    in0 = np.zeros(n, dtype=bool)
    in0[seed] = True
    conn = W[seed].copy()
    for _ in range(size0 - 1):
        conn_masked = np.where(in0, -np.inf, conn)
        nxt = int(np.argmax(conn_masked))
        if not np.isfinite(conn_masked[nxt]):
            nxt = int(rng.choice(np.flatnonzero(~in0)))
        in0[nxt] = True
        conn += W[nxt]

    for _ in range(fm_passes):
        int0 = W[:, in0].sum(axis=1)
        int1 = W[:, ~in0].sum(axis=1)
        gain = np.where(in0, int1 - int0, int0 - int1)
        side0 = np.flatnonzero(in0)
        side1 = np.flatnonzero(~in0)
        if side0.size == 0 or side1.size == 0:
            break
        a = side0[np.argsort(gain[side0])[::-1][:8]]
        b = side1[np.argsort(gain[side1])[::-1][:8]]
        best, pair = 0.0, None
        for u in a:
            for v in b:
                d = gain[u] + gain[v] - 2.0 * W[u, v]
                if d > best + 1e-12:
                    best, pair = d, (u, v)
        if pair is None:
            break
        u, v = pair
        in0[u], in0[v] = False, True
    return in0


def cut_weight(W: np.ndarray, in0: np.ndarray) -> float:
    """Total weight crossing the (in0, ~in0) bisection — lower is better."""
    return float(W[np.ix_(in0, ~in0)].sum())


# --------------------------------------------------------------------------
# host node-set bisection
# --------------------------------------------------------------------------

def bisect_nodes(
    nodes: np.ndarray,
    coords: np.ndarray,
    size0: int,
    D: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Split ``nodes`` into (size0, rest) keeping each half compact.

    Geometric split: order nodes along the longest bounding-box dimension of
    their coordinates (lexicographic within), take the first ``size0``.
    Falls back to a distance sweep from a peripheral node when ``D`` is
    given and coordinates are degenerate (e.g. fault-weighted selection).
    """
    nodes = np.asarray(nodes)
    if size0 <= 0:
        return nodes[:0], nodes
    if size0 >= len(nodes):
        return nodes, nodes[:0]
    sub = coords[nodes]  # (m, ndim)
    spans = sub.max(axis=0) - sub.min(axis=0)
    dim = int(np.argmax(spans))
    if spans[dim] == 0 and D is not None:
        # all nodes co-located geometrically: sweep by weighted distance
        seed_local = 0
        order = np.argsort(D[nodes[seed_local]][nodes], kind="stable")
    else:
        key = [sub[:, dim]]
        for k in range(sub.shape[1]):
            if k != dim:
                key.append(sub[:, k])
        order = np.lexsort(tuple(reversed(key)))
    ordered = nodes[order]
    return ordered[:size0], ordered[size0:]


def snake_order(nodes: np.ndarray, coords: np.ndarray) -> np.ndarray:
    """Order ``nodes`` along a boustrophedon (snake) curve of their coords.

    Consecutive nodes in the returned order are (on full grids) one hop
    apart, which makes a sequential seed near-optimal for banded guests —
    the regular-pattern regime where the paper observes default-slurm
    winning (Section 5.1, LAMMPS 256).
    """
    nodes = np.asarray(nodes)
    sub = coords[nodes].astype(np.int64)
    eff = sub.copy()
    ndim = sub.shape[1]
    for d in range(1, ndim):
        parity = sub[:, :d].sum(axis=1) % 2
        hi = sub[:, d].max() if len(sub) else 0
        eff[:, d] = np.where(parity == 1, hi - sub[:, d], sub[:, d])
    order = np.lexsort(tuple(eff[:, d] for d in reversed(range(ndim))))
    return nodes[order]


# --------------------------------------------------------------------------
# node subset selection (|V_H| > |V_G|)
# --------------------------------------------------------------------------

def select_nodes(D: np.ndarray, count: int, seed: int | None = None) -> np.ndarray:
    """Greedily grow a compact low-weight subset of ``count`` nodes.

    ``D`` is the (fault-aware) pairwise weight matrix of the full topology.
    Start from the node with the lowest total weight to its ``count``
    nearest peers (cheapest healthy region) and repeatedly add the node with
    minimum total weight to the chosen set.  The Eq. 1 fault penalty (100x)
    makes faulty nodes effectively unselectable unless unavoidable.

    The frontier cost vector is maintained in place across steps — chosen
    entries are pinned to +inf, so each step is one argmin + one row add,
    with no per-step masked copy of the full N-node array.
    """
    lazy = is_lazy(D)
    jx = None if lazy else _jax_kernels()
    if jx is not None:
        return jx.select_nodes(D, count, seed=seed)
    n = D.shape[0]
    count = min(count, n)
    if seed is None:
        if lazy:
            # blocked row generation keeps peak memory O(block * n); the
            # hierarchical policies pass an explicit seed at scale, this
            # path is the small-n / direct-call fallback
            best, seed = np.inf, 0
            step = max(1, 8_000_000 // max(n, 1))
            rows_idx = np.arange(n)
            for s in range(0, n, step):
                rows = D[rows_idx[s:s + step]]
                part = np.partition(rows, count - 1, axis=1)[:, :count]
                sums = part.sum(axis=1)
                k = int(np.argmin(sums))
                if sums[k] < best:
                    best, seed = float(sums[k]), s + k
        else:
            # cost of the best `count`-node ball centred at each node
            part = np.partition(D, count - 1, axis=1)[:, :count]
            seed = int(np.argmin(part.sum(axis=1)))
    chosen = np.zeros(n, dtype=bool)
    chosen[seed] = True
    cost = D[seed].astype(np.float64, copy=True)
    cost[seed] = np.inf
    for _ in range(count - 1):
        nxt = int(np.argmin(cost))
        chosen[nxt] = True
        cost += D[nxt]           # +inf entries stay +inf
        cost[nxt] = np.inf
    return np.flatnonzero(chosen)


def select_nodes_reference(
    D: np.ndarray, count: int, seed: int | None = None
) -> np.ndarray:
    """Retained scalar-masking subset growth (differential-test floor)."""
    n = D.shape[0]
    count = min(count, n)
    if seed is None:
        part = np.partition(D, count - 1, axis=1)[:, :count]
        seed = int(np.argmin(part.sum(axis=1)))
    chosen = np.zeros(n, dtype=bool)
    chosen[seed] = True
    cost = D[seed].copy()
    for _ in range(count - 1):
        masked = np.where(chosen, np.inf, cost)
        nxt = int(np.argmin(masked))
        chosen[nxt] = True
        cost += D[nxt]
    return np.flatnonzero(chosen)


def refine_batch(G_w: np.ndarray, D: np.ndarray, placements: np.ndarray,
                 ) -> np.ndarray:
    """Refine a (k, n) stack of candidate placements.

    On the numpy backend this loops the module-global ``_pairwise_refine``
    (so ``use_reference_impl`` still applies); on the jax backend the
    whole stack refines in one jitted, vmapped device dispatch.
    """
    P = np.stack([np.asarray(p) for p in placements]) \
        if not isinstance(placements, np.ndarray) else placements
    refiner = globals()["_pairwise_refine"]
    # dispatch to the jitted batch only when the *vectorized* kernel is
    # installed — under use_reference_impl the global is the scalar
    # reference, which must run regardless of backend (compare against
    # the saved original: the bare name would resolve to the same
    # swapped global and never detect reference mode)
    if refiner is _VECTORIZED_IMPL.get("_pairwise_refine"):
        jx = _jax_kernels(G_w, D)
        if jx is not None:
            return jx.refine_many(G_w, D, P)
    return np.stack([refiner(G_w, D, p) for p in P])


def best_map(G_w, node_sets, coords, D, rng) -> np.ndarray:
    """Map onto each candidate node subset, keep the lowest hop-bytes.

    Candidate generation (dual recursive bipartitioning + snake seed per
    node set) stays host-side; *all* resulting candidates are refined as
    one ``refine_batch`` stack and scored in one ``hop_bytes_batch``
    evaluation — on the jax backend that is a single device dispatch for
    TOFA's entire multi-candidate search.  Equivalent to mapping each
    set independently and keeping the best: the global argmin over
    refined candidates is the min of the per-set minima, with the same
    first-occurrence tie-break.
    """
    candidates: list[np.ndarray] = []
    for nodes in node_sets:
        candidates += _map_candidates(G_w, np.asarray(nodes), coords, D, rng)
    refined = refine_batch(G_w, D, np.stack(candidates))
    scores = hop_bytes_batch(G_w, D, refined)
    return refined[int(np.argmin(scores))]


# --------------------------------------------------------------------------
# dual recursive bipartitioning
# --------------------------------------------------------------------------

def map_graph(
    G_w: np.ndarray,
    nodes: np.ndarray,
    coords: np.ndarray,
    D: np.ndarray | None = None,
    rng: np.random.Generator | None = None,
    refine: bool = True,
    portfolio: bool = True,
) -> np.ndarray:
    """ScotchMap analogue: map processes {0..n-1} onto ``nodes``.

    ``G_w``    (n, n) guest edge weights (bytes, typically CommGraph.G_v)
    ``nodes``  host node ids available (len >= n)
    ``coords`` (N, ndim) coordinates of *all* host nodes (for geometric
               bisection)
    ``D``      optional (N, N) weight matrix for refinement + degenerate
               splits

    Like Scotch, runs a small strategy *portfolio*: dual recursive
    bipartitioning AND a sequential seed (which is near-optimal for banded /
    regular patterns — cf. the paper's LAMMPS discussion in Section 5.1),
    refines each with pairwise swaps, and keeps the best by hop-bytes.

    Returns placement: array of node ids, one per process.
    """
    candidates = _map_candidates(G_w, np.asarray(nodes), coords, D, rng,
                                 portfolio=portfolio)
    if D is None:
        return candidates[0]
    stack = np.stack(candidates)
    if refine:
        stack = refine_batch(G_w, D, stack)
    scores = hop_bytes_batch(G_w, D, stack)
    return stack[int(np.argmin(scores))]


def _map_candidates(
    G_w: np.ndarray,
    nodes: np.ndarray,
    coords: np.ndarray,
    D: np.ndarray | None,
    rng: np.random.Generator | None,
    portfolio: bool = True,
) -> list[np.ndarray]:
    """Unrefined candidate placements of one (guest, node set) mapping:
    dual recursive bipartitioning, plus (with ``D`` and ``portfolio``)
    the sequential snake seed.  Shared by :func:`map_graph` and
    :func:`best_map` so multi-set searches can refine every candidate in
    one batch."""
    n = G_w.shape[0]
    nodes = np.asarray(nodes)
    assert len(nodes) >= n, "not enough nodes"
    rng = rng or np.random.default_rng(0)
    placement = np.full(n, -1, dtype=np.int64)

    def rec(procs: np.ndarray, navail: np.ndarray) -> None:
        if len(procs) == 0:
            return
        if len(procs) == 1:
            # put the single proc on the first node (splits kept compact)
            placement[procs[0]] = navail[0]
            return
        half_nodes = len(navail) // 2
        # processes split proportionally to the node halves, but never more
        # procs than nodes on either side
        p0 = min(max(len(procs) * half_nodes // len(navail),
                     len(procs) - (len(navail) - half_nodes)), half_nodes)
        sub = G_w[np.ix_(procs, procs)]
        in0 = bisect_graph(sub, p0, rng=rng)
        n0, n1 = bisect_nodes(navail, coords, half_nodes, D=D)
        rec(procs[in0], n0)
        rec(procs[~in0], n1)

    rec(np.arange(n), nodes)

    if D is None:
        return [placement]
    candidates = [placement]
    if portfolio:
        # sequential seed: process i -> i-th node along a snake curve of the
        # available nodes (near-optimal chain for banded guests)
        candidates.append(snake_order(nodes, coords)[:n].copy())
    return candidates


def _pairwise_refine(
    G_w: np.ndarray, D: np.ndarray, placement: np.ndarray,
    max_passes: int = 3, movers: int = 64, extra_passes: int = 13,
) -> np.ndarray:
    """Greedy pairwise-swap refinement of a full placement under hop-bytes.

    Delta-based vectorized kernel.  State kept across swaps:

      M        = sym(D)[p, p]  — gathered pairwise distances of the placement
      C        = G_w * M       — per-pair cost terms
      contrib  = C.sum(1)      — per-process cost contribution

    For a mover ``i`` the gain of swapping with *every* ``j`` is one
    broadcast expression (two matvecs, no inner Python loop):

      gain = contrib[i] + contrib - 2*C[i] - M @ G_w[i] - G_w @ M[i]

    (the i<->j mutual term cancels because swapping endpoints preserves
    their own distance).  An accepted swap updates M, C and contrib
    incrementally in O(n) — two row/column gathers — instead of
    recomputing the O(n^2) gather per pass.

    Passes beyond ``max_passes`` (up to ``extra_passes`` more) continue only
    while improving: they are nearly free at array speed and let the refiner
    descend at least as far as the scalar reference, which stops after
    ``max_passes`` regardless.  A pass that accepts no swap leaves all state
    unchanged, so the first such pass terminates refinement.

    Mover order uses a *stable* descending sort so the swap sequence is a
    deterministic function of the inputs — the contract the jax backend's
    decision-identical port (:mod:`repro.core.mapping_jax`) relies on.
    """
    p = placement.copy()
    n = len(p)
    if n <= 1:
        return p
    jx = _jax_kernels(G_w, D)
    if jx is not None:
        return jx.pairwise_refine(G_w, D, p, max_passes=max_passes,
                                  movers=movers, extra_passes=extra_passes)
    G = G_w
    if np.count_nonzero(np.diagonal(G)):
        G = G.copy()
        np.fill_diagonal(G, 0.0)
    # symmetrise lazily on the gathered submatrix (hop_bytes implicitly
    # symmetrises an asymmetric D, so the refiner must optimise the same
    # objective); for the in-tree topologies D is already symmetric
    M = D[np.ix_(p, p)].astype(np.float64)
    M = 0.5 * (M + M.T)
    C = G * M
    contrib = C.sum(axis=1)

    def gathered_row(node: int) -> np.ndarray:
        return 0.5 * (D[node, p] + D[p, node])

    for _ in range(max_passes + extra_passes):
        improved = False
        # worst offenders first; stable descending (ties keep index order)
        # so the swap sequence is deterministic and exactly replicable by
        # the jax port
        order = np.argsort(-contrib, kind="stable")[: min(n, movers)]
        for i in order:
            gains = (contrib[i] + contrib - 2.0 * C[i]
                     - M @ G[i] - G @ M[i])
            gains[i] = 0.0
            j = int(np.argmax(gains))
            if gains[j] <= 1e-9:
                continue
            # accept swap (i, j); update all state in O(n)
            p[i], p[j] = p[j], p[i]
            old_col_i, old_col_j = M[:, i].copy(), M[:, j].copy()
            row_i, row_j = gathered_row(p[i]), gathered_row(p[j])
            M[i, :] = row_i
            M[:, i] = row_i
            M[j, :] = row_j
            M[:, j] = row_j
            M[i, j] = M[j, i] = row_i[j]
            contrib += (G[:, i] * (M[:, i] - old_col_i)
                        + G[:, j] * (M[:, j] - old_col_j))
            C[i, :] = G[i] * M[i]
            C[:, i] = C[i, :]
            C[j, :] = G[j] * M[j]
            C[:, j] = C[j, :]
            contrib[i] = C[i].sum()
            contrib[j] = C[j].sum()
            improved = True
        if not improved:
            break
    return p


def _pairwise_refine_reference(
    G_w: np.ndarray, D: np.ndarray, placement: np.ndarray,
    max_passes: int = 3,
) -> np.ndarray:
    """Retained scalar-loop refiner (quality floor for differential tests).

    O(passes * movers * n^2) with Python-level inner loops — the pre-
    vectorization hot path that dominated placement wall time.
    """
    p = placement.copy()
    n = len(p)
    for _ in range(max_passes):
        improved = False
        # cost contribution of each process: c_i = sum_j G_w[i,j] D[p_i, p_j]
        Dp = D[np.ix_(p, p)]
        contrib = (G_w * Dp).sum(axis=1)
        # worst offenders, stable descending — same deterministic mover
        # order as the vectorized kernel so the comparison stays paired
        order = np.argsort(-contrib, kind="stable")[: min(n, 64)]
        for i in order:
            best_d, best_j = 0.0, -1
            mask = np.ones(n, dtype=bool)
            mask[i] = False
            for j in range(n):
                if j == i:
                    continue
                mask[j] = False
                pi, pj = p[j], p[i]  # candidate swapped assignments
                # cost with i@pi, j@pj vs current, others fixed
                new = float(G_w[i, mask] @ D[pi][p[mask]]) \
                    + float(G_w[j, mask] @ D[pj][p[mask]]) \
                    + G_w[i, j] * D[pi, pj]
                old = float(G_w[i, mask] @ D[p[i]][p[mask]]) \
                    + float(G_w[j, mask] @ D[p[j]][p[mask]]) \
                    + G_w[i, j] * D[p[i], p[j]]
                mask[j] = True
                d = old - new
                if d > best_d + 1e-9:
                    best_d, best_j = d, j
            if best_j >= 0:
                p[i], p[best_j] = p[best_j], p[i]
                improved = True
        if not improved:
            break
    return p


# --------------------------------------------------------------------------
# reference-implementation switch (differential tests / baseline benchmarks)
# --------------------------------------------------------------------------

_VECTORIZED_IMPL = {}   # populated after greedy_placement is defined


@contextlib.contextmanager
def use_reference_impl():
    """Temporarily swap the retained loop kernels into the mapping pipeline.

    Inside the context, ``map_graph``/``best_map`` (and policies that
    resolve kernels through this module) run the pre-vectorization
    implementations — the baseline that ``benchmarks/refine_scale``
    measures speedups against and differential tests compare quality with.
    """
    g = globals()
    saved = {name: g[name] for name in _VECTORIZED_IMPL}
    g.update({name: g[name + "_reference"] for name in _VECTORIZED_IMPL})
    try:
        yield
    finally:
        g.update(saved)


# --------------------------------------------------------------------------
# baseline placement policies of Section 5.1
# --------------------------------------------------------------------------

def linear_placement(n_procs: int, nodes: np.ndarray) -> np.ndarray:
    """default-slurm: iterate available nodes sequentially."""
    nodes = np.asarray(nodes)
    return nodes[:n_procs].copy()


def random_placement(
    n_procs: int, nodes: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    nodes = np.asarray(nodes)
    return rng.choice(nodes, size=n_procs, replace=False)


def greedy_placement(
    G_w: np.ndarray, nodes: np.ndarray, D: np.ndarray,
) -> np.ndarray:
    """The paper's Greedy baseline: sort process pairs by traffic, place the
    heaviest pairs as close as possible (starting from one hop).

    Vectorized: only positive-traffic pairs are sorted (the reference built
    and sorted the full O(n^2) pair list), and the free-node frontier is a
    maintained id array — nearest-free is an argmin over the shrinking
    frontier, not a masked scan of the full N-node topology per step.
    Pair order is a stable descending sort (ties keep upper-triangle
    order), the deterministic contract shared with the jax port.
    """
    jx = None if is_lazy(D) else _jax_kernels()
    if jx is not None:
        return jx.greedy_placement(G_w, nodes, D)
    n = G_w.shape[0]
    nodes = np.asarray(nodes)
    iu = np.triu_indices(n, 1)
    w = G_w[iu]
    order = np.argsort(-w, kind="stable")
    order = order[w[order] > 0]   # reference stops at the first <= 0 pair
    pair_i, pair_j = iu[0][order], iu[1][order]

    placement = np.full(n, -1, dtype=np.int64)
    # frontier of free node ids, ascending (matches the reference's
    # lowest-id tie-break for both first-free and nearest-free)
    free = np.unique(nodes)

    def take(pos_in_free: int) -> int:
        nonlocal free
        node = int(free[pos_in_free])
        free = np.delete(free, pos_in_free)
        return node

    for i, j in zip(pair_i, pair_j):
        pi, pj = placement[i], placement[j]
        if pi < 0 and pj < 0:
            a = take(0)
            placement[i] = a
            placement[j] = take(int(np.argmin(D[a, free])))
        elif pi < 0:
            placement[i] = take(int(np.argmin(D[pj, free])))
        elif pj < 0:
            placement[j] = take(int(np.argmin(D[pi, free])))
    # any untouched processes (no traffic): fill with the lowest free ids
    rem = np.flatnonzero(placement < 0)
    placement[rem] = free[:len(rem)]
    return placement


def greedy_placement_reference(
    G_w: np.ndarray, nodes: np.ndarray, D: np.ndarray,
) -> np.ndarray:
    """Retained scalar-loop greedy baseline (differential-test floor)."""
    n = G_w.shape[0]
    nodes = np.asarray(nodes)
    iu = np.triu_indices(n, 1)
    order = np.argsort(-G_w[iu], kind="stable")
    pairs = list(zip(iu[0][order], iu[1][order]))

    placement = np.full(n, -1, dtype=np.int64)
    used = np.zeros(D.shape[0], dtype=bool)
    avail_mask = np.zeros(D.shape[0], dtype=bool)
    avail_mask[nodes] = True

    def nearest_free(anchor: int) -> int:
        cand = np.where(~used & avail_mask, D[anchor], np.inf)
        return int(np.argmin(cand))

    def first_free() -> int:
        free = np.flatnonzero(~used & avail_mask)
        return int(free[0])

    for i, j in pairs:
        if G_w[i, j] <= 0:
            break
        pi, pj = placement[i], placement[j]
        if pi < 0 and pj < 0:
            a = first_free()
            placement[i] = a
            used[a] = True
            b = nearest_free(a)
            placement[j] = b
            used[b] = True
        elif pi < 0:
            a = nearest_free(pj)
            placement[i] = a
            used[a] = True
        elif pj < 0:
            b = nearest_free(pi)
            placement[j] = b
            used[b] = True
    for i in range(n):
        if placement[i] < 0:
            a = first_free()
            placement[i] = a
            used[a] = True
    return placement


_VECTORIZED_IMPL.update({
    "bisect_graph": bisect_graph,
    "select_nodes": select_nodes,
    "greedy_placement": greedy_placement,
    "_pairwise_refine": _pairwise_refine,
})
