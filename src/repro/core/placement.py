"""Device assignment for JAX meshes — `srun --distribution=TOFA` analogue.

On an MPI cluster the placement degree of freedom is *which node runs which
rank*.  In JAX/XLA the same degree of freedom is the order of the device
array handed to ``jax.sharding.Mesh``: logical mesh coordinate ``k`` (in
row-major flattening) executes on ``devices.flat[k]``.  Permuting the device
list is therefore exactly rank placement, and the compiled program is
unchanged — only the physical realisation of each replica group moves.

This module computes that permutation:

  1. profile the compiled step (``core.profiler``) -> guest graph ``G`` over
     logical shard ids;
  2. model the physical fabric (:class:`Fabric`) — v5e pod = 16x16 2D torus
     of chips over ICI; multi-pod adds a DCN dimension modelled as a
     high-cost link layer.  ``Fabric`` satisfies the engine's ``Topology``
     protocol, so it plugs straight into ``PlacementEngine`` alongside
     ``TorusTopology`` and ``FatTreeTopology``;
  3. health feed (``cluster.heartbeat``) -> per-chip outage probabilities;
  4. the requested registry policy (default TOFA) maps logical shards onto
     physical chips through the engine.

``placement[k] = physical chip id of logical shard k``; the mesh builder
inverts this into a device reordering.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from .comm_graph import CommGraph
from .engine import (PlacementEngine, PlacementPlan, PlacementRequest,
                     default_engine)
from .mapping import hop_bytes

# DCN (inter-pod) links are ~an order of magnitude slower than ICI; in the
# hop-cost model one pod-crossing counts as this many ICI hops.
DCN_HOP_COST = 10.0


@dataclasses.dataclass(frozen=True)
class Fabric:
    """Physical fabric: per-pod 2D/3D torus of chips (+ optional pod axis).

    Satisfies the :class:`~repro.core.engine.Topology` protocol.
    """

    pod_dims: tuple[int, ...] = (16, 16)   # v5e pod: 16x16 ICI torus
    n_pods: int = 1
    dcn_hop_cost: float = DCN_HOP_COST

    @property
    def chips_per_pod(self) -> int:
        return int(np.prod(self.pod_dims))

    @property
    def n_chips(self) -> int:
        return self.chips_per_pod * self.n_pods

    @property
    def n_nodes(self) -> int:
        """Topology-protocol alias: one placement slot per chip."""
        return self.n_chips

    def torus(self):
        from .topology import TorusTopology
        return TorusTopology(self.pod_dims)

    def hop_matrix(self) -> np.ndarray:
        """(n_chips, n_chips) hop costs: intra-pod ICI hops; pod crossings
        add ``dcn_hop_cost`` (chips first grouped by pod, row-major)."""
        t = self.torus()
        intra = t.hop_matrix()
        n, P = self.chips_per_pod, self.n_pods
        full = np.empty((n * P, n * P))
        for a in range(P):
            for b in range(P):
                blk = intra.copy()
                if a != b:
                    blk = blk + self.dcn_hop_cost
                full[a * n:(a + 1) * n, b * n:(b + 1) * n] = blk
        return full

    def weight_matrix(self, p_f: np.ndarray | None = None,
                      straggler: np.ndarray | None = None) -> np.ndarray:
        """Eq. 1 fault-aware weights on the multi-pod fabric."""
        if p_f is None and straggler is None:
            return self.hop_matrix()
        n, P = self.chips_per_pod, self.n_pods
        p_f = np.zeros(self.n_chips) if p_f is None else np.asarray(p_f)
        t = self.torus()
        full = np.empty((self.n_chips, self.n_chips))
        for a in range(P):
            for b in range(P):
                if a == b:
                    s = straggler[a * n:(a + 1) * n] if straggler is not None else None
                    blk = t.weight_matrix(p_f[a * n:(a + 1) * n], straggler=s)
                else:
                    # conservative cross-pod model: ICI hops to/from the pod
                    # egress + DCN cost; fault penalty applies if either
                    # endpoint chip is unhealthy.
                    blk = t.hop_matrix() + self.dcn_hop_cost
                    fa = p_f[a * n:(a + 1) * n] > 0
                    fb = p_f[b * n:(b + 1) * n] > 0
                    blk = blk + 100.0 * (fa[:, None] | fb[None, :])
                full[a * n:(a + 1) * n, b * n:(b + 1) * n] = blk
        return full

    def coords_array(self) -> np.ndarray:
        """(n_chips, ndim+1) coordinates: (pod, *torus coords)."""
        t = self.torus().coords_array()
        out = []
        for pod in range(self.n_pods):
            pod_col = np.full((t.shape[0], 1), pod)
            out.append(np.concatenate([pod_col, t], axis=1))
        return np.concatenate(out, axis=0)


@dataclasses.dataclass
class DeviceAssignment:
    """Result of a placement policy applied to a mesh."""

    permutation: np.ndarray     # perm[k] = device index for logical shard k
    plan: PlacementPlan
    hop_bytes_linear: float     # baseline (identity assignment) cost
    hop_bytes_placed: float     # cost under this assignment

    @property
    def result(self) -> PlacementPlan:
        """Legacy alias kept from the pre-engine API."""
        return self.plan

    @property
    def improvement(self) -> float:
        if self.hop_bytes_linear <= 0:
            return 0.0
        return 1.0 - self.hop_bytes_placed / self.hop_bytes_linear


def assign_devices(
    comm: CommGraph,
    fabric: Fabric,
    policy: str = "tofa",
    p_f: np.ndarray | None = None,
    rng: np.random.Generator | None = None,
    engine: Optional[PlacementEngine] = None,
    state=None,
) -> DeviceAssignment:
    """Compute a device permutation for ``Mesh`` construction.

    The returned permutation satisfies: logical shard k should run on
    physical chip ``permutation[k]``.  For JAX:

        devs = np.asarray(jax.devices())[assignment.permutation]
        mesh = Mesh(devs.reshape(shape), axis_names)

    (On real hardware ``jax.devices()`` is ordered by physical coordinates,
    so indexing by chip id is indexing by physical position.)
    """
    if comm.n > fabric.n_chips:
        raise ValueError(
            f"comm graph has {comm.n} shards but fabric has only "
            f"{fabric.n_chips} chips")
    # comm.n < n_chips is fine: the job occupies a subset of the fabric
    # (placement[k] is then a chip id, not a permutation of 0..n-1)
    engine = engine if engine is not None else default_engine()
    # ``state`` (a ClusterState over chips) is the first-class health
    # input; the ``p_f`` kwarg remains as the engine-level shim does
    req = (PlacementRequest(comm=comm, topology=fabric, state=state)
           if state is not None
           else PlacementRequest(comm=comm, topology=fabric, p_f=p_f))
    plan = engine.place(req, policy=policy, rng=rng)
    hops = engine.hops(fabric)
    identity = np.arange(comm.n)
    return DeviceAssignment(
        permutation=plan.placement.copy(),
        plan=plan,
        hop_bytes_linear=hop_bytes(comm.G_v, hops, identity),
        hop_bytes_placed=hop_bytes(comm.G_v, hops, plan.placement),
    )


def compare_policies(
    comm: CommGraph,
    fabric: Fabric,
    policies: Optional[Iterable[str]] = None,
    p_f: np.ndarray | None = None,
    seed: int = 0,
    engine: Optional[PlacementEngine] = None,
    state=None,
) -> dict:
    """Hop-bytes and dilation per policy — the placement-quality report.

    ``policies`` defaults to every registered policy.  All policies share
    one engine, so the fabric's hop/weight matrices are derived once.
    """
    engine = engine if engine is not None else default_engine()
    req = (PlacementRequest(comm=comm, topology=fabric, state=state,
                            seed=seed)
           if state is not None
           else PlacementRequest(comm=comm, topology=fabric, p_f=p_f,
                                 seed=seed))
    plans = engine.compare(req, policies=policies)
    return {pol: {
        "hop_bytes": plan.hop_bytes,
        "avg_dilation": plan.avg_dilation,
        "faulty_nodes_used": plan.faulty_nodes_used,
    } for pol, plan in plans.items()}
