"""PlacementEngine — the unified placement service.

One typed entry point replaces the old per-call-site wiring: a frozen
:class:`PlacementRequest` (comm graph, topology, a versioned
:class:`~repro.core.state.ClusterState` health snapshot, stragglers,
metric, seed) goes in and a :class:`PlacementPlan` (placement array,
policy provenance, hop-bytes / dilation cost breakdown, faulty-node
exposure, wall-time) comes out.

Policies are classes registered in :mod:`repro.core.policies`; hosts are
anything satisfying the :class:`Topology` protocol (``TorusTopology``,
``Fabric``, ``FatTreeTopology``, ...).  The engine caches hop and Eq. 1
weight matrices per ``(topology, state key)`` — the state key is the
snapshot's monotonic *epoch* (plus an overlay digest for derived views),
so schedulers and batch simulators that place thousands of jobs against
a slowly-drifting health feed hit warm caches until health actually
changes, with no byte-hashing or quantization of the raw vectors.  When
a health change does arrive, topologies that implement
``weight_matrix_update`` get a *row-wise delta refresh*: only the matrix
entries whose routes touch a changed node are recomputed (bit-identical
to a full derivation, differentially tested).

:meth:`PlacementEngine.replace` performs incremental re-placement when a
state diff (or an explicit failed set) invalidates a running plan, with
a fast path that skips work entirely when the diff does not touch the
incumbent placement.  The legacy ``(p_f, available)`` kwargs remain as a
deprecation shim that interns an equivalent ``ClusterState`` internally.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from collections import OrderedDict
from typing import (Any, Iterable, Optional, Protocol, Sequence, Union,
                    runtime_checkable)

import numpy as np

from . import backend as _backend
from .comm_graph import CommGraph
from .lazydist import is_lazy
from .mapping import avg_dilation, hop_bytes
from .policies import PolicyContext, available_policies, get_policy
from .state import ClusterState, StateDiff

# free-row block budget of the lazy-exact replace cost: at most this many
# implicit W entries are materialised at a time (~32 MB of float64)
_REPLACE_BLOCK_ELEMS = 1 << 22


def _lazy_replace_cost(W, G_w: np.ndarray, i: int, peers: np.ndarray,
                       placement: np.ndarray, free: np.ndarray) -> np.ndarray:
    """Traffic-weighted cost of every free node for displaced proc ``i``
    against a :class:`~repro.core.lazydist.LazyDistance` ``W`` — O(block)
    memory instead of the dense gather's O(|free| * |peers|).

    Exactness: zero-weight peers are dropped before the gather (their
    products contribute exactly 0.0 — in-tree weights are integers, so
    every partial sum is exact in float64), and the blocking is over free
    *rows* only, so each cost entry is still one full-row reduction —
    bit-identical to the unblocked dense expression.
    """
    if peers.size:
        gw = G_w[i, peers]
        nz = gw != 0.0
        peers, gw = peers[nz], gw[nz]
    cost = np.empty(free.size, dtype=np.float64)
    if peers.size:
        cols = placement[peers]
        step = max(1, _REPLACE_BLOCK_ELEMS // max(1, cols.size))
        for s in range(0, free.size, step):
            blk = free[s:s + step]
            cost[s:s + step] = W[np.ix_(blk, cols)] @ gw
    else:
        # isolated proc: most central node (full row sums)
        step = max(1, _REPLACE_BLOCK_ELEMS // max(1, W.shape[0]))
        for s in range(0, free.size, step):
            blk = free[s:s + step]
            cost[s:s + step] = W[blk].sum(axis=1)
    return cost


@runtime_checkable
class Topology(Protocol):
    """Host-fabric protocol: anything exposing these can be placed onto.

    Implementations in-tree: :class:`~repro.core.topology.TorusTopology`
    (d-dim torus with dimension-ordered routing),
    :class:`~repro.core.placement.Fabric` (per-pod ICI torus + DCN hop
    layer), :class:`~repro.core.fattree.FatTreeTopology` (k-ary Clos).
    Topologies may additionally implement
    ``weight_matrix_update(W_prev, changed, p_f, straggler=...)`` to
    refresh only the entries a small health delta invalidates.
    """

    @property
    def n_nodes(self) -> int: ...

    def coords_array(self) -> np.ndarray: ...

    def hop_matrix(self) -> np.ndarray: ...

    def weight_matrix(self, p_f: Optional[np.ndarray] = None,
                      straggler: Optional[np.ndarray] = None) -> np.ndarray: ...


@dataclasses.dataclass(frozen=True, eq=False)
class PlacementRequest:
    """Everything a placement decision depends on, validated up front.

    Health and availability travel as one versioned ``state``
    (:class:`~repro.core.state.ClusterState`): allocatable nodes (UP or
    DEGRADED, minus any overlay mask) restrict every policy — Slurm never
    schedules onto DOWN/DRAINED nodes, independent of fault-awareness —
    and the state's pinned outage vector feeds Eq. 1.

    The pre-state ``(p_f, available)`` kwargs are kept one release as a
    deprecation shim: passing them (without ``state``) interns an
    equivalent ``ClusterState`` by content, so legacy callers that
    re-submit identical health vectors keep the same epoch and hence
    warm engine caches.  ``available`` order is preserved on the shim
    path — ``linear`` consumes it sequentially.
    """

    comm: CommGraph
    topology: Topology
    state: Optional[ClusterState] = None      # versioned health snapshot
    p_f: Optional[np.ndarray] = None          # deprecated: outage kwarg
    straggler: Optional[np.ndarray] = None    # per-node slowdown factor
    available: Optional[np.ndarray] = None    # deprecated: allocatable ids
    metric: str = "volume"                    # guest edge weight: volume|messages
    seed: int = 0                             # default RNG seed

    def __post_init__(self):
        n, N = self.comm.n, self.topology.n_nodes
        if self.metric not in ("volume", "messages"):
            raise ValueError(f"unknown metric {self.metric!r}")
        if self.straggler is not None:
            v = np.asarray(self.straggler, dtype=np.float64)
            if v.shape != (N,):
                raise ValueError(
                    f"straggler has shape {v.shape}, topology has {N} nodes")
            object.__setattr__(self, "straggler", v)
        if self.state is not None:
            if self.p_f is not None or self.available is not None:
                raise ValueError(
                    "pass either state= or the legacy (p_f, available) "
                    "kwargs, not both")
            if self.state.n_nodes != N:
                raise ValueError(
                    f"state has {self.state.n_nodes} nodes, topology {N}")
            object.__setattr__(self, "_explicit_available", False)
            # legacy-field views so policies and diagnostics keep working:
            # p_f is the *pinned* outage vector (non-allocatable == 1.0)
            object.__setattr__(self, "p_f", self.state.outage_vector())
            object.__setattr__(self, "available",
                               self.state.available_ids())
        else:
            if self.p_f is not None:
                v = np.asarray(self.p_f, dtype=np.float64)
                if v.shape != (N,):
                    raise ValueError(
                        f"p_f has shape {v.shape}, topology has {N} nodes")
                object.__setattr__(self, "p_f", v)
            if self.available is not None:
                a = np.asarray(self.available, dtype=np.int64)
                if a.ndim != 1:
                    raise ValueError(
                        "available must be a 1-d array of node ids")
                if a.size and (a.min() < 0 or a.max() >= N):
                    raise ValueError(
                        f"available ids out of range [0, {N}) for this "
                        f"topology")
                object.__setattr__(self, "available", a)
            object.__setattr__(self, "_explicit_available",
                               self.available is not None)
            # deprecation shim: intern an equivalent state by content so
            # identical legacy kwargs share one epoch (and warm caches)
            object.__setattr__(self, "state", ClusterState.from_arrays(
                N, p_f=self.p_f, available=self.available))
        if n > N:
            raise ValueError(f"{n} processes > {N} nodes")
        if len(self.available_ids) < n:
            raise ValueError(
                f"{n} processes > {len(self.available_ids)} available nodes")

    # ---------------------------------------------------------------- views
    @property
    def n_procs(self) -> int:
        return self.comm.n

    @property
    def n_nodes(self) -> int:
        return self.topology.n_nodes

    @property
    def available_ids(self) -> np.ndarray:
        if self.available is None:
            return np.arange(self.n_nodes)
        return self.available

    @property
    def health_key(self) -> tuple:
        """Cache token for everything derived from this request's health:
        the state key (epoch + overlay digest) plus the straggler bytes."""
        s = None if self.straggler is None else self.straggler.tobytes()
        return (self.state.key, s)

    @property
    def route_health_key(self) -> tuple:
        """Cache token for route-weight derivations: like
        :attr:`health_key` but ignoring busy-flavored overlay masks
        (``state.route_key``) — busy nodes are valid routers, so requests
        that differ only in who holds a lease share one weight matrix."""
        s = None if self.straggler is None else self.straggler.tobytes()
        return (self.state.route_key, s)

    def route_p_f(self) -> np.ndarray:
        """Outage vector as the Eq. 1 weight derivation sees it: failed /
        drained / down pinned to 1.0, busy-flavored overlay nodes kept at
        their base belief (identical to :meth:`effective_p_f` for every
        request without a busy-flavored overlay)."""
        return self.state.route_outage_vector()

    def effective_p_f(self) -> np.ndarray:
        """Outage vector as the mapper sees it: unavailable nodes are
        certain outages (pinned to 1.0) regardless of the heartbeat view."""
        p = (np.zeros(self.n_nodes) if self.p_f is None
             else self.p_f.copy())
        if self.available is not None:
            mask = np.ones(self.n_nodes, dtype=bool)
            mask[self.available] = False
            p[mask] = 1.0
        return p

    def restrict(self, busy, *, route_faulty: bool = True
                 ) -> "PlacementRequest":
        """This request minus ``busy`` nodes (exclusive-allocation
        threading).  State-built requests get a cheap overlay — fault
        flavored by default, busy flavored (weight caches keep keying on
        the base health) with ``route_faulty=False``; shim requests keep
        their verbatim availability order."""
        busy = np.atleast_1d(np.asarray(busy, dtype=np.int64))
        if not busy.size:
            return self
        if getattr(self, "_explicit_available", False):
            avail = self.available
            return PlacementRequest(
                comm=self.comm, topology=self.topology,
                p_f=None if self.p_f is None else self.p_f,
                straggler=self.straggler,
                available=avail[~np.isin(avail, busy)],
                metric=self.metric, seed=self.seed)
        return PlacementRequest(
            comm=self.comm, topology=self.topology,
            state=self.state.overlay(unavailable=busy,
                                     route_faulty=route_faulty),
            straggler=self.straggler, metric=self.metric, seed=self.seed)


@dataclasses.dataclass(frozen=True, eq=False)
class PlacementPlan:
    """T = <process id, node id> plus provenance and cost diagnostics."""

    placement: np.ndarray           # (n_procs,) node ids
    policy: str                     # registry name that produced this plan
    request: PlacementRequest       # the request it answers
    hop_bytes: float                # dilation-volume under healthy hop metric
    avg_dilation: float             # traffic-weighted mean hop distance
    hop_bytes_fault_weighted: Optional[float]  # under Eq. 1 weights, if computed
    faulty_nodes_used: int          # processes placed on p_f > 0 nodes
    used_consecutive_window: bool   # TOFA step 10 succeeded?
    wall_time_s: float              # mapper wall-clock for this plan
    provenance: str = "place"       # place | replace-incremental | replace-full

    @property
    def n_procs(self) -> int:
        return len(self.placement)

    def as_pairs(self) -> list[tuple[int, int]]:
        return [(i, int(nid)) for i, nid in enumerate(self.placement)]

    def cost_breakdown(self) -> dict:
        """Quality report: hop-bytes, dilation, fault exposure, wall time."""
        return {
            "hop_bytes": self.hop_bytes,
            "avg_dilation": self.avg_dilation,
            "hop_bytes_fault_weighted": self.hop_bytes_fault_weighted,
            "faulty_nodes_used": self.faulty_nodes_used,
            "wall_time_s": self.wall_time_s,
        }

    def to_result(self):
        """Legacy :class:`~repro.core.tofa.PlacementResult` view (shim)."""
        from .tofa import PlacementResult
        return PlacementResult(
            placement=self.placement,
            policy=self.policy,
            used_consecutive_window=self.used_consecutive_window,
            hop_bytes=self.hop_bytes,
            faulty_nodes_used=self.faulty_nodes_used,
        )


class PlacementEngine:
    """Policy-pluggable, cache-backed placement service.

    Hop matrices are cached per topology; Eq. 1 weight matrices and
    policy memo dicts per ``(topology, health key)`` with LRU eviction.
    The health key is the request state's epoch (plus overlay digest), so
    cache lifetime tracks *actual* health changes: a thousand placements
    against one epoch derive the weight matrix once, and on the jax
    backend the same matrix object stays device-resident across all of
    them (the backend's identity-keyed transfer cache composes with the
    epoch keying — one epoch, one host->device transfer).
    """

    def __init__(self, default_policy: str = "tofa",
                 max_cached_weights: int = 16,
                 backend: Optional[str] = None,
                 lazy_threshold: Optional[int] = None,
                 max_cached_topologies: int = 32):
        """``backend`` pins this engine's placements to an array backend
        (``"numpy"`` | ``"jax"``, see :mod:`repro.core.backend`): every
        ``place``/``place_many``/``replace`` call runs inside
        ``backend.use(...)``.  ``None`` (default) follows the process-wide
        active backend, so existing call sites are unaffected.

        ``lazy_threshold``: topologies with more nodes than this serve
        hop/weight metrics as O(N)-memory
        :class:`~repro.core.lazydist.LazyDistance` adapters instead of
        dense (N, N) matrices (policies go through the multilevel /
        hierarchical path).  ``None`` reads ``REPRO_LAZY_THRESHOLD``
        (default 4096); pass ``0`` to force lazy everywhere or a huge
        value to force dense.

        ``max_cached_topologies`` bounds the per-topology caches (hop
        metrics, coordinates, delta-refresh bases) with LRU eviction —
        long-lived service processes under topology churn stop growing
        without bound; evictions are counted in :meth:`stats`."""
        self.default_policy = default_policy
        self.backend = backend
        if lazy_threshold is None:
            lazy_threshold = int(os.environ.get("REPRO_LAZY_THRESHOLD",
                                                "4096"))
        self.lazy_threshold = lazy_threshold
        self._hops: OrderedDict[Any, np.ndarray] = OrderedDict()
        self._coords: OrderedDict[Any, np.ndarray] = OrderedDict()
        self._weights: OrderedDict[Any, np.ndarray] = OrderedDict()
        self._shared: OrderedDict[Any, dict] = OrderedDict()
        # per-topology record of the last derived weight matrix and the
        # health it answers — the base for row-wise delta refreshes
        self._weights_last: OrderedDict[Any, tuple] = OrderedDict()
        self._pinned: OrderedDict[int, Topology] = OrderedDict()
        self._max_weights = max_cached_weights
        self._max_topos = max_cached_topologies
        self.stats = {"hop_hits": 0, "hop_misses": 0,
                      "weight_hits": 0, "weight_misses": 0,
                      "shared_hits": 0, "shared_misses": 0,
                      "weight_delta_updates": 0,
                      "replace_skips": 0,
                      "topology_evictions": 0,
                      "weight_evictions": 0,
                      "shared_evictions": 0}

    def _lru_touch(self, cache: OrderedDict, key, build, cap: int,
                   evict_stat: str):
        """Fetch-or-build with LRU recency + bounded eviction."""
        if key in cache:
            cache.move_to_end(key)
            return cache[key]
        out = cache[key] = build()
        while len(cache) > cap:
            cache.popitem(last=False)
            self.stats[evict_stat] += 1
        return out

    # ------------------------------------------------------------ caching
    def _topo_key(self, topo: Topology):
        try:
            hash(topo)
            return topo       # dict resolves hash collisions via __eq__
        except TypeError:     # unhashable adapter: identity, pinned alive
            self._pinned[id(topo)] = topo
            while len(self._pinned) > self._max_topos:
                self._pinned.popitem(last=False)
            return ("id", id(topo))

    def _use_lazy(self, topo: Topology) -> bool:
        """Whether this topology's metrics are served implicitly (O(N)
        adapters) instead of as dense (N, N) matrices."""
        return (topo.n_nodes > self.lazy_threshold
                and hasattr(topo, "lazy_distance"))

    def hops(self, topo: Topology):
        key = self._topo_key(topo)
        if key in self._hops:
            self.stats["hop_hits"] += 1
        else:
            self.stats["hop_misses"] += 1
        build = (topo.lazy_distance if self._use_lazy(topo)
                 else topo.hop_matrix)
        return self._lru_touch(self._hops, key, build, self._max_topos,
                               "topology_evictions")

    def coords(self, topo: Topology) -> np.ndarray:
        key = self._topo_key(topo)
        return self._lru_touch(self._coords, key, topo.coords_array,
                               self._max_topos, "topology_evictions")

    def weights(self, topo: Topology, p_f: Optional[np.ndarray] = None,
                straggler: Optional[np.ndarray] = None) -> np.ndarray:
        """Eq. 1 route-weight matrix for one (topology, health) state.

        Direct-array entry point (legacy: keys on the raw bytes).
        Engine-internal placements go through :meth:`_weights_for`, which
        keys on the request state's epoch instead."""
        key = (self._topo_key(topo),
               None if p_f is None else np.asarray(p_f).tobytes(),
               None if straggler is None else np.asarray(straggler).tobytes())
        return self._weights_cached(topo, key, p_f, straggler)

    def _weights_for(self, topo: Topology,
                     request: PlacementRequest,
                     p_f_route: np.ndarray) -> np.ndarray:
        """Weight matrix for a request, epoch-keyed on its health state.

        Keys on the *route* health key: requests that differ only in a
        busy-flavored overlay (the service's lease churn) share one
        matrix per health epoch.  ``p_f_route`` must be the matching
        :meth:`PlacementRequest.route_p_f` vector."""
        key = (self._topo_key(topo),) + request.route_health_key
        return self._weights_cached(topo, key, p_f_route, request.straggler)

    def _weights_cached(self, topo: Topology, key,
                        p_f: Optional[np.ndarray],
                        straggler: Optional[np.ndarray]) -> np.ndarray:
        no_fault = p_f is None or not (np.asarray(p_f) > 0).any()
        no_slow = straggler is None or not (np.asarray(straggler) > 0).any()
        if no_fault and no_slow:
            # Eq. 1 with all-healthy nodes degenerates to the hop metric
            return self.hops(topo)
        if key in self._weights:
            self.stats["weight_hits"] += 1
            self._weights.move_to_end(key)
            return self._weights[key]
        self.stats["weight_misses"] += 1
        w = self._derive_weights(topo, p_f, straggler)
        self._weights[key] = w
        while len(self._weights) > self._max_weights:
            self._weights.popitem(last=False)
            self.stats["weight_evictions"] += 1
        return w

    def _derive_weights(self, topo: Topology,
                        p_f: Optional[np.ndarray],
                        straggler: Optional[np.ndarray]) -> np.ndarray:
        """Full derivation, or a row-wise delta refresh from the last
        derived matrix when the topology supports it and the health delta
        is small.  Delta results are bit-identical to full derivation
        (only entries whose routes touch a changed node can differ, and
        exactly those are recomputed with the same formula)."""
        if self._use_lazy(topo):
            # implicit regime: the adapter IS the weight matrix — O(N)
            # per (topology, state) entry, no delta machinery needed
            # (entries are computed per access, so there is no stored
            # base to refresh)
            return topo.lazy_distance(p_f, straggler=straggler)
        n = topo.n_nodes
        flags = (np.zeros(n, dtype=bool) if p_f is None
                 else np.asarray(p_f) > 0)
        slow = None
        if straggler is not None and (np.asarray(straggler) > 0).any():
            slow = np.asarray(straggler, dtype=np.float64)
        topo_key = self._topo_key(topo)
        last = self._weights_last.get(topo_key)
        W = None
        if last is not None and hasattr(topo, "weight_matrix_update"):
            prev_flags, prev_slow, W_prev = last
            changed = flags != prev_flags
            if slow is not None or prev_slow is not None:
                sl = slow if slow is not None else np.zeros(n)
                psl = prev_slow if prev_slow is not None else np.zeros(n)
                changed = changed | (sl != psl)
            n_changed = int(changed.sum())
            if n_changed == 0:
                W = W_prev
            elif n_changed <= max(1, n // 4):
                W = topo.weight_matrix_update(
                    W_prev, np.flatnonzero(changed), p_f,
                    straggler=straggler)
                self.stats["weight_delta_updates"] += 1
        if W is None:
            W = topo.weight_matrix(p_f, straggler=straggler)
        self._weights_last[topo_key] = (flags, slow, W)
        self._weights_last.move_to_end(topo_key)
        while len(self._weights_last) > self._max_topos:
            self._weights_last.popitem(last=False)
            self.stats["topology_evictions"] += 1
        return W

    def shared_cache(self, topo: Topology,
                     p_f: Optional[np.ndarray] = None,
                     straggler: Optional[np.ndarray] = None) -> dict:
        """Policy memo dict for one (topology, health) state (raw-array
        entry point; engine-internal placements key on the state epoch).

        Policies use it (via :meth:`PolicyContext.memo`) for
        guest-independent intermediates — e.g. TOFA's consecutive-window
        and compact-ball candidate node sets, which depend only on the
        health snapshot and job size, not on the traffic matrix — so batch
        runs placing many jobs against the same snapshot derive them once.
        """
        key = (self._topo_key(topo),
               None if p_f is None else np.asarray(p_f).tobytes(),
               None if straggler is None else np.asarray(straggler).tobytes())
        return self._shared_cached(key)

    def _shared_for(self, topo: Topology, request: PlacementRequest) -> dict:
        # scoped per route health key (one dict per epoch under lease
        # churn); availability-dependent entries are disambiguated inside
        # the dict by PolicyContext.avail_token
        return self._shared_cached(
            (self._topo_key(topo),) + request.route_health_key)

    def _shared_cached(self, key) -> dict:
        if key in self._shared:
            self.stats["shared_hits"] += 1
            self._shared.move_to_end(key)
            return self._shared[key]
        self.stats["shared_misses"] += 1
        d: dict = {}
        self._shared[key] = d
        while len(self._shared) > self._max_weights:
            self._shared.popitem(last=False)
            self.stats["shared_evictions"] += 1
        return d

    def cache_stats(self) -> dict:
        return dict(self.stats,
                    cached_topologies=len(self._hops),
                    cached_weight_matrices=len(self._weights),
                    cached_shared_dicts=len(self._shared))

    def cache_hit_rate(self) -> float:
        """Fraction of weight + shared lookups served warm (1.0 when no
        lookups happened yet) — the number the epoch-keyed state model
        keeps high under heartbeat jitter (see benchmarks/state_churn.py)."""
        hits = self.stats["weight_hits"] + self.stats["shared_hits"]
        misses = self.stats["weight_misses"] + self.stats["shared_misses"]
        total = hits + misses
        return 1.0 if total == 0 else hits / total

    def _backend_ctx(self):
        return (_backend.use(self.backend) if self.backend is not None
                else contextlib.nullcontext())

    # ----------------------------------------------------------- placement
    def place(self, request: PlacementRequest, policy: Optional[str] = None,
              *, rng: Optional[np.random.Generator] = None) -> PlacementPlan:
        """Run one registered policy against one request."""
        with self._backend_ctx():
            return self._place(request, policy, rng=rng)

    def _place(self, request: PlacementRequest, policy: Optional[str] = None,
               *, rng: Optional[np.random.Generator] = None) -> PlacementPlan:
        name = policy or self.default_policy
        pol = get_policy(name)
        rng = rng if rng is not None else np.random.default_rng(request.seed)
        t0 = time.perf_counter()
        topo = request.topology
        p_f = request.effective_p_f()
        route_p = request.route_p_f()
        ctx = PolicyContext(
            request=request,
            G_w=request.comm.weights(request.metric),
            coords=self.coords(topo),
            hops=self.hops(topo),
            p_f=p_f,
            available=request.available_ids,
            rng=rng,
            _weights_fn=lambda: self._weights_for(topo, request, route_p),
            shared=self._shared_for(topo, request),
            avail_token=request.state.key,
        )
        out = pol.place(ctx)
        wall = time.perf_counter() - t0
        return self._plan(request, name, np.asarray(out.placement),
                          out.used_consecutive_window, ctx, wall, "place")

    def compare(self, request: PlacementRequest,
                policies: Optional[Iterable[str]] = None,
                ) -> dict[str, PlacementPlan]:
        """One plan per policy (fresh seeded RNG each) — the quality report."""
        out = {}
        for pol in (tuple(policies) if policies is not None
                    else available_policies()):
            rng = np.random.default_rng(request.seed)
            out[pol] = self.place(request, policy=pol, rng=rng)
        return out

    def place_many(self, requests: Sequence[PlacementRequest],
                   policy: Union[str, Sequence[str], None] = None,
                   *, rng: Optional[np.random.Generator] = None,
                   exclusive: bool = False,
                   route_faulty: bool = True) -> list[PlacementPlan]:
        """Batched placement: one plan per request, in request order.

        Produces exactly the plans the equivalent sequence of
        :meth:`place` calls would (differentially tested in
        ``tests/test_backend_diff.py``) while paying batch costs once:
        the whole batch runs inside one backend scope, so per-(topology,
        health) hop/weight matrices, the policies' shared candidate
        memos, and — on the jax backend — the device-resident distance
        matrices and compiled kernels are derived or transferred a single
        time and reused by every job in the batch.

        ``policy`` is one name for the whole batch (default:
        ``default_policy``) or one name per request (the scheduler maps
        each job's ``srun --distribution`` here).  ``rng`` is threaded
        through the batch in order; ``None`` gives every request its own
        ``default_rng(request.seed)``, matching ``place``.

        ``exclusive=True`` applies scheduler queue-drain semantics:
        requests are placed in order and each is restricted — via a
        cheap :meth:`ClusterState.overlay` when the request carries a
        state — to nodes no earlier plan in the batch occupies (Slurm's
        exclusive node allocation).  Raises ``ValueError`` — like the
        equivalent sequential validation would — if a request no longer
        fits in what remains.  ``route_faulty`` picks the overlay flavor
        the intra-batch restriction uses: the default treats occupied
        nodes as certain outages (historical behavior); the placement
        service passes ``False`` so occupied nodes stay valid routers and
        the whole drain tick shares epoch-keyed weight matrices.
        """
        requests = list(requests)
        if policy is None or isinstance(policy, str):
            policies = [policy] * len(requests)
        else:
            policies = list(policy)
            if len(policies) != len(requests):
                raise ValueError(
                    f"{len(policies)} policies for {len(requests)} requests")
        plans: list[PlacementPlan] = []
        taken: dict[Any, np.ndarray] = {}   # topo key -> occupied node ids
        with self._backend_ctx():
            for req, pol in zip(requests, policies):
                key = self._topo_key(req.topology)
                if exclusive:
                    busy = taken.get(key)
                    if busy is not None and busy.size:
                        req = req.restrict(busy, route_faulty=route_faulty)
                plan = self._place(req, policy=pol, rng=rng)
                plans.append(plan)
                if exclusive:
                    prev = taken.get(key)
                    ids = np.asarray(plan.placement, dtype=np.int64)
                    taken[key] = (ids if prev is None
                                  else np.concatenate([prev, ids]))
        return plans

    # -------------------------------------------------------- re-placement
    def replace(self, plan: PlacementPlan,
                failed_nodes: Union[Sequence[int], np.ndarray, None] = None,
                *, state: Optional[ClusterState] = None,
                rng: Optional[np.random.Generator] = None,
                full: bool = False,
                p_f: Optional[np.ndarray] = None,
                available: Optional[np.ndarray] = None) -> PlacementPlan:
        """Incremental fault-driven (or diff-driven) re-placement.

        Marks ``failed_nodes`` as certain outages (an overlay on the
        health state), and moves only the displaced processes — each to
        the free surviving node minimising its traffic-weighted Eq. 1
        cost against the processes that stay put.  Falls back to a full
        re-map (``provenance="replace-full"``) when ``full=True`` or more
        than half the job is displaced.  Raises ``ValueError`` when the
        survivors cannot hold the job.

        ``state`` refreshes the health view to the caller's *current*
        snapshot — the plan's request carries the submit-time snapshot,
        stale once other nodes fail or drain after submission.  With
        ``state`` given and ``failed_nodes`` omitted, the failed set is
        computed from the **state diff**: the nodes that were allocatable
        at submit time but are not any more.  **Fast path:** when the
        diff (or the explicit failed set) does not touch any node the
        incumbent placement uses, the plan is returned unchanged — no
        matrices, no context, no new request.

        The legacy ``p_f=`` / ``available=`` kwargs remain as a
        deprecation shim equivalent to passing the interned state they
        describe.
        """
        with self._backend_ctx():
            return self._replace(plan, failed_nodes, state=state, rng=rng,
                                 full=full, p_f=p_f, available=available)

    def _replace(self, plan: PlacementPlan,
                 failed_nodes: Union[Sequence[int], np.ndarray, None] = None,
                 *, state: Optional[ClusterState] = None,
                 rng: Optional[np.random.Generator] = None,
                 full: bool = False,
                 p_f: Optional[np.ndarray] = None,
                 available: Optional[np.ndarray] = None) -> PlacementPlan:
        req = plan.request
        if state is not None and (p_f is not None or available is not None):
            raise ValueError("pass either state= or the legacy "
                             "(p_f, available) kwargs, not both")
        if state is not None:
            base = state
        elif p_f is not None or available is not None:
            base = ClusterState.from_arrays(
                req.n_nodes,
                p_f=req.p_f if p_f is None else np.asarray(p_f, np.float64),
                available=(req.available_ids if available is None
                           else np.asarray(available, dtype=np.int64)))
        else:
            base = req.state
        if failed_nodes is None:
            diff = req.state.diff(base)
            failed = diff.lost()
        else:
            failed = np.unique(np.atleast_1d(
                np.asarray(failed_nodes, dtype=np.int64)))
            if failed.size and (failed.min() < 0
                                or failed.max() >= req.n_nodes):
                raise ValueError(
                    f"failed node ids out of range [0, {req.n_nodes})")

        placement = plan.placement.copy()
        displaced = np.flatnonzero(np.isin(placement, failed))
        if not full and len(displaced) == 0:
            # the change does not touch this job: keep the plan as-is
            self.stats["replace_skips"] += 1
            return plan

        if state is None and (available is not None
                              or getattr(req, "_explicit_available", False)):
            # legacy shim with an explicitly-*ordered* availability array:
            # preserve the caller's order verbatim (``linear`` consumes it
            # sequentially), exactly as the pre-state API did
            base_p_f = (req.p_f if p_f is None
                        else np.asarray(p_f, np.float64))
            new_p_f = (np.zeros(req.n_nodes) if base_p_f is None
                       else base_p_f.copy())
            new_p_f[failed] = 1.0
            avail = (req.available_ids if available is None
                     else np.asarray(available, dtype=np.int64))
            new_avail = avail[~np.isin(avail, failed)]
            if len(new_avail) < req.n_procs:
                raise ValueError(
                    f"cannot re-place: {req.n_procs} processes > "
                    f"{len(new_avail)} surviving nodes")
            new_req = PlacementRequest(
                comm=req.comm, topology=req.topology, p_f=new_p_f,
                available=new_avail, straggler=req.straggler,
                metric=req.metric, seed=req.seed)
        else:
            new_state = base.overlay(unavailable=failed)
            new_avail = new_state.available_ids()
            if len(new_avail) < req.n_procs:
                raise ValueError(
                    f"cannot re-place: {req.n_procs} processes > "
                    f"{len(new_avail)} surviving nodes")
            new_req = PlacementRequest(
                comm=req.comm, topology=req.topology, state=new_state,
                straggler=req.straggler, metric=req.metric, seed=req.seed)

        if full or len(displaced) > max(1, len(placement) // 2):
            fresh = self._place(new_req, policy=plan.policy, rng=rng)
            return dataclasses.replace(fresh, provenance="replace-full")

        t0 = time.perf_counter()
        p_eff = new_req.effective_p_f()
        ctx = PolicyContext(
            request=new_req,
            G_w=req.comm.weights(req.metric),
            coords=self.coords(req.topology),
            hops=self.hops(req.topology),
            p_f=p_eff,
            available=new_avail,
            rng=rng if rng is not None else np.random.default_rng(req.seed),
            avail_token=new_req.state.key,
        )
        W = self._weights_for(req.topology, new_req, new_req.route_p_f())
        ctx._weights = W
        used = np.zeros(req.n_nodes, dtype=bool)
        kept = np.ones(len(placement), dtype=bool)
        kept[displaced] = False
        used[placement[kept]] = True
        free = new_avail[~used[new_avail]]
        # heaviest talkers first: they constrain the remaining choices most
        order = displaced[np.argsort(ctx.G_w[displaced].sum(axis=1))[::-1]]
        settled = kept.copy()
        lazy_W = is_lazy(W)
        for i in order:
            peers = np.flatnonzero(settled)
            if lazy_W:
                cost = _lazy_replace_cost(W, ctx.G_w, int(i), peers,
                                          placement, free)
            elif peers.size:
                cost = W[np.ix_(free, placement[peers])] @ ctx.G_w[i, peers]
            else:
                cost = W[free].sum(axis=1)  # isolated: most central node
            best = free[int(np.argmin(cost))]
            placement[i] = best
            settled[i] = True
            free = free[free != best]
        wall = time.perf_counter() - t0
        return self._plan(new_req, plan.policy, placement,
                          plan.used_consecutive_window, ctx, wall,
                          "replace-incremental")

    # ------------------------------------------------------------ internals
    def _plan(self, request, policy, placement, used_window, ctx, wall,
              provenance) -> PlacementPlan:
        weighted = (hop_bytes(ctx.G_w, ctx.weights, placement)
                    if ctx.weights_computed else None)
        return PlacementPlan(
            placement=placement,
            policy=policy,
            request=request,
            hop_bytes=hop_bytes(ctx.G_w, ctx.hops, placement),
            avg_dilation=avg_dilation(ctx.G_w, ctx.hops, placement),
            hop_bytes_fault_weighted=weighted,
            faulty_nodes_used=int((ctx.p_f[placement] > 0).sum()),
            used_consecutive_window=used_window,
            wall_time_s=wall,
            provenance=provenance,
        )


_DEFAULT_ENGINE: Optional[PlacementEngine] = None


def default_engine() -> PlacementEngine:
    """Process-wide shared engine (used by the legacy shims so repeated
    ``place()`` calls still benefit from matrix caching)."""
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = PlacementEngine()
    return _DEFAULT_ENGINE
