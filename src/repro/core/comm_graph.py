"""Communication graph G: the paper's MPI-profiler output, adapted to SPMD.

The paper's profiling tool intercepts MPI primitives and accumulates two
N x N matrices: ``G_v`` (bytes exchanged per rank pair) and ``G_m`` (message
count per rank pair).  Collectives are decomposed into the point-to-point
phases of the algorithm each collective actually uses, so per-pair traffic is
accurate (Section 3).

Here the same abstraction profiles an SPMD JAX program: each *shard* (logical
device) is a rank, and each XLA collective is decomposed over its replica
groups into point-to-point phases:

* ``ring``                all-reduce / all-gather / reduce-scatter on TPU ICI
* ``recursive_doubling``  small all-reduces (latency-bound regime)
* ``pairwise``            all-to-all (MoE dispatch/combine)
* ``binomial_tree``       broadcast
* ``direct``              collective-permute (explicit src->dst pairs)

Byte conventions (per device, matching XLA operand semantics):
  all_reduce(S)       operand S is the full buffer; ring sends 2*(g-1)/g*S
  all_gather(S)       operand S is the local shard; ring sends (g-1)*S
  reduce_scatter(S)   operand S is the full buffer; ring sends (g-1)/g*S
  all_to_all(S)       operand S is the local buffer; sends (g-1)/g*S total
  collective_permute  operand S sent once per (src, dst) pair

``G_v``/``G_m`` are symmetric: entry (i, j) is total traffic between i and j
in both directions, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass
class CommGraph:
    """The guest graph G = (V_G, E_G) with byte and message weights."""

    n: int
    G_v: np.ndarray = None  # bytes
    G_m: np.ndarray = None  # messages

    def __post_init__(self):
        if self.G_v is None:
            self.G_v = np.zeros((self.n, self.n), dtype=np.float64)
        if self.G_m is None:
            self.G_m = np.zeros((self.n, self.n), dtype=np.float64)
        assert self.G_v.shape == (self.n, self.n)
        assert self.G_m.shape == (self.n, self.n)

    # ------------------------------------------------------------------ p2p
    def add_p2p(self, i: int, j: int, nbytes: float, nmsgs: float = 1.0) -> None:
        """Record traffic between ranks i and j (symmetric accumulation)."""
        if i == j:
            return
        self.G_v[i, j] += nbytes
        self.G_v[j, i] += nbytes
        self.G_m[i, j] += nmsgs
        self.G_m[j, i] += nmsgs

    def _scatter_pairs(
        self, src: np.ndarray, dst: np.ndarray, nbytes: float, nmsgs: float
    ) -> None:
        """Vectorized symmetric accumulation of many (src, dst) pairs.

        ``np.add.at`` handles repeated pairs (e.g. the two directed ring
        edges of a 2-rank group) by accumulating, exactly like sequential
        ``add_p2p`` calls; self-pairs are dropped to match its i == j guard.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        keep = src != dst
        if not keep.all():
            src, dst = src[keep], dst[keep]
        if src.size == 0:
            return
        rows = np.concatenate([src, dst])
        cols = np.concatenate([dst, src])
        np.add.at(self.G_v, (rows, cols), nbytes)
        np.add.at(self.G_m, (rows, cols), nmsgs)

    # ----------------------------------------------------------- collectives
    def add_all_reduce(
        self, ranks: Sequence[int], nbytes: float,
        algorithm: str = "ring", repeats: float = 1.0,
    ) -> None:
        g = len(ranks)
        if g <= 1:
            return
        r = np.asarray(ranks, dtype=np.int64)
        if algorithm == "ring":
            # reduce-scatter phase + all-gather phase: each rank sends
            # 2*(g-1)/g*S to its ring successor over 2*(g-1) messages.
            per_pair = 2.0 * (g - 1) / g * nbytes
            self._scatter_pairs(r, np.roll(r, -1),
                                per_pair * repeats, 2 * (g - 1) * repeats)
        elif algorithm == "recursive_doubling":
            idx = np.arange(g)
            k = 1
            while k < g:
                peer = idx ^ k
                m = (peer < g) & (idx < peer)
                self._scatter_pairs(r[idx[m]], r[peer[m]],
                                    nbytes * repeats, repeats)
                k <<= 1
        else:
            raise ValueError(f"unknown all-reduce algorithm {algorithm!r}")

    def add_all_gather(
        self, ranks: Sequence[int], shard_bytes: float, repeats: float = 1.0
    ) -> None:
        g = len(ranks)
        if g <= 1:
            return
        r = np.asarray(ranks, dtype=np.int64)
        per_pair = (g - 1) * shard_bytes
        self._scatter_pairs(r, np.roll(r, -1),
                            per_pair * repeats, (g - 1) * repeats)

    def add_reduce_scatter(
        self, ranks: Sequence[int], full_bytes: float, repeats: float = 1.0
    ) -> None:
        g = len(ranks)
        if g <= 1:
            return
        r = np.asarray(ranks, dtype=np.int64)
        per_pair = (g - 1) / g * full_bytes
        self._scatter_pairs(r, np.roll(r, -1),
                            per_pair * repeats, (g - 1) * repeats)

    def add_all_to_all(
        self, ranks: Sequence[int], local_bytes: float, repeats: float = 1.0
    ) -> None:
        g = len(ranks)
        if g <= 1:
            return
        r = np.asarray(ranks, dtype=np.int64)
        chunk = local_bytes / g
        ii, jj = np.triu_indices(g, 1)
        self._scatter_pairs(r[ii], r[jj], 2 * chunk * repeats, 2 * repeats)

    def add_broadcast(
        self, ranks: Sequence[int], nbytes: float, root: int = 0,
        repeats: float = 1.0,
    ) -> None:
        """Binomial-tree broadcast rooted at ``ranks[root]``."""
        g = len(ranks)
        if g <= 1:
            return
        r = np.asarray(ranks, dtype=np.int64)
        order = np.arange(g)
        order[0], order[root] = order[root], order[0]
        k = 1
        while k < g:
            idx = np.arange(min(k, g - k))
            peer = idx + k
            self._scatter_pairs(r[order[idx]], r[order[peer]],
                                nbytes * repeats, repeats)
            k <<= 1

    def add_collective_permute(
        self, pairs: Iterable[tuple[int, int]], nbytes: float,
        repeats: float = 1.0,
    ) -> None:
        pairs = np.asarray(list(pairs), dtype=np.int64)
        if pairs.size == 0:
            return
        self._scatter_pairs(pairs[:, 0], pairs[:, 1],
                            nbytes * repeats, repeats)

    # -------------------------------------------------------------- algebra
    def merged(self, other: "CommGraph") -> "CommGraph":
        assert self.n == other.n
        return CommGraph(self.n, self.G_v + other.G_v, self.G_m + other.G_m)

    def scaled(self, factor: float) -> "CommGraph":
        return CommGraph(self.n, self.G_v * factor, self.G_m * factor)

    def total_bytes(self) -> float:
        return float(self.G_v.sum() / 2.0)

    def weights(self, metric: str = "volume") -> np.ndarray:
        """Edge-weight matrix used as guest graph: 'volume' or 'messages'.

        The paper (Section 3, citing [5]) notes the choice is application
        dependent and evaluates with *volume*; both are exposed.
        """
        if metric == "volume":
            return self.G_v
        if metric == "messages":
            return self.G_m
        raise ValueError(f"unknown metric {metric!r}")

    # -------------------------------------------------------------- heatmap
    def heatmap(self, width: int = 64, metric: str = "volume") -> str:
        """ASCII traffic heatmap (the paper's Fig. 1 analogue).

        Darker glyph == more traffic for that rank pair; supports visual
        inspection of pattern regularity.
        """
        m = self.weights(metric)
        n = self.n
        bins = min(width, n)
        idx = (np.arange(n) * bins // n)
        agg = np.zeros((bins, bins))
        # bin only the nonzero entries — the dense form materialised two
        # n x n index arrays just to scatter a (typically sparse) matrix
        i, j = np.nonzero(m)
        np.add.at(agg, (idx[i], idx[j]), m[i, j])
        shades = " .:-=+*#%@"
        mx = agg.max()
        if mx <= 0:
            return "\n".join(" " * bins for _ in range(bins))
        lvl = np.sqrt(agg / mx)  # sqrt for dynamic range, like a gamma curve
        rows = []
        for r in range(bins):
            rows.append("".join(shades[min(int(v * (len(shades) - 1) + 0.5),
                                           len(shades) - 1)] for v in lvl[r]))
        return "\n".join(rows)

    def regularity(self) -> float:
        """Fraction of traffic within +/- 10% of N of the main diagonal.

        LAMMPS-like banded patterns score near 1.0; NPB-DT-like irregular
        patterns score low.  Used by tests and the workload generator.
        """
        n = self.n
        band = max(1, int(0.1 * n))
        i, j = np.nonzero(self.G_v)
        if i.size == 0:
            return 1.0
        d = np.abs(i - j)
        w = self.G_v[i, j]
        return float(w[d <= band].sum() / w.sum())


def _ring_pairs(ranks: Sequence[int]) -> list[tuple[int, int]]:
    g = len(ranks)
    return [(ranks[i], ranks[(i + 1) % g]) for i in range(g)]
