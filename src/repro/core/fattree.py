"""Fat-tree host topology — the Clos-network counterpart of the torus.

A k-ary fat tree (Al-Fares et al., SIGCOMM 2008) has ``k`` pods, each with
``k/2`` edge switches serving ``k/2`` hosts, for ``k^3/4`` hosts total.
Compute nodes are the hosts; switches appear only in the distance model:

    same host                     0 hops
    same edge switch              2 hops   (host - edge - host)
    same pod, different edge      4 hops   (host - edge - agg - edge - host)
    different pods                6 hops   (... - core - ...)

Host ids are ordered (pod, edge, host), so *consecutive ids are maximally
co-located* — exactly the property TOFA's consecutive-healthy-window search
(Listing 1.1, step 10) and the resource-manager ordering assume.  Fault
weighting follows Eq. (1) in endpoint form: hosts do not relay traffic in a
Clos fabric, so only the first/last link of a path can touch a faulty
compute node.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import FAULT_PENALTY


@dataclasses.dataclass(frozen=True)
class FatTreeTopology:
    """k-ary fat tree of ``k**3 // 4`` hosts (k even, >= 2)."""

    k: int = 4

    def __post_init__(self):
        if self.k < 2 or self.k % 2:
            raise ValueError(f"fat-tree arity k must be even and >= 2, got {self.k}")

    # ------------------------------------------------------------------ basics
    @property
    def hosts_per_edge(self) -> int:
        return self.k // 2

    @property
    def edges_per_pod(self) -> int:
        return self.k // 2

    @property
    def hosts_per_pod(self) -> int:
        return self.hosts_per_edge * self.edges_per_pod

    @property
    def n_nodes(self) -> int:
        return self.hosts_per_pod * self.k

    def coords(self, node: int) -> tuple[int, int, int]:
        """Host id -> (pod, edge switch, host slot)."""
        pod, rest = divmod(node, self.hosts_per_pod)
        edge, host = divmod(rest, self.hosts_per_edge)
        return (pod, edge, host)

    def coords_array(self) -> np.ndarray:
        """(n_nodes, 3) (pod, edge, host) coordinates, id-ordered."""
        ids = np.arange(self.n_nodes)
        pod, rest = np.divmod(ids, self.hosts_per_pod)
        edge, host = np.divmod(rest, self.hosts_per_edge)
        return np.stack([pod, edge, host], axis=1)

    # --------------------------------------------------------------- distances
    def hop_matrix(self) -> np.ndarray:
        """(n, n) switch-level hop distances (0 / 2 / 4 / 6).

        Memoised on first use so topology construction stays O(1) and
        repeat callers share one dense matrix.
        """
        cached = self.__dict__.get("_hop_matrix")
        if cached is not None:
            return cached
        c = self.coords_array()
        same_pod = c[:, None, 0] == c[None, :, 0]
        same_edge = same_pod & (c[:, None, 1] == c[None, :, 1])
        same_host = same_edge & (c[:, None, 2] == c[None, :, 2])
        hops = np.full((self.n_nodes, self.n_nodes), 6.0)
        hops[same_pod] = 4.0
        hops[same_edge] = 2.0
        hops[same_host] = 0.0
        object.__setattr__(self, "_hop_matrix", hops)
        return hops

    def lazy_distance(self, p_f: np.ndarray | None = None, c: float = 1.0,
                      straggler: np.ndarray | None = None):
        """O(n)-memory implicit view of :meth:`weight_matrix` — exact for
        any health state (endpoint-form weighting)."""
        from .lazydist import FatTreeLazyDistance
        return FatTreeLazyDistance(self, p_f, c=c, straggler=straggler)

    def hierarchy_groups(self, target_groups: int = 64) -> np.ndarray:
        """(n,) group ids for hierarchical mapping: one group per edge
        switch (the natural "rack" of a fat-tree — hosts under one edge
        are mutually 2 hops)."""
        c = self.coords_array()
        return (c[:, 0] * self.edges_per_pod + c[:, 1]).astype(np.int64)

    def weight_matrix(
        self,
        p_f: np.ndarray | None = None,
        c: float = 1.0,
        straggler: np.ndarray | None = None,
    ) -> np.ndarray:
        """Eq. (1) path weights in endpoint form.

        A path's only compute-node contacts are its two endpoints, so the
        weight is ``c * hops`` plus ``c * 100`` per faulty endpoint and
        ``c * s`` per straggling endpoint (slowdown factor ``s``).
        """
        n = self.n_nodes
        w = c * self.hop_matrix()
        penalty = np.zeros(n)
        if p_f is not None:
            penalty += c * FAULT_PENALTY * (np.asarray(p_f, dtype=np.float64) > 0)
        if straggler is not None:
            penalty += c * np.asarray(straggler, dtype=np.float64)
        if (penalty > 0).any():
            extra = penalty[:, None] + penalty[None, :]
            np.fill_diagonal(extra, 0.0)
            w = w + extra
        return w

    def weight_matrix_update(
        self,
        W_prev: np.ndarray,
        changed,
        p_f: np.ndarray | None = None,
        c: float = 1.0,
        straggler: np.ndarray | None = None,
    ) -> np.ndarray:
        """Row-wise delta refresh of :meth:`weight_matrix`.

        In endpoint form a node's health only enters through its own
        penalty term, so a change at node x invalidates exactly row x and
        column x.  Recomputed entries use the same expression as the full
        derivation (bit-identical; see ``tests/test_state.py``).
        """
        changed = np.atleast_1d(np.asarray(changed, dtype=np.int64))
        if changed.size == 0:
            return W_prev
        n = self.n_nodes
        penalty = np.zeros(n)
        if p_f is not None:
            penalty += c * FAULT_PENALTY * (np.asarray(p_f, np.float64) > 0)
        if straggler is not None:
            penalty += c * np.asarray(straggler, dtype=np.float64)
        extra = penalty[:, None] + penalty[None, :]
        np.fill_diagonal(extra, 0.0)
        base = c * self.hop_matrix()
        ref = base + extra
        W = W_prev.copy()
        W[changed, :] = ref[changed, :]
        W[:, changed] = ref[:, changed]
        return W
