"""Legacy TOFA entry points — thin shims over the PlacementEngine.

The algorithm itself (paper Listing 1.1) lives in
:mod:`repro.core.policies.tofa`; the string-dispatched policy set lives in
the registry (:mod:`repro.core.policies`).  ``tofa_place`` / ``place`` are
kept so pre-engine callers and tests continue to work unchanged — they
build a :class:`~repro.core.engine.PlacementRequest`, run the shared
:func:`~repro.core.engine.default_engine`, and down-convert the resulting
:class:`~repro.core.engine.PlacementPlan` to the historical
:class:`PlacementResult`.  New code should use the engine API directly.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .comm_graph import CommGraph
from .engine import PlacementRequest, default_engine
from .policies import available_policies
from .policies.tofa import FAULT_BLOCK  # noqa: F401  (legacy re-export)
from .topology import TorusTopology


@dataclasses.dataclass
class PlacementResult:
    """T = <process id, node id> plus quality diagnostics (legacy view)."""

    placement: np.ndarray          # (n_procs,) node ids
    policy: str
    used_consecutive_window: bool  # TOFA step 10 succeeded?
    hop_bytes: float               # dilation-volume under healthy hop metric
    faulty_nodes_used: int         # processes placed on p_f > 0 nodes

    def as_pairs(self) -> list[tuple[int, int]]:
        return [(i, int(nid)) for i, nid in enumerate(self.placement)]


def _deprecated(name: str) -> None:
    warnings.warn(
        f"repro.core.tofa.{name}() is deprecated; use "
        "repro.core.engine.PlacementEngine with a PlacementRequest",
        DeprecationWarning, stacklevel=3)


def tofa_place(
    comm: CommGraph,
    topo: TorusTopology,
    p_f: np.ndarray | None = None,
    *,
    metric: str = "volume",
    rng: np.random.Generator | None = None,
    straggler: np.ndarray | None = None,
) -> PlacementResult:
    """Run TOFA (Listing 1.1) and return the placement with diagnostics."""
    _deprecated("tofa_place")
    req = PlacementRequest(comm=comm, topology=topo, p_f=p_f,
                           straggler=straggler, metric=metric)
    return default_engine().place(req, policy="tofa", rng=rng).to_result()


def place(
    policy: str,
    comm: CommGraph,
    topo: TorusTopology,
    p_f: np.ndarray | None = None,
    *,
    metric: str = "volume",
    rng: np.random.Generator | None = None,
    available: np.ndarray | None = None,
) -> PlacementResult:
    """Registry-dispatched placement: 'linear' (default-slurm), 'random',
    'greedy', 'tofa', and 'topo' (topology-aware but fault-blind — the
    Section 5.1 Scotch run), plus any third-party registered policy.

    ``available`` restricts every policy to allocatable nodes (Slurm never
    schedules onto DOWN/DRAINED nodes, independent of fault-awareness).
    """
    _deprecated("place")
    req = PlacementRequest(comm=comm, topology=topo, p_f=p_f,
                           available=available, metric=metric)
    return default_engine().place(req, policy=policy, rng=rng).to_result()


#: Legacy policy tuple — now sourced from the registry.
POLICIES = available_policies()
