"""TOFA — TOpology and Fault-Aware process placement (paper Listing 1.1).

    procedure TOFA(G, H):
        S = find |V_G| consecutive nodes s.t. p_f = 0
        if S != {}:
            H_s := ScotchExtract(H, S)
            T   := ScotchMap(G, H_s)
        else:
            T   := ScotchMap(G, H)     # H fault-weighted per Eq. (1)

``map_graph`` (our Scotch analogue) plays ScotchMap; extraction is matrix
restriction.  When no consecutive fault-free window exists, the guest is
mapped onto a compact subset grown under the Eq. 1-weighted metric, which is
how the 100x penalty steers placement away from failing nodes while
tolerating them if unavoidable (the trade-off discussed in Section 3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .comm_graph import CommGraph
from .mapping import (greedy_placement, hop_bytes, linear_placement,
                      map_graph, random_placement, select_nodes)
from .topology import TorusTopology, find_consecutive_healthy

# additive weight that makes a node effectively unselectable (used to mask
# faulty nodes out of ball extraction during TOFA step 14)
FAULT_BLOCK = 1e9


def _healthy_window_starts(p_f: np.ndarray, count: int) -> list[int]:
    """Start ids of all length->=count runs of healthy nodes (non-overlapping
    step count//2 within a run, to bound candidate count)."""
    healthy = p_f == 0
    starts: list[int] = []
    i, n = 0, len(p_f)
    while i + count <= n:
        if healthy[i:i + count].all():
            starts.append(i)
            i += max(count // 2, 1)
        else:
            # jump past the first unhealthy node in the window
            bad = i + int(np.argmax(~healthy[i:i + count]))
            i = bad + 1
    return starts


def _best_map(G_w, node_sets, coords, D, rng) -> np.ndarray:
    """Map onto each candidate node subset, keep the lowest hop-bytes."""
    best, best_hb = None, np.inf
    for nodes in node_sets:
        pl = map_graph(G_w, np.asarray(nodes), coords, D=D, rng=rng)
        hb = hop_bytes(G_w, D, pl)
        if hb < best_hb:
            best, best_hb = pl, hb
    return best


@dataclasses.dataclass
class PlacementResult:
    """T = <process id, node id> plus quality diagnostics."""

    placement: np.ndarray          # (n_procs,) node ids
    policy: str
    used_consecutive_window: bool  # TOFA step 10 succeeded?
    hop_bytes: float               # dilation-volume under healthy hop metric
    faulty_nodes_used: int         # processes placed on p_f > 0 nodes

    def as_pairs(self) -> list[tuple[int, int]]:
        return [(i, int(nid)) for i, nid in enumerate(self.placement)]


def tofa_place(
    comm: CommGraph,
    topo: TorusTopology,
    p_f: np.ndarray | None = None,
    *,
    metric: str = "volume",
    rng: np.random.Generator | None = None,
    straggler: np.ndarray | None = None,
) -> PlacementResult:
    """Run TOFA (Listing 1.1) and return the placement with diagnostics."""
    rng = rng or np.random.default_rng(0)
    n = comm.n
    N = topo.n_nodes
    if n > N:
        raise ValueError(f"{n} processes > {N} nodes")
    p_f = np.zeros(N) if p_f is None else np.asarray(p_f, dtype=np.float64)
    G_w = comm.weights(metric)
    coords = topo.coords_array()
    hops = topo.hop_matrix()

    S = find_consecutive_healthy(p_f, n)
    W = topo.weight_matrix(p_f, straggler=straggler)  # Eq. 1 weights on H
    if S is not None:
        # steps 14-15: extract sub-topology, map onto it.  Listing 1.1's H
        # carries Eq. 1 weights *before* extraction, so mapping quality is
        # still judged fault-aware: a window placement whose internal routes
        # cross a faulty node is priced at 100x and avoided.  Several
        # extraction shapes are tried (ScotchExtract is free to return any
        # sub-arch): consecutive-id windows (slabs — ideal for banded
        # guests) and compact balls grown from seeds spread across the
        # healthy region; more candidates raise the odds of a region whose
        # internal routes are entirely fault-free, which keeps full mapping
        # quality *and* zero abort exposure.
        W_sel = W + (FAULT_BLOCK * ((p_f[:, None] > 0) | (p_f[None, :] > 0)))
        candidates = [S]
        healthy = np.flatnonzero(p_f == 0)
        # additional healthy windows beyond the first
        run_starts = _healthy_window_starts(p_f, n)
        for s0 in run_starts[1:4]:
            candidates.append(np.arange(s0, s0 + n))
        # balls from diverse seeds: default (cheapest region) + the healthy
        # nodes farthest from any fault
        candidates.append(select_nodes(W_sel, n))
        if (p_f > 0).any():
            dist_to_fault = W[:, p_f > 0].min(axis=1)
            far = healthy[np.argsort(dist_to_fault[healthy])[::-1]]
            for seed_node in far[:3]:
                candidates.append(select_nodes(W_sel, n, seed=int(seed_node)))
        placement = _best_map(G_w, candidates, coords, W, rng)
        used_window = True
    else:
        # step 12: map onto the full fault-weighted topology.  Weighted
        # selection grows the cheapest (healthiest, most compact) subset.
        # Improvement over plain Eq. 1 (see DESIGN.md): when >= n healthy
        # nodes exist, restrict selection to them outright — Eq. 1 alone can
        # tie a directly-faulty node with healthy nodes whose routes merely
        # *pass through* faults, and lose that tie.  Faulty nodes are used
        # only when the job cannot fit on healthy ones (the paper's
        # tolerance trade-off).
        healthy = np.flatnonzero(p_f == 0)
        if len(healthy) >= n:
            sub = select_nodes(W[np.ix_(healthy, healthy)], n)
            nodes = healthy[sub]
        else:
            nodes = select_nodes(W, n)
        placement = map_graph(G_w, nodes, coords, D=W, rng=rng)
        used_window = False

    return _result(placement, "tofa", used_window, G_w, hops, p_f)


def place(
    policy: str,
    comm: CommGraph,
    topo: TorusTopology,
    p_f: np.ndarray | None = None,
    *,
    metric: str = "volume",
    rng: np.random.Generator | None = None,
    available: np.ndarray | None = None,
) -> PlacementResult:
    """Policy registry: 'linear' (default-slurm), 'random', 'greedy', 'tofa',
    and 'topo' (topology-aware but fault-blind — the Section 5.1 Scotch run).

    ``available`` restricts every policy to allocatable nodes (Slurm never
    schedules onto DOWN/DRAINED nodes, independent of fault-awareness).
    """
    rng = rng or np.random.default_rng(0)
    n = comm.n
    N = topo.n_nodes
    p_f = np.zeros(N) if p_f is None else np.asarray(p_f, dtype=np.float64)
    G_w = comm.weights(metric)
    coords = topo.coords_array()
    hops = topo.hop_matrix()
    avail = np.arange(N) if available is None else np.asarray(available)
    if len(avail) < n:
        raise ValueError(f"{n} processes > {len(avail)} available nodes")

    if policy == "tofa":
        if available is not None:
            # unavailable nodes are certain outages from the mapper's view
            p_f = p_f.copy()
            mask = np.ones(N, dtype=bool)
            mask[avail] = False
            p_f[mask] = 1.0
        return tofa_place(comm, topo, p_f, metric=metric, rng=rng)
    if policy == "linear":
        placement = linear_placement(n, avail)
    elif policy == "random":
        placement = random_placement(n, avail, rng)
    elif policy == "greedy":
        placement = greedy_placement(G_w, avail, hops)
    elif policy == "topo":
        # fault-blind Scotch mapping (paper Section 5.1): window + ball
        subsets = [avail[:n]]
        if n < len(avail):
            Wa = hops[np.ix_(avail, avail)]
            subsets.append(avail[select_nodes(Wa, n)])
        placement = _best_map(G_w, subsets, coords, hops, rng)
    else:
        raise ValueError(f"unknown policy {policy!r}")
    return _result(placement, policy, False, G_w, hops, p_f)


def _result(placement, policy, used_window, G_w, hops, p_f) -> PlacementResult:
    return PlacementResult(
        placement=np.asarray(placement),
        policy=policy,
        used_consecutive_window=used_window,
        hop_bytes=hop_bytes(G_w, hops, placement),
        faulty_nodes_used=int((p_f[np.asarray(placement)] > 0).sum()),
    )


POLICIES = ("linear", "random", "greedy", "topo", "tofa")
