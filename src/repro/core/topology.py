"""Topology graph H: torus platforms, routing R(u,v), fault-aware weights.

Implements the paper's platform model (Section 3):

* The platform is a d-dimensional torus (the paper evaluates 3D tori such as
  8x8x8; TPU v5e pods are 2D 16x16 tori — same machinery).
* Routing is dimension-ordered with shortest wrap-around direction per
  dimension, mirroring the fixed-routing assumption of the paper.  The
  routing function ``R(u, v)`` returns the ordered list of links traversed.
* Edge weights follow Eq. (1):

      w(e_uv) = sum_{l in R(u,v)}  c  +  c * 100 * 1[p_f(l_s) > 0 or p_f(l_d) > 0]

  i.e. a link costs ``c`` (one hop) when both endpoints are healthy and
  ``101 c`` when either endpoint has a non-zero outage probability, making
  any faulty path strictly more expensive than the longest healthy path.

Beyond the paper, :func:`TorusTopology.weight_matrix` accepts a *straggler*
vector: slow-but-alive nodes inflate link cost proportionally instead of the
hard 100x fault penalty (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

import numpy as np

FAULT_PENALTY = 100.0  # the paper's "100" in Eq. (1)


@dataclasses.dataclass(frozen=True)
class Link:
    """A directed link between two adjacent torus nodes."""

    src: int
    dst: int


@dataclasses.dataclass(frozen=True)
class TorusTopology:
    """A d-dimensional torus with dimension-ordered shortest-path routing."""

    dims: tuple[int, ...]

    # ------------------------------------------------------------------ basics
    @property
    def n_nodes(self) -> int:
        return int(np.prod(self.dims))

    @property
    def ndim(self) -> int:
        return len(self.dims)

    def coords(self, node: int) -> tuple[int, ...]:
        """Node id -> coordinates (row-major / x-major order)."""
        out = []
        for d in reversed(self.dims):
            out.append(node % d)
            node //= d
        return tuple(reversed(out))

    def coords_array(self) -> np.ndarray:
        """(n_nodes, ndim) coordinates for all nodes, row-major ids."""
        grids = np.meshgrid(*[np.arange(d) for d in self.dims], indexing="ij")
        return np.stack([g.ravel() for g in grids], axis=1)

    def node_at(self, coords: Sequence[int]) -> int:
        node = 0
        for c, d in zip(coords, self.dims):
            node = node * d + (c % d)
        return int(node)

    # ----------------------------------------------------------------- routing
    def _dim_steps(self, a: int, b: int, dim: int) -> list[int]:
        """Shortest sequence of coordinates from a to b along one torus dim."""
        d = self.dims[dim]
        fwd = (b - a) % d
        bwd = (a - b) % d
        steps = []
        cur = a
        if fwd <= bwd:  # tie broken toward +1, as a fixed deterministic routing
            for _ in range(fwd):
                cur = (cur + 1) % d
                steps.append(cur)
        else:
            for _ in range(bwd):
                cur = (cur - 1) % d
                steps.append(cur)
        return steps

    def route(self, u: int, v: int) -> list[Link]:
        """R(u, v): ordered links of the dimension-ordered route u -> v."""
        if u == v:
            return []
        cu, cv = list(self.coords(u)), self.coords(v)
        links: list[Link] = []
        prev = u
        for dim in range(self.ndim):
            for step in self._dim_steps(cu[dim], cv[dim], dim):
                cu[dim] = step
                nxt = self.node_at(cu)
                links.append(Link(prev, nxt))
                prev = nxt
        return links

    def route_nodes(self, u: int, v: int) -> list[int]:
        """All nodes touched by R(u, v), endpoints included."""
        return [u] + [l.dst for l in self.route(u, v)]

    # --------------------------------------------------------------- distances
    def hop_matrix(self) -> np.ndarray:
        """(n, n) hop distances (sum over dims of shortest wrap distance).

        Memoised on first use: constructing a topology stays O(1), and
        repeat callers (engine cache misses across health states, scenario
        presets) share one dense matrix instead of recomputing the
        O(n^2 * ndim) derivation per call.
        """
        cached = self.__dict__.get("_hop_matrix")
        if cached is not None:
            return cached
        c = self.coords_array()  # (n, ndim)
        diff = np.abs(c[:, None, :] - c[None, :, :])  # (n, n, ndim)
        wrap = np.array(self.dims)[None, None, :] - diff
        out = np.minimum(diff, wrap).sum(axis=-1).astype(np.float64)
        # frozen dataclass: bypass __setattr__ for the memo slot
        object.__setattr__(self, "_hop_matrix", out)
        return out

    def lazy_distance(self, p_f: np.ndarray | None = None, c: float = 1.0,
                      straggler: np.ndarray | None = None):
        """O(n)-memory implicit view of :meth:`weight_matrix` — entries
        are computed from coordinates on indexing, bit-identical to the
        dense matrix (see :mod:`repro.core.lazydist`)."""
        from .lazydist import TorusLazyDistance
        return TorusLazyDistance(self, p_f, c=c, straggler=straggler)

    def hierarchy_groups(self, target_groups: int = 64) -> np.ndarray:
        """(n,) contiguous-block group ids for hierarchical mapping.

        Splits the torus into >= ``target_groups`` axis-aligned bricks by
        repeatedly halving the dimension with the longest remaining
        segment — groups are compact sub-tori ("racks"), so the coarse
        mapper can treat group centroids as super-nodes.
        """
        segs = [1] * self.ndim
        n_groups = 1
        while n_groups < min(target_groups, self.n_nodes):
            k = max(range(self.ndim), key=lambda i: self.dims[i] / segs[i])
            if segs[k] >= self.dims[k]:
                break
            segs[k] *= 2
            n_groups = 1
            for s, d in zip(segs, self.dims):
                n_groups *= min(s, d)
        coords = self.coords_array()
        gid = np.zeros(self.n_nodes, dtype=np.int64)
        for i in range(self.ndim):
            s = min(segs[i], self.dims[i])
            gid = gid * s + (coords[:, i] * s) // self.dims[i]
        return gid

    def weight_matrix(
        self,
        p_f: np.ndarray | None = None,
        c: float = 1.0,
        straggler: np.ndarray | None = None,
    ) -> np.ndarray:
        """Pairwise path weights per Eq. (1) of the paper.

        ``p_f``        per-node outage probability (n,), or None == all healthy.
        ``straggler``  optional per-node slowdown factor >= 0 (beyond paper):
                       a link touching a straggler costs ``c * (1 + s)``.

        Returns an (n, n) matrix where entry (u, v) is the weight of the
        dimension-ordered route u -> v.  With no faults this equals
        ``c * hop_matrix()``.
        """
        n = self.n_nodes
        if p_f is None:
            p_f = np.zeros(n)
        p_f = np.asarray(p_f, dtype=np.float64)
        base = c * self.hop_matrix()
        faulty = p_f > 0
        slow = None
        if straggler is not None:
            slow = np.asarray(straggler, dtype=np.float64)
            if not np.any(slow > 0):
                slow = None
        if not faulty.any() and slow is None:
            return base

        # Count, per pair, the route links that touch a penalised node.  The
        # dimension-ordered route from u to v visits nodes u = n_0 .. n_k = v;
        # link i touches nodes (n_i, n_{i+1}).  A node x strictly inside the
        # route contributes to two links, an endpoint to one.
        w = base.copy()
        penal = np.flatnonzero(faulty)
        penal_set = set(int(x) for x in penal)
        slow_idx = set(np.flatnonzero(slow > 0).tolist()) if slow is not None else set()
        interesting = penal_set | slow_idx
        if not interesting:
            return w
        for u in range(n):
            for v in range(n):
                if u == v:
                    continue
                nodes = self.route_nodes(u, v)
                extra = 0.0
                for a, b in zip(nodes[:-1], nodes[1:]):
                    if a in penal_set or b in penal_set:
                        extra += c * FAULT_PENALTY
                    elif a in slow_idx or b in slow_idx:
                        sa = slow[a] if a in slow_idx else 0.0
                        sb = slow[b] if b in slow_idx else 0.0
                        extra += c * max(sa, sb)
                w[u, v] += extra
        return w

    def pairs_through(self, nodes) -> np.ndarray:
        """(n, n) bool: pairs whose dimension-ordered route touches any of
        ``nodes`` (endpoints included).

        While the route corrects dimension ``k``, the visited nodes have
        coordinates ``(v[<k], path(u[k] -> v[k]), u[>k])`` — so node x is
        on route(u, v) iff for some k the prefix of x matches v, the
        suffix matches u, and ``x[k]`` lies on the shortest wrap path in
        dimension k.  Vectorized over all pairs per probed node; used by
        :meth:`weight_matrix_update` to bound delta refreshes to exactly
        the entries a health change can invalidate.
        """
        c = self.coords_array()
        n = self.n_nodes
        aff = np.zeros((n, n), dtype=bool)
        for x in np.atleast_1d(np.asarray(nodes, dtype=np.int64)):
            xc = c[int(x)]
            # post[k]: u-side suffix match (u[j] == x[j] for all j > k-1);
            # post[k+1] is the constraint for dims strictly after k
            post = np.ones((self.ndim + 1, n), dtype=bool)
            for j in range(self.ndim - 1, -1, -1):
                post[j] = post[j + 1] & (c[:, j] == xc[j])
            pre = np.ones(n, dtype=bool)      # v-side prefix match (j < k)
            for k in range(self.ndim):
                d = self.dims[k]
                a = c[:, k]                   # u-side coordinate, dim k
                b = c[:, k]                   # v-side coordinate, dim k
                fwd = (b[None, :] - a[:, None]) % d
                bwd = (a[:, None] - b[None, :]) % d
                on_f = ((xc[k] - a[:, None]) % d) <= fwd
                on_b = ((a[:, None] - xc[k]) % d) <= bwd
                on = np.where(fwd <= bwd, on_f, on_b)
                aff |= post[k + 1][:, None] & pre[None, :] & on
                pre = pre & (c[:, k] == xc[k])
        np.fill_diagonal(aff, False)          # empty routes: nothing to touch
        return aff

    def weight_matrix_update(
        self,
        W_prev: np.ndarray,
        changed,
        p_f: np.ndarray | None = None,
        c: float = 1.0,
        straggler: np.ndarray | None = None,
    ) -> np.ndarray:
        """Row-wise delta refresh of :meth:`weight_matrix`.

        ``W_prev`` must be the weight matrix of a health state that
        differs from ``(p_f, straggler)`` exactly at the ``changed``
        node ids (penalty flag or slowdown value).  Only the entries
        whose routes touch a changed node are recomputed — with the same
        formula as the full derivation, so the result is bit-identical
        to ``weight_matrix(p_f, c, straggler)`` (asserted in
        ``tests/test_state.py``).
        """
        changed = np.atleast_1d(np.asarray(changed, dtype=np.int64))
        if changed.size == 0:
            return W_prev
        n = self.n_nodes
        p_f = np.zeros(n) if p_f is None else np.asarray(p_f, np.float64)
        base = c * self.hop_matrix()
        penal_set = set(np.flatnonzero(p_f > 0).tolist())
        slow = None
        if straggler is not None:
            slow = np.asarray(straggler, dtype=np.float64)
            if not np.any(slow > 0):
                slow = None
        slow_idx = (set(np.flatnonzero(slow > 0).tolist())
                    if slow is not None else set())
        aff = self.pairs_through(changed)
        W = W_prev.copy()
        for u, v in zip(*np.nonzero(aff)):
            nodes = self.route_nodes(int(u), int(v))
            extra = 0.0
            for a, b in zip(nodes[:-1], nodes[1:]):
                if a in penal_set or b in penal_set:
                    extra += c * FAULT_PENALTY
                elif a in slow_idx or b in slow_idx:
                    sa = slow[a] if a in slow_idx else 0.0
                    sb = slow[b] if b in slow_idx else 0.0
                    extra += c * max(sa, sb)
            W[u, v] = base[u, v] + extra
        return W

    # ------------------------------------------------------------- sub-extract
    def submatrix(self, weights: np.ndarray, nodes: Sequence[int]) -> np.ndarray:
        """ScotchExtract analogue: restrict a weight matrix to ``nodes``."""
        idx = np.asarray(nodes)
        return weights[np.ix_(idx, idx)]

    # ----------------------------------------------------------------- helpers
    def neighbors(self, node: int) -> list[int]:
        c = list(self.coords(node))
        out = []
        for dim in range(self.ndim):
            if self.dims[dim] == 1:
                continue
            for delta in (-1, +1):
                cc = list(c)
                cc[dim] = (cc[dim] + delta) % self.dims[dim]
                nb = self.node_at(cc)
                if nb != node:
                    out.append(nb)
        return sorted(set(out))

    def links(self) -> list[Link]:
        """All directed links of the torus."""
        out = []
        for u in range(self.n_nodes):
            for v in self.neighbors(u):
                out.append(Link(u, v))
        return out


def find_consecutive_healthy(
    p_f: np.ndarray, count: int, *, wrap: bool = False
) -> np.ndarray | None:
    """Step 10 of Listing 1.1: find ``count`` consecutive nodes with p_f == 0.

    "Consecutive" means consecutive node ids — the resource-manager ordering,
    exactly as in the paper (Slurm iterates nodes sequentially).  Returns the
    id array of the first such window, or None.  ``wrap=True`` also considers
    windows that wrap around the id space (torus ids are cyclic per row, the
    paper does not wrap; default off).
    """
    p_f = np.asarray(p_f)
    n = len(p_f)
    if count > n:
        return None
    healthy = (p_f == 0).astype(np.int64)
    if count == 0:
        return np.array([], dtype=np.int64)
    run = np.convolve(healthy, np.ones(count, dtype=np.int64), mode="valid")
    hits = np.flatnonzero(run == count)
    if hits.size:
        s = int(hits[0])
        return np.arange(s, s + count)
    if wrap:
        ext = np.concatenate([healthy, healthy[: count - 1]])
        run = np.convolve(ext, np.ones(count, dtype=np.int64), mode="valid")
        hits = np.flatnonzero(run == count)
        if hits.size:
            s = int(hits[0])
            return np.arange(s, s + count) % n
    return None


def arrangements(n_nodes: int, ndim: int = 3) -> list[tuple[int, ...]]:
    """All torus dim arrangements of ``n_nodes`` (Table 1 exploration)."""
    out = set()
    def rec(remaining: int, dims: tuple[int, ...]):
        if len(dims) == ndim - 1:
            out.add(dims + (remaining,))
            return
        for d in range(2, remaining + 1):
            if remaining % d == 0:
                rec(remaining // d, dims + (d,))
    rec(n_nodes, ())
    return sorted(out)
