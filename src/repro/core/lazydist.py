"""LazyDistance — numpy-indexable implicit distance matrices, O(N) memory.

Above the engine's size threshold, :meth:`TorusTopology.lazy_distance` /
:meth:`FatTreeTopology.lazy_distance` hand the mapping pipeline one of
these adapters instead of a dense (N, N) matrix.  Every indexing idiom
the hot kernels use —

    D[i]                     row            (N,)
    D[rows]                  row block      (len(rows), N)
    D[i, j] / D[i, cols]     elementwise
    D[np.ix_(rows, cols)]    open-mesh block
    D[P[:, :, None], P[:, None, :]]   broadcast fancy (hop_bytes_batch)

— is computed on demand from the coordinate table in O(#requested
elements) memory, bit-identical to the entries the topology's dense
``weight_matrix`` would hold (differentially asserted in
``tests/test_multilevel.py``).  ``np.asarray(D)`` raises: nothing in the
pipeline may silently densify the matrix.

Fault/straggler weighting stays **exact**, not approximate.  For the
torus, the Eq. (1) extra terms are nonzero only for pairs whose
dimension-ordered route touches a penalised node; the adapter flags
candidate pairs with the same vectorized route-membership conditions as
:meth:`TorusTopology.pairs_through` and walks the route scalar-exactly
for flagged pairs only — O(f * n^(1/ndim)) work per requested row for f
penalised nodes, instead of O(n^2 * hops) for the dense derivation.
Fat-tree weighting is endpoint-form and trivially elementwise.

The healthy uniform-cost torus case — and the fat-tree in *every*
health state, its weighting being endpoint-form — additionally exposes
an ``implicit`` spec (coordinates + metric kind + scale + optional
penalty vector) that lets the jax backend compute distances in-kernel
(:mod:`repro.kernels.hop_dist`) instead of going through
``__getitem__`` at all.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.kernels.hop_dist.ops import torus_hop_np


@dataclasses.dataclass(frozen=True)
class ImplicitSpec:
    """What the jax backend needs to compute distances in-kernel:
    per-node integer coordinates, a static metric spec, a uniform scale.

    ``kind="torus"`` interprets ``coords`` against wraparound ``dims``;
    ``kind="fattree"`` interprets them as (pod, edge, host) triples with
    ``dims=()`` and carries the per-node endpoint ``penalty`` vector
    (zeros when healthy — always present so the backend's identity-keyed
    device-transfer cache has a stable array to pin).
    """

    coords: np.ndarray          # (N, ndim) float64 — stable identity for
                                # the backend's device-transfer cache
    dims: tuple[int, ...]
    scale: float
    kind: str = "torus"
    penalty: Optional[np.ndarray] = None    # (N,) float64, fat-tree only


class LazyDistance:
    """Base adapter: numpy-compatible read-only 2-D indexing over an
    implicit distance function."""

    ndim = 2
    dtype = np.dtype(np.float64)

    def __init__(self, n: int):
        self.shape = (n, n)

    # ---- subclass hook -------------------------------------------------
    def _elems(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Entries D[u, v] for same-shape int arrays ``u``, ``v``."""
        raise NotImplementedError

    # ---- numpy protocol ------------------------------------------------
    def __array__(self, dtype=None, copy=None):
        raise TypeError(
            f"refusing to densify a {type(self).__name__} of shape "
            f"{self.shape} — index it (rows / pairs / np.ix_ blocks) "
            f"instead, or use the topology's dense weight_matrix() below "
            f"the lazy threshold")

    def __len__(self) -> int:
        return self.shape[0]

    @property
    def implicit(self) -> Optional[ImplicitSpec]:
        """In-kernel computation spec, or None when only ``__getitem__``
        applies (faults, stragglers, non-torus)."""
        return None

    def _axis(self, key, n: int) -> np.ndarray:
        if isinstance(key, slice):
            return np.arange(*key.indices(n))
        a = np.asarray(key)
        if a.dtype == bool:
            a = np.flatnonzero(a)
        return a.astype(np.int64, copy=False)

    def __getitem__(self, key):
        n = self.shape[0]
        if isinstance(key, tuple):
            if len(key) != 2:
                raise IndexError(
                    f"{type(self).__name__} supports 2-d indexing only")
            u, v = (self._axis(key[0], n), self._axis(key[1], n))
            both_scalar = u.ndim == 0 and v.ndim == 0
            u, v = np.broadcast_arrays(u, v)
            out = self._elems(u, v)
            return float(out) if both_scalar else out
        rows = self._axis(key, n)
        cols = np.arange(n, dtype=np.int64)
        u, v = np.broadcast_arrays(rows[..., None], cols)
        return self._elems(u, v)


class TorusLazyDistance(LazyDistance):
    """Implicit Eq. (1) route weights of a :class:`TorusTopology`."""

    def __init__(self, topo, p_f: Optional[np.ndarray] = None,
                 c: float = 1.0, straggler: Optional[np.ndarray] = None):
        super().__init__(topo.n_nodes)
        self.topo = topo
        self.c = float(c)
        self.coords = topo.coords_array().astype(np.int64)
        self.dims = tuple(topo.dims)
        penal = (np.zeros(topo.n_nodes, dtype=bool) if p_f is None
                 else np.asarray(p_f, np.float64) > 0)
        slow = None
        if straggler is not None:
            s = np.asarray(straggler, dtype=np.float64)
            if (s > 0).any():
                slow = s
        self._penal = penal
        self._slow = slow
        slow_mask = np.zeros(topo.n_nodes, bool) if slow is None else slow > 0
        self._interesting = np.flatnonzero(penal | slow_mask)
        self._pair_cache: dict[tuple[int, int], float] = {}
        self._spec = None
        if self._interesting.size == 0:
            self._spec = ImplicitSpec(
                coords=self.coords.astype(np.float64),
                dims=self.dims, scale=self.c)

    @property
    def implicit(self) -> Optional[ImplicitSpec]:
        return self._spec

    def _elems(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        cu = self.coords[u]
        cv = self.coords[v]
        out = self.c * torus_hop_np(cu, cv, self.dims)
        if self._interesting.size == 0:
            return out
        flagged = self._on_route_any(u, v, cu, cv)
        if not flagged.any():
            return out
        out = np.ascontiguousarray(out)
        flat = np.flatnonzero(flagged.ravel())
        uu = u.ravel()[flat]
        vv = v.ravel()[flat]
        extra = np.fromiter(
            (self._route_extra(int(a), int(b)) for a, b in zip(uu, vv)),
            dtype=np.float64, count=flat.size)
        out.ravel()[flat] += extra
        return out

    def _on_route_any(self, u, v, cu, cv) -> np.ndarray:
        """Pairs whose dimension-ordered route u -> v touches any
        penalised/straggling node — the elementwise form of
        :meth:`TorusTopology.pairs_through` (same membership conditions,
        evaluated per requested pair instead of over the full (n, n))."""
        ndim = len(self.dims)
        aff = np.zeros(u.shape, dtype=bool)
        for x in self._interesting:
            xc = self.coords[int(x)]
            # u-side suffix match for dims strictly after k
            post = np.ones(u.shape + (ndim + 1,), dtype=bool)
            for j in range(ndim - 1, -1, -1):
                post[..., j] = post[..., j + 1] & (cu[..., j] == xc[j])
            pre = np.ones(u.shape, dtype=bool)   # v-side prefix match
            for k in range(ndim):
                d = self.dims[k]
                a = cu[..., k]
                b = cv[..., k]
                fwd = (b - a) % d
                bwd = (a - b) % d
                on_f = ((xc[k] - a) % d) <= fwd
                on_b = ((a - xc[k]) % d) <= bwd
                on = np.where(fwd <= bwd, on_f, on_b)
                aff |= post[..., k + 1] & pre & on
                pre = pre & (cv[..., k] == xc[k])
        return aff & (u != v)                    # empty routes touch nothing

    def _route_extra(self, u: int, v: int) -> float:
        """Exact Eq. (1) extra for one pair: the same scalar route walk as
        :meth:`TorusTopology.weight_matrix` (memoised — refinement re-reads
        the same flagged pairs many times)."""
        hit = self._pair_cache.get((u, v))
        if hit is not None:
            return hit
        penal = self._penal
        slow = self._slow
        c = self.c
        from .topology import FAULT_PENALTY
        nodes = self.topo.route_nodes(u, v)
        extra = 0.0
        for a, b in zip(nodes[:-1], nodes[1:]):
            if penal[a] or penal[b]:
                extra += c * FAULT_PENALTY
            elif slow is not None and (slow[a] > 0 or slow[b] > 0):
                extra += c * max(slow[a], slow[b])
        if len(self._pair_cache) > 2_000_000:    # bound the memo
            self._pair_cache.clear()
        self._pair_cache[(u, v)] = extra
        return extra


class FatTreeLazyDistance(LazyDistance):
    """Implicit endpoint-form Eq. (1) weights of a
    :class:`FatTreeTopology` (exact for any health state — paths touch
    compute nodes only at their endpoints).

    Because the fault/straggler weighting is a per-endpoint penalty
    gather — no route walks — the adapter exposes an ``implicit`` spec
    for **every** health state, so the jax backend compiles fat-tree
    distances in-kernel even under faults (tori only qualify healthy).
    """

    def __init__(self, topo, p_f: Optional[np.ndarray] = None,
                 c: float = 1.0, straggler: Optional[np.ndarray] = None):
        super().__init__(topo.n_nodes)
        self.topo = topo
        self.c = float(c)
        self.coords = topo.coords_array().astype(np.int64)
        from .topology import FAULT_PENALTY
        penalty = np.zeros(topo.n_nodes)
        if p_f is not None:
            penalty += c * FAULT_PENALTY * (np.asarray(p_f, np.float64) > 0)
        if straggler is not None:
            penalty += c * np.asarray(straggler, dtype=np.float64)
        self._penalty = penalty if (penalty > 0).any() else None
        # the zeros vector is kept (not None) so the spec always carries
        # a stable array for the backend's identity-keyed transfer cache
        self._spec = ImplicitSpec(
            coords=self.coords.astype(np.float64), dims=(), scale=self.c,
            kind="fattree", penalty=penalty)

    @property
    def implicit(self) -> Optional[ImplicitSpec]:
        return self._spec

    def _elems(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        from repro.kernels.hop_dist.ops import fattree_hop_np
        out = self.c * fattree_hop_np(self.coords[u], self.coords[v])
        if self._penalty is not None:
            out += np.where(u != v, self._penalty[u] + self._penalty[v], 0.0)
        return out


def is_lazy(D) -> bool:
    """True when ``D`` is a lazy adapter rather than a dense ndarray."""
    return isinstance(D, LazyDistance)
