"""ClusterState — the versioned, immutable health model of the platform.

The paper's placement decisions are functions of *node health*: per-node
outage probabilities feed the Eq. 1 route weights, and availability
restricts every policy.  Before this module, health travelled as loose
``(p_f, available)`` arrays with four independent owners; here it is one
first-class value:

* **Immutable snapshot.**  A :class:`ClusterState` never changes; every
  mutation (:meth:`with_health`, :meth:`with_outage`, :meth:`evolve`)
  returns a *new* state carrying a fresh, process-monotonic **epoch**.
  ``snapshot()`` is the O(1) handle — the object itself.
* **Epoch-keyed caching.**  ``state.key`` is a stable cache token:
  equal keys imply identical health, so the
  :class:`~repro.core.engine.PlacementEngine` keys its hop/weight/memo
  caches on ``(topology, state.key)`` instead of hashing raw float
  vectors — a heartbeat round that does not change health keeps the
  epoch and every warm cache (no more quantization workarounds).
* **Overlays.**  :meth:`overlay` derives a cheap view with extra nodes
  made unallocatable (busy allocations, freshly failed nodes) without
  minting a new epoch: the derived key is ``(base key, digest of the
  masked set)``, so repeated placements against the same base state and
  busy set stay warm.  Overlays come in two flavors: the default
  (``route_faulty=True``) treats masked nodes exactly like certain
  outages — routes through them are penalized by Eq. 1, the right model
  for *failed* nodes — while ``route_faulty=False`` marks nodes merely
  *busy*: excluded from selection, but still perfectly good routers, so
  the route-weight matrix (and its :attr:`route_key` cache token) stays
  that of the base state.  A serving loop whose busy set changes every
  drain tick keeps one weight matrix per health epoch instead of one
  per busy digest (see :mod:`repro.service.service`).
* **Diffs.**  :meth:`diff` returns exactly the node ids whose effective
  health changed between two states — what incremental re-placement and
  row-wise weight-matrix updates consume.

Lifecycle is four-valued (:class:`NodeHealth`): ``UP`` and ``DEGRADED``
nodes are *allocatable* (a degraded node serves jobs with an elevated
outage estimate — Eq. 1 steers around it without banning it), while
``DRAINED`` (administrative removal) and ``DOWN`` nodes are not.  The
stored ``p_f`` vector is the scheduler's *belief* for allocatable nodes;
:meth:`outage_vector` pins non-allocatable nodes to 1.0 — the exact
"unavailable nodes are certain outages" convention the engine has always
applied.

Epoch semantics: epochs come from one process-wide monotonic counter, so
``(topology, epoch)`` can never collide across trackers.  Overlays keep
their base's epoch (they are views, not new health observations) and
differ only in ``key``.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np


class NodeHealth(enum.IntEnum):
    """Per-node lifecycle. UP/DEGRADED are allocatable; DRAINED/DOWN are not."""

    UP = 0
    DEGRADED = 1
    DRAINED = 2
    DOWN = 3


_ALLOCATABLE = frozenset((NodeHealth.UP, NodeHealth.DEGRADED))

# process-wide monotonic epoch source: two states with the same epoch are
# the same state, no matter which scheduler / tracker minted them
_EPOCHS = itertools.count(1)


def _ro(a: np.ndarray) -> np.ndarray:
    a.setflags(write=False)
    return a


@dataclasses.dataclass(frozen=True, eq=False)
class ClusterState:
    """One immutable health snapshot of the whole platform.

    Build with :meth:`healthy` / :meth:`from_arrays`, evolve with
    :meth:`with_health` / :meth:`with_outage` / :meth:`evolve`, derive
    views with :meth:`overlay`.  Never construct directly — the epoch
    and key fields must stay consistent with the content.
    """

    health: np.ndarray                 # (n,) int8 NodeHealth codes
    p_f: np.ndarray                    # (n,) float64 belief, allocatable nodes
    epoch: int                         # monotonic version of the base state
    key: tuple                         # cache token; equal key == equal health
    groups: Optional[tuple[tuple[int, ...], ...]] = None  # rack membership
    masked: Optional[np.ndarray] = None   # overlay-unavailable bool mask
    # busy-flavored overlay mask: unallocatable for *selection* but still a
    # valid router (route weights and route_key come from the base health)
    masked_busy: Optional[np.ndarray] = None

    # ------------------------------------------------------------ factories
    @classmethod
    def healthy(cls, n_nodes: int,
                groups: Optional[Sequence[Sequence[int]]] = None
                ) -> "ClusterState":
        """All nodes UP with zero outage probability."""
        return cls._mint(np.zeros(n_nodes, dtype=np.int8),
                         np.zeros(n_nodes, dtype=np.float64),
                         _freeze_groups(groups))

    @classmethod
    def from_arrays(cls, n_nodes: int,
                    p_f: Optional[np.ndarray] = None,
                    available: Optional[np.ndarray] = None,
                    groups: Optional[Sequence[Sequence[int]]] = None
                    ) -> "ClusterState":
        """State equivalent to the legacy ``(p_f, available)`` kwargs.

        Nodes outside ``available`` are DOWN; everything else is UP with
        the given belief.  Results are *interned* by content: passing the
        same arrays twice returns the same state object (same epoch), so
        legacy callers that re-submit identical health vectors keep warm
        epoch-keyed caches exactly as they kept byte-keyed ones.
        """
        frozen_groups = _freeze_groups(groups)
        key = (int(n_nodes),
               None if p_f is None else np.asarray(p_f, np.float64).tobytes(),
               None if available is None
               else np.asarray(available, np.int64).tobytes(),
               frozen_groups)
        hit = _INTERNED.get(key)
        if hit is not None:
            _INTERNED.move_to_end(key)
            return hit
        health = np.zeros(n_nodes, dtype=np.int8)
        if available is not None:
            down = np.ones(n_nodes, dtype=bool)
            down[np.asarray(available, dtype=np.int64)] = False
            health[down] = int(NodeHealth.DOWN)
        p = (np.zeros(n_nodes, dtype=np.float64) if p_f is None
             else np.asarray(p_f, dtype=np.float64).copy())
        state = cls._mint(health, p, frozen_groups)
        _INTERNED[key] = state
        while len(_INTERNED) > _MAX_INTERNED:
            _INTERNED.popitem(last=False)
        return state

    @classmethod
    def _mint(cls, health: np.ndarray, p_f: np.ndarray,
              groups=None) -> "ClusterState":
        epoch = next(_EPOCHS)
        return cls(health=_ro(health), p_f=_ro(p_f), epoch=epoch,
                   key=("e", epoch), groups=groups)

    # ---------------------------------------------------------------- views
    @property
    def n_nodes(self) -> int:
        return len(self.health)

    @property
    def is_overlay(self) -> bool:
        return self.masked is not None or self.masked_busy is not None

    def snapshot(self) -> "ClusterState":
        """The O(1) immutable handle — the state itself."""
        return self

    def allocatable_mask(self) -> np.ndarray:
        """(n,) bool: nodes placements may use (UP or DEGRADED, unmasked)."""
        m = self.health <= np.int8(NodeHealth.DEGRADED)
        if self.masked is not None:
            m = m & ~self.masked
        if self.masked_busy is not None:
            m = m & ~self.masked_busy
        return m

    def available_ids(self) -> np.ndarray:
        """Allocatable node ids in id (resource-manager) order."""
        return np.flatnonzero(self.allocatable_mask())

    def outage_vector(self) -> np.ndarray:
        """Belief with non-allocatable nodes pinned to certain outage (1.0).

        This is the vector node *selection* consumes: no policy may place
        a process on a busy, drained or down node, so all of them read as
        certain outages here."""
        p = self.p_f.copy()
        p[~self.allocatable_mask()] = 1.0
        return p

    def route_outage_vector(self) -> np.ndarray:
        """Belief as the Eq. 1 *route-weight* derivation consumes it.

        Lifecycle-unallocatable (DRAINED/DOWN) and fault-flavored overlay
        nodes are pinned to 1.0 — routes through them are penalized — but
        busy-flavored overlay nodes keep their base belief: an occupied
        node is a perfectly good router.  Equal :attr:`route_key` implies
        an equal result of this method."""
        p = self.p_f.copy()
        m = self.health <= np.int8(NodeHealth.DEGRADED)
        if self.masked is not None:
            m = m & ~self.masked
        p[~m] = 1.0
        return p

    @property
    def route_key(self) -> tuple:
        """Cache token for route-weight derivations: ignores busy-flavored
        masks, so every drain tick of a serving loop — each with a
        different busy set — shares one weight matrix per health epoch.
        Equals :attr:`key` when no busy mask is present; equals the key of
        the same overlay without its busy mask otherwise."""
        if self.masked_busy is None:
            return self.key          # base state or faulty-only overlay
        base_key = self.key[1]       # ("ob", base_key, f_digest, b_digest)
        if self.masked is None:
            return base_key
        return ("o", base_key, np.flatnonzero(self.masked).tobytes())

    def health_of(self, node_id: int) -> NodeHealth:
        return NodeHealth(int(self.health[node_id]))

    def group_of(self, node_id: int) -> Optional[int]:
        """Index of the rack/group containing ``node_id`` (None if ungrouped)."""
        if self.groups is None:
            return None
        for gi, grp in enumerate(self.groups):
            if node_id in grp:
                return gi
        return None

    # ------------------------------------------------------------ evolution
    def evolve(self, health: Optional[np.ndarray] = None,
               p_f: Optional[np.ndarray] = None,
               atol: Optional[float] = 0.0) -> "ClusterState":
        """New state with the given health codes / belief, *iff* changed.

        Returns ``self`` (same epoch, warm caches) when nothing changed:
        health codes equal, the ``p_f > 0`` pattern equal, and every
        belief delta within ``atol``.  ``atol=None`` means
        *pattern-only*: belief magnitudes never mint an epoch by
        themselves — correct for every Eq. 1-style consumer, which reads
        only the ``p_f > 0`` pattern.  A pattern or lifecycle change
        always mints.  Overlays cannot evolve (evolve the base instead).
        """
        if self.is_overlay:
            raise ValueError("cannot evolve an overlay; evolve its base state")
        new_h = (self.health if health is None
                 else np.asarray(health, dtype=np.int8))
        new_p = (self.p_f if p_f is None
                 else np.asarray(p_f, dtype=np.float64))
        if new_h.shape != self.health.shape or new_p.shape != self.p_f.shape:
            raise ValueError("evolve arrays must match n_nodes")
        same_h = new_h is self.health or np.array_equal(new_h, self.health)
        if same_h and (new_p is self.p_f or self._p_close(new_p, atol)):
            return self
        return ClusterState._mint(new_h.copy(), new_p.copy(),
                                  groups=self.groups)

    def _p_close(self, new_p: np.ndarray, atol: Optional[float]) -> bool:
        if not np.array_equal(new_p > 0, self.p_f > 0):
            return False
        if atol is None:
            return True
        return bool(np.all(np.abs(new_p - self.p_f) <= atol))

    def with_health(self, ids, state: NodeHealth) -> "ClusterState":
        """New state with ``ids`` transitioned to ``state`` (no-op -> self)."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_nodes):
            raise ValueError(f"node ids out of range [0, {self.n_nodes})")
        h = self.health.copy()
        h[ids] = np.int8(state)
        return self.evolve(health=h)

    def with_outage(self, p_f: np.ndarray,
                    atol: Optional[float] = 0.0) -> "ClusterState":
        """New state with a refreshed belief vector (within-``atol`` -> self)."""
        return self.evolve(p_f=p_f, atol=atol)

    # -------------------------------------------------------------- overlay
    def overlay(self, unavailable=(), *,
                route_faulty: bool = True) -> "ClusterState":
        """Derived view with extra nodes made unallocatable.

        O(n) to build, no new epoch: the key digests the masked sets, so
        two overlays of one base with the same masks share every
        epoch-keyed cache entry.  ``route_faulty`` picks the flavor:

        * ``True`` (default) — the nodes are treated as certain outages
          end to end: excluded from selection *and* penalized in the
          Eq. 1 route weights.  The right model for freshly **failed**
          nodes (``engine.replace``), and the historical behavior of
          every overlay.
        * ``False`` — the nodes are merely **busy**: excluded from
          selection, but still valid routers.  :attr:`route_key` and
          :meth:`route_outage_vector` ignore them, so route-weight
          caches key on the base health epoch — the property the online
          placement service relies on under lease churn.

        Overlaying an overlay composes each flavor's mask against the
        same base; the two flavors compose independently.
        """
        extra = np.atleast_1d(np.asarray(unavailable, dtype=np.int64))
        if extra.size == 0:
            return self
        if extra.min() < 0 or extra.max() >= self.n_nodes:
            raise ValueError(f"node ids out of range [0, {self.n_nodes})")
        prev = self.masked if route_faulty else self.masked_busy
        mask = (np.zeros(self.n_nodes, dtype=bool) if prev is None
                else prev.copy())
        mask[extra] = True
        if prev is not None and np.array_equal(mask, prev):
            return self
        faulty = _ro(mask) if route_faulty else self.masked
        busy = self.masked_busy if route_faulty else _ro(mask)
        base_key = (self.key if not self.is_overlay
                    else self.key[1])
        f_digest = (None if faulty is None
                    else np.flatnonzero(faulty).tobytes())
        b_digest = (None if busy is None
                    else np.flatnonzero(busy).tobytes())
        key = (("o", base_key, f_digest) if busy is None
               else ("ob", base_key, f_digest, b_digest))
        return ClusterState(health=self.health, p_f=self.p_f,
                            epoch=self.epoch, key=key,
                            groups=self.groups, masked=faulty,
                            masked_busy=busy)

    # ----------------------------------------------------------------- diff
    def diff(self, other: "ClusterState") -> "StateDiff":
        """Nodes whose *effective* health differs between two states.

        Effective means what a placement sees: allocatability (lifecycle
        + overlay mask) and the pinned outage vector.  ``diff`` is
        symmetric in membership: ``a.diff(b).nodes == b.diff(a).nodes``.
        """
        if other.n_nodes != self.n_nodes:
            raise ValueError("cannot diff states of different sizes")
        a_m, b_m = self.allocatable_mask(), other.allocatable_mask()
        changed = (self.health != other.health) | (a_m != b_m)
        pa, pb = self.outage_vector(), other.outage_vector()
        changed |= pa != pb
        return StateDiff(nodes=np.flatnonzero(changed),
                         old=self, new=other)


@dataclasses.dataclass(frozen=True)
class StateDiff:
    """The set of nodes whose health changed between two states."""

    nodes: np.ndarray          # changed node ids, ascending
    old: ClusterState
    new: ClusterState

    def __len__(self) -> int:
        return len(self.nodes)

    def __bool__(self) -> bool:
        return len(self.nodes) > 0

    def lost(self) -> np.ndarray:
        """Changed nodes that are allocatable in ``old`` but not ``new`` —
        the set that displaces running placements."""
        if not len(self.nodes):
            return self.nodes
        new_m = self.new.allocatable_mask()
        old_m = self.old.allocatable_mask()
        sel = old_m[self.nodes] & ~new_m[self.nodes]
        return self.nodes[sel]

    def touches(self, placement: np.ndarray) -> bool:
        """True when any changed node is used by ``placement``."""
        return bool(np.isin(np.asarray(placement), self.nodes).any())


def _freeze_groups(groups) -> Optional[tuple[tuple[int, ...], ...]]:
    if groups is None:
        return None
    return tuple(tuple(int(x) for x in np.asarray(g).ravel())
                 for g in groups)


_MAX_INTERNED = 64
_INTERNED: "OrderedDict[tuple, ClusterState]" = OrderedDict()


__all__ = ["NodeHealth", "ClusterState", "StateDiff"]
