"""TOFA core: the paper's contribution (comm graphs, topology, mapping)."""
from repro.core.comm_graph import CommGraph
from repro.core.topology import TorusTopology, find_consecutive_healthy
from repro.core.mapping import hop_bytes, avg_dilation, map_graph
from repro.core.tofa import tofa_place, place, PlacementResult, POLICIES
from repro.core.placement import Fabric, assign_devices, compare_policies
from repro.core.profiler import profile_hlo, comm_graph_from_hlo
