"""TOFA core: the paper's contribution (comm graphs, topology, mapping).

The placement stack is served by :class:`~repro.core.engine.PlacementEngine`
(typed request/plan, pluggable policy registry, topology protocol); the old
``place``/``tofa_place`` entry points remain as deprecation shims.
"""
from repro.core.comm_graph import CommGraph
from repro.core.state import ClusterState, NodeHealth, StateDiff
from repro.core.topology import TorusTopology, find_consecutive_healthy
from repro.core.fattree import FatTreeTopology
from repro.core.mapping import hop_bytes, avg_dilation, map_graph
from repro.core.engine import (PlacementEngine, PlacementPlan,
                               PlacementRequest, Topology, default_engine)
from repro.core.policies import (PlacementPolicy, PolicyContext, PolicyOutput,
                                 UnknownPolicyError, available_policies,
                                 get_policy, register_policy)
from repro.core.tofa import tofa_place, place, PlacementResult, POLICIES
from repro.core.placement import Fabric, assign_devices, compare_policies
from repro.core.profiler import profile_hlo, comm_graph_from_hlo
