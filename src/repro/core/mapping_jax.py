"""JIT-compiled JAX implementations of the mapping hot kernels.

This module is only imported when the ``jax`` backend is active
(:mod:`repro.core.backend`); a NumPy-only install never reaches it.  Every
public function mirrors its :mod:`repro.core.mapping` counterpart —
NumPy arrays in, NumPy arrays out — and is **decision-identical** to it:
with the in-tree workloads all guest weights and route distances are
exactly-representable integers, float64 arithmetic on them is exact, and
the kernels below are algebraic rearrangements of the NumPy expressions,
so for ``dtype="float64"`` the same swaps are accepted in the same order
and the returned placements match the NumPy backend bit-for-bit
(``tests/test_backend_diff.py``).

What the port changes is the *cost model*, not the algorithm:

* **Swap-gain scoring is gather+matvec, not dense matvec.**  The guest
  graphs of interest are sparse (NPB-DT at n=1024 has ~3 edges per rank),
  so the per-mover gains row

      gains = contrib[i] + contrib - 2*C[i] - M @ G[i] - G @ M[i]

  is evaluated from the CSR-padded rows of ``G`` in O(n*k) — a k-column
  gather of ``M`` and a k-wide weighted sum — instead of two O(n^2)
  matvecs.  Products against explicit zeros contribute exactly 0.0, so
  the sparse evaluation is bit-equal to the dense one.  Guests denser
  than half-full fall back to a dense-matvec variant of the same loop
  (routed through :mod:`repro.kernels.swap_gain` so TPU runs can use the
  Pallas kernel).
* **All candidates refine in one dispatch.**  ``refine_many`` vmaps the
  refinement loop over a stack of candidate placements (TOFA's windows,
  balls and snake seeds), replacing the per-candidate Python loop with a
  single device call.  Converged candidates are naturally idempotent
  (no improving swap exists), so the batched loop runs until the last
  candidate converges without perturbing the others.
* **The candidate stack shards across devices.**  With more than one
  visible device (``backend.JaxBackend.device_count`` > 1) the stack is
  split along the candidate axis with ``shard_map`` — guest structure
  and distances replicated, batch edge-padded to a device multiple — so
  each device refines only its slice and each shard's ``while_loop``
  stops when *its own* candidates converge.  Candidates never interact,
  so the sharded dispatch is bit-identical to the single-device one
  (``tests/test_sharded_refine.py``).  Two XLA:CPU landmines are worked
  around deliberately: operands are replicated from the **host** (see
  ``_shard_args``) and the mover-order sort is computed without the
  ``sort`` HLO inside sharded executables (see ``_refine_one``'s
  ``sortless`` path).
* **Distance matrices are device-resident.**  Hosts hand the same cached
  (topology, health) matrix object to every placement, and the backend
  keeps its symmetrised device copy alive across jobs, so a batch of
  placements pays one transfer.
* **Shapes are padded to powers of two** (process count, sparse row
  width, candidate count) with masked tails, so mixed job sizes reuse a
  small set of compiled kernels instead of recompiling per size.
"""
from __future__ import annotations

import functools
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from . import backend as _backend

# swap acceptance threshold — identical to the NumPy kernel
_GAIN_EPS = 1e-9


# --------------------------------------------------------------------------
# host-side preparation (sparse structure, symmetrised distances, padding)
# --------------------------------------------------------------------------

def _pow2(x: int) -> int:
    return 1 << max(0, int(x - 1)).bit_length() if x > 1 else 1


class _IdLRU:
    """Tiny identity-keyed LRU holding host intermediates alive."""

    def __init__(self, maxlen: int = 8):
        self._d: OrderedDict[int, tuple] = OrderedDict()
        self._maxlen = maxlen

    def get(self, key_obj, fn):
        key = id(key_obj)
        hit = self._d.get(key)
        if hit is not None and hit[0] is key_obj:
            self._d.move_to_end(key)
            return hit[1]
        out = fn()
        self._d[key] = (key_obj, out)   # strong ref pins id()
        while len(self._d) > self._maxlen:
            self._d.popitem(last=False)
        return out


_SPARSE_CACHE = _IdLRU()
_SYM_CACHE = _IdLRU()
_GUEST_OK_CACHE = _IdLRU(maxlen=32)
_SPARSE_DEV_CACHE = _IdLRU()


def guest_supported(G_w: np.ndarray) -> bool:
    """The jitted kernels assume the symmetric-guest convention
    (CommGraph accumulates both directions); asymmetric guests fall back
    to the NumPy kernels at the dispatch layer.  Cached by identity —
    the same guest matrix is scored/refined many times per placement."""
    return _GUEST_OK_CACHE.get(
        G_w, lambda: bool(np.array_equal(G_w, G_w.T)))


def lazy_supported(D) -> bool:
    """A lazy distance adapter is served by this module only when it
    exposes an implicit spec — distances are then computed in-kernel
    (:mod:`repro.kernels.hop_dist`), never gathered from a stored matrix.
    Healthy uniform tori and fat-trees in *any* health state qualify
    (fat-tree fault/straggler weighting is endpoint-form, so it jits as a
    penalty-vector gather); fault-weighted tori need scalar route walks
    and run the NumPy kernels instead."""
    return getattr(D, "implicit", None) is not None


def _dist_fns(Ds, dims, scale):
    """The two distance accessors of the refine/score loops, closed over
    either a dense (N, N) matrix (``dims is None``), an (N, ndim)
    coordinate table with static torus ``dims``, or — when ``dims`` is
    the static marker ``("fattree",)`` — a ``(coords, penalty)`` pair
    implementing the endpoint-form fat-tree metric
    (:class:`repro.core.lazydist.FatTreeLazyDistance`)."""
    if dims is None:
        def dist_pairs(a, b):
            return Ds[a, b]

        def dist_row(node, p):
            return Ds[node][p]
    elif dims == ("fattree",):
        from repro.kernels.hop_dist.ops import fattree_hop_pairs
        from repro.kernels.hop_dist.ref import fattree_hop_elems_ref
        coords, pen = Ds

        def _at(u, v):
            # c * hops + endpoint penalties — same expression (and
            # summation order) as FatTreeLazyDistance._elems
            hops = scale * fattree_hop_elems_ref(coords[u], coords[v])
            return hops + jnp.where(u != v, pen[u] + pen[v], 0.0)

        dist_pairs = _at

        def dist_row(node, p):
            return _at(node, p)

        def _all_pairs(u, v):
            hops = scale * fattree_hop_pairs(coords[u], coords[v])
            return hops + jnp.where(
                u[:, None] != v[None, :],
                pen[u][:, None] + pen[v][None, :], 0.0)

        dist_pairs.all_pairs = _all_pairs
    else:
        from repro.kernels.hop_dist.ops import torus_hop_pairs
        from repro.kernels.hop_dist.ref import torus_hop_elems_ref

        def dist_pairs(a, b):
            # broadcast-elementwise; the all-pairs M0 build in
            # _refine_one routes through torus_hop_pairs below instead
            return scale * torus_hop_elems_ref(Ds[a], Ds[b], dims)

        def dist_row(node, p):
            return scale * torus_hop_elems_ref(Ds[node], Ds[p], dims)

        dist_pairs.all_pairs = lambda u, v: (
            scale * torus_hop_pairs(Ds[u], Ds[v], dims))
    return dist_pairs, dist_row


def _sparse_rows(G_w: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """CSR-padded rows of the (diag-zeroed) guest: (idx, val, k_pad).

    Rows are padded to a power-of-two width with (index 0, weight 0.0)
    entries — gathers against them multiply by exactly 0.0, so padding
    never changes a result.
    """
    def build():
        G = np.asarray(G_w, dtype=np.float64)
        if np.count_nonzero(np.diagonal(G)):
            G = G.copy()
            np.fill_diagonal(G, 0.0)
        n = G.shape[0]
        nnz = (G != 0.0).sum(axis=1)
        # multiple-of-4 width: tight enough that padded gathers stay
        # cheap, coarse enough that compile-cache keys rarely vary
        k_true = max(1, int(nnz.max()) if n else 1)
        k = min(_pow2(n), (k_true + 3) & ~3)
        idx = np.zeros((n, k), dtype=np.int32)
        val = np.zeros((n, k), dtype=np.float64)
        for r in range(n):
            cols = np.flatnonzero(G[r])
            idx[r, :len(cols)] = cols
            val[r, :len(cols)] = G[r, cols]
        return idx, val, k, G
    return _SPARSE_CACHE.get(G_w, build)


def _sym_host(D: np.ndarray) -> np.ndarray:
    """0.5*(D + D.T), cached by identity — the symmetrised route-weight
    view every gathered-distance expression in the NumPy kernel uses."""
    return _SYM_CACHE.get(
        D, lambda: 0.5 * (np.asarray(D, np.float64)
                          + np.asarray(D, np.float64).T))


def _be():
    be = _backend.active()
    if not getattr(be, "is_jax", False):   # direct calls outside dispatch
        be = _backend.get_backend("jax")
    return be


def _pad_placements(placements: np.ndarray) -> tuple[np.ndarray, int, int]:
    """(B, n) -> zero-padded (B_pad?, n_pad) int32 plus original n."""
    P = np.asarray(placements, dtype=np.int32)
    B, n = P.shape
    n_pad = _pow2(n)
    if n_pad != n:
        P = np.pad(P, ((0, 0), (0, n_pad - n)))
    return P, n, n_pad


# --------------------------------------------------------------------------
# pairwise-swap refinement (the swap-gain kernel)
# --------------------------------------------------------------------------

def _refine_one(p0, idx, val, G_dense, Ds, n_valid, *, movers: int,
                total_passes: int, dense: bool, dims=None,
                scale: float = 1.0, sortless: bool = False):
    """Refine ONE placement; decision-identical to the NumPy loop.

    ``p0`` (n,) int32 node ids (tail >= n_valid is masked padding),
    ``idx``/``val`` (n, k) CSR-padded guest rows, ``G_dense`` (n, n) or
    a (1, 1) placeholder when the sparse path runs, ``Ds`` (N, N)
    symmetrised device-resident distances — or, with static ``dims``
    set (implicit mode), the (N, ndim) coordinate table from which
    every distance below is computed in-kernel, ``n_valid`` traced
    scalar.
    """
    n = p0.shape[0]
    rows = jnp.arange(n, dtype=jnp.int32)
    valid = rows < n_valid
    fdt = (Ds[0] if isinstance(Ds, tuple) else Ds).dtype
    dist_pairs, dist_row = _dist_fns(Ds, dims, scale)

    if dims is None:
        M0 = dist_pairs(p0[:, None], p0[None, :])           # (n, n) gather
    else:
        # all-pairs block build — the Pallas torus_hop kernel on TPU
        M0 = dist_pairs.all_pairs(p0, p0).astype(fdt)
    contrib0 = (val.astype(fdt)
                * jnp.take_along_axis(M0, idx, axis=1)).sum(-1)

    def select_mover(M, contrib, i):
        """(best gain, partner j) for mover ``i`` — the fused
        gains-row + masked-argmax + accept step.  ``j == i`` encodes a
        rejected mover (identity swap).  The dense branch is a single
        kernel (:func:`repro.kernels.swap_gain.ops.swap_select`) so the
        gains row never leaves it; the sparse branch applies the same
        mask/argmax/threshold to the CSR-gathered row."""
        if dense:
            from repro.kernels.swap_gain.ops import swap_select
            return swap_select(M, G_dense, contrib, i, n_valid)
        # M is kept exactly symmetric, so every column read below is
        # a (contiguous) row read instead
        idx_i, val_i = idx[i], val[i].astype(fdt)
        Mrow_i = M[i]
        a = val_i @ M[idx_i, :]                          # M @ G[i]
        b = (val.astype(fdt)
             * Mrow_i[idx]).sum(-1)                      # G @ M[i]
        Ci = jnp.zeros(n, fdt).at[idx_i].add(val_i * Mrow_i[idx_i])
        g = contrib[i] + contrib - 2.0 * Ci - a - b
        g = g.at[i].set(0.0)
        g = jnp.where(valid, g, -jnp.inf)
        j_raw = jnp.argmax(g)
        gain = g[j_raw]
        j = jnp.where((gain > _GAIN_EPS) & (i < n_valid), j_raw, i)
        return gain, j.astype(jnp.int32)

    def sparse_col(i):
        """Nonzero structure of G[:, i] (symmetric guest): row i's."""
        return idx[i], val[i].astype(fdt)

    def mover_step(t, s):
        p, M, contrib, improved, order = s
        i = order[t]
        # rejected movers arrive as an *identity swap* (j == i): the M
        # updates below then rewrite rows with their current exact
        # values, so no O(n^2) masked select of M is ever needed and XLA
        # keeps the loop-carried matrix in place.
        gain, j = select_mover(M, contrib, i)
        do = (i < n_valid) & (gain > _GAIN_EPS)

        oi, oj = p[i], p[j]
        p_old = p
        p = p.at[jnp.stack([i, j])].set(jnp.stack([oj, oi]))
        # every M entry is a directly gathered Ds value (never
        # accumulated), so the pre-swap rows are re-gathered from Ds
        # instead of read out of M — M stays *write-only* in this
        # section, which is what lets XLA update it in place rather than
        # copying the matrix once per mover
        row_i = dist_row(oj, p)                  # gathered_row(p[i])
        row_j = dist_row(oi, p)
        M = (M.at[i, :].set(row_i).at[:, i].set(row_i)
              .at[j, :].set(row_j).at[:, j].set(row_j))
        M = M.at[jnp.stack([i, j]), jnp.stack([j, i])].set(
            jnp.stack([row_i[j], row_i[j]]))
        if dense:
            old_row_i = dist_row(oi, p_old)
            old_row_j = dist_row(oj, p_old)
            c1 = contrib + (G_dense[i] * (row_i - old_row_i)
                            + G_dense[j] * (row_j - old_row_j))
            c1 = c1.at[i].set((G_dense[i] * row_i).sum())
            c1 = c1.at[j].set((G_dense[j] * row_j).sum())
        else:
            ii, vi = sparse_col(i)
            ij_, vj = sparse_col(j)
            # the sparse delta only needs the old rows at the k nonzero
            # columns — gather those few entries instead of full rows
            old_i_k = dist_row(oi, p_old[ii])
            old_j_k = dist_row(oj, p_old[ij_])
            # delta built separately then added, matching the NumPy
            # fused-expression summation order bit for bit
            delta = jnp.zeros(n, fdt).at[ii].add(vi * (row_i[ii]
                                                       - old_i_k))
            delta = delta.at[ij_].add(vj * (row_j[ij_] - old_j_k))
            c1 = contrib + delta
            c1 = c1.at[jnp.stack([i, j])].set(
                jnp.stack([(vi * row_i[ii]).sum(),
                           (vj * row_j[ij_]).sum()]))
        # contrib accumulates across swaps, so a rejected mover must keep
        # the accumulated values exactly — an O(n) select, unlike M
        contrib = jnp.where(do, c1, contrib)
        return p, M, contrib, improved | do, order

    def pass_body(state):
        p, M, contrib, stop, t = state
        key = jnp.where(valid, contrib, -jnp.inf)
        if sortless:
            # Stable descending argsort WITHOUT the ``sort`` HLO: rank
            # each entry by pairwise comparison (ties broken by index,
            # exactly ``np.argsort(-key, kind="stable")``) and scatter
            # the identity through the rank permutation.  The sharded
            # executables need this: XLA:CPU's SPMD partitioner wraps the
            # ``sort`` primitive inside a shard_map body in channel-
            # tagged AllReduces even though the op is lane-local, which
            # deadlocks its rendezvous and corrupts non-zero ranks.
            # Every other primitive in this loop partitions cleanly, so
            # only the sort is rewritten; the O(n^2) comparison block is
            # cheap at the (<= a few k procs) sizes refine runs at.
            beats = ((key[None, :] > key[:, None])
                     | ((key[None, :] == key[:, None])
                        & (rows[None, :] < rows[:, None])))
            rank = jnp.sum(beats, axis=1, dtype=jnp.int32)
            order = (jnp.zeros(n, jnp.int32).at[rank].set(rows))[:movers]
        else:
            # index tie-break folded into the comparison (two-key sort)
            # rather than ``is_stable`` alone: a unique total order keeps
            # any lowering bit-identical to the NumPy reference
            _, order = lax.sort((-key, rows), num_keys=2)
            order = order[:movers].astype(jnp.int32)
        p, M, contrib, improved, _ = lax.fori_loop(
            0, movers, mover_step, (p, M, contrib, jnp.bool_(False), order))
        return p, M, contrib, ~improved, t + 1

    def cond(state):
        _, _, _, stop, t = state
        return (t < total_passes) & ~stop

    p, _, _, _, _ = lax.while_loop(
        cond, pass_body, (p0, M0, contrib0, jnp.bool_(False),
                          jnp.int32(0)))
    return p


@functools.lru_cache(maxsize=32)
def _refine_jit(movers: int, total_passes: int, dense: bool,
                dims=None, scale: float = 1.0):
    fn = functools.partial(_refine_one, movers=movers,
                           total_passes=total_passes, dense=dense,
                           dims=dims, scale=scale)
    batched = jax.vmap(fn, in_axes=(0, None, None, None, None, None))
    return jax.jit(batched)


@functools.lru_cache(maxsize=8)
def _mesh(n_dev: int):
    """One cached 1-D device mesh per device count, shared between the
    shard_map trace and the explicit operand placement in
    :func:`refine_many` (the same mesh object must back both)."""
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:n_dev]), ("dev",))


@functools.lru_cache(maxsize=32)
def _refine_jit_sharded(movers: int, total_passes: int, dense: bool,
                        dims, scale: float, n_dev: int):
    """Candidate-stack refine sharded over ``n_dev`` devices.

    ``shard_map`` splits the (B, n) placement stack along the candidate
    axis — guest structure and distances are replicated — so each device
    vmaps only its B/n_dev slice, and each shard's ``lax.while_loop``
    stops as soon as *its own* candidates converge (the single-device
    vmap runs every pass until the slowest candidate in the whole stack
    converges).  Candidates never interact, so the result is
    bit-identical to the single-device dispatch in any shard order.

    Callers must hand in operands **already placed** on this mesh
    (stack sharded over ``"dev"``, everything else replicated — see
    ``_shard_args``): letting jit reshard single-device-committed inputs
    makes XLA:CPU synthesise cross-module collectives, which both
    deadlock its rendezvous under concurrent dispatches and mis-replicate
    on sub-meshes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    fn = functools.partial(_refine_one, movers=movers,
                           total_passes=total_passes, dense=dense,
                           dims=dims, scale=scale, sortless=True)
    batched = jax.vmap(fn, in_axes=(0, None, None, None, None, None))
    sharded = shard_map(batched, mesh=_mesh(n_dev),
                        in_specs=(P("dev"), P(), P(), P(), P(), P()),
                        out_specs=P("dev"), check_rep=False)
    return jax.jit(sharded)


def _shard_args(n_dev: int, P_stack, *replicated):
    """Place the candidate stack sharded over the mesh's ``dev`` axis and
    every other operand fully replicated, so the jitted shard_map never
    has to reshard committed single-device arrays itself.

    Replication is routed through the **host**: ``device_put`` of an
    array already committed to one device compiles a device-to-device
    broadcast, which XLA:CPU emits as a cross-module AllReduce that both
    deadlocks its rendezvous and hands corrupted replicas to non-zero
    ranks (deterministically wrong lanes).  A host ``np.ndarray`` takes
    the plain host-to-each-device copy path instead, which is collective
    free."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _mesh(n_dev)
    shard = NamedSharding(mesh, P("dev"))
    rep = NamedSharding(mesh, P())
    out = [jax.device_put(np.asarray(P_stack), shard)]
    out.extend(jax.tree_util.tree_map(
        lambda x: jax.device_put(np.asarray(x), rep), arg)
        for arg in replicated)
    return out


def _device_distances(D, be):
    """``(device operand, static spec key, scale)`` — the dense
    symmetrised matrix (key ``None``), the coordinate table with static
    torus ``dims``, or the ``(coords, penalty)`` pair keyed
    ``("fattree",)`` in fat-tree implicit mode."""
    spec = getattr(D, "implicit", None)
    if spec is None:
        return be.device_matrix(_sym_host(D)), None, 1.0
    if getattr(spec, "kind", "torus") == "fattree":
        operand = (be.device_matrix(spec.coords),
                   be.device_matrix(spec.penalty))
        return operand, ("fattree",), float(spec.scale)
    return be.device_matrix(spec.coords), spec.dims, float(spec.scale)


def refine_many(G_w: np.ndarray, D: np.ndarray, placements: np.ndarray,
                max_passes: int = 3, movers: int = 64,
                extra_passes: int = 13) -> np.ndarray:
    """Batched ``_pairwise_refine``: (B, n) placements in one dispatch.

    With multiple visible devices (``backend.JaxBackend.device_count``
    > 1, e.g. under ``XLA_FLAGS=--xla_force_host_platform_device_count``
    or a real multi-chip topology) the candidate stack is sharded across
    them; the batch axis is padded to a device multiple by repeating the
    last candidate (refinement is deterministic per candidate, so the
    duplicates are free of side effects and sliced off).
    """
    be = _be()
    P, n, n_pad = _pad_placements(np.atleast_2d(placements))
    with be.scope():
        idx, val, G_dense, dense = _guest_device(G_w, n_pad, be)
        Ds, dims, scale = _device_distances(D, be)
        movers_eff = min(movers, n_pad)
        B = P.shape[0]
        n_dev = min(int(getattr(be, "device_count", 1)), B)
        if n_dev > 1:
            pad_b = (-B) % n_dev
            if pad_b:
                P = np.pad(P, ((0, pad_b), (0, 0)), mode="edge")
            run = _refine_jit_sharded(movers_eff, max_passes + extra_passes,
                                      dense, dims, scale, n_dev)
            be.stats["sharded_dispatches"] = (
                be.stats.get("sharded_dispatches", 0) + 1)
            args = _shard_args(n_dev, P, idx, val, G_dense, Ds,
                               jnp.int32(n))
        else:
            run = _refine_jit(movers_eff, max_passes + extra_passes, dense,
                              dims, scale)
            args = (jnp.asarray(P), idx, val, G_dense, Ds, jnp.int32(n))
        out = run(*args)
    out = np.asarray(out)[:B, :n].astype(np.int64)
    return out if np.asarray(placements).ndim == 2 else out[0]


def _guest_device(G_w: np.ndarray, n_pad: int, be):
    """Device-resident guest structure (idx, val, G_dense, is_dense),
    cached by guest identity so repeated refine/score calls against one
    job's graph pay a single transfer."""
    def build():
        idx, val, k, _G = _sparse_rows(G_w)
        n = idx.shape[0]
        if n_pad != n:
            idx = np.pad(idx, ((0, n_pad - n), (0, 0)))
            val = np.pad(val, ((0, n_pad - n), (0, 0)))
        dense = k > max(8, n_pad // 2)
        fdt = be.np_dtype
        Gd = _G
        if dense and n_pad != n:
            Gd = np.pad(Gd, ((0, n_pad - n), (0, n_pad - n)))
        G_dense = (jnp.asarray(Gd, dtype=fdt) if dense
                   else jnp.zeros((1, 1), dtype=fdt))
        return (jnp.asarray(idx), jnp.asarray(val, dtype=fdt),
                G_dense, dense)
    key_holder = _sparse_rows(G_w)    # one entry per guest object
    cache = _SPARSE_DEV_CACHE.get(key_holder, dict)
    sub = (n_pad, be.dtype)
    if sub not in cache:
        cache[sub] = build()
    return cache[sub]


def pairwise_refine(G_w: np.ndarray, D: np.ndarray, placement: np.ndarray,
                    max_passes: int = 3, movers: int = 64,
                    extra_passes: int = 13) -> np.ndarray:
    """Drop-in for :func:`repro.core.mapping._pairwise_refine`."""
    return refine_many(G_w, D, np.asarray(placement)[None, :],
                       max_passes=max_passes, movers=movers,
                       extra_passes=extra_passes)[0]


# --------------------------------------------------------------------------
# hop-bytes scoring
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _hop_bytes_jit(dims=None, scale: float = 1.0):
    def score(P, idx, val, Ds, n_valid):
        dist_pairs, _ = _dist_fns(Ds, dims, scale)

        def one(p):
            tgt = p[idx]                       # (n, k) partner node ids
            d = dist_pairs(p[:, None], tgt)    # gathered / in-kernel
            ok = jnp.arange(p.shape[0])[:, None] < n_valid
            return 0.5 * jnp.where(ok, val * d.astype(val.dtype), 0.0).sum()
        return jax.vmap(one)(P)
    return jax.jit(score)


def hop_bytes_batch(G_w: np.ndarray, D: np.ndarray,
                    placements: np.ndarray) -> np.ndarray:
    """Batched hop-bytes on device; bit-equal to the NumPy gather."""
    be = _be()
    P2 = np.atleast_2d(np.asarray(placements))
    P, n, n_pad = _pad_placements(P2)
    with be.scope():
        idx, val, _Gd, _dense = _guest_device(G_w, n_pad, be)
        Ds, dims, scale = _device_distances(D, be)
        out = _hop_bytes_jit(dims, scale)(
            jnp.asarray(P), idx, val, Ds, jnp.int32(n))
    return np.asarray(out, dtype=np.float64)


def hop_bytes(G_w: np.ndarray, D: np.ndarray, placement: np.ndarray) -> float:
    return float(hop_bytes_batch(G_w, D, np.asarray(placement)[None, :])[0])


# --------------------------------------------------------------------------
# node-subset selection (frontier growth)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _select_jit():
    def grow(Ddev, seed, count):
        N = Ddev.shape[0]
        chosen0 = jnp.zeros(N, bool).at[seed].set(True)
        cost0 = Ddev[seed].at[seed].set(jnp.inf)

        def step(_, s):
            chosen, cost = s
            nxt = jnp.argmin(cost)
            return chosen.at[nxt].set(True), (cost + Ddev[nxt]).at[nxt].set(
                jnp.inf)

        chosen, _ = lax.fori_loop(0, count - 1, step, (chosen0, cost0))
        return chosen
    return jax.jit(grow)


def select_nodes(D: np.ndarray, count: int,
                 seed: int | None = None) -> np.ndarray:
    """Drop-in for :func:`repro.core.mapping.select_nodes` — the O(N^2)
    seed search stays on host (one partition, same arithmetic as NumPy);
    the sequential frontier growth runs jitted on device."""
    n = D.shape[0]
    count = min(count, n)
    if seed is None:
        part = np.partition(D, count - 1, axis=1)[:, :count]
        seed = int(np.argmin(part.sum(axis=1)))
    be = _be()
    with be.scope():
        Ddev = be.device_matrix(np.asarray(D, dtype=np.float64))
        chosen = _select_jit()(Ddev, jnp.int32(seed), jnp.int32(count))
    return np.flatnonzero(np.asarray(chosen)).astype(np.int64)


# --------------------------------------------------------------------------
# greedy pair placement (paper baseline)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _greedy_jit():
    def run(pair_i, pair_j, pair_ok, Ddev, free0, placement0):
        def nearest_free(free, anchor):
            return jnp.argmin(jnp.where(free, Ddev[anchor], jnp.inf))

        def step(t, s):
            placement, free = s
            i, j, ok = pair_i[t], pair_j[t], pair_ok[t]
            pi, pj = placement[i], placement[j]

            def both(args):
                placement, free = args
                a = jnp.argmax(free)                  # first free id
                free = free.at[a].set(False)
                b = nearest_free(free, a)
                free = free.at[b].set(False)
                return (placement.at[i].set(a.astype(jnp.int32))
                        .at[j].set(b.astype(jnp.int32)), free)

            def only_i(args):
                placement, free = args
                a = nearest_free(free, pj)
                return (placement.at[i].set(a.astype(jnp.int32)),
                        free.at[a].set(False))

            def only_j(args):
                placement, free = args
                b = nearest_free(free, pi)
                return (placement.at[j].set(b.astype(jnp.int32)),
                        free.at[b].set(False))

            def nothing(args):
                return args

            case = jnp.where(
                ~ok | ((pi >= 0) & (pj >= 0)), 0,
                jnp.where((pi < 0) & (pj < 0), 1,
                          jnp.where(pi < 0, 2, 3)))
            return lax.switch(case, [nothing, both, only_i, only_j],
                              (placement, free))

        return lax.fori_loop(0, pair_i.shape[0], step, (placement0, free0))
    return jax.jit(run)


def greedy_placement(G_w: np.ndarray, nodes: np.ndarray,
                     D: np.ndarray) -> np.ndarray:
    """Drop-in for :func:`repro.core.mapping.greedy_placement`: the
    traffic-sorted pair list is built on host (identical ordering), the
    frontier loop runs jitted against the device-resident distances."""
    n = G_w.shape[0]
    nodes = np.asarray(nodes)
    iu = np.triu_indices(n, 1)
    w = np.asarray(G_w)[iu]
    order = np.argsort(-w, kind="stable")
    order = order[w[order] > 0]
    m = len(order)
    m_pad = _pow2(max(1, m))
    pair_i = np.zeros(m_pad, dtype=np.int32)
    pair_j = np.zeros(m_pad, dtype=np.int32)
    pair_ok = np.zeros(m_pad, dtype=bool)
    pair_i[:m] = iu[0][order]
    pair_j[:m] = iu[1][order]
    pair_ok[:m] = True

    be = _be()
    free0 = np.zeros(D.shape[0], dtype=bool)
    free0[np.unique(nodes)] = True
    with be.scope():
        Ddev = be.device_matrix(np.asarray(D, dtype=np.float64))
        placement, free = _greedy_jit()(
            jnp.asarray(pair_i), jnp.asarray(pair_j), jnp.asarray(pair_ok),
            Ddev, jnp.asarray(free0), jnp.full(n, -1, dtype=jnp.int32))
    placement = np.asarray(placement).astype(np.int64)
    free_ids = np.flatnonzero(np.asarray(free))
    rem = np.flatnonzero(placement < 0)
    placement[rem] = free_ids[:len(rem)]
    return placement
