"""HLO profiler: the paper's MPI profiling tool, adapted to compiled XLA.

The paper's tool intercepts MPI calls at runtime to build the communication
graph.  An SPMD JAX program declares all of its communication statically in
the compiled HLO, so this profiler *parses* ``compiled.as_text()`` instead of
intercepting calls — same output, zero runtime overhead:

* every collective op (all-reduce / all-gather / reduce-scatter / all-to-all
  / collective-permute / collective-broadcast, sync or async ``-start``
  form) with its replica groups (explicit or iota ``[G,S]<=[dims]T(perm)``
  notation) and operand bytes;
* loop-aware FLOP and HBM-byte accounting: XLA's ``cost_analysis()`` counts a
  ``while`` body ONCE, so a 96-layer ``lax.scan`` under-reports ~96x.  This
  parser extracts the trip count from each loop's condition computation and
  multiplies through (nested loops compose);
* :func:`comm_graph_from_hlo` decomposes each collective over its replica
  groups into point-to-point phases (ring/pairwise/direct) and accumulates
  the same ``G_v``/``G_m`` matrices the paper's PMPI tool produces — this is
  the guest graph handed to TOFA.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

from .comm_graph import CommGraph

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# ops that are pure aliasing / bookkeeping — no HBM traffic of their own
_SKIP_BYTES = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "opt-barrier", "domain",
    "reshape",
}

# elementwise / layout ops a TPU-grade fusion pass melts into their
# producers/consumers: charging each as an HBM round-trip (the CPU-backend
# HLO leaves them unfused) would overstate the memory term 3-10x.  With
# ``fusion_model=True`` these contribute no traffic of their own — the
# boundary reads/writes are still charged at the non-elementwise ops that
# produce/consume the buffers.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "exponential", "exp", "log",
    "log-plus-one", "exponential-minus-one", "tanh", "maximum", "minimum",
    "compare", "select", "convert", "negate", "abs", "rsqrt", "sqrt",
    "power", "and", "or", "not", "xor", "clamp", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "is-finite", "iota", "broadcast",
    "reverse", "pad", "slice", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "atan2", "cbrt",
    "round-nearest-afz", "round-nearest-even", "real", "imag", "expm1",
    "log1p", "popcnt", "clz", "stochastic-convert", "reduce-precision",
    "map", "bitcast-convert",
}

# metadata op_name substrings attributed as kernel-fusible regions
_TAG_PATTERNS = {"flash": ("flash_attention",),
                 "ssd": ("ssd_chunked",)}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^(]*?\)?)\s*([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*(.+?)\s*\{\s*$")


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """'(f32[4,8]{1,0}, bf16[2])' or 'f32[4,8]{1,0}' -> [(dtype, dims), ...]"""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in DTYPE_BYTES and dt != "token":
            continue
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append((dt, dims))
    return out


def _nbytes(shapes: list[tuple[str, tuple[int, ...]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        if dt == "token":
            continue
        total += DTYPE_BYTES.get(dt, 4) * float(np.prod(dims)) if dims else \
            DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    shapes: list  # result shapes [(dtype, dims)]
    op: str
    operands: list  # operand %names (in-paren only)
    attrs: str      # raw text after the closing paren of operands
    raw: str


@dataclasses.dataclass
class CollectiveOp:
    kind: str                     # canonical, e.g. 'all-reduce'
    operand_bytes: float          # per-device operand payload (sum, tuple ok)
    groups: list                  # list of tuples of device ids (or None)
    group_size: int
    multiplier: float             # product of enclosing loop trip counts
    source_target_pairs: list | None = None

    @property
    def per_device_network_bytes(self) -> float:
        """Bytes each participating device sends over the network (ring)."""
        g, s = self.group_size, self.operand_bytes
        if g <= 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g * s
        if self.kind == "all-gather":
            return (g - 1) * s
        if self.kind == "reduce-scatter":
            return (g - 1) / g * s
        if self.kind == "all-to-all":
            return (g - 1) / g * s
        if self.kind in ("collective-permute", "collective-broadcast"):
            return s
        return s


@dataclasses.dataclass
class HloProfile:
    flops: float                  # loop-corrected, per device
    bytes_accessed: float         # loop-corrected HBM traffic model, per device
    collectives: list             # list[CollectiveOp], loop-corrected multipliers
    num_partitions: int
    raw_flops: float = 0.0        # body-once flops (cost_analysis convention)
    # bytes attributed to instruction-metadata tags (e.g. 'flash' for the
    # online-softmax attention internals) — lets the roofline substitute a
    # Pallas-kernel traffic model for regions the TPU kernel fuses entirely
    bytes_by_tag: dict = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        """Per-device network bytes across all collectives (x multipliers)."""
        return sum(c.per_device_network_bytes * c.multiplier
                   for c in self.collectives)

    def collective_bytes_by_kind(self) -> dict:
        out = defaultdict(float)
        for c in self.collectives:
            out[c.kind] += c.per_device_network_bytes * c.multiplier
        return dict(out)


# --------------------------------------------------------------------------
# parsing
# --------------------------------------------------------------------------

def parse_computations(hlo_text: str) -> tuple[dict, str, int]:
    """-> ({comp_name: [Instruction]}, entry_name, num_partitions)."""
    comps: dict[str, list[Instruction]] = {}
    entry = None
    num_partitions = 1
    m = re.search(r"num_partitions=(\d+)", hlo_text)
    if m:
        num_partitions = int(m.group(1))
    cur: list[Instruction] | None = None
    cur_name = None
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            cur_name = cm.group(2)
            cur = []
            comps[cur_name] = cur
            if cm.group(1):
                entry = cur_name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, type_str, op, rest = im.groups()
        # split operands (inside parens) from attrs (after matching paren)
        depth, idx = 1, 0
        while idx < len(rest) and depth > 0:
            if rest[idx] == "(":
                depth += 1
            elif rest[idx] == ")":
                depth -= 1
            idx += 1
        opstr, attrs = rest[: idx - 1], rest[idx:]
        operands = re.findall(r"%([\w.\-]+)", opstr)
        cur.append(Instruction(
            name=name, shapes=_parse_shapes(type_str), op=op,
            operands=operands, attrs=attrs, raw=line.strip()))
    return comps, entry, num_partitions


def _expand_iota_groups(num_groups: int, group_size: int,
                        reshape_dims: list[int],
                        perm: list[int] | None) -> list[tuple[int, ...]]:
    n = int(np.prod(reshape_dims))
    arr = np.arange(n).reshape(reshape_dims)
    if perm:
        arr = arr.transpose(perm)
    arr = arr.reshape(num_groups, group_size)
    return [tuple(int(x) for x in row) for row in arr]


def parse_replica_groups(attrs: str, num_partitions: int
                         ) -> list[tuple[int, ...]] | None:
    """Handle explicit ``{{0,1},{2,3}}`` and iota ``[G,S]<=[dims]T(perm)``."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  attrs)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else None
        return _expand_iota_groups(ng, gs, dims, perm)
    m = re.search(r"replica_groups=(\{\{.*?\}\}|\{\s*\})", attrs)
    if m:
        body = m.group(1)
        groups = re.findall(r"\{([\d,\s]+)\}", body)
        out = []
        for g in groups:
            ids = tuple(int(x) for x in g.replace(" ", "").split(",") if x)
            if ids:
                out.append(ids)
        if out:
            return out
        return [tuple(range(num_partitions))]
    return None


def _parse_source_target_pairs(attrs: str) -> list[tuple[int, int]] | None:
    m = re.search(r"source_target_pairs=\{(.*?)\}\}", attrs)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(0))
        return [(int(a), int(b)) for a, b in pairs]
    return None


def _trip_count(cond_instrs: list[Instruction]) -> float:
    """Extract the loop trip count from a while condition computation.

    ``lax.scan``/``fori_loop`` lower to ``compare(iv, K), direction=LT`` with
    iv starting at 0 and stepping by 1, so the comparison constant IS the
    trip count.  Fall back to the largest integer constant in the body.
    """
    consts: dict[str, int] = {}
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond_instrs:
        if ins.op == "compare" and "direction=LT" in ins.attrs:
            for o in ins.operands:
                if o in consts:
                    return float(max(consts[o], 1))
    if consts:
        return float(max(max(consts.values()), 1))
    return 1.0


def _dot_flops(ins: Instruction, symtab: dict) -> float:
    result_elems = 1.0
    for _, dims in ins.shapes:
        result_elems *= float(np.prod(dims)) if dims else 1.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    k = 1.0
    if ins.operands:
        lhs = symtab.get(ins.operands[0])
        if lhs and lhs.shapes:
            _, ldims = lhs.shapes[0]
            for c in cdims:
                if c < len(ldims):
                    k *= ldims[c]
    return 2.0 * result_elems * k


def _conv_flops(ins: Instruction, symtab: dict) -> float:
    result_elems = 1.0
    for _, dims in ins.shapes:
        result_elems *= float(np.prod(dims)) if dims else 1.0
    k = 1.0
    if len(ins.operands) >= 2:
        rhs = symtab.get(ins.operands[1])
        if rhs and rhs.shapes:
            _, rdims = rhs.shapes[0]
            k = float(np.prod(rdims)) if rdims else 1.0
            # divide by output-feature dim: each output elem sees kernel/out_f
            m = re.search(r"dim_labels=[\w?]*_([\w?]*)->", ins.attrs)
            if m and "o" in m.group(1) and rdims:
                o_pos = m.group(1).index("o")
                if o_pos < len(rdims) and rdims[o_pos] > 0:
                    k /= rdims[o_pos]
            gm = re.search(r"feature_group_count=(\d+)", ins.attrs)
            if gm:
                k /= max(int(gm.group(1)), 1)
    return 2.0 * result_elems * k


def _fusion_slice_sizes(ins, comps) -> dict:
    """For a fusion op: {operand_index: bytes actually read} for operands
    whose in-fusion consumers are all slicing ops (dynamic-slice / slice /
    gather) — the fused kernel only touches the sliced window."""
    import re as _re
    m = _re.search(r"calls=%?([\w.\-]+)", ins.attrs)
    if not m or m.group(1) not in comps:
        return {}
    body = comps[m.group(1)]
    params = {}
    for i2 in body:
        if i2.op == "parameter":
            pm = _re.search(r"parameter\((\d+)\)", i2.raw)
            if pm:
                params[i2.name] = int(pm.group(1))
    out: dict = {}
    slicing = ("dynamic-slice", "slice", "gather")
    for pname, pidx in params.items():
        consumers = [i2 for i2 in body if pname in i2.operands]
        if consumers and all(c.op in slicing for c in consumers):
            out[pidx] = sum(_nbytes(c.shapes) for c in consumers)
    return out


def profile_hlo(hlo_text: str, fusion_model: bool = True) -> HloProfile:
    """Parse optimized HLO into per-device FLOPs / HBM bytes / collectives.

    ``fusion_model=True`` (default) applies the TPU-fusion byte model: pure
    elementwise/layout ops carry no HBM traffic of their own (see
    _ELEMENTWISE).  ``False`` charges every instruction — an upper bound
    that mirrors the CPU backend's actual buffer boundaries.
    """
    comps, entry, nparts = parse_computations(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")

    memo: dict[str, tuple] = {}

    def cost(comp_name: str):
        """-> (flops, bytes_accessed, [CollectiveOp]) for one execution."""
        if comp_name in memo:
            return memo[comp_name]
        instrs = comps.get(comp_name, [])
        symtab = {i.name: i for i in instrs}
        flops = 0.0
        nbytes = 0.0
        tags: dict = {}
        colls: list[CollectiveOp] = []

        def _tag_of(ins):
            m = re.search(r'op_name="([^"]*)"', ins.attrs)
            if not m:
                return None
            name = m.group(1)
            for tag, pats in _TAG_PATTERNS.items():
                if any(p in name for p in pats):
                    return tag
            return None
        for ins in instrs:
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_KINDS:
                operand_bytes = 0.0
                for o in ins.operands:
                    d = symtab.get(o)
                    if d:
                        operand_bytes += _nbytes(d.shapes)
                if operand_bytes == 0.0:
                    # async-start result includes (operand, result, ...) tuple
                    operand_bytes = _nbytes(ins.shapes) / 2.0
                stp = _parse_source_target_pairs(ins.attrs) \
                    if base == "collective-permute" else None
                groups = parse_replica_groups(ins.attrs, nparts)
                if base == "collective-permute":
                    gsize = 2
                    groups = None
                else:
                    gsize = len(groups[0]) if groups else nparts
                colls.append(CollectiveOp(
                    kind=base, operand_bytes=operand_bytes, groups=groups,
                    group_size=gsize, multiplier=1.0,
                    source_target_pairs=stp))
                nbytes += operand_bytes + _nbytes(ins.shapes)
                continue
            if op.endswith("-done") or op.endswith("-update"):
                continue
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                trips = _trip_count(comps.get(cond.group(1), [])) if cond else 1.0
                if body:
                    bf, bb, bc, bt = cost(body.group(1))
                    flops += bf * trips
                    nbytes += bb * trips
                    for t, v in bt.items():
                        tags[t] = tags.get(t, 0.0) + v * trips
                    for c in bc:
                        colls.append(dataclasses.replace(
                            c, multiplier=c.multiplier * trips))
                continue
            if op in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter",
                      "conditional", "async-start"):
                # expand nested computations (calls=/to_apply=/branches)
                for attr in ("calls", "to_apply"):
                    mm = re.search(attr + r"=%?([\w.\-]+)", ins.attrs)
                    if mm and mm.group(1) in comps:
                        cf, cb, cc, ct = cost(mm.group(1))
                        flops += cf
                        colls.extend(cc)
                        if op in ("call", "async-start"):
                            # plain calls execute their body ops; fusions
                            # melt them (boundary charged at call site)
                            nbytes += cb
                            for t, v in ct.items():
                                tags[t] = tags.get(t, 0.0) + v
                        # fusion HBM traffic is params+result, counted below
                if op == "conditional":
                    br = re.findall(r"%([\w.\-]+)", ins.attrs)
                    sub = [b for b in br if b in comps]
                    if sub:
                        costs = [cost(b) for b in sub]
                        flops += max(c[0] for c in costs)
                        nbytes += max(c[1] for c in costs)
            if op == "dot":
                flops += _dot_flops(ins, symtab)
            elif op == "convolution":
                flops += _conv_flops(ins, symtab)
            if op in _SKIP_BYTES:
                continue
            if fusion_model and op in _ELEMENTWISE:
                continue
            if op == "dynamic-slice":
                # reads only the slice (result), not the whole operand
                rb = _nbytes(ins.shapes)
                nbytes += 2 * rb
                t = _tag_of(ins)
                if t:
                    tags[t] = tags.get(t, 0.0) + 2 * rb
                continue
            if op == "dynamic-update-slice":
                # in-place on TPU (input/output alias): traffic is the
                # updated slice (read + write), not the whole buffer
                upd = symtab.get(ins.operands[1]) if len(ins.operands) > 1 \
                    else None
                ub = _nbytes(upd.shapes) if upd else 0.0
                nbytes += 2 * ub
                t = _tag_of(ins)
                if t:
                    tags[t] = tags.get(t, 0.0) + 2 * ub
                continue
            # HBM traffic model: operands read + result written, per op.
            # For fusions, an operand consumed only via (dynamic-)slice /
            # gather inside the fused computation is read at slice size,
            # not full size (scan bodies slice one layer of a stacked
            # weight/cache buffer per step).
            slice_sizes = _fusion_slice_sizes(ins, comps) \
                if ins.op == "fusion" else {}
            seen = set()
            op_bytes = 0.0
            for idx, o in enumerate(ins.operands):
                if o in seen:
                    continue
                seen.add(o)
                d = symtab.get(o)
                if d:
                    b = _nbytes(d.shapes)
                    if idx in slice_sizes:
                        b = min(b, slice_sizes[idx])
                    op_bytes += b
            op_bytes += _nbytes(ins.shapes)
            nbytes += op_bytes
            t = _tag_of(ins)
            if t:
                tags[t] = tags.get(t, 0.0) + op_bytes
        memo[comp_name] = (flops, nbytes, colls, tags)
        return memo[comp_name]

    flops, nbytes, colls, tags = cost(entry)
    raw = sum(c[0] for name, c in memo.items()) if memo else flops
    return HloProfile(flops=flops, bytes_accessed=nbytes, collectives=colls,
                      num_partitions=nparts, raw_flops=raw,
                      bytes_by_tag=tags)


# --------------------------------------------------------------------------
# comm graph extraction (profiler output -> guest graph for TOFA)
# --------------------------------------------------------------------------

def comm_graph_from_profile(profile: HloProfile,
                            n_devices: int | None = None) -> CommGraph:
    """Decompose every profiled collective into p2p phases -> G_v / G_m."""
    n = n_devices or profile.num_partitions
    g = CommGraph(n)
    for c in profile.collectives:
        rep = c.multiplier
        if c.kind == "collective-permute" and c.source_target_pairs:
            g.add_collective_permute(c.source_target_pairs, c.operand_bytes,
                                     repeats=rep)
            continue
        groups = c.groups or [tuple(range(n))]
        for grp in groups:
            grp = [d for d in grp if d < n]
            if len(grp) <= 1:
                continue
            if c.kind == "all-reduce":
                g.add_all_reduce(grp, c.operand_bytes, repeats=rep)
            elif c.kind == "all-gather":
                g.add_all_gather(grp, c.operand_bytes, repeats=rep)
            elif c.kind == "reduce-scatter":
                g.add_reduce_scatter(grp, c.operand_bytes, repeats=rep)
            elif c.kind == "all-to-all":
                g.add_all_to_all(grp, c.operand_bytes, repeats=rep)
            elif c.kind == "collective-broadcast":
                g.add_broadcast(grp, c.operand_bytes, repeats=rep)
    return g


def comm_graph_from_hlo(hlo_text: str, n_devices: int | None = None
                        ) -> CommGraph:
    return comm_graph_from_profile(profile_hlo(hlo_text), n_devices)
