"""Pluggable array backend for the mapping hot path.

The placement stack is NumPy-first: every public function takes and returns
``np.ndarray`` and the default backend executes the hot kernels with the
vectorized NumPy implementations in :mod:`repro.core.mapping`.  When JAX is
installed (``pip install repro-tofa[jax]``), the ``jax`` backend routes the
same kernels — ``hop_bytes``/``hop_bytes_batch``, ``_pairwise_refine``
swap-gain scoring, ``select_nodes`` frontier growth, ``greedy_placement`` —
through jit-compiled implementations (:mod:`repro.core.mapping_jax`) that
score all candidate placements of TOFA's multi-candidate search in a single
device dispatch and keep the per-(topology, health) distance matrices
device-resident across placements.

Selection (first match wins):

* ``backend.use("jax")`` context manager (tests, benchmarks);
* ``PlacementEngine(backend="jax")`` — the engine wraps each placement call;
* ``REPRO_BACKEND=jax`` environment variable (read at import time);
* default: ``numpy``.

Dtype policy: the NumPy kernels are pinned to float64 (the committed
quality/parity baseline).  The jax backend computes in ``float64`` by
default — with in-tree workloads every guest weight and route distance is
an exactly-representable integer, so the jitted kernels reproduce the NumPy
placements *bit-for-bit* — and can be switched to ``float32``
(``REPRO_JAX_DTYPE=float32`` or ``set_backend("jax", dtype="float32")``)
when throughput on accelerators matters more than cross-backend parity.
``jax.config`` handling lives here, inside the backend: float64 kernel
calls run under a *scoped* ``jax.experimental.enable_x64`` context
(:meth:`JaxBackend.scope`), so neither call sites nor the float32
accelerator stack ever see mutated global JAX state.  Placements are
integer node-id arrays on every backend (asserted in
``tests/test_backend_diff.py``), never floats.

A NumPy-only install never imports JAX: requesting the jax backend without
the optional dependency raises :class:`BackendUnavailableError` and
everything else keeps working with zero behavior change.
"""
from __future__ import annotations

import contextlib
import os
from collections import OrderedDict
from typing import Iterator, Optional

import numpy as np


class BackendUnavailableError(RuntimeError):
    """Requested backend cannot be activated (missing optional dependency)."""


class NumpyBackend:
    """Default backend: the vectorized NumPy kernels run as-is."""

    name = "numpy"
    is_jax = False
    dtype = "float64"          # the NumPy kernels are pinned to float64

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<backend {self.name} dtype={self.dtype}>"


class JaxBackend:
    """JAX backend: jitted kernels + device-resident distance matrices.

    ``dtype`` selects the compute precision of the jitted kernels
    (placement ids stay integers regardless).  ``float64`` (default)
    runs every kernel call and device transfer inside a *scoped*
    ``jax.experimental.enable_x64`` context (:meth:`scope`) — the
    process-wide ``jax_enable_x64`` flag is never touched, so the
    accelerator stack's float32 world is unaffected by placement calls
    and vice versa (scoped config participates in the jit cache key).
    """

    name = "jax"
    is_jax = True

    def __init__(self, dtype: str = "float64", max_cached_devices: int = 8,
                 devices: Optional[int] = None):
        if dtype not in ("float32", "float64"):
            raise ValueError(f"jax backend dtype must be float32|float64, "
                             f"got {dtype!r}")
        try:
            import jax  # noqa: F401  (deferred: numpy-only installs)
        except ImportError as e:  # pragma: no cover - exercised on bare envs
            raise BackendUnavailableError(
                "the 'jax' placement backend needs the optional jax "
                "dependency: pip install repro-tofa[jax]") from e
        self.dtype = dtype
        # cap on the devices the sharded candidate-stack dispatch may
        # use; 0 = all local devices.  REPRO_JAX_DEVICES=1 pins the
        # single-device vmap path on multi-device hosts.
        self.devices = int(_resolve_devices(devices))
        # host ndarray -> device array, LRU by object identity.  The engine
        # hands the same cached D / Eq. 1 weight matrix object to every
        # placement against one (topology, health) state, so identity is
        # exactly the right key: one transfer per health state, then every
        # job in the batch reuses the device-resident copy.
        self._device: OrderedDict[int, tuple[np.ndarray, object]] = \
            OrderedDict()
        self._max_cached = max_cached_devices
        # identity keying composes with the engine's epoch-keyed matrix
        # cache: one (topology, state epoch) == one matrix object == one
        # transfer.  The counters make that contract testable
        # (tests/test_state.py asserts zero new transfers across a warm
        # state-churn sequence).
        self.stats = {"transfers": 0, "transfer_hits": 0,
                      "sharded_dispatches": 0}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<backend {self.name} dtype={self.dtype} "
                f"devices={self.devices or 'all'}>")

    @property
    def np_dtype(self):
        return np.float32 if self.dtype == "float32" else np.float64

    @property
    def device_count(self) -> int:
        """Devices visible to the sharded refine dispatch: local device
        count clamped by the ``devices`` cap (0 = uncapped)."""
        import jax
        n = len(jax.local_devices())
        return min(n, self.devices) if self.devices else n

    def scope(self):
        """Context the jitted kernels run under: scoped x64 for the
        float64 dtype policy, a no-op for float32."""
        if self.dtype == "float64":
            from jax.experimental import enable_x64
            return enable_x64()
        return contextlib.nullcontext()

    def device_matrix(self, arr: np.ndarray):
        """Device-resident copy of a host matrix, cached by identity.

        The host array is kept referenced so ``id()`` cannot be recycled
        while the cache entry lives.  Transfers happen inside
        :meth:`scope` so float64 matrices stay float64.

        A :class:`~repro.core.lazydist.LazyDistance` must never land
        here — densifying it on device would defeat the O(n)-memory
        contract.  The jax mapping layer ships its ``implicit`` coords
        instead (``mapping_jax._device_distances``); anything else is a
        dispatch bug, surfaced eagerly.
        """
        if hasattr(arr, "implicit"):
            raise TypeError(
                "refusing to densify a LazyDistance onto device; use its "
                ".implicit coordinate spec (see mapping_jax._device_distances)")
        import jax
        key = (id(arr), self.dtype)
        hit = self._device.get(key)
        if hit is not None:
            self.stats["transfer_hits"] += 1
            self._device.move_to_end(key)
            return hit[1]
        self.stats["transfers"] += 1
        with self.scope():
            dev = jax.device_put(np.asarray(arr, dtype=self.np_dtype))
        self._device[key] = (arr, dev)
        while len(self._device) > self._max_cached:
            self._device.popitem(last=False)
        return dev

    def clear_device_cache(self) -> None:
        self._device.clear()


def has_jax() -> bool:
    """True when the optional jax dependency is importable."""
    try:
        import jax  # noqa: F401
        return True
    except ImportError:
        return False


_NUMPY = NumpyBackend()
_JAX: Optional[JaxBackend] = None


def _resolve_devices(devices: Optional[int]) -> int:
    """Explicit argument, else ``REPRO_JAX_DEVICES``, else 0 (= all)."""
    if devices is not None:
        return int(devices)
    return int(os.environ.get("REPRO_JAX_DEVICES", "0") or 0)


def _jax_backend(dtype: Optional[str] = None,
                 devices: Optional[int] = None) -> JaxBackend:
    global _JAX
    want = dtype or os.environ.get("REPRO_JAX_DTYPE", "float64")
    want_dev = _resolve_devices(devices)
    if _JAX is None or _JAX.dtype != want or _JAX.devices != want_dev:
        _JAX = JaxBackend(dtype=want, devices=want_dev)
    return _JAX


def get_backend(name: str, dtype: Optional[str] = None,
                devices: Optional[int] = None):
    """Resolve a backend by name (``numpy`` | ``jax``)."""
    if name == "numpy":
        return _NUMPY
    if name == "jax":
        return _jax_backend(dtype, devices)
    raise ValueError(f"unknown backend {name!r}; have: numpy, jax")


_ACTIVE = get_backend(os.environ.get("REPRO_BACKEND", "numpy"))


def active():
    """The backend the mapping kernels currently dispatch to."""
    return _ACTIVE


def set_backend(name: str, dtype: Optional[str] = None,
                devices: Optional[int] = None):
    """Set the process-wide active backend; returns the backend object."""
    global _ACTIVE
    _ACTIVE = get_backend(name, dtype, devices)
    return _ACTIVE


@contextlib.contextmanager
def use(name: str, dtype: Optional[str] = None,
        devices: Optional[int] = None) -> Iterator[object]:
    """Scoped backend switch::

        with backend.use("jax"):
            engine.place(request)        # jitted kernels, device-resident D

    ``devices`` caps the sharded refine dispatch (``devices=1`` pins the
    single-device vmap path; 0/None follows ``REPRO_JAX_DEVICES`` or all
    local devices).
    """
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = get_backend(name, dtype, devices)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev
