"""RMSNorm oracle — the models/layers.py implementation."""
from repro.models.layers import rmsnorm as rmsnorm_ref  # noqa: F401
