"""Fused RMSNorm — Pallas TPU kernel (single HBM round-trip).

Unfused, XLA reads x for the square-mean, then again for the scale: ~3x HBM
traffic of the fused form.  The kernel tiles rows into VMEM blocks; the
reduction runs in f32 on the VPU; one read, one write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_tpu(x, w, eps: float = 1e-6, block_rows: int = 256,
                interpret: bool = False):
    """x (..., D), w (D,) -> same shape, fused."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w.reshape(1, D))
    return out[:n].reshape(orig_shape)
