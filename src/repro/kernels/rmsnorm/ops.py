"""rmsnorm — jit'd public wrapper with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm(x, w, *, eps: float = 1e-6, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.rmsnorm.kernel import rmsnorm_tpu
        return rmsnorm_tpu(x, w, eps=eps,
                           interpret=(impl == "pallas_interpret"))
    return rmsnorm_ref(x, w, eps=eps)
