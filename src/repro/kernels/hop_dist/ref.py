"""Implicit torus hop distance — jitted-jnp reference.

Differential oracle for the Pallas kernel and the off-TPU fallback of
``impl="auto"`` dispatch in :mod:`repro.kernels.hop_dist.ops`.  The
per-dimension loop is unrolled at trace time (``dims`` is static), so no
(m, k, ndim) intermediate is ever materialised — peak memory is one
(m, k) block.
"""
from __future__ import annotations

import jax.numpy as jnp


def torus_hop_elems_ref(cu, cv, dims):
    """Broadcast-elementwise hop distance: ``(..., ndim)`` coords in,
    ``(...)`` out (same broadcasting contract as the NumPy fallback)."""
    out = None
    for k, d in enumerate(dims):
        diff = jnp.abs(cu[..., k] - cv[..., k])
        h = jnp.minimum(diff, d - diff)
        out = h if out is None else out + h
    return out


def torus_hop_pairs_ref(cu, cv, dims):
    """All-pairs form: (m, ndim), (k, ndim) -> (m, k)."""
    return torus_hop_elems_ref(cu[:, None, :], cv[None, :, :], dims)
