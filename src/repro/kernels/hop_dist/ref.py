"""Implicit torus hop distance — jitted-jnp reference.

Differential oracle for the Pallas kernel and the off-TPU fallback of
``impl="auto"`` dispatch in :mod:`repro.kernels.hop_dist.ops`.  The
per-dimension loop is unrolled at trace time (``dims`` is static), so no
(m, k, ndim) intermediate is ever materialised — peak memory is one
(m, k) block.
"""
from __future__ import annotations

import jax.numpy as jnp


def torus_hop_elems_ref(cu, cv, dims):
    """Broadcast-elementwise hop distance: ``(..., ndim)`` coords in,
    ``(...)`` out (same broadcasting contract as the NumPy fallback)."""
    out = None
    for k, d in enumerate(dims):
        diff = jnp.abs(cu[..., k] - cv[..., k])
        h = jnp.minimum(diff, d - diff)
        out = h if out is None else out + h
    return out


def torus_hop_pairs_ref(cu, cv, dims):
    """All-pairs form: (m, ndim), (k, ndim) -> (m, k)."""
    return torus_hop_elems_ref(cu[:, None, :], cv[None, :, :], dims)


def fattree_hop_elems_ref(cu, cv):
    """Broadcast-elementwise fat-tree hop count from (pod, edge, host)
    coordinate triples: 0 same host, 2 same edge switch, 4 same pod,
    6 across pods.  Written branchless — each matching level subtracts
    2 hops, and the masks nest (same edge implies same pod) — so the
    values are the exact small integers of the NumPy fallback."""
    same_pod = cu[..., 0] == cv[..., 0]
    same_edge = same_pod & (cu[..., 1] == cv[..., 1])
    same_host = same_edge & (cu[..., 2] == cv[..., 2])
    return 6.0 - 2.0 * same_pod - 2.0 * same_edge - 2.0 * same_host


def fattree_hop_pairs_ref(cu, cv):
    """All-pairs form: (m, 3), (k, 3) -> (m, k)."""
    return fattree_hop_elems_ref(cu[:, None, :], cv[None, :, :])
