"""Implicit hop distance — Pallas TPU kernels (torus and fat-tree).

Computes an (m, k) block of hop distances directly from the coordinate
tables, so the mapping hot path never gathers from (or materialises) a
stored O(N^2) matrix.  Coordinates are fed transposed — ``(ndim, m)`` /
``(ndim, k)`` — so the large axis is the TPU lane dimension; each kernel
tiles the ``cu`` side into row blocks resident in VMEM, keeps the full
``cv`` table broadcast to every block, and evaluates its metric inline:
the torus kernel unrolls the per-dimension min(|d|, dim-|d|)
accumulation at trace time (``dims`` is static, 2–4 entries for the
in-tree tori); the fat-tree kernel nests the (pod, edge, host) level
matches branchlessly.  One write per output block, no dynamic gathers
in the bodies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hop_kernel(dims):
    def body(cu_ref, cv_ref, o_ref):
        total = None
        for j, d in enumerate(dims):
            a = cu_ref[j, :]                       # (block_rows,)
            b = cv_ref[j, :]                       # (k_pad,)
            diff = jnp.abs(a[:, None] - b[None, :])
            h = jnp.minimum(diff, d - diff)
            total = h if total is None else total + h
        o_ref[...] = total
    return body


def torus_hop_tpu(cu, cv, dims, block_rows: int = 256,
                  interpret: bool = False):
    """(m, ndim), (k, ndim) coords -> (m, k) hop distances.

    ``dims`` must be a static tuple (the torus extents).  Accepts int or
    float coordinate arrays; output dtype follows the input (the mapping
    backend feeds float coords in its compute dtype — hop values are
    small integers, exact in float32).
    """
    cu = jnp.asarray(cu)
    cv = jnp.asarray(cv)
    m, nd = cu.shape
    k = cv.shape[0]
    assert nd == len(dims) and cv.shape[1] == nd
    block_rows = min(block_rows, max(m, 1))
    cuT = cu.T                                     # (ndim, m)
    cvT = cv.T                                     # (ndim, k)
    pad_m = (-m) % block_rows
    pad_k = (-k) % 128                             # lane-dim alignment
    if pad_m:
        cuT = jnp.pad(cuT, ((0, 0), (0, pad_m)))
    if pad_k:
        cvT = jnp.pad(cvT, ((0, 0), (0, pad_k)))
    m_pad, k_pad = cuT.shape[1], cvT.shape[1]
    grid = (m_pad // block_rows,)

    out = pl.pallas_call(
        _hop_kernel(tuple(dims)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nd, block_rows), lambda r: (0, r)),  # cu block
            pl.BlockSpec((nd, k_pad), lambda r: (0, 0)),       # cv full
        ],
        out_specs=pl.BlockSpec((block_rows, k_pad), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, k_pad), cu.dtype),
        interpret=interpret,
    )(cuT, cvT)
    return out[:m, :k]


def _fattree_kernel(cu_ref, cv_ref, o_ref):
    # branchless level match: each nested level subtracts 2 hops
    # (identical arithmetic to .ref.fattree_hop_elems_ref)
    same_pod = cu_ref[0, :][:, None] == cv_ref[0, :][None, :]
    same_edge = same_pod & (cu_ref[1, :][:, None] == cv_ref[1, :][None, :])
    same_host = same_edge & (cu_ref[2, :][:, None] == cv_ref[2, :][None, :])
    o_ref[...] = (6.0 - 2.0 * same_pod - 2.0 * same_edge
                  - 2.0 * same_host).astype(o_ref.dtype)


def fattree_hop_tpu(cu, cv, block_rows: int = 256,
                    interpret: bool = False):
    """(m, 3), (k, 3) fat-tree (pod, edge, host) coords -> (m, k) hop
    counts (0/2/4/6); same transposed-coordinate tiling as
    :func:`torus_hop_tpu`."""
    cu = jnp.asarray(cu)
    cv = jnp.asarray(cv)
    m, nd = cu.shape
    k = cv.shape[0]
    assert nd == 3 and cv.shape[1] == 3
    block_rows = min(block_rows, max(m, 1))
    cuT = cu.T                                     # (3, m)
    cvT = cv.T                                     # (3, k)
    pad_m = (-m) % block_rows
    pad_k = (-k) % 128                             # lane-dim alignment
    if pad_m:
        # pad with -1: never equal to a real coordinate, so padded
        # lanes can't alias a real (pod, edge, host) triple
        cuT = jnp.pad(cuT, ((0, 0), (0, pad_m)), constant_values=-1)
    if pad_k:
        cvT = jnp.pad(cvT, ((0, 0), (0, pad_k)), constant_values=-1)
    m_pad, k_pad = cuT.shape[1], cvT.shape[1]
    grid = (m_pad // block_rows,)

    out = pl.pallas_call(
        _fattree_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nd, block_rows), lambda r: (0, r)),  # cu block
            pl.BlockSpec((nd, k_pad), lambda r: (0, 0)),       # cv full
        ],
        out_specs=pl.BlockSpec((block_rows, k_pad), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, k_pad), cu.dtype),
        interpret=interpret,
    )(cuT, cvT)
    return out[:m, :k]
