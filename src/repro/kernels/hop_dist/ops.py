"""hop_dist — implicit hop distances, computed from coordinates.

The implicit-distance contract of the mapping pipeline: instead of
gathering ``D[u, v]`` from a stored O(N^2) matrix, compute the metric
directly from the (N, ndim) coordinate table — O(N) memory for any
topology size.  Two metrics live here:

    torus:    hop(u, v) = sum_d min(|cu_d - cv_d|, dim_d - |cu_d - cv_d|)
    fat-tree: hop(u, v) = 0 | 2 | 4 | 6  (same host / edge / pod / across)

Three implementations share this module's dispatch:

* :func:`torus_hop_np` / :func:`torus_hop_pairs_np` — pure NumPy, no jax
  import at module scope, so :class:`repro.core.lazydist.LazyDistance`
  works on NumPy-only installs.
* :mod:`.ref` — jitted ``jnp`` reference (CPU/GPU, and the differential
  oracle for the kernel).
* :mod:`.kernel` — Pallas TPU kernel tiling the coordinate table through
  VMEM row blocks.

``impl="auto"`` runs the Pallas kernel on TPU and the jitted reference
everywhere else — the same fallback contract as
:mod:`repro.kernels.swap_gain`.
"""
from __future__ import annotations

import functools

import numpy as np


# ------------------------------------------------------------- numpy fallback

def torus_hop_np(cu, cv, dims) -> np.ndarray:
    """Elementwise hop distance; broadcastable ``(..., ndim)`` coords in,
    float64 ``(...)`` out.  Pure NumPy — never imports jax."""
    cu = np.asarray(cu, dtype=np.int64)
    cv = np.asarray(cv, dtype=np.int64)
    out = None
    for k, d in enumerate(dims):
        diff = np.abs(cu[..., k] - cv[..., k])
        h = np.minimum(diff, d - diff)
        out = h if out is None else out + h
    return np.asarray(out, dtype=np.float64)


def torus_hop_pairs_np(cu, cv, dims) -> np.ndarray:
    """All-pairs form: (m, ndim), (k, ndim) -> (m, k) float64."""
    cu = np.asarray(cu)
    cv = np.asarray(cv)
    return torus_hop_np(cu[:, None, :], cv[None, :, :], dims)


def fattree_hop_np(cu, cv) -> np.ndarray:
    """Elementwise fat-tree hop count from broadcastable (..., 3)
    (pod, edge, host) coordinate triples: 0 same host, 2 same edge
    switch, 4 same pod, 6 across pods.  Pure NumPy — never imports jax
    (:class:`repro.core.lazydist.FatTreeLazyDistance` routes through
    here on NumPy-only installs)."""
    cu = np.asarray(cu, dtype=np.int64)
    cv = np.asarray(cv, dtype=np.int64)
    same_pod = cu[..., 0] == cv[..., 0]
    same_edge = same_pod & (cu[..., 1] == cv[..., 1])
    same_host = same_edge & (cu[..., 2] == cv[..., 2])
    return 6.0 - 2.0 * same_pod - 2.0 * same_edge - 2.0 * same_host


def fattree_hop_pairs_np(cu, cv) -> np.ndarray:
    """All-pairs form: (m, 3), (k, 3) -> (m, k) float64."""
    cu = np.asarray(cu)
    cv = np.asarray(cv)
    return fattree_hop_np(cu[:, None, :], cv[None, :, :])


# --------------------------------------------------------------- jax dispatch

def _resolve(impl: str) -> str:
    if impl == "auto":
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def torus_hop_pairs(cu, cv, dims, impl: str = "auto"):
    """Traceable all-pairs hop distance: (m, ndim), (k, ndim) -> (m, k).

    Safe to call inside other jitted code (the jitted refine loop of
    :mod:`repro.core.mapping_jax` builds its gathered-distance matrix
    through here).
    """
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.hop_dist.kernel import torus_hop_tpu
        return torus_hop_tpu(cu, cv, dims,
                             interpret=(impl == "pallas_interpret"))
    from repro.kernels.hop_dist.ref import torus_hop_pairs_ref
    return torus_hop_pairs_ref(cu, cv, dims)


def fattree_hop_pairs(cu, cv, impl: str = "auto"):
    """Traceable all-pairs fat-tree hop count: (m, 3), (k, 3) -> (m, k).

    Same contract as :func:`torus_hop_pairs` — safe inside other jitted
    code (the fat-tree implicit branch of
    :func:`repro.core.mapping_jax._dist_fns` builds its gathered-distance
    matrix through here).
    """
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.hop_dist.kernel import fattree_hop_tpu
        return fattree_hop_tpu(cu, cv,
                               interpret=(impl == "pallas_interpret"))
    from repro.kernels.hop_dist.ref import fattree_hop_pairs_ref
    return fattree_hop_pairs_ref(cu, cv)


@functools.lru_cache(maxsize=64)
def _jitted(dims: tuple | None, impl: str):
    import jax

    def f(cu, cv):
        if dims is None:
            return fattree_hop_pairs(cu, cv, impl=impl)
        return torus_hop_pairs(cu, cv, dims, impl=impl)
    return jax.jit(f)


def torus_hop(cu, cv, dims, *, impl: str = "auto"):
    """Jitted public entry: (m, ndim), (k, ndim) device/host arrays ->
    (m, k) hop distances on the active jax device."""
    return _jitted(tuple(int(d) for d in dims), _resolve(impl))(cu, cv)


def fattree_hop(cu, cv, *, impl: str = "auto"):
    """Jitted public entry, fat-tree metric: (m, 3), (k, 3) -> (m, k)."""
    return _jitted(None, _resolve(impl))(cu, cv)
