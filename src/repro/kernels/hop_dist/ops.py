"""torus_hop — implicit wraparound hop distance, computed from coordinates.

The implicit-distance contract of the mapping pipeline: instead of
gathering ``D[u, v]`` from a stored O(N^2) matrix, compute

    hop(u, v) = sum_d min(|cu_d - cv_d|, dim_d - |cu_d - cv_d|)

directly from the (N, ndim) coordinate table — O(N) memory for any
topology size.  Three implementations share this module's dispatch:

* :func:`torus_hop_np` / :func:`torus_hop_pairs_np` — pure NumPy, no jax
  import at module scope, so :class:`repro.core.lazydist.LazyDistance`
  works on NumPy-only installs.
* :mod:`.ref` — jitted ``jnp`` reference (CPU/GPU, and the differential
  oracle for the kernel).
* :mod:`.kernel` — Pallas TPU kernel tiling the coordinate table through
  VMEM row blocks.

``impl="auto"`` runs the Pallas kernel on TPU and the jitted reference
everywhere else — the same fallback contract as
:mod:`repro.kernels.swap_gain`.
"""
from __future__ import annotations

import functools

import numpy as np


# ------------------------------------------------------------- numpy fallback

def torus_hop_np(cu, cv, dims) -> np.ndarray:
    """Elementwise hop distance; broadcastable ``(..., ndim)`` coords in,
    float64 ``(...)`` out.  Pure NumPy — never imports jax."""
    cu = np.asarray(cu, dtype=np.int64)
    cv = np.asarray(cv, dtype=np.int64)
    out = None
    for k, d in enumerate(dims):
        diff = np.abs(cu[..., k] - cv[..., k])
        h = np.minimum(diff, d - diff)
        out = h if out is None else out + h
    return np.asarray(out, dtype=np.float64)


def torus_hop_pairs_np(cu, cv, dims) -> np.ndarray:
    """All-pairs form: (m, ndim), (k, ndim) -> (m, k) float64."""
    cu = np.asarray(cu)
    cv = np.asarray(cv)
    return torus_hop_np(cu[:, None, :], cv[None, :, :], dims)


# --------------------------------------------------------------- jax dispatch

def _resolve(impl: str) -> str:
    if impl == "auto":
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def torus_hop_pairs(cu, cv, dims, impl: str = "auto"):
    """Traceable all-pairs hop distance: (m, ndim), (k, ndim) -> (m, k).

    Safe to call inside other jitted code (the jitted refine loop of
    :mod:`repro.core.mapping_jax` builds its gathered-distance matrix
    through here).
    """
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.hop_dist.kernel import torus_hop_tpu
        return torus_hop_tpu(cu, cv, dims,
                             interpret=(impl == "pallas_interpret"))
    from repro.kernels.hop_dist.ref import torus_hop_pairs_ref
    return torus_hop_pairs_ref(cu, cv, dims)


@functools.lru_cache(maxsize=64)
def _jitted(dims: tuple, impl: str):
    import jax

    def f(cu, cv):
        return torus_hop_pairs(cu, cv, dims, impl=impl)
    return jax.jit(f)


def torus_hop(cu, cv, dims, *, impl: str = "auto"):
    """Jitted public entry: (m, ndim), (k, ndim) device/host arrays ->
    (m, k) hop distances on the active jax device."""
    return _jitted(tuple(int(d) for d in dims), _resolve(impl))(cu, cv)
