"""Flash attention — pure-jnp oracle (online softmax, double-chunked).

This is simultaneously (a) the correctness reference for the Pallas TPU
kernel and (b) the production attention path for long sequences on
non-TPU backends: memory is O(S * block) instead of O(S^2), which is what
makes the 32k-prefill cells compile within per-device HBM.

Contract (shared with kernel.py / ops.py):
  q (B, H, Sq, Dh), k/v (B, Hkv, Sk, Dh), GQA via H % Hkv == 0
  causal masking aligns the *ends* of q and k (standard decode/prefill
  convention: query i attends to keys j <= i + (Sk - Sq)).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    B, H, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    groups = H // Hkv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=1)
        v = jnp.repeat(v, groups, axis=1)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    # pad to block multiples
    pq = (-Sq) % q_block
    pk = (-Sk) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = q.shape[2] // q_block
    nk = k.shape[2] // kv_block
    offset = Sk - Sq  # causal alignment

    scale = 1.0 / math.sqrt(Dh)
    qb = q.reshape(B, H, nq, q_block, Dh)
    kb = k.reshape(B, H, nk, kv_block, Dh)
    vb = v.reshape(B, H, nk, kv_block, Dh)

    @functools.partial(jax.checkpoint, static_argnums=())
    def q_step(qi, q_chunk):
        """q_chunk (B,H,q_block,Dh) -> attention output for this q block.

        jax.checkpoint: the VJP recomputes the online-softmax internals
        (the ``p`` blocks) instead of saving them — this IS the flash
        backward-pass memory strategy, without it the scan residuals are
        O(S^2) again."""
        acc0 = jnp.zeros(q_chunk.shape, jnp.float32)
        m0 = jnp.full(q_chunk.shape[:3], NEG_INF, jnp.float32)
        l0 = jnp.zeros(q_chunk.shape[:3], jnp.float32)

        def kv_step(carry, kj):
            acc, m, l = carry
            kc = jax.lax.dynamic_index_in_dim(kb, kj, 2, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vb, kj, 2, keepdims=False)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_chunk, kc,
                           preferred_element_type=jnp.float32) * scale
            qpos = qi * q_block + jnp.arange(q_block) + offset
            kpos = kj * kv_block + jnp.arange(kv_block)
            mask = kpos[None, :] <= qpos[:, None] if causal else \
                jnp.ones((q_block, kv_block), bool)
            # also mask key padding
            mask = mask & (kpos[None, :] < Sk)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
            return (acc_new, m_new, l_new), None

        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    with jax.named_scope("flash_attention"):
        out = jax.lax.map(lambda i: q_step(i, qb[:, :, i]), jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 2).reshape(B, H, nq * q_block, Dh)
    return out[:, :, :Sq].astype(q.dtype)
