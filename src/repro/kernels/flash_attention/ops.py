"""flash_attention — jit'd public wrapper, backend dispatch.

On TPU the Pallas kernel (kernel.py) runs; elsewhere (and under
``interpret=True`` testing) the pure-jnp oracle (ref.py) is used.  Both
share one contract; tests sweep shapes/dtypes asserting allclose.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "impl",
                                             "q_block", "kv_block"))
def flash_attention(q, k, v, *, causal: bool = True, impl: str = "auto",
                    q_block: int = 512, kv_block: int = 1024):
    """q (B,H,Sq,Dh), k/v (B,Hkv,Sk,Dh) -> (B,H,Sq,Dh)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        from repro.kernels.flash_attention.kernel import flash_attention_tpu
        return flash_attention_tpu(q, k, v, causal=causal)
    if impl == "pallas_interpret":
        from repro.kernels.flash_attention.kernel import flash_attention_tpu
        return flash_attention_tpu(q, k, v, causal=causal, interpret=True)
    return flash_attention_ref(q, k, v, causal=causal,
                               q_block=q_block, kv_block=kv_block)
