"""Flash attention — Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the CUDA flash-attention tiling (warps over
shared memory) becomes VMEM block tiling driven by BlockSpecs, with the MXU
doing the (block_q x Dh) @ (Dh x block_k) and (block_q x block_k) @
(block_k x Dh) matmuls.  The kv-block grid axis is the innermost,
*sequential* ("arbitrary") dimension: the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across kv steps; causal
upper-triangle blocks are skipped entirely via ``pl.when``.

Block sizes default to (512, 512): with Dh <= 256 the working set
  q (512 x 256) + k,v (2 x 512 x 256) + acc (512 x 256 f32) + scores
stays well under the ~16 MB v5e VMEM budget and all matmul dims are
multiples of the 128-lane MXU tile.

Validated against ref.py in interpret mode (CPU) by tests/test_kernels_*.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, sk: int, sq: int, block_q: int,
                  block_k: int, num_kv: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = kj * block_k
    offset = sk - sq  # causal end-alignment (unpadded lengths)

    # visit the block unless it lies entirely above the causal diagonal
    run = (k_start <= q_start + block_q - 1 + offset) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if causal:
            qpos = q_start + offset + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        s = jnp.where(kpos < sk, s, NEG_INF)       # key padding

        m_prev = m_ref[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == num_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_tpu(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q (B,H,Sq,Dh), k/v (B,Hkv,Sk,Dh) -> (B,H,Sq,Dh).  GQA folded into
    the index maps (no materialised repeat of K/V)."""
    B, H, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    groups = max(H // Hkv, 1)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, causal=causal, sk=Sk, sq=Sq, block_q=block_q,
        block_k=block_k, num_kv=nk)

    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except Exception:  # pragma: no cover - older pallas naming
        cparams = None

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, i, j: (b, h // groups, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, i, j: (b, h // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=cparams,
    )(q, k, v)
    return out[:, :, :Sq]
