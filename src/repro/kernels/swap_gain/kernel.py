"""Swap-gain gather+matvec — Pallas TPU kernel.

The refiner's dense gains row needs two matvecs against the mover's
guest and distance rows plus a fused elementwise combine.  Unfused, XLA
materialises both matvec results and three temporaries in HBM; the
kernel tiles ``M`` and ``G`` into row blocks resident in VMEM, keeps the
mover's rows (``Mi``, ``Gi``) broadcast to every block, and emits the
combined gains row with one read of each matrix and one write.

The mover's rows are dynamic-sliced out on the host side (the *gather*
half of the op); the kernel is the matvec+combine half.  ``Mi``/``Gi``
are fed twice — once full-width for the dot products, once as the
current column block for the fused elementwise term — so the kernel body
needs no dynamic gathers.

:func:`swap_select_tpu` is the fused whole-step variant: the same gains
blocks are reduced to a running (best gain, argmax) pair *inside* the
kernel — the output blocks are revisited across the sequential TPU grid,
so the n-length gains row never exists outside VMEM — and the final grid
step applies the accept-or-identity-swap decision in-kernel.  The
refiner's ``lax.while_loop`` then consumes two scalars per mover instead
of a gains row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.swap_gain.ref import GAIN_EPS


def _swap_gain_kernel(m_ref, g_ref, mi_ref, gi_ref, mib_ref, gib_ref,
                      c_ref, ci_ref, o_ref):
    a = jnp.dot(m_ref[...], gi_ref[0, :],
                preferred_element_type=m_ref.dtype)      # (M @ G[i])[block]
    b = jnp.dot(g_ref[...], mi_ref[0, :],
                preferred_element_type=m_ref.dtype)      # (G @ M[i])[block]
    o_ref[0, :] = (ci_ref[0, 0] + c_ref[0, :]
                   - 2.0 * gib_ref[0, :] * mib_ref[0, :] - a - b)


def swap_gain_tpu(M, G, contrib, i, block_rows: int = 256,
                  interpret: bool = False):
    """gains (n,) for mover ``i``; see :mod:`.ref` for the formula."""
    n = M.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    Mi = jax.lax.dynamic_slice_in_dim(M, i, 1, axis=0)      # (1, n)
    Gi = jax.lax.dynamic_slice_in_dim(G, i, 1, axis=0)
    ci = jax.lax.dynamic_slice_in_dim(contrib, i, 1)
    if pad:
        # square zero-padding: the extra K-dim zeros contribute exactly
        # nothing to the dots, and padded gain rows are sliced off
        M = jnp.pad(M, ((0, pad), (0, pad)))
        G = jnp.pad(G, ((0, pad), (0, pad)))
        contrib = jnp.pad(contrib, (0, pad))
        Mi = jnp.pad(Mi, ((0, 0), (0, pad)))
        Gi = jnp.pad(Gi, ((0, 0), (0, pad)))
    np_ = M.shape[0]
    grid = (np_ // block_rows,)

    out = pl.pallas_call(
        _swap_gain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, np_), lambda r: (r, 0)),   # M rows
            pl.BlockSpec((block_rows, np_), lambda r: (r, 0)),   # G rows
            pl.BlockSpec((1, np_), lambda r: (0, 0)),            # Mi full
            pl.BlockSpec((1, np_), lambda r: (0, 0)),            # Gi full
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # Mi block
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # Gi block
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # contrib
            pl.BlockSpec((1, 1), lambda r: (0, 0)),              # contrib[i]
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda r: (0, r)),
        out_shape=jax.ShapeDtypeStruct((1, np_), M.dtype),
        interpret=interpret,
    )(M, G, Mi, Gi, Mi, Gi,
      contrib.reshape(1, np_), ci.reshape(1, 1))
    return out[0, :n]


def _swap_select_kernel(iv_ref, m_ref, g_ref, mi_ref, gi_ref, mib_ref,
                        gib_ref, c_ref, ci_ref, best_ref, j_ref):
    r = pl.program_id(0)
    last = pl.num_programs(0) - 1
    block = m_ref.shape[0]
    a = jnp.dot(m_ref[...], gi_ref[0, :],
                preferred_element_type=m_ref.dtype)      # (M @ G[i])[block]
    b = jnp.dot(g_ref[...], mi_ref[0, :],
                preferred_element_type=m_ref.dtype)      # (G @ M[i])[block]
    gains = (ci_ref[0, 0] + c_ref[0, :]
             - 2.0 * gib_ref[0, :] * mib_ref[0, :] - a - b)[None, :]
    i = iv_ref[0, 0]
    n_valid = iv_ref[0, 1]
    col = (r * block
           + jax.lax.broadcasted_iota(jnp.int32, (1, block), dimension=1))
    # the refine-loop mask: identity swap scores 0, padding scores -inf
    gains = jnp.where(col == i, 0.0, gains)
    gains = jnp.where(col < n_valid, gains, -jnp.inf)
    bv = jnp.max(gains)
    bj = r * block + jnp.argmax(gains).astype(jnp.int32)

    @pl.when(r == 0)
    def _():
        best_ref[0, 0] = bv
        j_ref[0, 0] = bj

    @pl.when(r > 0)
    def _():
        # strictly-greater update keeps the earlier block on ties — the
        # first-occurrence argmax semantics of the reference
        better = bv > best_ref[0, 0]
        best_ref[0, 0] = jnp.where(better, bv, best_ref[0, 0])
        j_ref[0, 0] = jnp.where(better, bj, j_ref[0, 0])

    @pl.when(r == last)
    def _():
        # the apply decision: reject (identity swap j := i) unless the
        # best gain clears the acceptance threshold and i is a live mover
        ok = (best_ref[0, 0] > GAIN_EPS) & (i < n_valid)
        j_ref[0, 0] = jnp.where(ok, j_ref[0, 0], i)


def swap_select_tpu(M, G, contrib, i, n_valid, block_rows: int = 256,
                    interpret: bool = False):
    """Fused (gains row -> masked argmax -> accept-or-identity) step;
    returns ``(gain, j)`` scalars — see :func:`.ref.swap_select_ref`."""
    n = M.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    Mi = jax.lax.dynamic_slice_in_dim(M, i, 1, axis=0)      # (1, n)
    Gi = jax.lax.dynamic_slice_in_dim(G, i, 1, axis=0)
    ci = jax.lax.dynamic_slice_in_dim(contrib, i, 1)
    if pad:
        M = jnp.pad(M, ((0, pad), (0, pad)))
        G = jnp.pad(G, ((0, pad), (0, pad)))
        contrib = jnp.pad(contrib, (0, pad))
        Mi = jnp.pad(Mi, ((0, 0), (0, pad)))
        Gi = jnp.pad(Gi, ((0, 0), (0, pad)))
    np_ = M.shape[0]
    grid = (np_ // block_rows,)
    iv = jnp.stack([jnp.asarray(i, jnp.int32),
                    jnp.asarray(n_valid, jnp.int32)]).reshape(1, 2)

    best, j = pl.pallas_call(
        _swap_select_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda r: (0, 0)),              # i, n_valid
            pl.BlockSpec((block_rows, np_), lambda r: (r, 0)),   # M rows
            pl.BlockSpec((block_rows, np_), lambda r: (r, 0)),   # G rows
            pl.BlockSpec((1, np_), lambda r: (0, 0)),            # Mi full
            pl.BlockSpec((1, np_), lambda r: (0, 0)),            # Gi full
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # Mi block
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # Gi block
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # contrib
            pl.BlockSpec((1, 1), lambda r: (0, 0)),              # contrib[i]
        ],
        out_specs=(pl.BlockSpec((1, 1), lambda r: (0, 0)),
                   pl.BlockSpec((1, 1), lambda r: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((1, 1), M.dtype),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)),
        interpret=interpret,
    )(iv, M, G, Mi, Gi, Mi, Gi,
      contrib.reshape(1, np_), ci.reshape(1, 1))
    return best[0, 0], j[0, 0]
