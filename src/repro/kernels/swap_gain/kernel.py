"""Swap-gain gather+matvec — Pallas TPU kernel.

The refiner's dense gains row needs two matvecs against the mover's
guest and distance rows plus a fused elementwise combine.  Unfused, XLA
materialises both matvec results and three temporaries in HBM; the
kernel tiles ``M`` and ``G`` into row blocks resident in VMEM, keeps the
mover's rows (``Mi``, ``Gi``) broadcast to every block, and emits the
combined gains row with one read of each matrix and one write.

The mover's rows are dynamic-sliced out on the host side (the *gather*
half of the op); the kernel is the matvec+combine half.  ``Mi``/``Gi``
are fed twice — once full-width for the dot products, once as the
current column block for the fused elementwise term — so the kernel body
needs no dynamic gathers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swap_gain_kernel(m_ref, g_ref, mi_ref, gi_ref, mib_ref, gib_ref,
                      c_ref, ci_ref, o_ref):
    a = jnp.dot(m_ref[...], gi_ref[0, :],
                preferred_element_type=m_ref.dtype)      # (M @ G[i])[block]
    b = jnp.dot(g_ref[...], mi_ref[0, :],
                preferred_element_type=m_ref.dtype)      # (G @ M[i])[block]
    o_ref[0, :] = (ci_ref[0, 0] + c_ref[0, :]
                   - 2.0 * gib_ref[0, :] * mib_ref[0, :] - a - b)


def swap_gain_tpu(M, G, contrib, i, block_rows: int = 256,
                  interpret: bool = False):
    """gains (n,) for mover ``i``; see :mod:`.ref` for the formula."""
    n = M.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    Mi = jax.lax.dynamic_slice_in_dim(M, i, 1, axis=0)      # (1, n)
    Gi = jax.lax.dynamic_slice_in_dim(G, i, 1, axis=0)
    ci = jax.lax.dynamic_slice_in_dim(contrib, i, 1)
    if pad:
        # square zero-padding: the extra K-dim zeros contribute exactly
        # nothing to the dots, and padded gain rows are sliced off
        M = jnp.pad(M, ((0, pad), (0, pad)))
        G = jnp.pad(G, ((0, pad), (0, pad)))
        contrib = jnp.pad(contrib, (0, pad))
        Mi = jnp.pad(Mi, ((0, 0), (0, pad)))
        Gi = jnp.pad(Gi, ((0, 0), (0, pad)))
    np_ = M.shape[0]
    grid = (np_ // block_rows,)

    out = pl.pallas_call(
        _swap_gain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, np_), lambda r: (r, 0)),   # M rows
            pl.BlockSpec((block_rows, np_), lambda r: (r, 0)),   # G rows
            pl.BlockSpec((1, np_), lambda r: (0, 0)),            # Mi full
            pl.BlockSpec((1, np_), lambda r: (0, 0)),            # Gi full
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # Mi block
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # Gi block
            pl.BlockSpec((1, block_rows), lambda r: (0, r)),     # contrib
            pl.BlockSpec((1, 1), lambda r: (0, 0)),              # contrib[i]
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda r: (0, r)),
        out_shape=jax.ShapeDtypeStruct((1, np_), M.dtype),
        interpret=interpret,
    )(M, G, Mi, Gi, Mi, Gi,
      contrib.reshape(1, np_), ci.reshape(1, 1))
    return out[0, :n]
