"""swap_gain — jit'd public wrapper with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.swap_gain.ref import swap_gain_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def swap_gain(M, G, contrib, i, *, impl: str = "auto"):
    """Dense gains row of the pairwise-swap refiner for mover ``i``.

    ``impl="auto"`` runs the Pallas kernel on TPU and the jitted-jnp
    reference everywhere else (the fallback contract of the mapping
    backend's dense path).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.swap_gain.kernel import swap_gain_tpu
        return swap_gain_tpu(M, G, contrib, i,
                             interpret=(impl == "pallas_interpret"))
    return swap_gain_ref(M, G, contrib, i)
