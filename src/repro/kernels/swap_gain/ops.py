"""swap_gain — jit'd public wrapper with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.swap_gain.ref import swap_gain_ref, swap_select_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def swap_gain(M, G, contrib, i, *, impl: str = "auto"):
    """Dense gains row of the pairwise-swap refiner for mover ``i``.

    ``impl="auto"`` runs the Pallas kernel on TPU and the jitted-jnp
    reference everywhere else (the fallback contract of the mapping
    backend's dense path).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.swap_gain.kernel import swap_gain_tpu
        return swap_gain_tpu(M, G, contrib, i,
                             interpret=(impl == "pallas_interpret"))
    return swap_gain_ref(M, G, contrib, i)


@functools.partial(jax.jit, static_argnames=("impl",))
def swap_select(M, G, contrib, i, n_valid, *, impl: str = "auto"):
    """Fused select step of the dense refiner: gains row, masked argmax
    and the accept-or-identity apply decision in one kernel.

    Returns ``(gain, j)`` scalars; ``j == i`` encodes a rejected mover
    (the identity-swap convention of ``mapping_jax._refine_one``), so the
    refine loop applies the returned swap unconditionally and never
    materialises a gains row.  Decision-identical to composing
    :func:`swap_gain` with the loop's own mask/argmax/threshold — the
    Pallas kernel and the jitted reference share the arithmetic and the
    first-occurrence tie-break (differentially tested in
    ``tests/test_kernels.py``).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.swap_gain.kernel import swap_select_tpu
        return swap_select_tpu(M, G, contrib, i, n_valid,
                               interpret=(impl == "pallas_interpret"))
    return swap_select_ref(M, G, contrib, i, n_valid)
