"""Swap-gain oracle — the dense gains row of the pairwise-swap refiner.

For mover ``i`` over a placement with gathered pairwise distances ``M``
and guest weights ``G`` (``contrib = (G * M).sum(1)``), the gain of
swapping ``i`` with every other process ``j`` is

    gains = contrib[i] + contrib - 2 * G[i] * M[i] - M @ G[i] - G @ M[i]

(the i<->j mutual term cancels because swapping endpoints preserves
their own distance).  This is the jitted-JAX fallback the Pallas kernel
is differentially tested against, and the dense-guest path of
:mod:`repro.core.mapping_jax` routes through it off-TPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def swap_gain_ref(M, G, contrib, i):
    """(n, n), (n, n), (n,), scalar index -> (n,) gains row."""
    Mi, Gi = M[i], G[i]
    return (contrib[i] + contrib - 2.0 * Gi * Mi
            - M @ Gi - G @ Mi)


# swap acceptance threshold — shared with the refine loops
# (repro.core.mapping._pairwise_refine and mapping_jax._refine_one)
GAIN_EPS = 1e-9


def swap_select_ref(M, G, contrib, i, n_valid):
    """Fused select step of the refiner: gains row + masked argmax +
    the apply decision, in one traced expression.

    Returns ``(gain, j)``: the best masked gain and the swap partner.
    Masking matches the refine loop exactly — ``gains[i] = 0`` (the
    identity swap), indices ``>= n_valid`` are ``-inf`` padding — and the
    argmax keeps the *first* occurrence on ties.  The accept test and
    identity-swap substitution happen here too: when the best gain does
    not clear ``GAIN_EPS`` (or mover ``i`` is itself padding),
    ``j == i`` so the caller applies the returned swap unconditionally.
    """
    g = swap_gain_ref(M, G, contrib, i)
    n = g.shape[0]
    g = g.at[i].set(0.0)
    g = jnp.where(jnp.arange(n) < n_valid, g, -jnp.inf)
    j_raw = jnp.argmax(g)
    gain = g[j_raw]
    j = jnp.where((gain > GAIN_EPS) & (i < n_valid), j_raw, i)
    return gain, j.astype(jnp.int32)
