"""Swap-gain oracle — the dense gains row of the pairwise-swap refiner.

For mover ``i`` over a placement with gathered pairwise distances ``M``
and guest weights ``G`` (``contrib = (G * M).sum(1)``), the gain of
swapping ``i`` with every other process ``j`` is

    gains = contrib[i] + contrib - 2 * G[i] * M[i] - M @ G[i] - G @ M[i]

(the i<->j mutual term cancels because swapping endpoints preserves
their own distance).  This is the jitted-JAX fallback the Pallas kernel
is differentially tested against, and the dense-guest path of
:mod:`repro.core.mapping_jax` routes through it off-TPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def swap_gain_ref(M, G, contrib, i):
    """(n, n), (n, n), (n,), scalar index -> (n,) gains row."""
    Mi, Gi = M[i], G[i]
    return (contrib[i] + contrib - 2.0 * Gi * Mi
            - M @ Gi - G @ Mi)
