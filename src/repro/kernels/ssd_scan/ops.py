"""ssd_scan — jit'd public wrapper with backend dispatch + layout shim."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, impl: str = "auto"):
    """Model-layer layout: x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,G,N)
    -> y (B,S,H,P), final_state (B,H,P,N).

    Pre-conditions dt into ``xdt``/``dA`` and dispatches to the Pallas
    kernel (TPU), its interpreter (tests), or the exact recurrence ref."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    xdt = jnp.moveaxis(x * dt[..., None], 1, 2)        # (B,H,S,P)
    dA = jnp.moveaxis(dt * A[None, None, :], 1, 2)     # (B,H,S)
    Bk = jnp.moveaxis(B, 1, 2)                         # (B,G,S,N)
    Ck = jnp.moveaxis(C, 1, 2)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ssd_scan.kernel import ssd_scan_tpu
        y, st = ssd_scan_tpu(xdt, dA, Bk, Ck, chunk=chunk,
                             interpret=(impl == "pallas_interpret"))
    else:
        y, st = ssd_scan_ref(xdt, dA, Bk, Ck, chunk=chunk)
    return jnp.moveaxis(y, 1, 2), st
