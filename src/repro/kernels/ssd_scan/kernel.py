"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the Triton SSD kernel's SM-parallel chunk
matmuls become MXU matmuls on VMEM blocks; the inter-chunk state recurrence
— the part GPUs handle with grid-sync tricks — maps naturally onto a
*sequential* innermost grid axis with the running (P x N) state held in
VMEM scratch across chunk steps (same pattern as flash attention's online
softmax, which is exactly the state-space-duality point of the paper).

Inputs are pre-conditioned by ops.py: ``xdt = x * dt`` and ``dA = dt * A``
so the kernel sees only tensor contractions:

  intra-chunk: y  = tril(C B^T * L) @ xdt          (Q x Q on the MXU)
  carry-in:    y += (C * exp(cumsum dA)) @ state^T
  state:       state' = exp(sum dA) state + (xdt * decay)^T @ B

Block alignment: chunk Q defaults to 128 (MXU tile), P = head_dim (64 or
128), N = d_state (64/128) — all lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, st_out_ref, state_ref,
                *, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dA = dA_ref[0, 0].astype(jnp.float32)        # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)          # (Q, N)
    Q = dA.shape[0]

    dA_cs = jnp.cumsum(dA)                       # (Q,)
    # L[i, j] = exp(dA_cs[i] - dA_cs[j]) for j <= i (segment products)
    diff = dA_cs[:, None] - dA_cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    state = state_ref[...]                       # (P, N)
    c_in = C * jnp.exp(dA_cs)[:, None]           # (Q, N)
    y = y + jax.lax.dot_general(c_in, state,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    decay_to_end = jnp.exp(dA_cs[-1] - dA_cs)    # (Q,)
    state_new = state * jnp.exp(dA_cs[-1]) + jax.lax.dot_general(
        xdt * decay_to_end[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_ref[...] = state_new

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        st_out_ref[0, 0] = state_new.astype(st_out_ref.dtype)


def ssd_scan_tpu(xdt, dA, B, C, chunk: int = 128, interpret: bool = False):
    """xdt (B,H,S,P), dA (B,H,S), B/C (B,G,S,N) -> y (B,H,S,P),
    final_state (B,H,P,N)."""
    b, H, S, P = xdt.shape
    G, N = B.shape[1], B.shape[3]
    groups = max(H // G, 1)
    chunk = min(chunk, S)
    assert S % chunk == 0, f"S={S} % chunk={chunk}"
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:  # pragma: no cover
        cparams = None

    y, st = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda i, h, c: (i, h, c)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda i, h, c: (i, h // groups, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda i, h, c: (i, h // groups, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xdt.shape, xdt.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        compiler_params=cparams,
    )(xdt, dA, B, C)
    return y, st
