"""SSD scan oracle: thin wrapper over the models/ssm.py chunked algorithm
with the kernel's (B, H, S, ...) layout contract."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_scan_ref(xdt, dA, B, C, chunk: int):
    """Kernel-layout reference.

    xdt (B,H,S,P)  x pre-multiplied by dt
    dA  (B,H,S)    dt * A  (negative decays)
    B,C (B,G,S,N)
    ->  y (B,H,S,P), final_state (B,H,P,N)

    Implemented by calling the model-layer reference with dt == 1 (the dt
    factors are folded into xdt / dA, exactly what the kernel consumes).
    """
    b, H, S, P = xdt.shape
    x_l = jnp.moveaxis(xdt, 1, 2)               # (B,S,H,P)
    dt_l = jnp.ones((b, S, H), xdt.dtype)
    B_l = jnp.moveaxis(B, 1, 2)                 # (B,S,G,N)
    C_l = jnp.moveaxis(C, 1, 2)
    # ssd_chunked computes dA = dt * A with per-head A; here decay varies
    # per (b,s,h), so inject via the dt slot with A = 1... not expressible.
    # Instead run the direct recurrence definition (exact, O(S)):
    return _direct(xdt, dA, B, C)


def _direct(xdt, dA, B, C):
    """Exact sequential recurrence (the SSD definition)."""
    b, H, S, P = xdt.shape
    G, N = B.shape[1], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1) if rep > 1 else B   # (b,H,S,N)
    Ch = jnp.repeat(C, rep, axis=1) if rep > 1 else C

    import jax

    def step(state, inp):
        x_s, dA_s, B_s, C_s = inp                # (b,H,P),(b,H),(b,H,N)x2
        state = state * jnp.exp(dA_s)[..., None, None] \
            + jnp.einsum("bhp,bhn->bhpn", x_s, B_s)
        y = jnp.einsum("bhn,bhpn->bhp", C_s, state)
        return state, y

    xs = (jnp.moveaxis(xdt, 2, 0), jnp.moveaxis(dA, 2, 0),
          jnp.moveaxis(Bh, 2, 0), jnp.moveaxis(Ch, 2, 0))
    state0 = jnp.zeros((b, H, P, N), jnp.float32)
    final, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(xdt.dtype), final
