from repro.workloads.patterns import (WORKLOADS, Workload, get_workload)
from repro.workloads.arrivals import (JobSpec, burst_stream,
                                      mixed_size_factory, poisson_stream,
                                      replicated, serial_stream)
