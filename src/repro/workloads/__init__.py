from repro.workloads.patterns import (WORKLOADS, Workload, get_workload)
