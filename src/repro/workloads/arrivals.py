"""Arrival processes and job-mix factories for the cluster simulator.

A *job stream* is a list of :class:`JobSpec`: what to run (a
:class:`~repro.workloads.patterns.Workload` plus an srun distribution
policy) and when it enters the system.  ``submit_time`` is absolute
simulated seconds; ``after_previous=True`` instead chains the job behind
the previous spec in the stream (submitted the instant it completes) —
the *serial* arrival discipline of the paper's batch protocol, where a
batch is 100 instances of the same application run back-to-back.

Job mixes model what the paper's single-application batches cannot: a
scheduler facing jobs of different widths and communication patterns at
once, where queueing and backfill decisions interact with placement.

All draws take an explicit ``numpy.random.Generator``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.workloads.patterns import (Workload, halo3d, lammps_like,
                                      npb_dt_like)


@dataclasses.dataclass
class JobSpec:
    """One job in a stream: payload, policy, and arrival semantics."""

    workload: Workload
    policy: str = "tofa"
    submit_time: float = 0.0            # absolute seconds (ignored if chained)
    after_previous: bool = False        # serial chaining: submit on prev done
    fixed_placement: Optional[np.ndarray] = None  # bypass the scheduler
    name: Optional[str] = None

    def label(self) -> str:
        return self.name or self.workload.name


def serial_stream(workloads: Sequence[Workload], policy: str = "tofa",
                  fixed_placement: Optional[np.ndarray] = None
                  ) -> list[JobSpec]:
    """The paper's batch discipline: instance i+1 is submitted the moment
    instance i completes.  With ``fixed_placement`` every instance reuses
    one placement (the paper computes placement once per batch)."""
    if not workloads:
        raise ValueError("serial_stream needs at least one workload")
    out = []
    for i, wl in enumerate(workloads):
        out.append(JobSpec(wl, policy=policy, submit_time=0.0,
                           after_previous=(i > 0),
                           fixed_placement=fixed_placement,
                           name=f"{wl.name}#{i}"))
    return out


def burst_stream(workloads: Sequence[Workload], policy: str = "tofa",
                 at: float = 0.0) -> list[JobSpec]:
    """Saturation discipline: every job submitted at the same instant —
    the queue starts full and drains against capacity."""
    if not workloads:
        raise ValueError("burst_stream needs at least one workload")
    if at < 0:
        raise ValueError(f"submit instant must be >= 0, got {at}")
    return [JobSpec(wl, policy=policy, submit_time=at, name=f"{wl.name}#{i}")
            for i, wl in enumerate(workloads)]


def poisson_stream(workload_factory: Callable[[np.random.Generator],
                                              Workload],
                   rate: float, n_jobs: int, rng: np.random.Generator,
                   policy: str = "tofa",
                   max_duration: Optional[float] = None) -> list[JobSpec]:
    """Open-arrival discipline: exponential inter-arrival times with mean
    ``1 / rate`` jobs/second; each job drawn from ``workload_factory``.

    ``max_duration`` caps the arrival window in simulated seconds: the
    stream stops at the first arrival past the cap (so it may hold fewer
    than ``n_jobs`` specs) — the storm benchmark uses this to bound an
    open-loop run independently of the sampled inter-arrival draws."""
    if not (rate > 0) or not np.isfinite(rate):
        raise ValueError(f"arrival rate must be a finite value > 0 "
                         f"jobs/second, got {rate}")
    if n_jobs < 1:
        raise ValueError(f"n_jobs must be >= 1, got {n_jobs}")
    if max_duration is not None and max_duration <= 0:
        raise ValueError(f"max_duration must be > 0, got {max_duration}")
    t = 0.0
    out = []
    for i in range(n_jobs):
        t += float(rng.exponential(1.0 / rate))
        if max_duration is not None and t > max_duration:
            break
        wl = workload_factory(rng)
        out.append(JobSpec(wl, policy=policy, submit_time=t,
                           name=f"{wl.name}#{i}"))
    return out


def mixed_size_factory(sizes: Sequence[int] = (8, 27, 64),
                       weights: Sequence[float] | None = None,
                       ) -> Callable[[np.random.Generator], Workload]:
    """Job-mix factory: each draw picks a width from ``sizes`` and a
    pattern (regular halo vs irregular DAG) at random — small frequent
    jobs alongside wide rare ones, the mix that exercises backfill."""
    sizes = list(sizes)
    if not sizes:
        raise ValueError("mixed_size_factory needs at least one size")
    w = None if weights is None else np.asarray(weights, float)
    if w is not None:
        if len(w) != len(sizes) or (w < 0).any() or w.sum() <= 0:
            raise ValueError(
                f"weights must be {len(sizes)} nonnegative values with a "
                f"positive sum")
        w = w / w.sum()

    def factory(rng: np.random.Generator) -> Workload:
        n = int(rng.choice(sizes, p=w))
        if rng.random() < 0.5:
            dims = _near_cube(n)
            return halo3d(dims)
        return npb_dt_like(n, seed=int(rng.integers(1 << 31)))
    return factory


def replicated(wl_factory: Callable[[], Workload], n: int) -> list[Workload]:
    """n instances of one application — the paper's batch composition."""
    return [wl_factory() for _ in range(n)]


def _near_cube(n: int) -> tuple[int, int, int]:
    """Most cubic (a, b, c) with a*b*c == n (fallback (1, 1, n))."""
    best = (1, 1, n)
    for a in range(1, int(round(n ** (1 / 3))) + 2):
        if n % a:
            continue
        m = n // a
        for b in range(a, int(m ** 0.5) + 2):
            if m % b == 0 and m // b >= b:
                if max(a, b, m // b) - a < max(best) - best[0]:
                    best = (a, b, m // b)
    return best


__all__ = ["JobSpec", "serial_stream", "burst_stream", "poisson_stream",
           "mixed_size_factory", "replicated", "lammps_like", "npb_dt_like"]
