"""Synthetic communication workloads — the paper's benchmark applications.

The paper evaluates with LAMMPS (regular, banded pattern: halo exchange from
spatial decomposition + global collectives for thermo output) and NPB-DT
class C (irregular: traffic flows along a randomized task DAG between
source, intermediate and sink ranks, nothing on the main diagonal).  These
generators reproduce those *patterns* (cf. the paper's Fig. 1 heatmaps) so
placement policies face the same regular-vs-irregular contrast, plus a few
classic kernels used by the wider literature.

Every generator also reports per-rank compute work (flop counts) so the
cluster simulator can model the communication/computation ratio.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.comm_graph import CommGraph


@dataclasses.dataclass
class Workload:
    """A job's profile: communication graph + compute + phase structure."""

    name: str
    comm: CommGraph
    flops_per_rank: float          # per communication round
    rounds: int                    # communication rounds per run
    pattern: str                   # 'regular' | 'irregular' | ...

    @property
    def n_ranks(self) -> int:
        return self.comm.n


def _grid3(n: int) -> tuple[int, int, int]:
    """Factor n into the most cubic (nx, ny, nz) grid, nx <= ny <= nz."""
    best = (1, 1, n)
    for a in range(1, int(round(n ** (1 / 3))) + 2):
        if n % a:
            continue
        m = n // a
        for b in range(a, int(m ** 0.5) + 2):
            if m % b:
                continue
            c = m // b
            if a * b * c == n and c >= b:
                if max(a, b, c) - min(a, b, c) < max(best) - min(best):
                    best = (a, b, c)
    return best


def lammps_like(
    n_ranks: int = 64,
    *,
    halo_bytes: float = 512e3,
    collective_bytes: float = 128e3,
    rounds: int = 100,
    flops_per_rank: float = 25e6,
) -> Workload:
    """LAMMPS rhodopsin-style profile: halo exchange of a periodic 3D
    spatial decomposition (rank grid nx x ny x nz, neighbours at rank
    strides 1, nz, ny*nz) + global all-reduces (thermo output).

    This is the multi-band regular heatmap of the paper's Fig. 1a: traffic
    concentrates on a few fixed diagonals.  A topology mapper can fold the
    3D rank grid isomorphically onto a 3D torus block (every halo 1 hop) —
    exactly the structure LAMMPS exposes in the paper's evaluation.  Byte
    arguments are per communication round."""
    nx, ny, nz = _grid3(n_ranks)
    g = CommGraph(n_ranks)

    def rid(x, y, z):
        return (x * ny + y) * nz + z

    for x in range(nx):
        for y in range(ny):
            for z in range(nz):
                i = rid(x, y, z)
                for dx, dy, dz in ((1, 0, 0), (0, 1, 0), (0, 0, 1)):
                    if (dx and nx < 2) or (dy and ny < 2) or (dz and nz < 2):
                        continue
                    j = rid((x + dx) % nx, (y + dy) % ny, (z + dz) % nz)
                    if i != j:
                        g.add_p2p(i, j, rounds * halo_bytes, rounds)
    g.add_all_reduce(list(range(n_ranks)), collective_bytes, repeats=rounds / 10)
    return Workload("lammps", g, flops_per_rank, rounds, "regular")


def npb_dt_like(
    n_ranks: int = 85,
    *,
    msg_bytes: float = 640e3,
    seed: int = 7,
    rounds: int = 20,
    flops_per_rank: float = 30e6,
) -> Workload:
    """NPB-DT class C-style profile: a randomized task DAG (sources ->
    intermediate shuffle layers -> sinks).  DT class C uses 85 ranks; the
    shuffle edges put traffic far off the main diagonal (paper Fig. 1b)."""
    rng = np.random.default_rng(seed)
    g = CommGraph(n_ranks)
    perm = rng.permutation(n_ranks)
    n_src = max(2, n_ranks // 4)
    n_sink = max(2, n_ranks // 4)
    src = perm[:n_src]
    sink = perm[n_src:n_src + n_sink]
    mid = perm[n_src + n_sink:]
    # each source feeds 2 random intermediates, each intermediate feeds 2
    # others or sinks — a quad-tree-ish data-flow like DT's graphs
    for s in src:
        pool = mid if len(mid) else sink
        k = min(2, len(pool))
        for t in rng.choice(pool, size=k, replace=False):
            g.add_p2p(int(s), int(t), rounds * msg_bytes, rounds)
    for m in mid:
        k = min(2, len(sink))
        for t in rng.choice(sink, size=k, replace=False):
            g.add_p2p(int(m), int(t), rounds * msg_bytes * 2, rounds)
    return Workload("npb_dt", g, flops_per_rank, rounds, "irregular")


def halo3d(
    dims: tuple[int, int, int] = (4, 4, 4),
    *,
    face_bytes: float = 128e3,
    rounds: int = 100,
    flops_per_rank: float = 40e6,
) -> Workload:
    """3D nearest-neighbour halo exchange on a rank grid (stencil codes)."""
    nx, ny, nz = dims
    n = nx * ny * nz
    g = CommGraph(n)

    def rid(x, y, z):
        return (x * ny + y) * nz + z

    for x in range(nx):
        for y in range(ny):
            for z in range(nz):
                i = rid(x, y, z)
                for dx, dy, dz in ((1, 0, 0), (0, 1, 0), (0, 0, 1)):
                    j = rid((x + dx) % nx, (y + dy) % ny, (z + dz) % nz)
                    if i != j:
                        g.add_p2p(i, j, face_bytes, rounds)
    return Workload("halo3d", g, flops_per_rank, rounds, "regular")


def alltoall_heavy(
    n_ranks: int = 64, *, local_bytes: float = 1e6, rounds: int = 50,
    flops_per_rank: float = 10e6,
) -> Workload:
    """FFT/transpose-style all-to-all — placement-insensitive worst case."""
    g = CommGraph(n_ranks)
    g.add_all_to_all(list(range(n_ranks)), local_bytes, repeats=rounds)
    return Workload("alltoall", g, flops_per_rank, rounds, "uniform")


def allreduce_heavy(
    n_ranks: int = 64, *, nbytes: float = 4e6, rounds: int = 100,
    flops_per_rank: float = 100e6,
) -> Workload:
    """Data-parallel training style: one big ring all-reduce per round."""
    g = CommGraph(n_ranks)
    g.add_all_reduce(list(range(n_ranks)), nbytes, repeats=rounds)
    return Workload("allreduce", g, flops_per_rank, rounds, "ring")


WORKLOADS = {
    "lammps": lammps_like,
    "npb_dt": npb_dt_like,
    "halo3d": halo3d,
    "alltoall": alltoall_heavy,
    "allreduce": allreduce_heavy,
}


def get_workload(name: str, **kw) -> Workload:
    return WORKLOADS[name](**kw)
