"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400; MLA kv_lora=512;
layer 0 dense (d_ff 10944, HF config), layers 1..26 MoE with 64 routed
experts top-6 plus 2 shared experts.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    act="silu_glu", rope_theta=10000.0, attn_type="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  first_dense=1, d_ff_first=10944),
    source="arXiv:2405.04434",
)
