"""Config system: model architecture + input-shape + run configuration.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
``repro.configs.registry`` resolves ``--arch <id>``.  ``ShapeConfig`` holds
the assigned input-shape cells (train_4k / prefill_32k / decode_32k /
long_500k).  ``reduced()`` produces the CPU-smoke-test variant of any arch
(same family and wiring, tiny dimensions).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared: int = 0             # shared (always-on) experts
    d_ff_expert: int = 0          # per-expert hidden size
    first_dense: int = 0          # leading dense layers (deepseek style)
    d_ff_first: int = 0           # d_ff of the leading dense layers
    impl: str = "replicated"      # 'replicated' | 'alltoall' (EP dispatch)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 0
    q_lora_rank: int = 0          # 0 = no q compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64               # SSD chunk length
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "silu_glu"         # silu_glu | gelu | relu2
    norm: str = "rmsnorm"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    attn_type: str = "gqa"        # gqa | mla | none
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    hybrid_every: int = 0
    # encdec (seamless): n_layers encoder + n_layers decoder
    n_enc_layers: int = 0
    # vlm (llama-3.2-vision): a cross-attn layer after every k self layers
    cross_attn_every: int = 0
    n_vision_tokens: int = 1600   # stubbed patch-embedding count
    n_audio_frames: int = 0       # stubbed frame-embedding count (encdec)
    dtype: str = "bfloat16"
    # notes carried into DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k decode cell?"""
        return self.family in ("ssm", "hybrid")

    @property
    def n_params(self) -> float:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.head_dim_
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.attn_type == "mla" and self.mla:
            m = self.mla
            qdim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            q = d * m.q_lora_rank + m.q_lora_rank * qdim if m.q_lora_rank \
                else d * qdim
            kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) \
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            o = self.n_heads * m.v_head_dim * d
            attn = q + kv + o
        elif self.attn_type == "none":
            attn = 0
        else:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
        glu = 3 if self.act == "silu_glu" else 2
        if self.family == "ssm":
            ssm = self.ssm
            d_in = ssm.expand * d
            nh = d_in // ssm.head_dim
            blk = d * (2 * d_in + 2 * ssm.n_groups * ssm.d_state + nh) \
                + d_in * d  # in_proj + out_proj (+ conv, dt, A, D small)
            return emb + L * blk
        if self.family == "hybrid":
            ssm = self.ssm
            d_in = ssm.expand * d
            blk = d * (2 * d_in + 2 * ssm.n_groups * ssm.d_state) + d_in * d
            shared = attn + glu * d * f
            return emb + L * blk + shared
        if self.family == "moe" and self.moe:
            mo = self.moe
            moe_layers = L - mo.first_dense
            expert = glu * d * mo.d_ff_expert
            blk = attn + (mo.n_experts + mo.n_shared) * expert + d * mo.n_experts
            dense_blk = attn + glu * d * (mo.d_ff_first or f)
            return emb + moe_layers * blk + mo.first_dense * dense_blk
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + glu * d * f)
            dec = L * (2 * attn + glu * d * f)
            return emb + enc + dec
        if self.family == "vlm":
            n_cross = L // (self.cross_attn_every + 1) if self.cross_attn_every else 0
            return emb + L * (attn + glu * d * f) + n_cross * attn
        return emb + L * (attn + glu * d * f)

    @property
    def n_active_params(self) -> float:
        """Active (per-token) parameters — MoE top-k instead of all experts."""
        if self.family != "moe" or not self.moe:
            return self.n_params
        mo = self.moe
        glu = 3 if self.act == "silu_glu" else 2
        expert = glu * self.d_model * mo.d_ff_expert
        inactive = (mo.n_experts - mo.top_k) * expert
        return self.n_params - (self.n_layers - mo.first_dense) * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_cells(cfg: ModelConfig) -> list[str]:
    """The live (arch x shape) cells for this architecture (skips per
    DESIGN.md §4: long_500k only for sub-quadratic archs)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family not in ("hybrid",) else 5),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        n_vision_tokens=8,
        n_audio_frames=16,
        dtype="float32",
    )
    if cfg.family == "encdec":
        kw["n_enc_layers"] = 2
        kw["n_layers"] = 2
    if cfg.family == "vlm":
        kw["cross_attn_every"] = 2
        kw["n_layers"] = 3  # 2 self + 1 cross per group: 3 -> one group
    if cfg.family == "hybrid":
        kw["hybrid_every"] = 2
    if cfg.mla:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2,
            n_shared=min(cfg.moe.n_shared, 1),
            d_ff_expert=64,
            first_dense=min(cfg.moe.first_dense, 1),
            d_ff_first=96 if cfg.moe.first_dense else 0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                        chunk=8)
    kw.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **kw)
