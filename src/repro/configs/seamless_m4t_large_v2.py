"""seamless-m4t-large-v2 — encoder-decoder multimodal translator
[arXiv:2308.11596; hf].

24L(enc) + 24L(dec) d_model=1024 16H d_ff=8192 vocab=256206.  The speech
frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings as encoder input; the text decoder runs
self + cross attention.  Decode caches: self-KV + frozen cross-KV.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    act="gelu", rope_theta=10000.0,
    source="arXiv:2308.11596",
)
