"""minicpm3-4b — dense LM with MLA attention [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H d_ff=6400 vocab=73448; multi-head latent attention
with kv_lora_rank=256, q_lora_rank=768, qk heads split 64 nope + 32 rope,
v_head_dim=64 (HF config values).
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448,
    act="silu_glu", rope_theta=10000.0, attn_type="mla",
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B",
)
