"""smollm-135m — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152; tied embeddings,
silu-GLU MLP, RoPE.  NOTE: 9 heads do not divide the 16-way model axis, so
the sharding rules replicate the head dim (DESIGN.md divisibility rule).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152, head_dim=64,
    act="silu_glu", rope_theta=10000.0, tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
