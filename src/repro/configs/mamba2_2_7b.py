"""mamba2-2.7b — attention-free SSM via state-space duality
[arXiv:2405.21060; unverified].

64L d_model=2560 vocab=50280, ssm_state=128, expand=2 (d_inner 5120),
head_dim=64 (80 heads), conv window 4.  Sub-quadratic: runs the long_500k
cell (decode state is O(1) in sequence length).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=80, n_kv_heads=0,
    d_ff=0, vocab=50280, attn_type="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=64),
    source="arXiv:2405.21060 (unverified)",
)
