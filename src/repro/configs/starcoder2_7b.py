"""starcoder2-7b — dense GQA code LM [arXiv:2402.19173; hf].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152; RoPE, GQA.
(The HF config uses layernorm + gelu pre-GLU-less MLP; we keep the
assignment's d_ff with a plain gelu MLP.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152, head_dim=128,
    act="gelu", rope_theta=100000.0,
    source="arXiv:2402.19173",
)
