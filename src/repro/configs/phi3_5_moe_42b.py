"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE [hf:microsoft/Phi-3.5-MoE].

32L d_model=4096 32H (GQA kv=8) d_ff(expert)=6400 vocab=32064, every layer
MoE with 16 experts top-2.  The EP showcase arch: 16 experts over the
16-way model axis = exactly one expert per shard.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab=32064, head_dim=128,
    act="silu_glu", rope_theta=10000.0,
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_ff_expert=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
