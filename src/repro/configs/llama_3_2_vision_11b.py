"""llama-3.2-vision-11b — VLM text backbone with cross-attention layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; a cross-attention
layer after every 4 self-attention layers (8 cross layers).  The vision
tower is a STUB per the assignment: ``input_specs`` provides precomputed
patch embeddings (B, 1600, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    act="silu_glu", rope_theta=500000.0,
    cross_attn_every=4, n_vision_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision (unverified)",
)
