"""nemotron-4-340b — dense GQA with squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.  At 340B params
this is the memory-floor stress test of the zoo: bf16 weights alone are
~680 GB; Adam m/v in fp32 add 2.7 TB (see EXPERIMENTS.md §Dry-run for the
per-chip budget discussion and the ``state_dtype=bf16`` knob).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, head_dim=192,
    act="relu2", rope_theta=10000.0,
    source="arXiv:2402.16819 (unverified)",
)
