"""zamba2-7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 32H d_ff=14336 vocab=32000, ssm_state=64.  One SHARED
attention+MLP block (true weight sharing) applied after every 6 mamba2
layers (13 applications + 3 trailing mamba layers).  Sub-quadratic family:
runs long_500k (shared-block KV caches are the only seq-length state).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    act="silu_glu", rope_theta=10000.0, hybrid_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64),
    source="arXiv:2411.15242 (unverified)",
)
