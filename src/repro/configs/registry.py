"""--arch <id> resolution for every assigned architecture."""
from repro.configs.base import ModelConfig, SHAPES, reduced, shape_cells

from repro.configs.smollm_135m import CONFIG as smollm_135m
from repro.configs.starcoder2_7b import CONFIG as starcoder2_7b
from repro.configs.nemotron_4_340b import CONFIG as nemotron_4_340b
from repro.configs.minicpm3_4b import CONFIG as minicpm3_4b
from repro.configs.llama_3_2_vision_11b import CONFIG as llama_3_2_vision_11b
from repro.configs.phi3_5_moe_42b import CONFIG as phi3_5_moe_42b
from repro.configs.deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from repro.configs.mamba2_2_7b import CONFIG as mamba2_2_7b
from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2

ARCHS: dict[str, ModelConfig] = {c.name: c for c in [
    smollm_135m, starcoder2_7b, nemotron_4_340b, minicpm3_4b,
    llama_3_2_vision_11b, phi3_5_moe_42b, deepseek_v2_lite_16b,
    mamba2_2_7b, zamba2_7b, seamless_m4t_large_v2,
]}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells() -> list[tuple[str, str]]:
    """Every live (arch, shape) dry-run cell."""
    out = []
    for name, cfg in ARCHS.items():
        for cell in shape_cells(cfg):
            out.append((name, cell))
    return out
