"""Event-driven belief tracker: O(1)-per-event lifetime accounting.

:class:`BeliefTracker` is the mutable half of the belief subsystem: it
consumes the same failure / repair / heartbeat stream the scheduler
already sees (:class:`~repro.cluster.failures.NodeEvent` semantics —
``Scheduler.handle_node_failure`` / ``Scheduler.recover`` forward to it
when attached) and maintains the :class:`~repro.beliefs.estimators.
LifetimeStats` sufficient statistics incrementally — constant work per
event, never a history replay.  Any :class:`~repro.beliefs.estimators.
BeliefModel` then turns those statistics into a per-node ``p_f`` vector
on demand.

Two properties matter for the placement loop:

* **Pattern hygiene** — Eq. 1 consumers read the ``p_f > 0`` indicator,
  so the tracker clamps beliefs below ``p_floor`` to exactly 0.0.
  Without the floor every node carries residual prior mass, the faulty
  pattern saturates, and fault-aware placement degenerates to uniform
  avoidance.
* **Cache friendliness** — between genuine pattern changes the belief
  drifts only as exposure accumulates, which is smooth and tiny per
  heartbeat round; ``ClusterState.evolve``'s atol interning (scheduler
  ``p_f_atol``) absorbs it, so tracker jitter never mints epochs or
  cold-starts engine weight caches (gated ≥95% hit rate, see
  ``tests/test_beliefs.py`` and ``benchmarks/belief_sweep.py``).

Overlapping outages (a rack event downing an already-down node) are
reference-counted like ``ClusterSim``'s ``_down_count`` so a node only
closes one lifetime per up→down transition.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from .estimators import BeliefModel, LifetimeStats


class BeliefTracker:
    """Incremental per-node lifetime statistics + a pluggable belief model.

    Parameters
    ----------
    n_nodes:
        Cluster size; all event node ids must be ``< n_nodes``.
    model:
        The :class:`BeliefModel` queried by :meth:`p_f_vector`.
    horizon:
        Default job-duration window (simulated seconds) for belief
        queries; per-query override via ``p_f_vector(duration=...)``.
    p_floor:
        Emission floor: beliefs strictly below this are clamped to 0.0
        so residual prior mass on healthy nodes never flips the Eq. 1
        fault pattern.  Set to 0.0 to disable (calibration studies).
    t0:
        Clock origin; all nodes start up at ``t0``.
    """

    def __init__(self, n_nodes: int, model: BeliefModel, *,
                 horizon: float = 1.0, p_floor: float = 0.02,
                 t0: float = 0.0):
        if n_nodes <= 0:
            raise ValueError("n_nodes must be positive")
        if horizon <= 0:
            raise ValueError("horizon must be positive")
        self.n_nodes = int(n_nodes)
        self.model = model
        self.horizon = float(horizon)
        self.p_floor = float(p_floor)
        self.now = float(t0)
        self._n_failures = np.zeros(n_nodes, dtype=np.float64)
        self._closed_exposure = np.zeros(n_nodes, dtype=np.float64)
        self._sum_life = np.zeros(n_nodes, dtype=np.float64)
        self._sum_life_sq = np.zeros(n_nodes, dtype=np.float64)
        self._up_since = np.full(n_nodes, float(t0), dtype=np.float64)
        self._down_count = np.zeros(n_nodes, dtype=np.int64)
        self.events_ingested = 0

    # ------------------------------------------------------------ ingestion
    @staticmethod
    def _ids(nodes: Iterable[int] | int) -> np.ndarray:
        arr = np.atleast_1d(np.asarray(nodes, dtype=np.int64))
        return arr

    def observe_failure(self, nodes: Iterable[int] | int, t: float) -> None:
        """Ingest a failure event downing ``nodes`` at time ``t``.

        Nodes transitioning up→down close one completed lifetime (time
        since their last repair); nodes already down only bump the
        overlap refcount.  O(len(nodes)) work, vectorized.
        """
        ids = self._ids(nodes)
        t = float(t)
        self.now = max(self.now, t)
        was_up = self._down_count[ids] == 0
        up_ids = ids[was_up]
        life = np.maximum(0.0, t - self._up_since[up_ids])
        self._n_failures[up_ids] += 1.0
        self._closed_exposure[up_ids] += life
        self._sum_life[up_ids] += life
        self._sum_life_sq[up_ids] += life * life
        self._down_count[ids] += 1
        self.events_ingested += 1

    def observe_repair(self, nodes: Iterable[int] | int, t: float) -> None:
        """Ingest a repair event; nodes whose overlap refcount reaches 0
        start a fresh (censored-until-failure) up interval at ``t``.  A
        spurious repair of an already-up node is a no-op (its running
        censored interval is preserved, not restarted)."""
        ids = self._ids(nodes)
        t = float(t)
        self.now = max(self.now, t)
        was_down = self._down_count[ids] > 0
        self._down_count[ids] = np.maximum(self._down_count[ids] - 1, 0)
        newly_up = ids[was_down & (self._down_count[ids] == 0)]
        self._up_since[newly_up] = t
        self.events_ingested += 1

    def observe_heartbeat(self, t: float) -> None:
        """Advance the clock from a heartbeat round — accrues censored
        exposure on every up node without touching any per-node state
        (exposure is materialized lazily at query time)."""
        self.now = max(self.now, float(t))
        self.events_ingested += 1

    def advance(self, t: float) -> None:
        """Advance the clock without counting an ingested event."""
        self.now = max(self.now, float(t))

    def rebase(self, t0: float = 0.0) -> None:
        """Shift the clock origin to ``t0`` while preserving accumulated
        statistics — used after pre-training on a generated trace whose
        time base differs from the live scenario's.  All nodes are
        treated as up at ``t0`` (a mid-outage training tail does not leak
        a down state into the live run)."""
        shift = self.now - float(t0)
        self._up_since -= shift
        self._up_since[self._down_count > 0] = float(t0)
        self._down_count[:] = 0
        self.now = float(t0)

    def ingest_events(self, events: Sequence, t_end: Optional[float] = None
                      ) -> None:
        """Replay a :meth:`FailureProcess.generate` trace (training /
        backfill path — the live path is the per-event observers)."""
        for ev in events:
            if ev.kind == "fail":
                self.observe_failure(list(ev.nodes), ev.time)
            elif ev.kind == "recover":
                self.observe_repair(list(ev.nodes), ev.time)
        if t_end is not None:
            self.advance(t_end)

    # -------------------------------------------------------------- queries
    def stats(self, now: Optional[float] = None) -> LifetimeStats:
        """Current sufficient statistics; ``exposure`` includes each up
        node's censored interval through ``now``."""
        if now is not None:
            self.advance(now)
        up = self._down_count == 0
        censored = np.where(up, np.maximum(0.0, self.now - self._up_since),
                            0.0)
        return LifetimeStats(
            n_failures=self._n_failures.copy(),
            exposure=self._closed_exposure + censored,
            sum_life=self._sum_life.copy(),
            sum_life_sq=self._sum_life_sq.copy(),
            down=~up,
        )

    def p_f_vector(self, now: Optional[float] = None,
                   duration: Optional[float] = None) -> np.ndarray:
        """Belief vector ``P(>= 1 failure within `duration`)`` per node,
        clamped to [0, 1] with the ``p_floor`` emission floor applied."""
        d = self.horizon if duration is None else float(duration)
        p = np.clip(self.model.p_f(self.stats(now), d), 0.0, 1.0)
        if self.p_floor > 0.0:
            p[p < self.p_floor] = 0.0
        return p


__all__ = ["BeliefTracker"]
