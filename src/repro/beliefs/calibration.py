"""Belief-calibration metrics vs. the failure-process ground truth.

The failure layer's :meth:`~repro.cluster.failures.FailureProcess.
expected_p_f` is the truth a learned belief is scored against.  Two
families of metrics:

* **Probability quality** — :func:`brier_score`, :func:`log_loss` score
  a belief vector against realized binary outcomes (did the node fail
  within the window?); :func:`belief_mse` / :func:`belief_mae` score it
  directly against the truth vector; :func:`reliability_diagram` bins
  predictions for a calibration plot (predicted vs. empirical
  frequency per bin).
* **Pattern quality** — because Eq. 1 consumers read only the
  ``p_f > 0`` indicator, :func:`pattern_confusion` reports
  precision/recall of the *nonzero-belief set* against the
  nonzero-truth set; this is the metric that actually predicts
  placement quality (see ``benchmarks/belief_sweep.py``).

:func:`window_outcomes` turns a generated event trace into the binary
per-window outcome matrix the scoring rules consume.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

_EPS = 1e-12


def _as_prob(p) -> np.ndarray:
    p = np.asarray(p, dtype=np.float64)
    if np.any(p < -1e-9) or np.any(p > 1.0 + 1e-9):
        raise ValueError("probabilities must lie in [0, 1]")
    return np.clip(p, 0.0, 1.0)


def brier_score(p: np.ndarray, outcomes: np.ndarray) -> float:
    """Mean squared error of ``p`` against binary ``outcomes`` —
    0 is perfect, 0.25 is the uninformed p=0.5 forecast."""
    p = _as_prob(p)
    y = np.asarray(outcomes, dtype=np.float64)
    return float(np.mean((p - y) ** 2))


def log_loss(p: np.ndarray, outcomes: np.ndarray) -> float:
    """Mean negative log-likelihood of binary ``outcomes`` under ``p``
    (probabilities clipped away from {0, 1} for finiteness)."""
    p = np.clip(_as_prob(p), _EPS, 1.0 - _EPS)
    y = np.asarray(outcomes, dtype=np.float64)
    return float(-np.mean(y * np.log(p) + (1.0 - y) * np.log(1.0 - p)))


def belief_mse(p: np.ndarray, truth: np.ndarray) -> float:
    """Mean squared belief error against the truth probability vector."""
    return float(np.mean((_as_prob(p) - _as_prob(truth)) ** 2))


def belief_mae(p: np.ndarray, truth: np.ndarray) -> float:
    """Mean absolute belief error against the truth probability vector."""
    return float(np.mean(np.abs(_as_prob(p) - _as_prob(truth))))


def reliability_diagram(p: np.ndarray, outcomes: np.ndarray,
                        n_bins: int = 10) -> Dict[str, np.ndarray]:
    """Equal-width calibration bins over [0, 1].

    Returns ``bin_mid`` (bin centers), ``mean_pred`` (mean prediction
    per bin), ``frac_pos`` (empirical failure frequency per bin) and
    ``count`` (samples per bin); empty bins carry NaN means.  A
    calibrated forecaster has ``mean_pred ≈ frac_pos`` in every
    populated bin — the expected-calibration-error summary is
    ``sum(count * |mean_pred - frac_pos|) / sum(count)``.
    """
    p = _as_prob(p).ravel()
    y = np.asarray(outcomes, dtype=np.float64).ravel()
    if p.shape != y.shape:
        raise ValueError("p and outcomes must have matching shapes")
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    idx = np.clip(np.digitize(p, edges[1:-1]), 0, n_bins - 1)
    count = np.bincount(idx, minlength=n_bins).astype(np.float64)
    sum_p = np.bincount(idx, weights=p, minlength=n_bins)
    sum_y = np.bincount(idx, weights=y, minlength=n_bins)
    with np.errstate(invalid="ignore", divide="ignore"):
        mean_pred = np.where(count > 0, sum_p / count, np.nan)
        frac_pos = np.where(count > 0, sum_y / count, np.nan)
    return {
        "bin_mid": 0.5 * (edges[:-1] + edges[1:]),
        "mean_pred": mean_pred,
        "frac_pos": frac_pos,
        "count": count,
    }


def expected_calibration_error(p: np.ndarray, outcomes: np.ndarray,
                               n_bins: int = 10) -> float:
    """Count-weighted mean |mean_pred - frac_pos| over populated bins."""
    d = reliability_diagram(p, outcomes, n_bins=n_bins)
    pop = d["count"] > 0
    gaps = np.abs(d["mean_pred"][pop] - d["frac_pos"][pop])
    total = d["count"][pop].sum()
    return float((d["count"][pop] * gaps).sum() / total) if total else 0.0


def pattern_confusion(p: np.ndarray, truth: np.ndarray
                      ) -> Dict[str, float]:
    """Precision/recall/F1 of the nonzero-belief set vs. the
    nonzero-truth set — the Eq. 1 pattern metric.  Conventions:
    precision is 1.0 when nothing is predicted positive, recall is 1.0
    when the truth has no positives."""
    pred = _as_prob(p) > 0.0
    pos = _as_prob(truth) > 0.0
    tp = float(np.sum(pred & pos))
    fp = float(np.sum(pred & ~pos))
    fn = float(np.sum(~pred & pos))
    precision = tp / (tp + fp) if (tp + fp) else 1.0
    recall = tp / (tp + fn) if (tp + fn) else 1.0
    f1 = (2 * precision * recall / (precision + recall)
          if (precision + recall) else 0.0)
    return {"precision": precision, "recall": recall, "f1": f1,
            "predicted_positive": tp + fp, "true_positive_rate": recall}


def window_outcomes(events: Sequence, n_nodes: int, horizon: float,
                    duration: float) -> np.ndarray:
    """Binary outcome matrix from a generated failure trace.

    Splits ``[0, horizon)`` into ``floor(horizon / duration)`` windows
    and marks ``out[w, i]`` True when node ``i`` has at least one
    ``fail`` event inside window ``w`` — the realized outcomes that
    :func:`brier_score` / :func:`log_loss` score a constant-horizon
    belief against.
    """
    n_windows = int(horizon // duration)
    out = np.zeros((max(n_windows, 0), n_nodes), dtype=bool)
    for ev in events:
        if ev.kind != "fail":
            continue
        w = int(ev.time // duration)
        if 0 <= w < n_windows:
            out[w, np.asarray(list(ev.nodes), dtype=np.int64)] = True
    return out


__all__ = [
    "brier_score", "log_loss", "belief_mse", "belief_mae",
    "reliability_diagram", "expected_calibration_error",
    "pattern_confusion", "window_outcomes",
]
