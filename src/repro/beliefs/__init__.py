"""Online outage-belief subsystem.

Learns per-node / per-rack hazard rates from observed failure events and
feeds calibrated ``p_f`` vectors into fault-aware placement — see
``docs/BELIEFS.md`` for the estimator catalog, the truth-vs-estimate
contract, and the belief-error sweep (``benchmarks/belief_sweep.py``).
"""
from .calibration import (belief_mae, belief_mse, brier_score,
                          expected_calibration_error, log_loss,
                          pattern_confusion, reliability_diagram,
                          window_outcomes)
from .estimators import (AdversarialBeliefs, BeliefModel, ExponentialBayes,
                         HeartbeatBeliefAdapter, LifetimeStats,
                         OracleBeliefs, RackPooledBayes, StaticPrior,
                         WeibullMoM)
from .tracker import BeliefTracker

__all__ = [
    "BeliefModel", "LifetimeStats", "ExponentialBayes", "WeibullMoM",
    "RackPooledBayes", "OracleBeliefs", "StaticPrior",
    "AdversarialBeliefs", "HeartbeatBeliefAdapter", "BeliefTracker",
    "brier_score", "log_loss", "belief_mse", "belief_mae",
    "reliability_diagram", "expected_calibration_error",
    "pattern_confusion", "window_outcomes",
]
