"""Outage-belief estimators: hazard-rate models behind one protocol.

The scheduler's fault-aware placement consumes one artifact — a per-node
outage-probability vector ``p_f`` — and the paper's headline result
(18.9-31% completion-time reduction) is only as good as that belief.
This module is the estimation side of the loop: a common
:class:`BeliefModel` protocol mapping observed per-node lifetime
statistics (:class:`LifetimeStats`, maintained incrementally by
:class:`~repro.beliefs.tracker.BeliefTracker`) to calibrated horizon
probabilities ``P(>= 1 failure within a job of the given duration)``.

Estimator catalog (see ``docs/BELIEFS.md`` for the math):

* :class:`ExponentialBayes` — conjugate Bayesian exponential-lifetime
  model: Gamma(a0, b0) prior over the per-node failure rate, posterior
  Gamma(a0 + k, b0 + T) after ``k`` observed failures over exposure
  ``T``, and the *closed-form* posterior-predictive horizon probability
  ``p_f(d) = 1 - (b / (b + d))^a`` (Lomax survival).
* :class:`WeibullMoM` — Weibull lifetime fitter by method of moments
  (shape from the coefficient of variation via a scipy-free bisection,
  scale from the mean), with shape-aware horizon probabilities
  ``1 - exp(-(d / scale)^shape)``; nodes with too few completed
  lifetimes fall back to a conjugate exponential model.
* :class:`RackPooledBayes` — hierarchical empirical-Bayes shrinkage:
  each rack's pooled Gamma posterior becomes the prior for its member
  nodes (pseudo-count ``strength``), so sparse per-node histories
  borrow statistical strength from their rack — the estimator matched
  to :class:`~repro.cluster.failures.CorrelatedOutages` /
  :class:`~repro.cluster.failures.CascadingOutages` group structure.

Reference beliefs for sweeps: :class:`OracleBeliefs` (ground truth),
:class:`StaticPrior` (uniform, uninformed) and
:class:`AdversarialBeliefs` (truth mass on the wrong nodes).
:class:`HeartbeatBeliefAdapter` wraps the legacy
:class:`~repro.cluster.heartbeat.OutageEstimator` hierarchy
(MovingAverage / EWMA) behind the same protocol, so the heartbeat
monitor and the belief tracker share one interface.

**Pattern dominance.**  Every in-tree Eq. 1 consumer reads the belief
through the ``p_f > 0`` indicator (the paper's ``1[p_f > 0]`` route
penalty), so what placement quality actually depends on is the *set* of
nodes with nonzero belief.  Learned estimators therefore must not leak
tiny positive posteriors onto healthy nodes — the tracker applies an
emission floor (``p_floor``) that clamps sub-threshold probabilities to
exactly zero.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LifetimeStats:
    """Sufficient statistics of one node population's observed lifetimes.

    Maintained O(1)-per-event by :class:`~repro.beliefs.tracker.
    BeliefTracker`; every array is shaped ``(n_nodes,)``.  ``exposure``
    includes the *censored* current up-interval (time since the last
    repair with no failure yet), while ``sum_life`` / ``sum_life_sq``
    aggregate *completed* lifetimes only — the moments a distribution
    fitter may use.
    """

    n_failures: np.ndarray      # observed failures per node
    exposure: np.ndarray        # total observed up-time, seconds (censored
                                # current interval included)
    sum_life: np.ndarray        # sum of completed lifetimes, seconds
    sum_life_sq: np.ndarray     # sum of squared completed lifetimes
    down: np.ndarray            # bool: currently in an outage

    @property
    def n_nodes(self) -> int:
        return len(self.n_failures)

    @classmethod
    def empty(cls, n_nodes: int) -> "LifetimeStats":
        z = np.zeros(n_nodes, dtype=np.float64)
        return cls(z, z.copy(), z.copy(), z.copy(),
                   np.zeros(n_nodes, dtype=bool))


class BeliefModel:
    """Protocol: observed lifetime statistics -> per-node ``p_f`` vector.

    ``p_f(stats, duration)`` returns the probability, per node, of at
    least one failure within a job window of ``duration`` simulated
    seconds.  Implementations must be pure functions of ``(stats,
    duration)`` — all mutable accounting lives in the tracker — and
    vectorized over nodes.
    """

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class ExponentialBayes(BeliefModel):
    """Conjugate Gamma-exponential hazard model.

    Prior over each node's failure rate: Gamma(``prior_events``,
    ``prior_exposure``) (shape/rate parametrization — prior mean rate
    ``prior_events / prior_exposure`` per second, weight equivalent to
    ``prior_exposure`` seconds of failure-free observation).  With ``k``
    observed failures over exposure ``T`` the posterior is
    Gamma(a, b) = Gamma(``prior_events + k``, ``prior_exposure + T``)
    and the posterior-predictive probability of surviving a window ``d``
    is ``E[exp(-lambda d)] = (b / (b + d))^a``, hence::

        p_f(d) = 1 - (b / (b + d)) ** a

    — closed form, no sampling, exact under exponential lifetimes.
    """

    prior_events: float = 0.5
    prior_exposure: float = 100.0

    def __post_init__(self):
        if self.prior_events <= 0 or self.prior_exposure <= 0:
            raise ValueError("Gamma prior needs positive shape and rate")

    def posterior(self, stats: LifetimeStats) -> tuple[np.ndarray, np.ndarray]:
        """Per-node posterior Gamma (shape ``a``, rate ``b``) arrays."""
        a = self.prior_events + stats.n_failures
        b = self.prior_exposure + stats.exposure
        return a, b

    def posterior_mean_rate(self, stats: LifetimeStats) -> np.ndarray:
        a, b = self.posterior(stats)
        return a / b

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        a, b = self.posterior(stats)
        return 1.0 - (b / (b + duration)) ** a


def _weibull_shape_from_cv2(cv2: np.ndarray, lo: float = 0.08,
                            hi: float = 25.0, iters: int = 60) -> np.ndarray:
    """Invert the Weibull squared coefficient of variation to the shape.

    ``CV^2(k) = Gamma(1 + 2/k) / Gamma(1 + 1/k)^2 - 1`` is strictly
    decreasing in the shape ``k`` (heavy-tailed shapes < 1 have CV > 1),
    so a plain bisection recovers ``k`` from sample moments without
    scipy.  Inputs outside the bracket clamp to the bracket ends.
    """
    lgamma = np.frompyfunc(math.lgamma, 1, 1)

    def cv2_of(k: np.ndarray) -> np.ndarray:
        k = np.asarray(k, dtype=np.float64)
        g2 = np.asarray(lgamma(1.0 + 2.0 / k), dtype=np.float64)
        g1 = np.asarray(lgamma(1.0 + 1.0 / k), dtype=np.float64)
        return np.exp(g2 - 2.0 * g1) - 1.0

    cv2 = np.asarray(cv2, dtype=np.float64)
    cv2 = np.clip(cv2, cv2_of(np.array(hi)), cv2_of(np.array(lo)))
    a = np.full(cv2.shape, lo)
    b = np.full(cv2.shape, hi)
    for _ in range(iters):
        mid = 0.5 * (a + b)
        too_heavy = cv2_of(mid) > cv2       # CV too big -> shape above mid
        a = np.where(too_heavy, mid, a)
        b = np.where(too_heavy, b, mid)
    return 0.5 * (a + b)


@dataclasses.dataclass
class WeibullMoM(BeliefModel):
    """Weibull lifetime fitter by method of moments.

    Per node, the completed-lifetime sample mean and variance give the
    coefficient of variation; :func:`_weibull_shape_from_cv2` inverts it
    to the shape and the mean fixes the scale
    (``scale = mean / Gamma(1 + 1/shape)``).  The horizon probability is
    the Weibull first-failure CDF ``1 - exp(-(d / scale)^shape)`` — for
    LANL-style infant-mortality lifetimes (shape < 1) this is *larger*
    at short horizons than the exponential model with the same mean,
    which is exactly the signal a fault-aware placement wants.

    Nodes with fewer than ``min_samples`` completed lifetimes (or a
    degenerate variance) fall back to ``fallback`` — censored exposure
    carries no moment information, so sparse histories are better served
    by the conjugate model.
    """

    min_samples: int = 3
    fallback: BeliefModel = dataclasses.field(default_factory=ExponentialBayes)

    def __post_init__(self):
        if self.min_samples < 2:
            raise ValueError("Weibull MoM needs min_samples >= 2 "
                             "(variance is undefined below two lifetimes)")

    def fit(self, stats: LifetimeStats
            ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-node ``(shape, scale, fitted)``; unfitted entries hold 1.0
        shape and +inf scale with ``fitted`` False."""
        k = stats.n_failures
        with np.errstate(invalid="ignore", divide="ignore"):
            mean = np.where(k > 0, stats.sum_life / np.maximum(k, 1), 0.0)
            var = np.where(k > 0,
                           stats.sum_life_sq / np.maximum(k, 1) - mean ** 2,
                           0.0)
        fitted = (k >= self.min_samples) & (mean > 0) & (var > 1e-12 * mean**2)
        cv2 = np.where(fitted, var / np.maximum(mean ** 2, 1e-300), 1.0)
        shape = np.where(fitted, _weibull_shape_from_cv2(cv2), 1.0)
        lgamma = np.frompyfunc(math.lgamma, 1, 1)
        gam = np.exp(lgamma(1.0 + 1.0 / shape).astype(np.float64))
        scale = np.where(fitted, mean / gam, np.inf)
        return shape, scale, fitted

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        shape, scale, fitted = self.fit(stats)
        with np.errstate(invalid="ignore", divide="ignore", over="ignore"):
            p = 1.0 - np.exp(-(duration / scale) ** shape)
        return np.where(fitted, p, self.fallback.p_f(stats, duration))


@dataclasses.dataclass
class RackPooledBayes(BeliefModel):
    """Hierarchical rack-pooled conjugate model.

    Two-level empirical Bayes: each rack's pooled history (summed
    failures and exposure of its members) yields a rack-level Gamma
    posterior whose mean rate becomes the *prior* mean for every member
    node, with prior weight ``strength`` pseudo-failures.  A node with a
    rich history converges to its own rate; a node with a sparse history
    is shrunk toward its rack's — the right bias when outages are
    rack-correlated (shared PDU / top-of-rack switch), and provably
    lower-MSE than per-node estimation on sparse histories (see
    ``tests/test_beliefs.py``).

    ``groups`` is the rack membership (e.g. :func:`~repro.cluster.
    failures.contiguous_racks` or ``ClusterState.groups``); nodes not
    covered by any group get the plain un-pooled posterior.
    """

    groups: Sequence[Sequence[int]]
    strength: float = 2.0
    prior_events: float = 0.5
    prior_exposure: float = 100.0

    def __post_init__(self):
        if self.strength <= 0:
            raise ValueError("strength must be > 0")
        self._gidx_cache: Optional[np.ndarray] = None

    def _group_index(self, n: int) -> np.ndarray:
        if self._gidx_cache is None or len(self._gidx_cache) != n:
            gidx = np.full(n, -1, dtype=np.int64)
            for gi, grp in enumerate(self.groups):
                gidx[np.asarray(grp, dtype=np.int64)] = gi
            self._gidx_cache = gidx
        return self._gidx_cache

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        n = stats.n_nodes
        gidx = self._group_index(n)
        n_groups = len(self.groups)
        k_g = np.zeros(n_groups)
        t_g = np.zeros(n_groups)
        grouped = gidx >= 0
        np.add.at(k_g, gidx[grouped], stats.n_failures[grouped])
        np.add.at(t_g, gidx[grouped], stats.exposure[grouped])
        # rack-level posterior mean rate under the top-level prior
        lam_g = (self.prior_events + k_g) / (self.prior_exposure + t_g)
        lam0 = self.prior_events / self.prior_exposure
        lam_prior = np.where(grouped, lam_g[np.maximum(gidx, 0)], lam0)
        # node prior Gamma(strength, strength / lam_prior): mean lam_prior,
        # weight `strength` pseudo-failures -> conjugate node posterior
        a = self.strength + stats.n_failures
        b = self.strength / lam_prior + stats.exposure
        return 1.0 - (b / (b + duration)) ** a


# ------------------------------------------------- reference / sweep models
@dataclasses.dataclass
class OracleBeliefs(BeliefModel):
    """Ground truth handed straight to the scheduler — the zero-error
    anchor of the belief sweep (the paper's 'scheduler knows p_f'
    setting)."""

    p_truth: np.ndarray

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        return np.asarray(self.p_truth, dtype=np.float64).copy()


@dataclasses.dataclass
class StaticPrior(BeliefModel):
    """An uninformed static prior: the same ``p0`` on every node.

    Because Eq. 1 consumers read the ``p_f > 0`` pattern, a uniform
    positive prior penalizes every route equally — placement degrades to
    fault-*blind* (still topology-aware) behavior.  This is the baseline
    a learned estimator must beat.
    """

    p0: float = 0.1

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        return np.full(stats.n_nodes, float(self.p0))


@dataclasses.dataclass
class AdversarialBeliefs(BeliefModel):
    """Truth mass on the wrong nodes: the ground-truth vector reversed in
    id order, so the belief steers placements *toward* the flaky zone
    and away from healthy capacity — the worst-case end of the
    belief-error axis (assumes the flaky set is not id-symmetric, which
    holds for every in-tree preset)."""

    p_truth: np.ndarray

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        return np.asarray(self.p_truth, dtype=np.float64)[::-1].copy()


class HeartbeatBeliefAdapter(BeliefModel):
    """Adapter: a legacy :class:`~repro.cluster.heartbeat.OutageEstimator`
    (MovingAverage / EWMA) + its monitor's histories, behind the
    :class:`BeliefModel` protocol.

    The legacy estimators post-process heartbeat *miss fractions* and
    return per-round probabilities with no horizon model, so ``p_f``
    ignores ``duration`` (documented horizon-blindness) and reads the
    monitor's histories instead of the tracker's lifetime statistics.
    This is the bridge that lets the monitor and the tracker share one
    interface while the legacy hierarchy is deprecated in place — see
    the note in :mod:`repro.cluster.heartbeat`.
    """

    def __init__(self, estimator, monitor):
        self.estimator = estimator
        self.monitor = monitor

    def p_f(self, stats: LifetimeStats, duration: float) -> np.ndarray:
        return np.array([self.estimator.estimate(h)
                         for h in self.monitor.history])


__all__ = [
    "LifetimeStats", "BeliefModel", "ExponentialBayes", "WeibullMoM",
    "RackPooledBayes", "OracleBeliefs", "StaticPrior", "AdversarialBeliefs",
    "HeartbeatBeliefAdapter",
]
