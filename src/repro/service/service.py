"""PlacementService — the online, event-driven placement loop.

Where the batch scheduler (:mod:`repro.cluster.scheduler`) drains a FIFO
queue when capacity changes, this service runs serving traffic: requests
arrive continuously, are admitted through SLO lanes
(:class:`~repro.service.queue.AdmissionQueue`), and are placed in batched
*drain ticks* — one
:meth:`~repro.core.engine.PlacementEngine.place_many` call per tick —
against a single versioned :class:`~repro.core.state.ClusterState` the
service owns.  Failures, recoveries and heartbeats arrive as events on
the deterministic :class:`~repro.sim.events.EventQueue` and drive
diff-style incremental re-placement (:meth:`PlacementEngine.replace`),
elastic replica resize, and SLO preemption.

**Cache discipline.**  Every view the service hands the engine is a
*busy-flavored* overlay (``overlay(..., route_faulty=False)``) of its
base state: leased nodes are excluded from selection but remain valid
routers, so the overlay's ``route_key`` — and with it the engine's
weight-matrix and memo-dict cache keys — stays the base health epoch.
Lease churn (every tick has a different busy set) therefore never
cold-starts a cache; only *health* changes (failures, recoveries,
beyond-``p_f_atol`` belief moves) mint epochs.  This is the property the
storm benchmark (:mod:`benchmarks.serve_storm`) gates at a >= 0.90 hit
rate.

**Determinism.**  One ``numpy.random.Generator`` (from ``seed``) feeds
every placement; events sort by the queue's total order; and the service
appends each placement to ``placement_log`` — two runs with equal seeds
and inputs produce identical logs bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import (PlacementEngine, PlacementPlan,
                               PlacementRequest)
from repro.core.state import ClusterState, NodeHealth
from repro.service.metrics import ServiceMetrics
from repro.service.queue import AdmissionQueue
from repro.service.requests import ServiceReply, ServiceRequest, SLOClass
from repro.sim.events import EventQueue, EventType
from repro.sim.jobsim import successful_runtime
from repro.sim.network import network_for
from repro.workloads.patterns import Workload


@dataclasses.dataclass
class _Lease:
    """One running allocation: current shape, nodes and completion state.

    ``epoch`` invalidates stale COMPLETE events: every re-placement,
    preemption or resize bumps it, and a COMPLETE carrying an older epoch
    is dropped (the event-queue lazy-invalidation protocol).  ``plan`` is
    the engine plan backing ``nodes`` — ``None`` after an elastic resize,
    when the placement is no longer a single engine plan and a failure
    triggers a full re-place of the current workload instead of the
    incremental path."""

    req: ServiceRequest
    workload: Workload
    nodes: np.ndarray
    n_replicas: int
    epoch: int = 0
    t_placed: float = 0.0
    service_time: float = 0.0
    t_complete: float = 0.0
    plan: Optional[PlacementPlan] = None


@dataclasses.dataclass
class ServiceResult:
    """What :meth:`PlacementService.run` returns."""

    replies: dict                 # req_id -> ServiceReply
    metrics: ServiceMetrics
    row: dict                     # BENCH-shaped flat summary
    placement_log: list           # (req_id, node tuple) in decision order
    makespan: float               # simulated seconds to the last event
    n_events: int
    hit_rate: float               # engine cache hit rate over the run
    wall_time_s: float


class PlacementService:
    """Long-running fault-aware placement service over one topology."""

    def __init__(self, topo, *, engine: Optional[PlacementEngine] = None,
                 policy: str = "tofa", drain_interval: float = 0.25,
                 restart_delay: float = 1.0, p_f_atol: float = 0.25,
                 seed: int = 0, net=None,
                 queue: Optional[AdmissionQueue] = None,
                 metrics: Optional[ServiceMetrics] = None):
        if drain_interval <= 0:
            raise ValueError(
                f"drain_interval must be > 0, got {drain_interval}")
        self.topo = topo
        self.engine = engine or PlacementEngine(default_policy=policy)
        self.policy = policy
        self.net = net or network_for(topo)
        self.drain_interval = drain_interval
        self.restart_delay = restart_delay
        self.p_f_atol = p_f_atol
        self.rng = np.random.default_rng(seed)
        self.queue = queue or AdmissionQueue()
        self.metrics = metrics or ServiceMetrics()
        self.events = EventQueue()
        self.state = ClusterState.healthy(topo.n_nodes)
        self.leases: dict[int, _Lease] = {}
        self.replies: dict[int, ServiceReply] = {}
        self.placement_log: list[tuple[int, tuple]] = []
        self._tick_pending = False

    # ------------------------------------------------------------ views
    def busy_nodes(self, exclude: Optional[int] = None) -> np.ndarray:
        """Node ids held by current leases (minus ``exclude``'s own)."""
        held = [l.nodes for rid, l in self.leases.items() if rid != exclude]
        if not held:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(held)

    def busy_view(self, exclude: Optional[int] = None) -> ClusterState:
        """The engine-facing state: base health with every leased node
        masked busy (``route_faulty=False`` — still a valid router, so
        the route-weight caches keep keying on the base epoch)."""
        return self.state.overlay(self.busy_nodes(exclude),
                                  route_faulty=False)

    def free_capacity(self) -> int:
        return len(self.busy_view().available_ids())

    # ----------------------------------------------------------- admission
    def submit(self, req: ServiceRequest, now: float) -> ServiceReply:
        """Admit one request into its SLO lane (or shed/reject it)."""
        reply = ServiceReply(req_id=req.req_id, slo=req.slo,
                             submit_time=now)
        self.replies[req.req_id] = reply
        self.metrics.submitted += 1
        if self.queue.push(req, now):
            reply.status = "queued"
            self._schedule_tick(now)
        elif req.deadline <= now:
            reply.status = "shed"
            self.metrics.shed += 1
        else:
            reply.status = "rejected"
            self.metrics.rejected += 1
        return reply

    def _schedule_tick(self, now: float) -> None:
        if not self._tick_pending and self.queue:
            self._tick_pending = True
            self.events.push(now + self.drain_interval, EventType.START)

    # --------------------------------------------------------- drain tick
    def tick(self, now: float) -> None:
        """Run one drain tick immediately (direct-drive entry point for
        tests and external loops; :meth:`run` schedules these as START
        events on the drain interval)."""
        self._drain(now)

    def _drain(self, now: float) -> None:
        self._tick_pending = False
        self.metrics.drain_ticks += 1
        for req in self.queue.shed_expired(now):
            self.replies[req.req_id].status = "shed"
            self.metrics.shed += 1
        self._preempt_for_pressure(now)
        batch = self.queue.drain(now, self.free_capacity())
        if batch:
            self._place_batch(batch, now)
        self.metrics.sample_queue_depth(self.queue.depth)
        self._schedule_tick(now)

    def _preempt_for_pressure(self, now: float) -> None:
        """Evict best-effort leases (newest first) while the interactive
        lane's head cannot fit in free capacity.  Victims go back to
        their lane — preemption is a requeue, not a kill."""
        head = self.queue.head(SLOClass.INTERACTIVE)
        while head is not None:
            if self.free_capacity() >= head.n_ranks:
                return
            victims = [l for l in self.leases.values()
                       if l.req.slo == SLOClass.BEST_EFFORT]
            if not victims:
                return
            victim = max(victims, key=lambda l: (l.t_placed, l.req.req_id))
            self._evict(victim, now, kind="preempted")
            head = self.queue.head(SLOClass.INTERACTIVE)

    def _evict(self, lease: _Lease, now: float, kind: str) -> None:
        """Release a lease and send its request back through admission."""
        del self.leases[lease.req.req_id]
        reply = self.replies[lease.req.req_id]
        if kind == "preempted":
            reply.preemptions += 1
            self.metrics.preempted += 1
        if self.queue.push(lease.req, now):
            reply.status = "queued"
            self.metrics.requeued += 1
        elif lease.req.deadline <= now:
            reply.status = "shed"
            self.metrics.shed += 1
        else:
            reply.status = "rejected"
            self.metrics.rejected += 1

    def _place_batch(self, batch: Sequence[ServiceRequest],
                     now: float) -> None:
        view = self.busy_view()
        requests = [PlacementRequest(comm=req.workload.comm,
                                     topology=self.topo, state=view,
                                     seed=req.req_id)
                    for req in batch]
        plans = self.engine.place_many(
            requests, policy=[req.policy or self.policy for req in batch],
            rng=self.rng, exclusive=True, route_faulty=False)
        for req, plan in zip(batch, plans):
            first = self.replies[req.req_id].placed_time < 0
            self._start_lease(req, plan.placement, now, plan=plan)
            self.metrics.placed += 1
            self.metrics.place_wall_s += plan.wall_time_s
            if first:                          # first placement only
                self.metrics.admission.observe(
                    now - self.replies[req.req_id].submit_time)

    def _start_lease(self, req: ServiceRequest, nodes: np.ndarray,
                     now: float, plan: Optional[PlacementPlan] = None,
                     workload: Optional[Workload] = None,
                     n_replicas: Optional[int] = None) -> _Lease:
        wl = workload if workload is not None else req.workload
        nodes = np.asarray(nodes, dtype=np.int64).copy()
        prev = self.leases.get(req.req_id)
        lease = _Lease(req=req, workload=wl, nodes=nodes,
                       n_replicas=(n_replicas if n_replicas is not None
                                   else req.n_replicas),
                       epoch=(prev.epoch + 1 if prev is not None else 0),
                       t_placed=now, plan=plan)
        lease.service_time = (req.hold_time if req.hold_time is not None
                              else successful_runtime(wl, nodes, self.net))
        lease.t_complete = now + lease.service_time
        self.leases[req.req_id] = lease
        self.events.push(lease.t_complete, EventType.COMPLETE,
                         req_id=req.req_id, epoch=lease.epoch)
        reply = self.replies[req.req_id]
        reply.status = "placed"
        reply.placed_time = now
        reply.nodes = nodes
        self.placement_log.append(
            (req.req_id, tuple(int(x) for x in nodes)))
        return lease

    def _reschedule(self, lease: _Lease, new_nodes: np.ndarray,
                    now: float, plan: Optional[PlacementPlan]) -> None:
        """Move a lease onto ``new_nodes`` preserving progress: remaining
        work is rescaled by the new placement's runtime ratio, plus the
        restart penalty."""
        frac = max(0.0, (lease.t_complete - now) / lease.service_time) \
            if lease.service_time > 0 else 0.0
        req = lease.req
        new_runtime = (req.hold_time if req.hold_time is not None
                       else successful_runtime(lease.workload, new_nodes,
                                               self.net))
        lease.nodes = np.asarray(new_nodes, dtype=np.int64).copy()
        lease.plan = plan
        lease.service_time = new_runtime
        lease.epoch += 1
        lease.t_complete = now + frac * new_runtime + self.restart_delay
        self.events.push(lease.t_complete, EventType.COMPLETE,
                         req_id=req.req_id, epoch=lease.epoch)
        self.replies[req.req_id].nodes = lease.nodes
        self.placement_log.append(
            (req.req_id, tuple(int(x) for x in lease.nodes)))

    # ------------------------------------------------------------ lifecycle
    def _complete(self, req_id: int, epoch: int, now: float) -> None:
        lease = self.leases.get(req_id)
        if lease is None or lease.epoch != epoch:
            return                         # superseded attempt: drop
        del self.leases[req_id]
        reply = self.replies[req_id]
        reply.status = "completed"
        reply.finish_time = now
        self.metrics.completed += 1
        self.metrics.completion.observe(now - reply.submit_time)
        self._schedule_tick(now)

    def handle_failure(self, nodes, now: float) -> list[int]:
        """Nodes went DOWN: mint the new health epoch, then walk every
        lease through :meth:`PlacementEngine.replace` — the engine's fast
        path skips untouched leases, touched ones get incremental
        re-placement on the survivors (or a requeue when the survivors
        cannot hold them).  Returns the touched req_ids."""
        nodes = np.atleast_1d(np.asarray(nodes, dtype=np.int64))
        self.metrics.failure_events += 1
        self.state = self.state.with_health(nodes, NodeHealth.DOWN)
        touched: list[int] = []
        for req_id in list(self.leases):
            lease = self.leases[req_id]
            if not np.isin(lease.nodes, nodes).any():
                if lease.plan is not None:
                    # engine fast path: diff misses this placement
                    same = self.engine.replace(lease.plan, nodes,
                                               state=self.busy_view(req_id),
                                               rng=self.rng)
                    assert same is lease.plan
                    self.metrics.replace_skipped += 1
                continue
            touched.append(req_id)
            self.replies[req_id].replacements += 1
            view = self.busy_view(exclude=req_id)
            try:
                if lease.plan is not None:
                    plan = self.engine.replace(lease.plan, nodes,
                                               state=view, rng=self.rng)
                else:
                    # resized lease: no single plan backs it — full
                    # re-place of the current workload on the survivors
                    plan = self.engine.place(
                        PlacementRequest(comm=lease.workload.comm,
                                         topology=self.topo, state=view,
                                         seed=req_id),
                        policy=lease.req.policy or self.policy,
                        rng=self.rng)
            except ValueError:
                self._evict(lease, now, kind="failed-over")
                continue
            self.metrics.replaced += 1
            self.metrics.place_wall_s += plan.wall_time_s
            self._reschedule(lease, plan.placement, now, plan)
        self._schedule_tick(now)
        return touched

    def handle_recover(self, nodes, now: float) -> None:
        """Repaired nodes return to service (capacity may unblock the
        queue, so a drain tick is scheduled)."""
        nodes = np.atleast_1d(np.asarray(nodes, dtype=np.int64))
        self.state = self.state.with_health(nodes, NodeHealth.UP)
        self._schedule_tick(now)

    def heartbeat(self, p_f: np.ndarray, now: float) -> None:
        """Refresh the outage belief.  Within-``p_f_atol`` jitter (with an
        unchanged ``p_f > 0`` pattern) reuses the current epoch — the
        engine caches stay warm across no-op heartbeat rounds."""
        self.metrics.heartbeats += 1
        self.state = self.state.with_outage(
            np.asarray(p_f, dtype=np.float64), atol=self.p_f_atol)

    # ------------------------------------------------------------- resize
    def resize(self, req_id: int, n_replicas: int, now: float) -> _Lease:
        """Elastically grow or shrink a replica-set lease.

        Growth places only the *added* replica blocks (against the busy
        view — existing nodes, including this lease's own, stay put);
        shrink frees whole trailing replica blocks.  Remaining completion
        time is rescaled to the new shape's runtime."""
        lease = self.leases.get(req_id)
        if lease is None:
            raise KeyError(f"no active lease for request {req_id}")
        spec = lease.req.replica_spec
        if spec is None:
            raise ValueError(f"request {req_id} is not a replica set")
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if n_replicas == lease.n_replicas:
            return lease
        rpr = spec.ranks_per_replica
        if n_replicas > lease.n_replicas:
            delta_wl = spec.workload(n_replicas - lease.n_replicas)
            plan = self.engine.place(
                PlacementRequest(comm=delta_wl.comm, topology=self.topo,
                                 state=self.busy_view(), seed=req_id),
                policy=lease.req.policy or self.policy, rng=self.rng)
            self.metrics.place_wall_s += plan.wall_time_s
            new_nodes = np.concatenate([lease.nodes, plan.placement])
        else:
            new_nodes = lease.nodes[:n_replicas * rpr]
        lease.workload = spec.workload(n_replicas)
        lease.n_replicas = n_replicas
        self.metrics.resized += 1
        # the merged allocation is no longer one engine plan: failures on
        # this lease now take the full re-place path
        self._reschedule(lease, new_nodes, now, plan=None)
        self._schedule_tick(now)
        return lease

    # ---------------------------------------------------------------- run
    def run(self, requests: Sequence[ServiceRequest], *,
            failures: Sequence = (), recoveries: Sequence = (),
            heartbeat_interval: Optional[float] = None,
            belief: Optional[np.ndarray] = None,
            belief_jitter: float = 0.0,
            horizon: Optional[float] = None,
            heartbeat_seed: int = 1) -> ServiceResult:
        """Drive the service to completion over a request stream.

        ``failures`` / ``recoveries`` are ``(time, node_ids)`` pairs;
        ``belief`` is the heartbeat-reported outage vector, re-published
        every ``heartbeat_interval`` with multiplicative noise of
        relative magnitude ``belief_jitter`` on its nonzero entries (the
        zero pattern is preserved, so jitter models estimator noise, not
        phantom faults).  ``horizon`` drops events past a cutoff."""
        t_wall = time.perf_counter()
        for req in requests:
            self.events.push(req.submit_time, EventType.SUBMIT, req=req)
        for t, nodes in failures:
            self.events.push(float(t), EventType.FAILURE, nodes=nodes)
        for t, nodes in recoveries:
            self.events.push(float(t), EventType.RECOVER, nodes=nodes)
        hb_rng = np.random.default_rng(heartbeat_seed)
        if heartbeat_interval is not None:
            self.events.push(heartbeat_interval, EventType.HEARTBEAT)
        makespan = 0.0
        n_events = 0
        while self.events:
            ev = self.events.pop()
            now = ev.time
            if horizon is not None and now > horizon:
                break
            n_events += 1
            makespan = now
            if ev.type == EventType.SUBMIT:
                self.submit(ev["req"], now)
            elif ev.type == EventType.START:
                self._drain(now)
            elif ev.type == EventType.COMPLETE:
                self._complete(ev["req_id"], ev["epoch"], now)
            elif ev.type == EventType.FAILURE:
                self.handle_failure(ev["nodes"], now)
            elif ev.type == EventType.RECOVER:
                self.handle_recover(ev["nodes"], now)
            elif ev.type == EventType.HEARTBEAT:
                if belief is not None:
                    p = np.asarray(belief, dtype=np.float64).copy()
                    if belief_jitter > 0.0:
                        nz = p > 0
                        noise = hb_rng.uniform(-belief_jitter,
                                               belief_jitter, nz.sum())
                        p[nz] = np.clip(p[nz] * (1.0 + noise), 1e-6, 1.0)
                    self.heartbeat(p, now)
                else:
                    self.metrics.heartbeats += 1
                # keep polling while any work remains anywhere
                if self.events or self.queue:
                    self.events.push(now + heartbeat_interval,
                                     EventType.HEARTBEAT)
        wall = time.perf_counter() - t_wall
        row = dict(self.metrics.to_row(),
                   makespan_s=makespan, n_events=n_events,
                   hit_rate=self.engine.cache_hit_rate(),
                   epoch=self.state.epoch, wall_time_s=wall)
        return ServiceResult(replies=self.replies, metrics=self.metrics,
                             row=row, placement_log=self.placement_log,
                             makespan=makespan, n_events=n_events,
                             hit_rate=self.engine.cache_hit_rate(),
                             wall_time_s=wall)


__all__ = ["PlacementService", "ServiceResult"]
