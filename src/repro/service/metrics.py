"""Service-side observability: latency histograms + lifecycle counters.

:class:`ServiceMetrics` is what the storm benchmark and the service's
``run()`` result report from.  It keeps simulated-time admission and
completion latencies in :class:`LatencyHistogram` (log-spaced buckets for
the JSON row, raw samples for exact percentiles), wall-clock placement
cost, queue-depth samples per drain tick, and counters for every
lifecycle transition (placed, shed, rejected, preempted, re-placed,
resized...).  :meth:`ServiceMetrics.to_row` flattens everything into the
flat-dict shape the ``BENCH_*.json`` trajectory files use.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


class LatencyHistogram:
    """Log-spaced latency histogram with exact percentiles.

    Buckets cover [lo, hi) multiplicatively (plus underflow/overflow
    edges) for a compact JSON export; the raw samples are also kept so
    p50/p99 are exact rather than bucket-interpolated — sample counts
    here are thousands, not billions."""

    def __init__(self, lo: float = 1e-3, hi: float = 1e4,
                 n_buckets: int = 36):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        if n_buckets < 1:
            raise ValueError(f"n_buckets must be >= 1, got {n_buckets}")
        self.edges = np.concatenate(
            ([0.0], np.geomspace(lo, hi, n_buckets + 1), [math.inf]))
        self.counts = np.zeros(len(self.edges) - 1, dtype=np.int64)
        self._samples: list[float] = []

    def observe(self, value: float) -> None:
        if value < 0:
            raise ValueError(f"latency must be >= 0, got {value}")
        self.counts[np.searchsorted(self.edges, value, side="right") - 1] += 1
        self._samples.append(float(value))

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float:
        """Exact q-th percentile of the observed samples (-1 when empty)."""
        if not self._samples:
            return -1.0
        return float(np.percentile(self._samples, q))

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def mean(self) -> float:
        return float(np.mean(self._samples)) if self._samples else -1.0

    @property
    def max(self) -> float:
        return float(np.max(self._samples)) if self._samples else -1.0

    def to_dict(self) -> dict:
        nz = np.flatnonzero(self.counts)
        return {
            "n": len(self),
            "p50": self.p50, "p99": self.p99,
            "mean": self.mean, "max": self.max,
            "buckets": {f"{self.edges[i]:.3g}": int(self.counts[i])
                        for i in nz},
        }


@dataclasses.dataclass
class ServiceMetrics:
    """Counters and latency distributions of one service run."""

    submitted: int = 0
    placed: int = 0            # placement events (re-placements excluded)
    completed: int = 0
    shed: int = 0              # deadline expired while queued
    rejected: int = 0          # bounded queue full at submit
    failed: int = 0            # survivors could not hold the job
    preempted: int = 0         # best-effort leases evicted for SLO traffic
    requeued: int = 0          # preempted/failed-over requests re-admitted
    replaced: int = 0          # leases migrated after node failure
    replace_skipped: int = 0   # failure diff missed the lease (fast path)
    resized: int = 0           # elastic replica grow/shrink operations
    drain_ticks: int = 0
    failure_events: int = 0
    heartbeats: int = 0
    place_wall_s: float = 0.0  # wall-clock spent inside engine placement
    admission: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)
    completion: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)
    queue_depths: list = dataclasses.field(default_factory=list)

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depths.append(int(depth))

    @property
    def peak_queue_depth(self) -> int:
        return max(self.queue_depths) if self.queue_depths else 0

    @property
    def mean_queue_depth(self) -> float:
        return (float(np.mean(self.queue_depths))
                if self.queue_depths else 0.0)

    def placements_per_sec(self) -> float:
        """Sustained engine throughput: placements per wall-clock second
        actually spent placing (re-placements and resizes included)."""
        n = self.placed + self.replaced + self.resized
        if self.place_wall_s <= 0:
            return 0.0
        return n / self.place_wall_s

    def to_row(self) -> dict:
        """Flatten into the BENCH-file row shape."""
        return {
            "submitted": self.submitted,
            "placed": self.placed,
            "completed": self.completed,
            "shed": self.shed,
            "rejected": self.rejected,
            "failed": self.failed,
            "preempted": self.preempted,
            "requeued": self.requeued,
            "replaced": self.replaced,
            "replace_skipped": self.replace_skipped,
            "resized": self.resized,
            "drain_ticks": self.drain_ticks,
            "failure_events": self.failure_events,
            "heartbeats": self.heartbeats,
            "place_wall_s": self.place_wall_s,
            "placements_per_sec": self.placements_per_sec(),
            "admission_p50_s": self.admission.p50,
            "admission_p99_s": self.admission.p99,
            "admission_mean_s": self.admission.mean,
            "completion_p50_s": self.completion.p50,
            "completion_p99_s": self.completion.p99,
            "peak_queue_depth": self.peak_queue_depth,
            "mean_queue_depth": self.mean_queue_depth,
        }


__all__ = ["LatencyHistogram", "ServiceMetrics"]
