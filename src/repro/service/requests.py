"""Typed requests and replies of the online placement service.

A :class:`ServiceRequest` is what a serving frontend hands the placement
service: *what* needs nodes (an inference replica set with its KV-cache
shards, or a small elastic job), *how urgently* (an :class:`SLOClass`
lane plus an absolute admission ``deadline``), and *for how long* (the
lease's ``hold_time``).  The service answers with a mutable
:class:`ServiceReply` that tracks the request through its lifecycle
(queued → placed → completed, with shed / rejected / preempted exits).

**KV-shard affinity.**  A replica request models the communication
structure of one decode engine plus ``shards_per_replica`` KV-cache
shards: the engine streams attention reads/writes to every one of its
shards each decode round (the heavy, affinity-defining edges), shards
exchange a light sequence-parallel ring, and replica engines share a
light session-sync all-reduce.  Shard traffic volume is derived from the
model's *cache schema* (:func:`repro.serve.kvcache.cache_schema`) when
the accelerator stack is importable, with a pure-arithmetic fallback
mirroring the same shape formulas on NumPy-only installs — so placement
pressure scales with the real cache footprint of the architecture being
served.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Optional

import numpy as np

from repro.core.comm_graph import CommGraph
from repro.workloads.patterns import Workload

_req_ids = itertools.count(1)


class SLOClass(enum.IntEnum):
    """Priority lane of a request; lower value drains first.

    ``INTERACTIVE`` may preempt ``BEST_EFFORT`` leases under pressure;
    ``BEST_EFFORT`` is the preemption victim pool and is never allowed to
    delay the other lanes."""

    INTERACTIVE = 0
    STANDARD = 1
    BEST_EFFORT = 2


# ---------------------------------------------------------------------------
# KV-cache shard sizing
# ---------------------------------------------------------------------------

def _schema_bytes(schema, default_itemsize: int = 2) -> float:
    """Total bytes of a ParamDef tree (bf16 default, pinned dtypes kept)."""
    total = 0.0
    for node in schema.values():
        if isinstance(node, dict):
            total += _schema_bytes(node, default_itemsize)
            continue
        size = float(np.prod(node.shape))
        if node.dtype is None:
            itemsize = default_itemsize
        else:
            itemsize = np.dtype(str(node.dtype.dtype)
                                if hasattr(node.dtype, "dtype")
                                else node.dtype).itemsize
        total += size * itemsize
    return total


def _analytic_cache_bytes(cfg, batch: int, max_seq: int,
                          itemsize: int = 2) -> float:
    """Cache footprint from config arithmetic alone (no accelerator deps).

    Mirrors the per-family shape formulas of
    :func:`repro.serve.kvcache.cache_schema` for the attention and SSM
    families; hybrid/vlm/encdec splits (which live in the JAX model
    layer) are approximated by their dominant term.  Exact agreement is
    not required — shard *traffic* only needs the right scale."""
    L, B, S = cfg.n_layers, batch, max_seq
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        if getattr(cfg, "attn_type", "gqa") == "mla" and cfg.mla:
            per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            return float(L * B * S * per_tok * itemsize)
        hd = cfg.head_dim_
        return float(2 * L * B * cfg.n_kv_heads * S * hd * itemsize)
    # ssm / hybrid: O(1) in sequence length
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    conv = L * B * (s.d_conv - 1) * conv_ch * itemsize
    state = L * B * H * s.head_dim * s.d_state * 4        # pinned float32
    return float(conv + state)


def kv_shard_bytes(cfg, batch: int, max_seq: int,
                   shards: int = 1) -> float:
    """Bytes per KV-cache shard for serving ``cfg`` at (batch, max_seq).

    Uses the exact :func:`repro.serve.kvcache.cache_schema` ParamDef tree
    when the accelerator stack imports (bf16 serving dtype, pinned
    float32 SSM states honored), falling back to the analytic formulas on
    NumPy-only installs.  The cache is assumed evenly sharded."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    try:
        from repro.serve.kvcache import cache_schema
        total = _schema_bytes(cache_schema(cfg, batch, max_seq))
    except ImportError:      # numpy-only install: jax-free approximation
        total = _analytic_cache_bytes(cfg, batch, max_seq)
    return total / shards


# ---------------------------------------------------------------------------
# request payloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Shape of one replica set — kept on the request so elastic resize
    can mint the communication graph for any replica count.

    ``shard_bytes`` is the per-shard cache footprint; per-round traffic
    is derived from it: the decode engine touches ``rw_fraction`` of
    every shard each round (attention reads dominate), shards exchange a
    tenth of that on the sequence-parallel ring, and replica engines
    all-reduce ``sync_bytes`` of session state every tenth round."""

    shards_per_replica: int
    shard_bytes: float
    rw_fraction: float = 0.05
    sync_bytes: float = 64e3
    rounds: int = 50
    flops_per_rank: float = 5e6
    arch: str = "generic"

    @property
    def ranks_per_replica(self) -> int:
        return 1 + self.shards_per_replica

    def workload(self, n_replicas: int) -> Workload:
        """Communication graph of ``n_replicas`` replicas of this shape.

        Rank layout: replica ``i`` owns the contiguous block
        ``[i * ranks_per_replica, (i+1) * ranks_per_replica)`` — engine
        rank first, then its shards — so resize can grow/shrink whole
        trailing blocks."""
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        rpr = self.ranks_per_replica
        n = n_replicas * rpr
        g = CommGraph(n)
        kv_bytes = self.rw_fraction * self.shard_bytes
        engines = []
        for i in range(n_replicas):
            eng = i * rpr
            engines.append(eng)
            shards = list(range(eng + 1, eng + rpr))
            for s in shards:
                g.add_p2p(eng, s, self.rounds * kv_bytes, self.rounds)
            # light sequence-parallel ring between a replica's shards
            for a, b in zip(shards, shards[1:] + shards[:1]):
                if a != b:
                    g.add_p2p(a, b, self.rounds * kv_bytes * 0.1,
                              self.rounds)
        if len(engines) > 1:
            g.add_all_reduce(engines, self.sync_bytes,
                             repeats=self.rounds / 10)
        return Workload(f"serve-{self.arch}x{n_replicas}", g,
                        self.flops_per_rank, self.rounds, "serve")


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    """One unit of serving work submitted to the placement service."""

    workload: Workload
    slo: SLOClass = SLOClass.STANDARD
    deadline: float = math.inf       # absolute sim seconds; admission bound
    submit_time: float = 0.0
    hold_time: Optional[float] = None    # lease length; None = model runtime
    policy: Optional[str] = None         # None = service default
    replica_spec: Optional[ReplicaSpec] = None   # resizable replica sets
    n_replicas: int = 1
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))

    def __post_init__(self):
        if self.deadline < self.submit_time:
            raise ValueError(
                f"deadline {self.deadline} precedes submit_time "
                f"{self.submit_time}")
        if self.hold_time is not None and self.hold_time <= 0:
            raise ValueError(f"hold_time must be > 0, got {self.hold_time}")

    @property
    def n_ranks(self) -> int:
        return self.workload.n_ranks

    @property
    def ranks_per_replica(self) -> int:
        if self.replica_spec is not None:
            return self.replica_spec.ranks_per_replica
        return max(1, self.n_ranks // max(1, self.n_replicas))

    def label(self) -> str:
        return f"{self.workload.name}#{self.req_id}"


def replica_request(cfg=None, *, n_replicas: int = 2,
                    shards_per_replica: int = 3,
                    batch: int = 8, max_seq: int = 4096,
                    shard_bytes: Optional[float] = None,
                    slo: SLOClass = SLOClass.INTERACTIVE,
                    deadline: float = math.inf,
                    submit_time: float = 0.0,
                    hold_time: Optional[float] = None,
                    policy: Optional[str] = None,
                    **spec_kw) -> ServiceRequest:
    """Build a resizable inference-replica request.

    ``cfg`` is a :class:`~repro.configs.base.ModelConfig` whose cache
    schema sizes the shards; pass ``shard_bytes`` directly to skip model
    configs entirely (the benchmark does)."""
    if shard_bytes is None:
        if cfg is None:
            raise ValueError("pass a ModelConfig or shard_bytes")
        shard_bytes = kv_shard_bytes(cfg, batch, max_seq,
                                     shards=shards_per_replica)
        spec_kw.setdefault("arch", getattr(cfg, "name", "model"))
    spec = ReplicaSpec(shards_per_replica=shards_per_replica,
                       shard_bytes=shard_bytes, **spec_kw)
    return ServiceRequest(workload=spec.workload(n_replicas), slo=slo,
                          deadline=deadline, submit_time=submit_time,
                          hold_time=hold_time, policy=policy,
                          replica_spec=spec, n_replicas=n_replicas)


def elastic_request(workload: Workload, *,
                    slo: SLOClass = SLOClass.BEST_EFFORT,
                    deadline: float = math.inf,
                    submit_time: float = 0.0,
                    hold_time: Optional[float] = None,
                    policy: Optional[str] = None) -> ServiceRequest:
    """Wrap a batch-style :class:`Workload` as a (default best-effort)
    service request — the small elastic jobs that ride alongside serving
    traffic and form the preemption victim pool."""
    return ServiceRequest(workload=workload, slo=slo, deadline=deadline,
                          submit_time=submit_time, hold_time=hold_time,
                          policy=policy)


# ---------------------------------------------------------------------------
# replies
# ---------------------------------------------------------------------------

#: terminal reply states (no further transitions)
TERMINAL = frozenset(("completed", "shed", "rejected", "failed"))


@dataclasses.dataclass
class ServiceReply:
    """Mutable lifecycle record the service keeps per request.

    ``status`` walks ``pending -> queued -> placed -> completed`` in the
    happy path; ``shed`` (deadline passed in queue), ``rejected`` (queue
    full), ``failed`` (survivors cannot hold the job) and transient
    ``preempted`` (victim of an SLO preemption, back in its lane) mark
    the exits.  Times are simulated seconds; ``-1`` = not reached."""

    req_id: int
    slo: SLOClass
    status: str = "pending"
    submit_time: float = 0.0
    placed_time: float = -1.0
    finish_time: float = -1.0
    preemptions: int = 0
    replacements: int = 0
    nodes: Optional[np.ndarray] = None

    @property
    def admission_latency(self) -> float:
        """Queue entry to first placement (simulated seconds; -1 if never
        placed)."""
        if self.placed_time < 0:
            return -1.0
        return self.placed_time - self.submit_time

    @property
    def completion_time(self) -> float:
        """Sojourn: submit to completion (queue wait, preemptions and
        re-placement restarts included; -1 if not completed)."""
        if self.finish_time < 0:
            return -1.0
        return self.finish_time - self.submit_time

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL


__all__ = ["SLOClass", "ReplicaSpec", "ServiceRequest", "ServiceReply",
           "replica_request", "elastic_request", "kv_shard_bytes",
           "TERMINAL"]
