"""Online fault-aware placement service for serving traffic.

The batch path (:mod:`repro.cluster.scheduler`, :mod:`repro.sim.clustersim`)
places MPI jobs once per submission; this package stands up the serving
counterpart the ROADMAP names: a long-running, event-driven service that
admits a continuous stream of placement requests — inference replicas with
their KV-cache shards, plus small elastic jobs — and places them on the
same fault-aware topology with interactive latency.

Modules:

* :mod:`~repro.service.requests` — typed :class:`ServiceRequest` /
  :class:`ServiceReply` with SLO class, deadline, replica structure, and
  KV-shard affinity derived from :mod:`repro.serve.kvcache` cache schemas.
* :mod:`~repro.service.queue` — SLO-aware admission: per-class priority
  lanes, deadline (EDF) ordering, load shedding.
* :mod:`~repro.service.service` — the event loop: one versioned
  :class:`~repro.core.state.ClusterState`, batched ``place_many`` drain
  ticks, heartbeat/failure-driven re-placement, preemption, elastic
  resize.
* :mod:`~repro.service.metrics` — latency histograms, queue depth,
  placements/sec, preemption/re-placement counters (BENCH-shaped JSON).
"""
from repro.service.metrics import LatencyHistogram, ServiceMetrics
from repro.service.queue import AdmissionQueue
from repro.service.requests import (ReplicaSpec, ServiceReply,
                                    ServiceRequest, SLOClass,
                                    elastic_request, kv_shard_bytes,
                                    replica_request)
from repro.service.service import PlacementService, ServiceResult

__all__ = [
    "SLOClass", "ServiceRequest", "ServiceReply", "ReplicaSpec",
    "replica_request", "elastic_request", "kv_shard_bytes",
    "AdmissionQueue", "ServiceMetrics", "LatencyHistogram",
    "PlacementService", "ServiceResult",
]
