"""SLO-aware admission queue of the online placement service.

One lane per :class:`~repro.service.requests.SLOClass`, drained in lane
priority order; within a lane, requests are ordered earliest-deadline
first (EDF — ties broken by arrival sequence, so runs are deterministic).
Three protections keep the queue honest under overload:

* **Load shedding on admit** — a bounded queue (``max_depth``) rejects
  new arrivals outright instead of growing without bound; a request
  whose deadline has already passed is never admitted.
* **Deadline shedding on drain** — every drain tick first drops queued
  requests whose admission deadline has expired; they leave with a
  ``shed`` reply rather than consuming placement capacity.
* **Capacity-bounded batching** — :meth:`drain` returns at most what the
  currently-free node count can hold (count-based, like the batch
  scheduler's admission step), backfilling smaller requests past a
  blocked wide head within and across lanes.  The service places the
  whole returned batch with one
  :meth:`~repro.core.engine.PlacementEngine.place_many` call.
"""
from __future__ import annotations

import bisect
import itertools
from typing import Optional

from repro.service.requests import ServiceRequest, SLOClass


class AdmissionQueue:
    """Per-SLO priority lanes with EDF order, shedding and bounded depth."""

    def __init__(self, max_depth: Optional[int] = None):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = max_depth
        # lane -> sorted list of (deadline, seq, request)
        self._lanes: dict[SLOClass, list] = {c: [] for c in SLOClass}
        self._seq = itertools.count()
        self.peak_depth = 0

    # -------------------------------------------------------------- state
    def __len__(self) -> int:
        return sum(len(v) for v in self._lanes.values())

    def __bool__(self) -> bool:
        return len(self) > 0

    @property
    def depth(self) -> int:
        return len(self)

    def depths(self) -> dict[str, int]:
        """Current queue depth per lane (keyed by SLO class name)."""
        return {c.name: len(v) for c, v in self._lanes.items()}

    def head(self, lane: SLOClass) -> Optional[ServiceRequest]:
        """The next request a drain would consider for ``lane`` (EDF)."""
        entries = self._lanes[lane]
        return entries[0][2] if entries else None

    # ------------------------------------------------------------- admit
    def push(self, req: ServiceRequest, now: float) -> bool:
        """Admit ``req``; False means *rejected* (queue full) or the
        deadline has already passed (the caller sheds it)."""
        if req.deadline <= now:
            return False
        if self.max_depth is not None and len(self) >= self.max_depth:
            return False
        entries = self._lanes[req.slo]
        bisect.insort(entries, (req.deadline, next(self._seq), req))
        self.peak_depth = max(self.peak_depth, len(self))
        return True

    def shed_expired(self, now: float) -> list[ServiceRequest]:
        """Remove and return every queued request whose deadline passed."""
        shed: list[ServiceRequest] = []
        for entries in self._lanes.values():
            keep = []
            for item in entries:
                (shed if item[0] <= now else keep).append(item)
            entries[:] = keep
        return [item[2] for item in sorted(shed)]

    # ------------------------------------------------------------- drain
    def drain(self, now: float, capacity: int,
              max_batch: Optional[int] = None) -> list[ServiceRequest]:
        """Pop the batch one drain tick should place.

        Lanes drain in priority order, EDF within a lane; a request that
        does not fit the remaining node ``capacity`` is left queued while
        later (smaller) requests may still backfill.  Expired requests
        must be collected with :meth:`shed_expired` first — drain
        assumes live deadlines."""
        batch: list[ServiceRequest] = []
        free = int(capacity)
        for lane in SLOClass:
            entries = self._lanes[lane]
            keep = []
            for item in entries:
                req = item[2]
                if free >= req.n_ranks and (
                        max_batch is None or len(batch) < max_batch):
                    batch.append(req)
                    free -= req.n_ranks
                else:
                    keep.append(item)
            entries[:] = keep
        return batch

    def remove(self, req_id: int) -> Optional[ServiceRequest]:
        """Pull one request out of its lane (cancellation)."""
        for entries in self._lanes.values():
            for i, item in enumerate(entries):
                if item[2].req_id == req_id:
                    entries.pop(i)
                    return item[2]
        return None


__all__ = ["AdmissionQueue"]
