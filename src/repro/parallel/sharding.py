"""Sharding rules: logical param/activation axes -> mesh axes.

The model schemas (``models/*.py``) tag every tensor dimension with a
logical axis name; this module maps those names onto mesh axes and builds
``NamedSharding`` trees.  One rule table covers every architecture:

  vocab / heads / kv_heads / mlp / experts / ssm_inner  -> "model"   (TP/EP)
  embed                                                 -> "data"    (FSDP)
  batch                                                 -> ("pod", "data")
  cache_seq                                             -> "model"   (decode)

A dimension is only sharded if its size divides the mesh-axis size —
otherwise it silently falls back to replication (GSPMD padding wastes real
HBM; better to replicate a 9-head dimension than pad it to 16).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamDef

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, object] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "ssm_inner": "model",
    "embed": "data",          # FSDP: weights gathered per layer inside scan
    "lora": None,
    "layers": None,
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "cache_seq": "model",
    "cache_heads": None,
    "vis_seq": None,
}

# pure-FSDP layout: no tensor parallelism — batch over every mesh axis,
# weights fully sharded on their embed dim and gathered per layer inside the
# scan.  The right configuration for archs whose head/ff dims divide the
# model axis poorly (smollm 9 heads, minicpm 40 heads, starcoder 36): TP
# would replicate their attention compute up to 16x.  §Perf layout knob.
FSDP_RULES: dict[str, object] = {
    **DEFAULT_RULES,
    "vocab": None,
    "heads": None,
    "kv_heads": None,
    "mlp": None,
    "experts": None,
    "ssm_inner": None,
    "embed": ("data", "model"),
    "batch": ("pod", "data", "model"),
    "cache_seq": None,
}

LAYOUTS = {"tp": DEFAULT_RULES, "fsdp": FSDP_RULES}


@dataclasses.dataclass
class ShardingCtx:
    """Mesh + rules + helpers. ``mesh=None`` => single-device (tests)."""

    mesh: Optional[Mesh] = None
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))
    moe_impl: str = "replicated"   # replicated | alltoall | auto
    remat: bool = True
    # logical axes allowed to shard with GSPMD padding when the dim does
    # not divide the mesh axis (e.g. 40 heads over 16 shards -> pad to 48:
    # 20% pad beats 1500% replicated compute).  §Perf knob.
    pad_shard_axes: tuple = ()
    # decode attention over a model-sharded KV cache via shard_map
    # flash-decoding (partial softmax + psum combine).  §Perf knob.
    flash_decode: bool = False

    # ------------------------------------------------------------ axis math
    def _axis_size(self, mesh_axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(mesh_axes, str):
            return self.mesh.shape[mesh_axes]
        return int(np.prod([self.mesh.shape[a] for a in mesh_axes]))

    def spec_for(self, axes: tuple, shape: tuple | None = None) -> P:
        """Logical axes tuple -> PartitionSpec (with divisibility checks)."""
        parts = []
        used: set = set()
        for i, ax in enumerate(axes):
            mesh_axes = self.rules.get(ax) if ax else None
            if mesh_axes is None:
                parts.append(None)
                continue
            flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            if self.mesh is not None:
                # drop axes absent from this mesh (e.g. "pod" on single-pod)
                flat = tuple(a for a in flat if a in self.mesh.shape)
            if not flat or any(a in used for a in flat):
                parts.append(None)  # a mesh axis may appear only once
                continue
            mesh_axes = flat[0] if len(flat) == 1 else flat
            if self.mesh is not None and shape is not None:
                sz = self._axis_size(mesh_axes)
                if shape[i] % sz != 0:
                    # padded sharding only where opted-in and dim >= axis
                    if not (ax in self.pad_shard_axes and shape[i] >= sz):
                        parts.append(None)
                        continue
            parts.append(mesh_axes)
            used.update(flat)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding_for(self, axes: tuple, shape: tuple | None = None):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(axes, shape))

    # ------------------------------------------------------------ trees
    def param_shardings(self, schema):
        """Schema tree -> NamedSharding tree (or None tree w/o mesh)."""
        return jax.tree.map(
            lambda d: self.sharding_for(d.axes, d.shape),
            schema, is_leaf=lambda x: isinstance(x, ParamDef))

    def param_specs(self, schema):
        return jax.tree.map(
            lambda d: self.spec_for(d.axes, d.shape),
            schema, is_leaf=lambda x: isinstance(x, ParamDef))

    # ------------------------------------------------------------ act utils
    def constrain(self, x, *axes):
        """with_sharding_constraint on activations (no-op without mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec_for(axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    @property
    def model_axis_size(self) -> int:
        if self.mesh is None or "model" not in self.mesh.shape:
            return 1
        return self.mesh.shape["model"]

    def batch_axes(self) -> tuple:
        """Mesh axes that shard the batch dim."""
        r = self.rules.get("batch")
        if r is None or self.mesh is None:
            return ()
        flat = (r,) if isinstance(r, str) else tuple(r)
        return tuple(a for a in flat if a in self.mesh.shape)
