"""Single-token decode step for every architecture family.

``decode_step(cfg, params, caches, tokens, pos, ...)`` consumes a (B, 1)
token batch plus the cache tree and returns (logits (B,1,V), new caches).
Layer stacks are scanned with the caches as scan inputs/outputs, so the
compiled decode HLO is O(1) in depth like the forward pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.model import (NULL_CTX, _attn_apply, _ffn_apply,
                                _hybrid_split, _mamba_layer, _rope,
                                _vlm_split)
from repro.models import ssm as ssm_mod
from repro.parallel.sharding import ShardingCtx


def _cache_tuple(c: dict):
    if "ckv" in c:
        return c["ckv"]
    return (c["k"], c["v"])


def _retuple(c, new):
    if "ckv" in c:
        return {"ckv": new}
    return {"k": new[0], "v": new[1]}


def _dense_decode_scan(cfg, params, caches, h, cos, sin, pos, ctx):
    def body(carry, xs):
        p, c = xs
        cache = _cache_tuple(c)
        a, new = _attn_apply(p, rmsnorm(carry, p["ln1"]), cfg, cos, sin, ctx,
                             cache=cache, pos=pos)
        carry = carry + a
        carry = carry + _ffn_apply(p, rmsnorm(carry, p["ln2"]), cfg, ctx)
        return carry, _retuple(c, new)

    return jax.lax.scan(body, h, (params, caches))


def _mamba_decode_scan(cfg, params, caches, h):
    def body(carry, xs):
        p, c = xs
        out, (conv, state) = _mamba_layer(p, carry, cfg,
                                          conv_state=c["conv"],
                                          ssm_state=c["state"])
        return out, {"conv": conv, "state": state}

    return jax.lax.scan(body, h, (params, caches))


def decode_step(cfg: ModelConfig, params, caches, tokens, pos,
                ctx: ShardingCtx = NULL_CTX, extras: dict | None = None):
    """One token for the whole batch.  ``pos``: scalar int32 write position.

    ``extras``: family-specific frozen inputs (encdec: none needed once the
    cross cache is built; vlm: none — vision K/V live in the cache)."""
    B, S1 = tokens.shape
    h = jnp.take(params["tok_emb"], tokens, axis=0)
    h = ctx.constrain(h, "batch", None, "act_embed")
    # rope table for max cache length, sliced at pos
    fam = cfg.family
    max_seq = _max_cache_len(caches, cfg)
    cos_full, sin_full = _rope(cfg, max_seq)
    cos = jax.lax.dynamic_slice_in_dim(cos_full, pos, S1, axis=0)
    sin = jax.lax.dynamic_slice_in_dim(sin_full, pos, S1, axis=0)

    new_caches = dict(caches)
    if fam in ("dense", "moe"):
        if "dense0" in params:
            h, nc0 = _dense_decode_scan(cfg, params["dense0"],
                                        caches["dense0"], h, cos, sin, pos,
                                        ctx)
            new_caches["dense0"] = nc0
        h, nc = _dense_decode_scan(cfg, params["blocks"], caches["blocks"],
                                   h, cos, sin, pos, ctx)
        new_caches["blocks"] = nc

    elif fam == "ssm":
        h, nc = _mamba_decode_scan(cfg, params["blocks"], caches["blocks"], h)
        new_caches["blocks"] = nc

    elif fam == "hybrid":
        G, k, trail = _hybrid_split(cfg)
        mparams = jax.tree.map(
            lambda a: a.reshape((G, k) + a.shape[1:]), params["blocks"])
        mcaches = jax.tree.map(
            lambda a: a.reshape((G, k) + a.shape[1:]), caches["blocks"])

        def group_body(carry, xs):
            pg, cg, csh = xs

            def inner(c2, xs2):
                p, c = xs2
                out, (conv, state) = _mamba_layer(
                    p, c2, cfg, conv_state=c["conv"], ssm_state=c["state"])
                return out, {"conv": conv, "state": state}
            c2, ncm = jax.lax.scan(inner, carry, (pg, cg))
            p1 = jax.tree.map(lambda a: a[0], params["shared"])
            cache = _cache_tuple(csh)
            a, new = _attn_apply(p1, rmsnorm(c2, p1["ln1"]), cfg, cos, sin,
                                 ctx, cache=cache, pos=pos)
            c2 = c2 + a
            c2 = c2 + _ffn_apply(p1, rmsnorm(c2, p1["ln2"]), cfg, ctx)
            return c2, (ncm, _retuple(csh, new))

        h, (ncm, ncs) = jax.lax.scan(group_body, h,
                                     (mparams, mcaches, caches["shared"]))
        new_caches["blocks"] = jax.tree.map(
            lambda a: a.reshape((G * k,) + a.shape[2:]), ncm)
        new_caches["shared"] = ncs
        if trail:
            h, nct = _mamba_decode_scan(cfg, params["trailing"],
                                        caches["trailing"], h)
            new_caches["trailing"] = nct

    elif fam == "vlm":
        G, k = _vlm_split(cfg)
        bparams = jax.tree.map(
            lambda a: a.reshape((G, k) + a.shape[1:]), params["blocks"])
        bcaches = jax.tree.map(
            lambda a: a.reshape((G, k) + a.shape[1:]), caches["blocks"])

        def group_body(carry, xs):
            pg, cg, pc, cc = xs

            def inner(c2, xs2):
                p, c = xs2
                cache = _cache_tuple(c)
                a, new = _attn_apply(p, rmsnorm(c2, p["ln1"]), cfg, cos, sin,
                                     ctx, cache=cache, pos=pos)
                c2 = c2 + a
                c2 = c2 + _ffn_apply(p, rmsnorm(c2, p["ln2"]), cfg, ctx)
                return c2, _retuple(c, new)
            c2, ncb = jax.lax.scan(inner, carry, (pg, cg))
            # cross-attention reads the frozen vision K/V cache
            a, _ = _cross_from_cache(pc, rmsnorm(c2, pc["ln1"]), cc, cfg)
            c2 = c2 + a
            c2 = c2 + _ffn_apply(pc, rmsnorm(c2, pc["ln2"]), cfg, ctx)
            return c2, ncb

        h, ncb = jax.lax.scan(group_body, h,
                              (bparams, bcaches, params["cross"],
                               caches["cross"]))
        new_caches["blocks"] = jax.tree.map(
            lambda a: a.reshape((G * k,) + a.shape[2:]), ncb)

    elif fam == "encdec":
        def body(carry, xs):
            p, cs, cc = xs
            cache = _cache_tuple(cs)
            a, new = _attn_apply(p["self"], rmsnorm(carry, p["ln1"]), cfg,
                                 cos, sin, ctx, cache=cache, pos=pos)
            carry = carry + a
            a, _ = _cross_from_cache(p["cross"], rmsnorm(carry, p["ln2"]),
                                     cc, cfg)
            carry = carry + a
            carry = carry + _ffn_apply(p, rmsnorm(carry, p["ln3"]), cfg, ctx)
            return carry, _retuple(cs, new)

        h, ncs = jax.lax.scan(body, h,
                              (params["decoder"], caches["self"],
                               caches["cross"]))
        new_caches["self"] = ncs
    else:
        raise ValueError(fam)

    h = rmsnorm(h, params["final_norm"])
    unembed = params["tok_emb"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", h, unembed)
    logits = ctx.constrain(logits, "batch", None, "vocab")
    return logits, new_caches


def _cross_from_cache(p, xq, kv_cache: dict, cfg: ModelConfig):
    """Cross-attention against a frozen K/V cache (no rope, no causal).
    ``xq`` must already be normalised by the caller's cross-attn norm."""
    import math
    q = jnp.einsum("bsd,dhk->bhsk", xq, p["wq"])
    k, v = kv_cache["k"], kv_cache["v"]
    groups = q.shape[1] // k.shape[1]
    if groups > 1:
        k = jnp.repeat(k, groups, axis=1)
        v = jnp.repeat(v, groups, axis=1)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhsk,bhtk->bhst", q, k).astype(jnp.float32) * scale
    o = jnp.einsum("bhst,bhtk->bhsk",
                   jax.nn.softmax(s, axis=-1).astype(v.dtype), v)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"]), None


def _max_cache_len(caches, cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe"):
        c = caches["blocks"]
        return c["ckv"].shape[2] if "ckv" in c else c["k"].shape[3]
    if cfg.family == "hybrid":
        c = caches["shared"]
        return c["ckv"].shape[2] if "ckv" in c else c["k"].shape[3]
    if cfg.family == "vlm":
        c = caches["blocks"]
        return c["k"].shape[3] if "k" in c else c["ckv"].shape[2]
    if cfg.family == "encdec":
        c = caches["self"]
        return c["ckv"].shape[2] if "ckv" in c else c["k"].shape[3]
    return 1  # ssm: position-free


def encode(cfg: ModelConfig, params, enc_embed: jax.Array,
           ctx: ShardingCtx = NULL_CTX) -> jax.Array:
    """Run the encoder stack (encdec family) over frame embeddings."""
    from repro.models.layers import mlp
    from repro.models.model import _rope as rope_fn
    cos_e, sin_e = rope_fn(cfg, enc_embed.shape[1])

    def enc_body(carry, p):
        a, _ = _attn_apply(p, rmsnorm(carry, p["ln1"]), cfg, cos_e, sin_e,
                           ctx, causal=False)
        c = carry + a
        c = c + mlp(p, rmsnorm(c, p["ln2"]), cfg.act)
        return c, None

    enc, _ = jax.lax.scan(enc_body, enc_embed, params["encoder"])
    return rmsnorm(enc, params["enc_norm"])


def prefill_cross_cache(cfg: ModelConfig, params, src: jax.Array,
                        which: str = "cross"):
    """Build the frozen cross-attention K/V cache from source embeddings
    (encoder output or vision patches): (L_or_G, B, Hkv, S_src, Dh)."""
    p = params["cross"] if which == "cross" and "cross" in params \
        else params["decoder"]["cross"]
    k = jnp.einsum("bsd,ldhk->lbhsk", src, p["wk"])
    v = jnp.einsum("bsd,ldhk->lbhsk", src, p["wv"])
    return {"k": k, "v": v}
