"""Decode caches for every architecture family.

Cache schemas are ParamDef trees (same machinery as weights) so the
dry-run can hand ShapeDtypeStructs to ``decode_step`` and the sharding
rules apply uniformly:

  GQA      k/v       (L, B, Hkv, S_max, Dh)    cache_seq -> model
  MLA      latent    (L, B, S_max, lora+rope)  cache_seq -> model
  SSM      conv      (L, B, d_conv-1, C) ; state (L, B, H, P, N)
  hybrid   mamba caches + shared-block KV per application (G, B, ...)
  encdec   decoder self KV + frozen cross K/V over the source
  vlm      self KV + frozen cross K/V over the vision tokens

The SSM/hybrid caches are O(1) in sequence length — that is why only these
families run the long_500k cell (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef
from repro.models.model import _hybrid_split, _vlm_split


def _gqa_kv(cfg: ModelConfig, L: int, B: int, S: int) -> dict:
    hd = cfg.head_dim_
    shp = (L, B, cfg.n_kv_heads, S, hd)
    axes = ("layers", "batch", "cache_heads", "cache_seq", None)
    return {"k": ParamDef(shp, axes, init="zeros"),
            "v": ParamDef(shp, axes, init="zeros")}


def _mla_latent(cfg: ModelConfig, L: int, B: int, S: int) -> dict:
    m = cfg.mla
    shp = (L, B, S, m.kv_lora_rank + m.qk_rope_head_dim)
    return {"ckv": ParamDef(shp, ("layers", "batch", "cache_seq", None),
                            init="zeros")}


def _attn_cache(cfg: ModelConfig, L: int, B: int, S: int) -> dict:
    if cfg.attn_type == "mla":
        return _mla_latent(cfg, L, B, S)
    return _gqa_kv(cfg, L, B, S)


def _ssm_cache(cfg: ModelConfig, L: int, B: int) -> dict:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return {
        "conv": ParamDef((L, B, s.d_conv - 1, conv_ch),
                         ("layers", "batch", None, "ssm_inner"),
                         init="zeros"),
        "state": ParamDef((L, B, H, s.head_dim, s.d_state),
                          ("layers", "batch", "heads", None, None),
                          init="zeros", dtype=jnp.float32),
    }


def cache_schema(cfg: ModelConfig, batch: int, max_seq: int,
                 src_len: int | None = None) -> dict:
    fam = cfg.family
    if fam in ("dense", "moe"):
        sch = {}
        if fam == "moe" and cfg.moe and cfg.moe.first_dense:
            sch["dense0"] = _attn_cache(cfg, cfg.moe.first_dense, batch, max_seq)
            sch["blocks"] = _attn_cache(cfg, cfg.n_layers - cfg.moe.first_dense,
                                        batch, max_seq)
        else:
            sch["blocks"] = _attn_cache(cfg, cfg.n_layers, batch, max_seq)
        return sch
    if fam == "ssm":
        return {"blocks": _ssm_cache(cfg, cfg.n_layers, batch)}
    if fam == "hybrid":
        G, k, trail = _hybrid_split(cfg)
        sch = {"blocks": _ssm_cache(cfg, G * k, batch),
               "shared": _attn_cache(cfg, G, batch, max_seq)}
        if trail:
            sch["trailing"] = _ssm_cache(cfg, trail, batch)
        return sch
    if fam == "vlm":
        G, k = _vlm_split(cfg)
        return {"blocks": _attn_cache(cfg, G * k, batch, max_seq),
                "cross": _gqa_kv(cfg, G, batch, cfg.n_vision_tokens)}
    if fam == "encdec":
        L = cfg.n_layers
        return {"self": _attn_cache(cfg, L, batch, max_seq),
                "cross": _gqa_kv(cfg, L, batch, src_len or max_seq)}
    raise ValueError(fam)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.float32, src_len: int | None = None):
    sch = cache_schema(cfg, batch, max_seq, src_len=src_len)
    return jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype or dtype), sch,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16, src_len: int | None = None):
    sch = cache_schema(cfg, batch, max_seq, src_len=src_len)
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype), sch,
        is_leaf=lambda x: isinstance(x, ParamDef))
