#!/usr/bin/env python3
"""Fail on broken intra-repo markdown links and heading anchors.

    python tools/check_links.py README.md API.md docs

Scans the given markdown files (directories are walked for ``*.md``) for
``[text](target)`` links, resolves relative targets against the linking
file, and exits 1 listing every target that does not exist.  External
(``http(s)://``, ``mailto:``) targets are skipped.

Anchor coverage: a ``path#anchor`` target is checked against the
headings of the *target* file and a pure ``#anchor`` target against the
headings of the *linking* file, using GitHub's slug rules (lowercase,
punctuation stripped, spaces to dashes, ``-1``/``-2`` suffixes for
duplicates) — so section links in API.md/docs stay valid as the
documents are refactored.
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target must not contain spaces or a closing paren;
# images (![alt](...)) are matched too via the optional leading !
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(text: str) -> str:
    """GitHub's heading -> anchor slug: strip markdown emphasis/code and
    punctuation, lowercase, spaces to dashes."""
    # backticks/asterisks are markup; literal underscores survive in
    # GitHub slugs (it slugs the *rendered* text)
    text = re.sub(r"[`*]", "", text)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)   # [text](url)
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_anchors(md: pathlib.Path) -> set[str]:
    """All anchor slugs a file's headings define (with -N dedup suffixes)."""
    seen: dict[str, int] = {}
    anchors: set[str] = set()
    in_fence = False
    for line in md.read_text(encoding="utf-8").splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def check_file(md: pathlib.Path,
               anchor_cache: dict[pathlib.Path, set[str]]) -> list[str]:
    def anchors_of(path: pathlib.Path) -> set[str]:
        path = path.resolve()
        if path not in anchor_cache:
            anchor_cache[path] = heading_anchors(path)
        return anchor_cache[path]

    broken = []
    for m in LINK_RE.finditer(md.read_text(encoding="utf-8")):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        dest = md if not path else md.parent / path
        if path and not dest.exists():
            broken.append(f"{md}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md" and dest.is_file():
            if anchor.lower() not in anchors_of(dest):
                broken.append(f"{md}: broken anchor -> {target}")
    return broken


def iter_md_files(args: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            out += sorted(p.rglob("*.md"))
        else:
            out.append(p)
    return out


def main(argv: list[str]) -> int:
    files = iter_md_files(argv or ["README.md", "API.md", "docs"])
    missing = [str(f) for f in files if not f.exists()]
    anchor_cache: dict[pathlib.Path, set[str]] = {}
    broken = [b for f in files if f.exists()
              for b in check_file(f, anchor_cache)]
    for b in missing:
        print(f"missing input file: {b}")
    for b in broken:
        print(b)
    if broken or missing:
        print(f"{len(broken) + len(missing)} broken link(s)")
        return 1
    print(f"ok: {len(files)} file(s), all intra-repo links and anchors "
          f"resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
