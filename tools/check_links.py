#!/usr/bin/env python3
"""Fail on broken intra-repo markdown links.

    python tools/check_links.py README.md API.md docs

Scans the given markdown files (directories are walked for ``*.md``) for
``[text](target)`` links, resolves relative targets against the linking
file, and exits 1 listing every target that does not exist.  External
(``http(s)://``, ``mailto:``) and pure-anchor (``#...``) targets are
skipped; a ``path#anchor`` target is checked for the path part only.
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target must not contain spaces or a closing paren;
# images (![alt](...)) are matched too via the optional leading !
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def iter_md_files(args: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            out += sorted(p.rglob("*.md"))
        else:
            out.append(p)
    return out


def check_file(md: pathlib.Path) -> list[str]:
    broken = []
    for m in LINK_RE.finditer(md.read_text(encoding="utf-8")):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            broken.append(f"{md}: broken link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    files = iter_md_files(argv or ["README.md", "API.md", "docs"])
    missing = [str(f) for f in files if not f.exists()]
    broken = [b for f in files if f.exists() for b in check_file(f)]
    for b in missing:
        print(f"missing input file: {b}")
    for b in broken:
        print(b)
    if broken or missing:
        print(f"{len(broken) + len(missing)} broken link(s)")
        return 1
    print(f"ok: {len(files)} file(s), all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
