"""Peak-RSS measurement helper (stdlib-only, no psutil).

``resource.getrusage(RUSAGE_SELF).ru_maxrss`` is the process's high-water
resident set — a monotonic counter, so a meaningful per-measurement value
requires a fresh process.  Benchmarks that want peak-RSS per row therefore
run each row in a subprocess (see ``benchmarks/mapping_scale.py
--implicit-case``) and read this helper at child exit.
"""
from __future__ import annotations

import resource
import sys


def peak_rss_bytes() -> int:
    """Peak resident set size of the current process, in bytes.

    Linux reports ``ru_maxrss`` in KiB, macOS in bytes (the only two
    platforms the benchmarks target).
    """
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        return int(ru)
    return int(ru * 1024)


if __name__ == "__main__":
    print(peak_rss_bytes())
