import numpy as np
import pytest

from repro.cluster.failures import BernoulliPerJob, NoFailures
from repro.core.comm_graph import CommGraph
from repro.core.topology import TorusTopology
from repro.sim.batchsim import run_batch, run_scenario
from repro.sim.jobsim import simulate_instance, successful_runtime
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import Workload, lammps_like, npb_dt_like


@pytest.fixture(scope="module")
def net():
    return TorusNetwork(TorusTopology((4, 4, 4)))


def _tiny_wl(n=4, nbytes=1e6):
    g = CommGraph(n)
    for i in range(n - 1):
        g.add_p2p(i, i + 1, nbytes, 10)
    return Workload("tiny", g, flops_per_rank=6e9, rounds=1, pattern="chain")


def test_comm_time_adjacent_vs_far(net):
    wl = _tiny_wl()
    near = np.array([0, 1, 2, 3])          # chain along a torus row
    far = np.array([0, 21, 42, 63])        # spread across the machine
    assert net.comm_time(wl.comm, near) < net.comm_time(wl.comm, far)


def test_comm_time_bandwidth_term(net):
    # one pair, adjacent: serialization = bytes / bw (plus tiny latency)
    g = CommGraph(2)
    g.add_p2p(0, 1, 1.25e9, 1)  # 1 second at 10 Gbps
    wl = Workload("pair", g, 0.0, 1, "p2p")
    t = net.comm_time(wl.comm, np.array([0, 1]))
    # symmetric convention routes half the bytes each direction
    assert t == pytest.approx(0.5, rel=0.05)


def test_compute_time(net):
    assert net.compute_time(6e9, 2) == pytest.approx(2.0)


def test_failed_node_on_route_aborts(net):
    wl = _tiny_wl(2)
    # place on 0 and 2: dimension-ordered route passes node 1
    placement = np.array([0, 2])
    out_ok = simulate_instance(wl, placement, net, np.array([], dtype=int))
    assert out_ok.completed
    out_mid = simulate_instance(wl, placement, net, np.array([1]))
    assert not out_mid.completed, "failed intermediate hop must abort the job"
    out_end = simulate_instance(wl, placement, net, np.array([2]))
    assert not out_end.completed, "failed endpoint must abort the job"
    out_far = simulate_instance(wl, placement, net, np.array([63]))
    assert out_far.completed, "unrelated failed node must not abort"


def test_batch_no_failures_time_is_linear(net):
    wl = _tiny_wl()
    r = run_batch(wl, "linear", net, NoFailures(), None, n_instances=10)
    assert r.abort_ratio == 0.0
    assert r.completion_time == pytest.approx(10 * r.success_runtime)


def test_batch_with_failures_charges_restarts(net):
    wl = _tiny_wl()
    fm = BernoulliPerJob(np.arange(16), 0.3)   # aggressive failure rate
    r = run_batch(wl, "linear", net, fm, None, n_instances=50,
                  rng=np.random.default_rng(0))
    assert r.n_aborted_attempts > 0
    assert r.completion_time == pytest.approx(
        (50 + r.n_aborted_attempts) * r.success_runtime)
    assert r.abort_ratio > 0


def test_checkpointing_reduces_abort_cost(net):
    wl = _tiny_wl()
    fm = BernoulliPerJob(np.arange(16), 0.3)
    kw = dict(n_instances=50, rng=np.random.default_rng(0))
    base = run_batch(wl, "linear", net, fm, None, **kw)
    ck = run_batch(wl, "linear", net, fm, None,
                   checkpoint_interval=base.success_runtime / 10,
                   checkpoint_overhead=base.success_runtime / 200,
                   rng=np.random.default_rng(0), n_instances=50)
    assert ck.completion_time < base.completion_time


def test_tofa_beats_linear_under_failures():
    """Mini Fig. 4: TOFA must cut batch completion time vs default-slurm."""
    res = run_scenario(
        lambda: npb_dt_like(24), ("linear", "tofa"), dims=(4, 4, 4),
        n_batches=3, n_instances=40, n_faulty=8, p_f=0.05, seed=1)
    assert res["tofa"].mean_completion < res["linear"].mean_completion
    assert res["tofa"].mean_abort_ratio <= res["linear"].mean_abort_ratio


def test_scenario_paired_candidates():
    """All policies inside a batch face the same N_f (paired comparison)."""
    res = run_scenario(
        lambda: lammps_like(16), ("linear", "random"), dims=(4, 4),
        n_batches=2, n_instances=5, n_faulty=2, p_f=0.5, seed=3)
    assert set(res) == {"linear", "random"}
    assert len(res["linear"].batches) == 2
