import numpy as np
import pytest

from repro.cluster.failures import (CompositeProcess, CorrelatedOutages,
                                    ExponentialLifetimes, WeibullLifetimes,
                                    contiguous_racks)
from repro.sim.events import Event, EventQueue, EventType


def test_pop_orders_by_time():
    q = EventQueue()
    q.push(3.0, EventType.SUBMIT, tag="c")
    q.push(1.0, EventType.SUBMIT, tag="a")
    q.push(2.0, EventType.SUBMIT, tag="b")
    assert [e["tag"] for e in q.drain()] == ["a", "b", "c"]


def test_equal_timestamp_type_priority():
    """At one instant: COMPLETE < FAILURE < RECOVER < HEARTBEAT <
    CHECKPOINT < SUBMIT < START, regardless of push order."""
    q = EventQueue()
    order = [EventType.START, EventType.SUBMIT, EventType.CHECKPOINT,
             EventType.HEARTBEAT, EventType.RECOVER, EventType.FAILURE,
             EventType.COMPLETE]
    for t in order:                       # pushed in reverse priority
        q.push(5.0, t)
    popped = [e.type for e in q.drain()]
    assert popped == sorted(order, key=int)
    assert popped[0] == EventType.COMPLETE and popped[-1] == EventType.START


def test_equal_time_and_type_pops_in_insertion_order():
    q = EventQueue()
    for i in range(10):
        q.push(1.0, EventType.FAILURE, i=i)
    assert [e["i"] for e in q.drain()] == list(range(10))


def test_deterministic_across_runs():
    def stream(seed):
        rng = np.random.default_rng(seed)
        q = EventQueue()
        for _ in range(200):
            q.push(float(rng.integers(0, 5)),
                   EventType(int(rng.integers(0, 7))))
        return [(e.time, e.type, e.seq) for e in q.drain()]
    assert stream(7) == stream(7)


def test_no_time_travel():
    q = EventQueue()
    q.push(2.0, EventType.SUBMIT)
    q.pop()
    with pytest.raises(ValueError):
        q.push(1.0, EventType.SUBMIT)
    q.push(2.0, EventType.SUBMIT)          # same instant is fine


def test_peek_and_counters():
    q = EventQueue()
    assert q.peek() is None
    q.push(1.0, EventType.HEARTBEAT)
    assert q.peek().type == EventType.HEARTBEAT
    assert (q.pushed, q.popped) == (1, 0)
    q.pop()
    assert (q.pushed, q.popped) == (1, 1)
    assert q.now == 1.0


# ------------------------------------------------------- failure processes
def test_exponential_lifetimes_alternate_and_sort():
    proc = ExponentialLifetimes(np.arange(4), mtbf=10.0, mttr=2.0)
    ev = proc.generate(np.random.default_rng(0), horizon=200.0)
    times = [e.time for e in ev]
    assert times == sorted(times)
    for node in range(4):
        kinds = [e.kind for e in ev if e.nodes == (node,)]
        # strict alternation starting with a failure
        assert all(k == ("fail" if i % 2 == 0 else "repair")
                   for i, k in enumerate(kinds))


def test_exponential_permanent_failures_without_repair():
    proc = ExponentialLifetimes(np.arange(8), mtbf=5.0, mttr=None)
    ev = proc.generate(np.random.default_rng(1), horizon=1000.0)
    assert all(e.kind == "fail" for e in ev)
    assert len(ev) <= 8                      # at most one death per node


def test_weibull_mean_matches_mtbf():
    proc = WeibullLifetimes(np.arange(300), mtbf=50.0, shape=0.7, mttr=None)
    ev = proc.generate(np.random.default_rng(2), horizon=1e6)
    first = [e.time for e in ev]
    assert np.mean(first) == pytest.approx(50.0, rel=0.15)


def test_correlated_outages_take_whole_group():
    racks = contiguous_racks(64, 16)
    proc = CorrelatedOutages(racks[:2], mtbf=10.0, mttr=1.0)
    ev = proc.generate(np.random.default_rng(3), horizon=500.0)
    assert ev, "expected at least one outage in 50 MTBFs"
    assert all(len(e.nodes) == 16 for e in ev)
    frac = proc.expected_p_f(64)
    assert frac[:32].min() > 0 and frac[32:].sum() == 0


def test_composite_merges_sorted():
    a = ExponentialLifetimes(np.arange(4), mtbf=7.0, mttr=1.0)
    b = CorrelatedOutages([np.arange(4, 8)], mtbf=9.0, mttr=1.0)
    ev = CompositeProcess([a, b]).generate(np.random.default_rng(4), 300.0)
    times = [e.time for e in ev]
    assert times == sorted(times)
    assert {e.nodes for e in ev if len(e.nodes) == 4}


def test_contiguous_racks_partition():
    racks = contiguous_racks(10, 4)
    assert [len(r) for r in racks] == [4, 4, 2]
    assert np.concatenate(racks).tolist() == list(range(10))
    with pytest.raises(ValueError):
        contiguous_racks(10, 0)
