"""HLO profiler tests: synthetic HLO snippets + a real compiled program."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.profiler import (CollectiveOp, comm_graph_from_hlo,
                                 parse_replica_groups, profile_hlo)

SYNTH = """\
HloModule test, num_partitions=8

%cond (arg: (s32[], f32[4,4])) -> pred[] {
  %arg = (s32[], f32[4,4]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %k = s32[] constant(12)
  ROOT %lt = pred[] compare(%iv, %k), direction=LT
}

%body (arg: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %arg = (s32[], f32[4,4]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %one = s32[] constant(1)
  %ivn = s32[] add(%iv, %one)
  %x = f32[4,4] get-tuple-element(%arg), index=1
  %d = f32[4,4] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,4] all-reduce(%d), replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  ROOT %t = (s32[], f32[4,4]) tuple(%ivn, %ar)
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[4,4]) -> (s32[], f32[4,4]) {
  %p0 = f32[4,4] parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[4,4]) tuple(%c0, %p0)
  ROOT %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body
}
"""


def test_synthetic_while_loop_flops_and_collectives():
    prof = profile_hlo(SYNTH)
    assert prof.num_partitions == 8
    # dot: 2*4*4*4 = 128 flops, x12 trips = 1536
    assert prof.flops == pytest.approx(128 * 12)
    assert len(prof.collectives) == 1
    c = prof.collectives[0]
    assert c.kind == "all-reduce"
    assert c.multiplier == 12
    assert c.group_size == 4
    assert c.operand_bytes == 4 * 4 * 4
    # ring all-reduce: 2*(4-1)/4*64 = 96 bytes/device/trip
    assert prof.collective_bytes == pytest.approx(96 * 12)


def test_iota_replica_groups():
    g = parse_replica_groups("replica_groups=[2,4]<=[8]", 8)
    assert g == [(0, 1, 2, 3), (4, 5, 6, 7)]
    g = parse_replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)", 8)
    assert g == [(0, 4), (1, 5), (2, 6), (3, 7)]
    g = parse_replica_groups("replica_groups={{0,2},{1,3}}, foo=bar", 4)
    assert g == [(0, 2), (1, 3)]
    g = parse_replica_groups("replica_groups={}", 4)
    assert g == [(0, 1, 2, 3)]


def test_comm_graph_from_synthetic():
    cg = comm_graph_from_hlo(SYNTH)
    assert cg.n == 8
    # two ring groups (0..3), (4..7) — no cross-group traffic
    assert cg.G_v[0, 1] > 0 and cg.G_v[3, 0] > 0
    assert cg.G_v[0, 4] == 0
    assert cg.G_v[1, 2] == cg.G_v[5, 6]


@pytest.fixture(scope="module")
def real_compiled():
    """A real jitted program with a scan, on 1 device (CPU)."""
    L, D = 6, 32

    def step(ws, x):
        def layer(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(layer, x, ws)
        return h.sum()

    f = jax.jit(jax.grad(step))
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)
    return f.lower(ws, x).compile()


def test_real_hlo_loop_corrected_flops(real_compiled):
    """Our loop-corrected FLOPs must exceed XLA's body-once count and be in
    the right ballpark of the analytic value."""
    prof = profile_hlo(real_compiled.as_text())
    L, D, B = 6, 32, 4
    # fwd: L * 2*B*D*D ; bwd: ~2x fwd (dgrad+wgrad)
    analytic = 3 * L * 2 * B * D * D
    xla_flops = real_compiled.cost_analysis().get("flops", 0)
    assert prof.flops >= 0.6 * analytic, (prof.flops, analytic)
    assert prof.flops <= 2.0 * analytic, (prof.flops, analytic)
    # XLA undercounts the scan: our corrected count must be larger
    assert prof.flops > xla_flops, (prof.flops, xla_flops)


def test_real_hlo_bytes_positive(real_compiled):
    prof = profile_hlo(real_compiled.as_text())
    assert prof.bytes_accessed > 0
    # weights alone are read at least once per step
    assert prof.bytes_accessed >= 6 * 32 * 32 * 4
