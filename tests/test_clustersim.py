import numpy as np
import pytest

from repro.cluster.failures import (BernoulliPerJob, ExponentialLifetimes,
                                    FailureProcess, NoFailures, NodeEvent)
from repro.cluster.scheduler import Job, Scheduler
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.topology import TorusTopology
from repro.sim.batchsim import run_batch, run_scenario
from repro.sim.clustersim import ClusterSim, SimConfig
from repro.sim.network import TorusNetwork
from repro.sim.scenarios import run_preset
from repro.workloads.arrivals import burst_stream, serial_stream
from repro.workloads.patterns import halo3d, npb_dt_like


@pytest.fixture(scope="module")
def cluster():
    topo = TorusTopology((4, 4, 4))
    return topo, TorusNetwork(topo)


def _sched(cluster, **kw):
    topo, net = cluster
    return Scheduler(topo, net=net, **kw)


# ----------------------------------------------------- paper equivalence
def _event_sim_batch(topo, net, wl, pol, fm, known, n_instances, seed,
                     engine, **cfg):
    """Mirror run_batch through the event loop: same engine, same RNG."""
    rng = np.random.default_rng(seed)
    plan = engine.place(
        PlacementRequest(comm=wl.comm, topology=topo, p_f=known),
        policy=pol, rng=rng)
    sim = ClusterSim(
        Scheduler(topo, net=net, engine=engine),
        serial_stream([wl] * n_instances, policy=pol,
                      fixed_placement=plan.placement),
        attempt_failures=fm, rng=rng, config=SimConfig(**cfg))
    return sim.run()


def test_event_sim_matches_run_batch_exactly(cluster):
    """Serial arrivals + per-batch Bernoulli N_f: the event simulator
    reproduces run_batch completion times bit-for-bit (same RNG order)."""
    topo, net = cluster
    wl = npb_dt_like(24)
    cand = np.random.default_rng(5).choice(64, 8, replace=False)
    fm = BernoulliPerJob(cand, 0.05)
    known = fm.outage_vector(64)
    engine = PlacementEngine()
    for pol in ("linear", "tofa"):
        rb = run_batch(wl, pol, net, fm, known, n_instances=40,
                       rng=np.random.default_rng(11), engine=engine)
        res = _event_sim_batch(topo, net, wl, pol, fm, known, 40, 11,
                               engine)
        assert res.makespan == rb.completion_time
        assert res.aborted_attempts == rb.n_aborted_attempts
        assert not res.truncated


def test_event_sim_matches_run_batch_with_checkpointing(cluster):
    topo, net = cluster
    wl = npb_dt_like(24)
    fm = BernoulliPerJob(np.arange(16), 0.3)
    engine = PlacementEngine()
    rb = run_batch(wl, "linear", net, fm, None, n_instances=30,
                   rng=np.random.default_rng(2), engine=engine,
                   checkpoint_interval=0.02, checkpoint_overhead=0.001)
    res = _event_sim_batch(topo, net, wl, "linear", fm, None, 30, 2,
                           engine, checkpoint_interval=0.02,
                           checkpoint_overhead=0.001)
    # same draws and the same charge terms; only the floating-point
    # summation order differs (absolute event times vs one accumulator)
    assert res.makespan == pytest.approx(rb.completion_time, rel=1e-9)
    assert res.aborted_attempts == rb.n_aborted_attempts


def test_paper_preset_matches_run_scenario():
    """Acceptance: the Fig. 4/5 preset matches run_scenario per policy
    (criterion is 1%; the implementation is draw-for-draw identical)."""
    ev = run_preset("paper-fig4-5", fast=True, seed=3)
    ref = run_scenario(lambda: npb_dt_like(24), ("linear", "tofa"),
                       dims=(4, 4, 4), n_batches=2, n_instances=20,
                       n_faulty=8, p_f=0.02, seed=3)
    for pol in ("linear", "tofa"):
        a = ev["policies"][pol]["mean_completion"]
        b = ref[pol].mean_completion
        assert a == pytest.approx(b, rel=0.01)
        assert a == b, "draw-for-draw mirror should be exact, not just close"


# ------------------------------------------------- queueing and backfill
def test_queue_serialises_over_capacity(cluster):
    """Burst of jobs wider than half the cluster: they must run one at a
    time; completions drain the queue in FIFO order."""
    sch = _sched(cluster)
    wl = halo3d((2, 2, 2))            # 8 ranks
    jobs = burst_stream([halo3d((4, 4, 3)) for _ in range(3)],  # 48 ranks
                        policy="linear")
    sim = ClusterSim(sch, jobs, attempt_failures=NoFailures(),
                     rng=np.random.default_rng(0))
    res = sim.run()
    starts = sorted(j.first_start for j in res.jobs)
    # with 64 nodes and 48-rank jobs, starts must be strictly staggered
    assert starts[0] == 0.0 and starts[1] > 0.0 and starts[2] > starts[1]
    assert res.makespan == pytest.approx(sum(j.finish_time - j.first_start
                                             for j in res.jobs), rel=1e-6)


def test_backfill_lets_small_job_skip_blocked_head():
    topo = TorusTopology((4, 4))
    sch = Scheduler(topo)
    wide = Job(halo3d((4, 2, 2)), distribution="linear")    # 16 ranks
    wide2 = Job(halo3d((4, 2, 2)), distribution="linear")   # blocks
    small = Job(halo3d((2, 2, 2)), distribution="linear")   # 8 ranks
    assert sch.submit(wide).state == "running"
    assert sch.submit(wide2).state == "pending"   # head of queue, blocked
    rec_small = sch.submit(small)
    assert rec_small.state == "pending", "no free capacity at all"
    sch.complete(wide.job_id)
    # wide2 takes the whole machine again; small must wait behind it
    assert sch.records[wide2.job_id].state == "running"
    assert rec_small.state == "pending"
    sch.complete(wide2.job_id)
    assert rec_small.state == "running"


def test_backfill_disabled_is_strict_fifo():
    topo = TorusTopology((4, 4))
    for backfill, expected in ((True, "running"), (False, "pending")):
        sch = Scheduler(topo, backfill=backfill)
        sch.submit(Job(halo3d((3, 2, 2)), distribution="linear"))  # 12 ranks
        blocked = sch.submit(Job(halo3d((2, 2, 2)),
                                 distribution="linear"))           # 8 > 4
        assert blocked.state == "pending"
        small = sch.submit(Job(halo3d((2, 2, 1)), distribution="linear"))
        assert small.state == expected


# --------------------------------------- checkpoint / restart accounting
def test_mid_attempt_failure_restarts_from_checkpoint(cluster):
    """Time-based failure mid-attempt: work since the last checkpoint is
    lost, earlier work is preserved, and the job still finishes."""
    topo, net = cluster
    sch = _sched(cluster)
    wl = halo3d((2, 2, 2))
    t_ok = None
    # no-failure reference
    ref = ClusterSim(_sched(cluster), burst_stream([wl], policy="linear"),
                     rng=np.random.default_rng(0)).run()
    t_ok = ref.makespan
    ci = t_ok / 10
    victim_proc = ExponentialLifetimes([0], mtbf=t_ok * 0.6, mttr=0.01)
    sim = ClusterSim(
        sch, burst_stream([wl], policy="linear"),
        failure_process=victim_proc,
        config=SimConfig(checkpoint_interval=ci, checkpoint_overhead=0.0,
                         failure_horizon=t_ok * 0.9),
        rng=np.random.default_rng(1))
    res = sim.run()
    job = res.jobs[0]
    assert not res.truncated and job.finish_time > 0
    if job.aborts:
        # restarted: total elapsed exceeds t_ok, but by less than one full
        # re-run — the checkpoint preserved most of the aborted work
        # (bound includes the re-placed placement's runtime, within 2x)
        assert t_ok < res.makespan < 3 * t_ok
        assert job.attempts == job.aborts + 1


def test_node_failure_aborts_and_replaces(cluster):
    """A node death under a running job triggers engine.replace: the dead
    node leaves the placement and the job restarts."""
    topo, net = cluster
    sch = _sched(cluster)
    wl = halo3d((2, 2, 2))
    rec = sch.submit(Job(wl, distribution="linear"))
    victim = int(rec.placement.placement[0])
    affected = sch.handle_node_failure([victim])
    assert rec in affected and rec.state == "running"
    assert victim not in set(rec.placement.placement.tolist())
    assert rec.placement.provenance == "replace-incremental"
    assert rec.restarts == 1


def test_failure_requeues_job_when_survivors_cannot_hold_it():
    topo = TorusTopology((3, 3))
    sch = Scheduler(topo)
    rec = sch.submit(Job(halo3d((3, 3, 1)), distribution="linear"))
    assert rec.state == "running"
    victim = int(rec.placement.placement[0])
    affected = sch.handle_node_failure([victim])   # 8 survivors < 9 ranks
    assert rec in affected
    assert rec.state == "pending" and rec.placement is None
    assert rec.requeues == 1
    started = sch.recover([victim])
    assert rec in started and rec.state == "running"


class _FixedTrace(FailureProcess):
    """Deterministic trace for targeted failure timing in tests."""

    def __init__(self, events):
        self._events = list(events)

    def generate(self, rng, horizon):
        return [e for e in self._events if e.time < horizon]


def test_requeue_frees_capacity_for_pending_jobs():
    """A requeued job's released allocation must let other pending jobs
    start, even when no later SUBMIT/COMPLETE/RECOVER event arrives."""
    topo = TorusTopology((2, 4))                   # 8 nodes
    sch = Scheduler(topo)
    jobs = burst_stream([halo3d((3, 2, 1)),        # A: 6 ranks, runs first
                         halo3d((2, 2, 1))],       # B: 4 ranks, pending
                        policy="linear")
    # 4 of A's nodes die permanently: survivors (4) can't hold A, but
    # A's freed allocation gives B exactly the capacity it needs
    trace = _FixedTrace([NodeEvent(1e-4, "fail", (0, 1, 2, 3))])
    res = ClusterSim(sch, jobs, failure_process=trace,
                     config=SimConfig(failure_horizon=10.0),
                     rng=np.random.default_rng(0)).run()
    a, b = res.jobs
    assert a.finish_time < 0, "A cannot run on 4 surviving nodes"
    assert b.finish_time > 0, "B must start on the capacity A released"
    assert res.truncated, "run ends with A stuck pending"


def test_combined_mode_checkpoints_survive_node_failure(cluster):
    """attempt_failures + failure_process + checkpointing together: a
    node failure mid-attempt only loses work since the last checkpoint."""
    topo, net = cluster
    wl = halo3d((2, 2, 2))
    ref = ClusterSim(_sched(cluster), burst_stream([wl], policy="linear"),
                     rng=np.random.default_rng(0)).run()
    t_ok = ref.makespan
    trace = _FixedTrace([NodeEvent(0.55 * t_ok, "fail", (0,))])
    res = ClusterSim(
        _sched(cluster), burst_stream([wl], policy="linear"),
        attempt_failures=NoFailures(), failure_process=trace,
        config=SimConfig(checkpoint_interval=t_ok / 10,
                         checkpoint_overhead=t_ok / 200,
                         failure_horizon=10.0 * t_ok),
        rng=np.random.default_rng(1)).run()
    job = res.jobs[0]
    assert job.aborts == 1 and job.finish_time > 0
    # ~5 checkpoints preserved ~half the work: total well below the
    # ~1.55 * t_ok a from-scratch restart would cost.  The bound also
    # polices overhead charging: the restarted attempt (R ~ 0.5 t_ok)
    # must pay for its own ~4 checkpoint writes, not the initial 10.
    assert res.makespan < 1.45 * t_ok
    assert res.makespan > t_ok


def test_requeued_job_finishes_after_recover(cluster):
    """End-to-end drain-then-recover: a 9-rank job on a 9-node cluster
    loses a node (no survivors can hold it), waits in the queue, and
    completes once the node is repaired."""
    topo = TorusTopology((3, 3))
    sch = Scheduler(topo)
    proc = ExponentialLifetimes([4], mtbf=0.5, mttr=1.0)
    sim = ClusterSim(
        sch, burst_stream([halo3d((3, 3, 1))], policy="linear"),
        failure_process=proc,
        config=SimConfig(failure_horizon=2.0, checkpoint_interval=0.1),
        rng=np.random.default_rng(6))
    res = sim.run()
    job = res.jobs[0]
    assert job.finish_time > 0 and not res.truncated
    if job.requeues:
        assert job.aborts >= 1


# ----------------------------------------- heartbeat drain-then-recover
def test_drain_then_undrain_hysteresis():
    topo = TorusTopology((4, 4))
    sch = Scheduler(topo, drain_threshold=0.5)
    bad = np.ones(16, dtype=bool)
    bad[3] = False
    for _ in range(20):
        sch.heartbeat_round(bad)
    assert sch.registry[3].state.value == "drained"
    # node recovers: misses fade below the undrain threshold (0.25)
    good = np.ones(16, dtype=bool)
    for _ in range(100):
        sch.heartbeat_round(good)
    assert sch.registry[3].state.value == "up"


def test_drained_node_excluded_then_reused_after_recovery(cluster):
    """Heartbeat-driven drain keeps a flaky node out of placements; once
    its heartbeats recover, the queue drains onto it again."""
    topo = TorusTopology((2, 2))
    sch = Scheduler(topo, drain_threshold=0.5)
    bad = np.ones(4, dtype=bool)
    bad[0] = False
    for _ in range(10):
        sch.heartbeat_round(bad)
    # 4-rank job cannot run on 3 nodes
    rec = sch.submit(Job(halo3d((2, 2, 1)), distribution="linear"))
    assert rec.state == "pending"
    started = []
    for _ in range(40):
        started += sch.heartbeat_round(np.ones(4, dtype=bool))
    assert rec in started and rec.state == "running"


def test_recover_respects_drain_hysteresis():
    """Repair fixes the outage, not the flakiness evidence: a repaired
    node whose estimate still exceeds the drain threshold comes back
    DRAINED (undrain happens via heartbeat hysteresis, not repair)."""
    from repro.cluster.nodes import NodeState
    topo = TorusTopology((4, 4))
    sch = Scheduler(topo, drain_threshold=0.5)
    bad = np.ones(16, dtype=bool)
    bad[2] = False
    for _ in range(20):
        sch.heartbeat_round(bad)
    assert sch.registry[2].state == NodeState.DRAINED
    sch.registry.mark([2], NodeState.DOWN)       # ...then it actually dies
    sch.recover([2])
    assert sch.registry[2].state == NodeState.DRAINED
    # a node with clean heartbeat history returns straight to UP
    sch.registry.mark([3], NodeState.DOWN)
    sch.recover([3])
    assert sch.registry[3].state == NodeState.UP


def test_heartbeat_events_drive_monitor(cluster):
    """In-sim HEARTBEAT events feed the estimator from ground-truth node
    flakiness (registry.true_outage_p)."""
    sch = _sched(cluster)
    sch.registry.set_outage_probabilities([7], 0.8)
    wl = halo3d((2, 2, 2))
    sim = ClusterSim(
        sch, burst_stream([wl] * 8, policy="linear"),
        attempt_failures=NoFailures(),
        config=SimConfig(heartbeat_interval=0.001),
        rng=np.random.default_rng(8))
    res = sim.run()
    assert not res.truncated
    est = sch.monitor.outage_probabilities()
    assert est[7] > 0.3 and est[:7].max() == 0.0


# ----------------------------------------------------- stream semantics
def test_serial_stream_chains_submissions(cluster):
    sch = _sched(cluster)
    wl = halo3d((2, 2, 2))
    sim = ClusterSim(sch, serial_stream([wl] * 5, policy="linear"),
                     attempt_failures=NoFailures(),
                     rng=np.random.default_rng(0))
    res = sim.run()
    subs = [j.submit_time for j in res.jobs]
    fins = [j.finish_time for j in res.jobs]
    assert subs[0] == 0.0
    assert subs[1:] == fins[:-1], "each instance submits as the prior ends"


def test_max_events_truncates():
    topo = TorusTopology((2, 2))
    sch = Scheduler(topo)
    sim = ClusterSim(sch, burst_stream([halo3d((2, 2, 1))] * 4,
                                       policy="linear"),
                     attempt_failures=NoFailures(),
                     config=SimConfig(max_events=2),
                     rng=np.random.default_rng(0))
    assert sim.run().truncated


def test_fixed_placement_rejects_failure_process():
    topo = TorusTopology((2, 2))
    with pytest.raises(ValueError):
        ClusterSim(Scheduler(topo),
                   serial_stream([halo3d((2, 2, 1))], policy="linear",
                                 fixed_placement=np.arange(4)),
                   failure_process=ExponentialLifetimes([0], mtbf=1.0),
                   config=SimConfig(failure_horizon=10.0))


# ------------------------------------------------------ scenario gates
def test_tofa_beats_linear_in_saturated_queue():
    out = run_preset("saturated-queue", fast=True, seed=0)
    assert (out["policies"]["tofa"]["mean_completion"]
            < out["policies"]["linear"]["mean_completion"])


def test_tofa_beats_linear_under_correlated_failures():
    out = run_preset("correlated-failures", fast=True, seed=0)
    assert (out["policies"]["tofa"]["mean_completion"]
            < out["policies"]["linear"]["mean_completion"])


def test_fat_tree_preset_runs_on_clos_host():
    out = run_preset("fat-tree", fast=True, seed=0)
    for pol in ("linear", "tofa"):
        row = out["policies"][pol]
        assert row["mean_completion"] > 0 and not row["truncated"]


def test_run_scenario_accepts_topology_instance():
    from repro.core.fattree import FatTreeTopology
    res = run_scenario(lambda: npb_dt_like(8), ("linear", "tofa"),
                       topology=FatTreeTopology(4), n_batches=1,
                       n_instances=5, n_faulty=2, p_f=0.3, seed=0)
    for pol in ("linear", "tofa"):
        assert res[pol].mean_completion > 0
