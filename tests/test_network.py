"""Network model semantics + the dragonfly topology contract.

``TorusNetwork`` is *route form* (a failed node kills any flow routed
through it, like SimGrid's zero-capacity links); ``HopNetwork`` is
*endpoint form* (multi-path fabrics detour around interior failures, so
only a failed endpoint aborts).  Both distinctions are gate-relevant:
the simulators' doom decisions and the vectorized paper path depend on
them.  The dragonfly tests pin the ``Topology``-protocol contract the
placement engine assumes: symmetric hop matrix, zero diagonal, the
{2,3,4,5} dragonfly distance spectrum, partition-valid
``hierarchy_groups``, and endpoint-form Eq. (1) weights.
"""
import numpy as np
import pytest

from repro.core.comm_graph import CommGraph
from repro.core.dragonfly import DragonflyTopology
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.state import ClusterState
from repro.core.topology import FAULT_PENALTY, TorusTopology
from repro.sim.network import GBPS, HopNetwork, TorusNetwork, network_for


def _pair_graph(nbytes=8e6, nmsgs=10.0):
    g = CommGraph(2)
    g.add_p2p(0, 1, nbytes, nmsgs)
    return g


# ----------------------------------------------------------- TorusNetwork
def test_torus_touches_failed_route_form():
    net = TorusNetwork(TorusTopology((4, 4, 4)))
    comm = _pair_graph()
    # ranks on nodes 0=(0,0,0) and 2=(0,0,2): dimension-ordered route
    # passes through node 1
    placement = np.array([0, 2])
    assert net.touches_failed(comm, placement, np.array([1]))   # interior
    assert net.touches_failed(comm, placement, np.array([2]))   # endpoint
    assert not net.touches_failed(comm, placement, np.array([5]))
    assert not net.touches_failed(comm, placement, np.array([], dtype=int))


def test_torus_link_loads_split_both_directions():
    net = TorusNetwork(TorusTopology((4, 4, 4)))
    b = 4e6
    comm = _pair_graph(nbytes=b)
    loads = net.link_loads(comm, np.array([0, 1]))       # adjacent nodes
    assert loads[(0, 1)] == pytest.approx(b / 2)
    assert loads[(1, 0)] == pytest.approx(b / 2)
    assert sum(loads.values()) == pytest.approx(b)
    # two hops away: each direction crosses two links
    loads2 = net.link_loads(comm, np.array([0, 2]))
    assert sum(loads2.values()) == pytest.approx(2 * b)


def test_torus_comm_time_bottleneck_plus_latency():
    topo = TorusTopology((4, 4, 4))
    net = TorusNetwork(topo)
    b, m = 8e6, 10.0
    comm = _pair_graph(nbytes=b, nmsgs=m)
    t = net.comm_time(comm, np.array([0, 2]))
    expected = (b / 2) / net.link_bandwidth + m * 2 * net.link_latency
    assert t == pytest.approx(expected)
    assert net.compute_time(6e9, 2.0) == pytest.approx(2.0)


# ------------------------------------------------------------- HopNetwork
def test_hop_network_endpoint_fault_form():
    topo = DragonflyTopology(p=2, a=2, h=1, g=3)
    net = HopNetwork(topo)
    comm = _pair_graph()
    placement = np.array([0, topo.n_nodes - 1])
    assert net.touches_failed(comm, placement, np.array([0]))
    # interior nodes never abort a HopNetwork job (multi-path detours)
    interior = np.array([n for n in range(1, topo.n_nodes - 1)])
    assert not net.touches_failed(comm, placement, interior)
    assert not net.touches_failed(comm, placement, np.array([], dtype=int))


def test_hop_network_byte_hops_formula():
    topo = DragonflyTopology(p=2, a=2, h=1, g=3)
    net = HopNetwork(topo)
    b, m = 6e6, 4.0
    comm = _pair_graph(nbytes=b, nmsgs=m)
    p = np.array([0, 1])                                 # same router
    D = topo.hop_matrix()
    hops = D[0, 1]
    t = net.comm_time(comm, p)
    byte_hops = b * hops                                 # one symmetric pair
    expected = byte_hops / (net.link_bandwidth * comm.n) \
        + m * hops * net.link_latency
    assert t == pytest.approx(expected)


def test_hop_network_memoises_hop_matrix():
    net = HopNetwork(DragonflyTopology(p=2, a=2, h=1, g=3))
    assert net.hop_matrix() is net.hop_matrix()


def test_network_for_dispatch():
    assert isinstance(network_for(TorusTopology((2, 2, 2))), TorusNetwork)
    assert isinstance(network_for(DragonflyTopology(p=2, a=2, h=1, g=3)),
                      HopNetwork)
    assert GBPS == pytest.approx(1e9 / 8.0)


# ----------------------------------------------------- dragonfly contract
def test_dragonfly_shape_and_defaults():
    d = DragonflyTopology(p=2, a=4, h=2)
    assert d.g == 4 * 2 + 1                              # balanced default
    assert d.hosts_per_group == 8
    assert d.n_nodes == 9 * 8
    assert d.coords(0) == (0, 0, 0)
    assert d.coords(d.n_nodes - 1) == (8, 3, 1)
    c = d.coords_array()
    assert c.shape == (d.n_nodes, 3)
    # id-ordering: consecutive ids co-located (group-major, router-major)
    assert list(c[:, 0]) == sorted(c[:, 0])


def test_dragonfly_invalid_configs():
    with pytest.raises(ValueError):
        DragonflyTopology(p=0, a=4, h=2)
    with pytest.raises(ValueError):
        DragonflyTopology(p=2, a=2, h=1, g=1)            # < 2 groups
    with pytest.raises(ValueError):
        DragonflyTopology(p=2, a=2, h=1, g=5)            # g-1 > a*h slots


def test_dragonfly_hop_matrix_contract():
    d = DragonflyTopology(p=2, a=4, h=2, g=5)
    D = d.hop_matrix()
    assert D.shape == (d.n_nodes, d.n_nodes)
    assert np.array_equal(D, D.T)                        # symmetric
    assert np.all(np.diag(D) == 0)
    off = D[~np.eye(d.n_nodes, dtype=bool)]
    assert set(np.unique(off)) <= {2.0, 3.0, 4.0, 5.0}
    # same router -> 2, same group different router -> 3
    assert D[0, 1] == 2.0                                # hosts of router 0
    assert D[0, d.p] == 3.0                              # routers 0 and 1
    # inter-group distance >= 3 everywhere
    grp = d.coords_array()[:, 0]
    assert (D[grp[:, None] != grp[None, :]] >= 3.0).all()
    assert d.hop_matrix() is D                           # memoised


def test_dragonfly_gateway_consistency():
    d = DragonflyTopology(p=2, a=4, h=2, g=9)
    for src in range(d.g):
        owned = {}
        for dst in range(d.g):
            if dst == src:
                with pytest.raises(ValueError):
                    d.gateway_router(src, dst)
                continue
            r = d.gateway_router(src, dst)
            assert 0 <= r < d.a
            owned.setdefault(r, []).append(dst)
        # consecutive slot assignment: every router gateways <= h groups
        assert all(len(v) <= d.h for v in owned.values())
        assert sum(len(v) for v in owned.values()) == d.g - 1


def test_dragonfly_gateway_explains_hops():
    d = DragonflyTopology(p=2, a=2, h=2, g=4)
    D = d.hop_matrix()
    c = d.coords_array()
    for u in range(d.n_nodes):
        for v in range(d.n_nodes):
            gu, ru = c[u, 0], c[u, 1]
            gv, rv = c[v, 0], c[v, 1]
            if gu == gv:
                continue
            detours = (int(ru != d.gateway_router(gu, gv))
                       + int(rv != d.gateway_router(gv, gu)))
            assert D[u, v] == 3.0 + detours


def test_dragonfly_hierarchy_groups_partition():
    d = DragonflyTopology(p=2, a=4, h=2, g=9)
    grp = d.hierarchy_groups(target_groups=4)            # coarse: per group
    assert grp.shape == (d.n_nodes,)
    ids, counts = np.unique(grp, return_counts=True)
    assert len(ids) == d.g
    assert (counts == d.hosts_per_group).all()           # equal partition
    fine = d.hierarchy_groups(target_groups=64)          # finer than g
    ids2, counts2 = np.unique(fine, return_counts=True)
    assert len(ids2) == d.g * d.a
    assert (counts2 == d.p).all()
    # refinement: equal fine ids imply equal coarse ids
    for gid in ids2:
        assert len(np.unique(grp[fine == gid])) == 1


def test_dragonfly_weight_matrix_endpoint_penalty():
    d = DragonflyTopology(p=2, a=2, h=1, g=3)
    p_f = np.zeros(d.n_nodes)
    k = 5
    p_f[k] = 0.4
    W0 = d.weight_matrix()
    W = d.weight_matrix(p_f)
    assert np.array_equal(W0, d.hop_matrix())            # no faults: hops
    delta = W - W0
    assert np.all(np.diag(delta) == 0)
    mask = np.zeros_like(W, dtype=bool)
    mask[k, :] = mask[:, k] = True
    np.fill_diagonal(mask, False)
    assert (delta[mask] == FAULT_PENALTY).all()
    assert (delta[~mask] == 0).all()


def test_dragonfly_weight_matrix_update_matches_full():
    d = DragonflyTopology(p=2, a=2, h=1, g=3)
    p_f0 = np.zeros(d.n_nodes)
    p_f1 = p_f0.copy()
    p_f1[[2, 7]] = 0.3
    W_prev = d.weight_matrix(p_f0, c=2.0)
    full = d.weight_matrix(p_f1, c=2.0)
    inc = d.weight_matrix_update(W_prev, [2, 7], p_f=p_f1, c=2.0)
    assert np.array_equal(inc, full)
    assert d.weight_matrix_update(W_prev, [], p_f=p_f1) is W_prev


def test_dragonfly_placement_engine_smoke():
    d = DragonflyTopology(p=2, a=4, h=2)                 # 72 hosts
    p_f = np.zeros(d.n_nodes)
    faulty = [3, 11, 40]
    p_f[faulty] = 0.5
    state = ClusterState.from_arrays(d.n_nodes, p_f=p_f)
    g = CommGraph(8)
    for i in range(8):
        g.add_p2p(i, (i + 1) % 8, 1e6, 4.0)
    eng = PlacementEngine()
    for policy in ("linear", "tofa"):
        plan = eng.place(PlacementRequest(comm=g, topology=d, state=state),
                         policy=policy,
                         rng=np.random.default_rng(0))
        p = np.asarray(plan.placement)
        assert p.shape == (8,) and len(set(p.tolist())) == 8
        assert (p >= 0).all() and (p < d.n_nodes).all()
    # tofa avoids the flagged nodes
    assert not set(p.tolist()) & set(faulty)
