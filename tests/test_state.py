"""ClusterState: lifecycle, overlay/diff algebra, epoch-keyed engine
caching (zero misses on no-op heartbeat rounds), delta weight refreshes,
replace fast-path, and cross-backend parity across a state churn
sequence."""
import numpy as np
import pytest

from repro.cluster.nodes import NodeState
from repro.cluster.scheduler import Job, Scheduler
from repro.core.backend import has_jax
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.fattree import FatTreeTopology
from repro.core.state import ClusterState, NodeHealth
from repro.core.topology import TorusTopology
from repro.workloads.patterns import lammps_like, npb_dt_like

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()


# ------------------------------------------------------------- lifecycle
def test_healthy_state_has_all_nodes_allocatable():
    s = ClusterState.healthy(16)
    assert s.n_nodes == 16
    assert (s.available_ids() == np.arange(16)).all()
    assert (s.outage_vector() == 0).all()
    assert s.snapshot() is s
    assert s.health_of(3) == NodeHealth.UP


def test_lifecycle_transitions_mint_monotonic_epochs():
    s0 = ClusterState.healthy(8)
    s1 = s0.with_health([2], NodeHealth.DEGRADED)
    s2 = s1.with_health([2], NodeHealth.DRAINED)
    s3 = s2.with_health([2], NodeHealth.DOWN)
    s4 = s3.with_health([2], NodeHealth.UP)
    epochs = [s.epoch for s in (s0, s1, s2, s3, s4)]
    assert epochs == sorted(epochs) and len(set(epochs)) == 5
    # DEGRADED stays allocatable; DRAINED and DOWN do not
    assert 2 in s1.available_ids()
    assert 2 not in s2.available_ids()
    assert 2 not in s3.available_ids()
    assert 2 in s4.available_ids()
    # non-allocatable nodes are pinned to certain outage
    assert s2.outage_vector()[2] == 1.0 and s1.outage_vector()[2] == 0.0


def test_noop_transition_returns_same_state():
    s0 = ClusterState.healthy(8)
    assert s0.with_health([3], NodeHealth.UP) is s0
    assert s0.with_outage(np.zeros(8)) is s0
    assert s0.overlay(unavailable=[]) is s0


def test_with_outage_atol_and_pattern():
    s0 = ClusterState.healthy(8).with_outage(np.full(8, 0.2))
    # drift within atol: same state, same epoch
    assert s0.with_outage(np.full(8, 0.25), atol=0.1) is s0
    # drift beyond atol mints
    s1 = s0.with_outage(np.full(8, 0.5), atol=0.1)
    assert s1 is not s0 and s1.epoch > s0.epoch
    # a p_f > 0 pattern flip always mints, regardless of atol
    p = np.full(8, 0.2)
    p[3] = 0.0
    s2 = s0.with_outage(p, atol=None)
    assert s2 is not s0
    # pattern-only mode (atol=None) ignores pure magnitude drift
    assert s0.with_outage(np.full(8, 0.9), atol=None) is s0


def test_states_are_immutable():
    s = ClusterState.healthy(4)
    with pytest.raises(ValueError):
        s.health[0] = 3
    with pytest.raises(ValueError):
        s.p_f[0] = 0.5


def test_from_arrays_interns_by_content():
    p = np.zeros(16)
    p[5] = 0.1
    a = ClusterState.from_arrays(16, p_f=p)
    b = ClusterState.from_arrays(16, p_f=p.copy())
    assert a is b
    c = ClusterState.from_arrays(16, p_f=p, available=np.arange(8))
    assert c is not a
    assert (c.available_ids() == np.arange(8)).all()
    assert c.outage_vector()[12] == 1.0   # outside available == DOWN


def test_groups_carried_and_queryable():
    s = ClusterState.healthy(8, groups=[[0, 1, 2, 3], [4, 5, 6, 7]])
    assert s.group_of(5) == 1 and s.group_of(0) == 0
    s2 = s.with_health([1], NodeHealth.DOWN)
    assert s2.groups == s.groups


def test_from_arrays_interning_keys_on_groups():
    ungrouped = ClusterState.from_arrays(8)
    grouped = ClusterState.from_arrays(8, groups=[[0, 1], [2, 3]])
    assert grouped is not ungrouped
    assert grouped.group_of(1) == 0 and ungrouped.group_of(1) is None
    assert ClusterState.from_arrays(8, groups=[[0, 1], [2, 3]]) is grouped


# --------------------------------------------------------- overlay / diff
def test_overlay_masks_without_minting_epoch():
    s = ClusterState.healthy(16)
    o = s.overlay(unavailable=[3, 4])
    assert o.epoch == s.epoch and o.key != s.key
    assert 3 not in o.available_ids() and 3 in s.available_ids()
    assert o.outage_vector()[3] == 1.0
    # same masked set => same key (cache-stable)
    assert s.overlay(unavailable=[4, 3]).key == o.key
    # composing overlays unions the masks against the same base
    oo = o.overlay(unavailable=[7])
    assert set(np.setdiff1d(s.available_ids(), oo.available_ids())) \
        == {3, 4, 7}
    assert oo.key == s.overlay(unavailable=[3, 4, 7]).key


def test_overlay_cannot_evolve():
    o = ClusterState.healthy(8).overlay(unavailable=[1])
    with pytest.raises(ValueError):
        o.with_health([2], NodeHealth.DOWN)


def test_diff_identifies_changed_nodes():
    s0 = ClusterState.healthy(16)
    s1 = s0.with_health([2, 9], NodeHealth.DOWN)
    d = s0.diff(s1)
    assert set(d.nodes.tolist()) == {2, 9}
    assert set(d.lost().tolist()) == {2, 9}
    assert d.touches(np.array([1, 2, 3])) and not d.touches(np.array([4, 5]))
    # symmetric membership; lost() is directional
    assert (s1.diff(s0).nodes == d.nodes).all()
    assert len(s1.diff(s0).lost()) == 0
    # self-diff is empty
    assert not s0.diff(s0)


def test_diff_sees_overlay_masking():
    s = ClusterState.healthy(8)
    o = s.overlay(unavailable=[5])
    assert set(s.diff(o).nodes.tolist()) == {5}
    assert set(s.diff(o).lost().tolist()) == {5}


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=2, max_value=32), st.data())
def test_overlay_diff_algebra_properties(n, data):
    """Property: overlay availability is base minus mask; diff is exactly
    the symmetric difference of effective health; overlay keys are a
    function of (base, masked set)."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    p = np.where(rng.random(n) < 0.3, rng.random(n), 0.0)
    s = ClusterState.healthy(n).with_outage(p)
    k = int(rng.integers(0, n))
    masked = rng.choice(n, size=k, replace=False)
    o = s.overlay(unavailable=masked)
    expect = np.setdiff1d(np.arange(n), masked)
    assert (o.available_ids() == expect).all()
    # diff(s, o) == masked set exactly (p_f pinning tracks allocatability)
    assert set(s.diff(o).nodes.tolist()) == set(int(x) for x in masked)
    # key determinism: rebuilding the same overlay reproduces the key
    assert s.overlay(unavailable=np.sort(masked)).key == o.key \
        or k == 0
    # epochs never move backwards
    s2 = s.with_health(masked, NodeHealth.DOWN) if k else s
    assert s2.epoch >= s.epoch


# ------------------------------------------- engine epoch-keyed caching
def test_request_from_state_exposes_legacy_views():
    topo = TorusTopology((4, 4))
    s = ClusterState.healthy(16).with_health([3], NodeHealth.DOWN)
    req = PlacementRequest(comm=lammps_like(8).comm, topology=topo, state=s)
    assert 3 not in req.available_ids
    assert req.p_f[3] == 1.0
    assert req.effective_p_f()[3] == 1.0
    with pytest.raises(ValueError, match="not both"):
        PlacementRequest(comm=lammps_like(8).comm, topology=topo, state=s,
                         p_f=np.zeros(16))


def test_same_epoch_hits_weight_and_memo_caches():
    topo = TorusTopology((4, 4, 4))
    engine = PlacementEngine()
    s = ClusterState.healthy(64).with_outage(
        np.where(np.arange(64) < 6, 0.1, 0.0))
    wl = npb_dt_like(20)
    req = PlacementRequest(comm=wl.comm, topology=topo, state=s)
    p1 = engine.place(req, policy="tofa", rng=np.random.default_rng(0))
    misses = engine.cache_stats()["weight_misses"]
    req2 = PlacementRequest(comm=wl.comm, topology=topo, state=s)
    p2 = engine.place(req2, policy="tofa", rng=np.random.default_rng(0))
    stats = engine.cache_stats()
    assert stats["weight_misses"] == misses      # zero new derivations
    assert stats["weight_hits"] >= 1 and stats["shared_hits"] >= 1
    assert (p1.placement == p2.placement).all()


def test_heartbeat_round_with_unchanged_health_zero_cache_misses():
    """Regression for the deleted quantized-estimated_outage hack: a
    heartbeat round that does not change health must not mint an epoch,
    so a following placement hits every engine cache."""
    topo = TorusTopology((4, 4, 4))
    sch = Scheduler(topo)
    truth = np.zeros(64)
    truth[:5] = 0.3
    sch.registry.set_outage_probabilities(range(5), 0.3)
    sch.monitor.simulate_rounds(np.random.default_rng(7), truth, 400)
    rec_a = sch.submit(Job(npb_dt_like(12), distribution="tofa"))
    rec_b = sch.submit(Job(npb_dt_like(12), distribution="tofa"))
    assert rec_a.state == rec_b.state == "running"
    sch.complete(rec_b.job.job_id)
    epoch0 = sch.cluster_state().epoch
    before = dict(sch.engine.cache_stats())
    # jittery but health-preserving heartbeat rounds (estimates drift
    # inside p_f_atol, no lifecycle transitions), then a placement
    # against the identical busy profile rec_b saw
    for _ in range(5):
        sch.heartbeat_round(np.ones(64, dtype=bool))
    assert sch.cluster_state().epoch == epoch0
    rec_c = sch.submit(Job(npb_dt_like(12), distribution="tofa"))
    assert rec_c.state == "running"
    after = sch.engine.cache_stats()
    assert after["weight_misses"] == before["weight_misses"]
    assert after["shared_misses"] == before["shared_misses"]
    assert after["hop_misses"] == before["hop_misses"]


def test_estimator_jitter_would_have_missed_on_byte_keys():
    """The jitter really is there — raw byte keys would change: the
    monitor's estimates move between rounds even though health did not."""
    topo = TorusTopology((4, 4))
    sch = Scheduler(topo)
    truth = np.zeros(16)
    truth[0] = 0.3
    sch.registry.set_outage_probabilities([0], 0.3)
    rng = np.random.default_rng(3)
    sch.monitor.simulate_rounds(rng, truth, 150)
    e0 = sch.monitor.outage_probabilities()
    s0 = sch.cluster_state()
    replies = np.ones(16, dtype=bool)
    replies[0] = False                      # missed beats: estimate moves
    jittered = False
    for _ in range(6):
        sch.heartbeat_round(replies)
        jittered |= e0.tobytes() != sch.monitor.outage_probabilities() \
            .tobytes()
    assert jittered                         # byte key would have missed
    assert sch.cluster_state() is s0        # epoch key does not


# ------------------------------------------------ delta weight refreshes
def test_torus_delta_weight_update_bit_identical():
    t = TorusTopology((4, 4, 3))
    rng = np.random.default_rng(5)
    prev_p = np.zeros(t.n_nodes)
    W = t.weight_matrix(prev_p)
    for _ in range(4):
        p = np.zeros(t.n_nodes)
        p[rng.choice(t.n_nodes, 4, replace=False)] = 0.2
        changed = np.flatnonzero((p > 0) != (prev_p > 0))
        W2 = t.weight_matrix_update(W, changed, p)
        assert (W2 == t.weight_matrix(p)).all()
        prev_p, W = p, W2


def test_fattree_delta_weight_update_bit_identical():
    ft = FatTreeTopology(4)
    p0 = np.zeros(16)
    p0[[1, 2]] = 0.3
    W0 = ft.weight_matrix(p0)
    p1 = np.zeros(16)
    p1[[2, 9]] = 0.1
    changed = np.flatnonzero((p0 > 0) != (p1 > 0))
    assert (ft.weight_matrix_update(W0, changed, p1)
            == ft.weight_matrix(p1)).all()


def test_engine_uses_delta_updates_across_churn():
    topo = TorusTopology((4, 4, 4))
    engine = PlacementEngine()
    wl = npb_dt_like(12)
    s = ClusterState.healthy(64).with_outage(
        np.where(np.arange(64) < 4, 0.2, 0.0))
    rng = np.random.default_rng(0)
    full = PlacementEngine()                 # reference: fresh engine per state
    for step in range(4):
        req = PlacementRequest(comm=wl.comm, topology=topo, state=s)
        plan = engine.place(req, policy="tofa",
                            rng=np.random.default_rng(step))
        ref = full.place(PlacementRequest(comm=wl.comm, topology=topo,
                                          state=s),
                         policy="tofa", rng=np.random.default_rng(step))
        assert (plan.placement == ref.placement).all()
        assert plan.hop_bytes == ref.hop_bytes
        s = s.with_health([int(rng.integers(0, 64))], NodeHealth.DOWN)
    assert engine.cache_stats()["weight_delta_updates"] >= 2


# --------------------------------------------------- replace fast-path
def test_replace_skips_when_diff_misses_placement():
    topo = TorusTopology((4, 4, 4))
    engine = PlacementEngine()
    wl = npb_dt_like(8)
    plan = engine.place(
        PlacementRequest(comm=wl.comm, topology=topo,
                         state=ClusterState.healthy(64)),
        policy="linear")
    unused = [int(x) for x in
              np.setdiff1d(np.arange(64), plan.placement)[:3]]
    out = engine.replace(plan, unused)
    assert out is plan                       # zero-work fast path
    assert engine.cache_stats()["replace_skips"] == 1
    # diff-driven form: new state lost only unused nodes -> same skip
    s2 = plan.request.state.with_health(unused, NodeHealth.DOWN)
    out2 = engine.replace(plan, state=s2)
    assert out2 is plan
    # but a diff touching the placement does re-place
    victim = int(plan.placement[0])
    s3 = plan.request.state.with_health([victim], NodeHealth.DOWN)
    out3 = engine.replace(plan, state=s3)
    assert out3 is not plan
    assert victim not in out3.placement
    assert out3.provenance == "replace-incremental"


def test_replace_diff_driven_matches_failed_nodes_form():
    topo = TorusTopology((4, 4, 4))
    engine = PlacementEngine()
    wl = npb_dt_like(10)
    base = ClusterState.healthy(64)
    plan = engine.place(PlacementRequest(comm=wl.comm, topology=topo,
                                         state=base), policy="tofa")
    victims = [int(plan.placement[0]), int(plan.placement[3])]
    by_nodes = engine.replace(plan, victims,
                              rng=np.random.default_rng(1))
    new_state = base.with_health(victims, NodeHealth.DOWN)
    by_diff = engine.replace(plan, state=new_state,
                             rng=np.random.default_rng(1))
    assert (by_nodes.placement == by_diff.placement).all()


# ------------------------------------------------ legacy-shim ordering
def test_replace_preserves_explicit_available_order():
    """The shim's equivalence promise: a plan placed over an explicitly
    *ordered* availability array must keep that order through replace
    (``linear`` consumes it sequentially)."""
    topo = TorusTopology((4, 4))
    engine = PlacementEngine()
    order = np.arange(15, 7, -1)            # 15, 14, ..., 8
    plan = engine.place(
        PlacementRequest(comm=lammps_like(4).comm, topology=topo,
                         available=order),
        policy="linear")
    assert plan.placement.tolist() == [15, 14, 13, 12]
    new = engine.replace(plan, [15], full=True)
    assert new.placement.tolist() == [14, 13, 12, 11]
    # and with the availability refreshed via the legacy kwarg
    new2 = engine.replace(plan, [15], full=True,
                          available=np.arange(15, 5, -1))
    assert new2.placement.tolist() == [14, 13, 12, 11]


def test_scheduler_placement_request_honours_custom_available():
    """An explicit what-if availability — custom order, possibly naming
    drained nodes — passes through verbatim instead of being re-sorted
    or silently filtered by the overlay."""
    topo = TorusTopology((4, 4))
    sch = Scheduler(topo)
    sch.registry.mark([9], NodeState.DRAINED)
    req = sch.placement_request(Job(lammps_like(3), distribution="linear"),
                                available=np.array([9, 3, 5]))
    assert req.available_ids.tolist() == [9, 3, 5]
    assert req.p_f[9] == 1.0                # belief still pins drained
    plan = sch.engine.place(req, policy="linear")
    assert plan.placement.tolist() == [9, 3, 5]
    # the id-ordered free subset still rides the epoch-keyed overlay
    req2 = sch.placement_request(Job(lammps_like(3)))
    assert req2.state.is_overlay or req2.state is sch.cluster_state()


# -------------------------------------------------- backend parity churn
@pytest.mark.skipif(not has_jax(), reason="jax not installed")
def test_backend_epoch_caches_bit_identical_across_churn():
    """numpy and jax engines must return bit-identical placements through
    a state churn sequence, and the jax device cache must transfer each
    epoch's matrix once."""
    from repro.core import backend as B
    topo = TorusTopology((4, 4, 4))
    wl = npb_dt_like(16)
    churn = [ClusterState.healthy(64).with_outage(
        np.where(np.arange(64) < 5, 0.1, 0.0))]
    for ids in ([7], [9, 33], [12]):
        churn.append(churn[-1].with_health(ids, NodeHealth.DOWN))
    eng_np = PlacementEngine(backend="numpy")
    eng_jx = PlacementEngine(backend="jax")
    jx = B.get_backend("jax")
    for s in churn:
        req = PlacementRequest(comm=wl.comm, topology=topo, state=s)
        a = eng_np.place(req, policy="tofa", rng=np.random.default_rng(0))
        b = eng_jx.place(PlacementRequest(comm=wl.comm, topology=topo,
                                          state=s),
                         policy="tofa", rng=np.random.default_rng(0))
        assert (a.placement == b.placement).all()
    # warm re-placement against the last epoch: no new device transfers
    transfers = jx.stats["transfers"]
    req = PlacementRequest(comm=wl.comm, topology=topo, state=churn[-1])
    eng_jx.place(req, policy="tofa", rng=np.random.default_rng(1))
    assert jx.stats["transfers"] == transfers
