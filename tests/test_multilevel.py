"""Multilevel mapping + lazy-distance tests (PR 7).

Pins the three contracts of the scaling stack:

* ``LazyDistance`` is *bit-identical* to the dense Eq. 1 weight matrix on
  every index form — implicitness is a memory optimisation, never a
  quality change.
* ``tofa-ml`` degrades to flat ``tofa`` exactly below the coarsening
  threshold, and stays within 5% hop-bytes of it above.
* The engine's lazy path (above ``lazy_threshold``) places end-to-end
  without ever materialising an O(N^2) matrix, and its LRU caches evict
  with counters.
"""
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

from repro.core import backend as core_backend
from repro.core import mapping, multilevel
from repro.core.comm_graph import CommGraph
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.fattree import FatTreeTopology
from repro.core.lazydist import (FatTreeLazyDistance, TorusLazyDistance,
                                 is_lazy)
from repro.core.topology import TorusTopology
from repro.workloads.patterns import npb_dt_like

given, settings, st = hypothesis_or_stubs()


def _faults(n_nodes, n_faulty, seed=7, p=0.02):
    p_f = np.zeros(n_nodes)
    if n_faulty:
        bad = np.random.default_rng(seed).choice(n_nodes, n_faulty,
                                                 replace=False)
        p_f[bad] = p
    return p_f


# --------------------------------------------------------------- lazy metric
@pytest.mark.parametrize("dims", [(4, 3, 5), (5, 5), (2, 3, 4, 3)])
@pytest.mark.parametrize("n_faulty,straggle", [(0, False), (5, False),
                                               (5, True), (0, True)])
def test_torus_lazy_bitexact(dims, n_faulty, straggle):
    topo = TorusTopology(dims)
    N = topo.n_nodes
    p_f = _faults(N, n_faulty)
    s = None
    if straggle:
        s = np.zeros(N)
        s[[1, N // 2]] = 0.5
    dense = topo.weight_matrix(p_f, c=1.0, straggler=s)
    lazy = topo.lazy_distance(p_f, straggler=s)
    assert is_lazy(lazy) and lazy.shape == (N, N)
    # full row-block / ix_ / broadcast / scalar forms, all bit-equal
    rows = np.asarray(lazy[np.arange(N)])
    np.testing.assert_array_equal(rows, dense)
    sub = np.random.default_rng(0).choice(N, 7, replace=False)
    np.testing.assert_array_equal(np.asarray(lazy[np.ix_(sub, sub)]),
                                  dense[np.ix_(sub, sub)])
    np.testing.assert_array_equal(
        np.asarray(lazy[sub[:, None], sub[None, :]]),
        dense[np.ix_(sub, sub)])
    np.testing.assert_array_equal(np.asarray(lazy[3]), dense[3])
    assert lazy[2, 5] == dense[2, 5]


def test_fattree_lazy_bitexact():
    topo = FatTreeTopology(8)
    N = topo.n_nodes
    for p_f in (None, _faults(N, 6)):
        dense = topo.weight_matrix(p_f)
        lazy = topo.lazy_distance(p_f)
        assert isinstance(lazy, FatTreeLazyDistance)
        np.testing.assert_array_equal(np.asarray(lazy[np.arange(N)]), dense)


def test_lazy_never_silently_densifies():
    lazy = TorusTopology((4, 4, 4)).lazy_distance()
    with pytest.raises(TypeError):
        np.asarray(lazy)
    with pytest.raises(TypeError):
        np.array(lazy)


def test_implicit_spec_only_when_uniform():
    topo = TorusTopology((4, 4, 4))
    assert topo.lazy_distance().implicit is not None
    assert topo.lazy_distance(_faults(64, 3)).implicit is None
    s = np.zeros(64)
    s[5] = 1.0
    assert topo.lazy_distance(straggler=s).implicit is None
    spec = topo.lazy_distance(p_f=np.zeros(64)).implicit
    assert spec is not None and spec.dims == (4, 4, 4)


def test_hop_matrix_memoised_construction_cheap():
    topo = TorusTopology((6, 6, 6))
    assert "_hop_matrix" not in topo.__dict__  # deferred until first use
    M = topo.hop_matrix()
    assert topo.hop_matrix() is M
    ft = FatTreeTopology(8)
    assert ft.hop_matrix() is ft.hop_matrix()


# ------------------------------------------------------- coarsen / uncoarsen
def test_coarsen_conserves_sizes_and_weight():
    G = npb_dt_like(300, seed=3).comm.weights("volume")
    levels, Gc, sizes_c = multilevel.coarsen(G, 160)
    assert levels and Gc.shape[0] <= 160
    assert sizes_c.sum() == 300
    assert Gc.sum() <= G.sum() + 1e-9          # matched weight internalised
    assert np.allclose(Gc, Gc.T) and np.all(np.diag(Gc) == 0)
    # every original process lands in exactly one final super-vertex
    labels = multilevel.uncoarsen_map(levels)
    assert len(labels[-1]) == 300
    counts = np.bincount(labels[-1], minlength=Gc.shape[0])
    np.testing.assert_array_equal(counts, sizes_c)


def test_coarsen_noop_below_target():
    G = npb_dt_like(64, seed=3).comm.weights("volume")
    levels, Gc, sizes_c = multilevel.coarsen(G, 160)
    assert levels == [] and Gc.shape[0] == 64


@given(n=st.integers(min_value=2, max_value=40), seed=st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_coarsen_roundtrip_property(n, seed):
    rng = np.random.default_rng(seed)
    G = rng.random((n, n))
    G = (G + G.T) / 2
    np.fill_diagonal(G, 0.0)
    match, Gc, sizes_c = multilevel.coarsen_level(
        G, np.ones(n, dtype=np.int64))
    nc = Gc.shape[0]
    assert match.min() >= 0 and match.max() == nc - 1
    assert sizes_c.sum() == n and sizes_c.max() <= 2  # HEM pairs at most
    # coarse edge (a, b) equals the sum of fine edges crossing a-b
    for a in range(min(nc, 4)):
        for b in range(min(nc, 4)):
            if a == b:
                continue
            fa, fb = match == a, match == b
            assert Gc[a, b] == pytest.approx(G[np.ix_(fa, fb)].sum())


# ---------------------------------------------------------------- multilevel
def test_multilevel_noop_is_map_graph():
    topo = TorusTopology((5, 5, 5))
    G = npb_dt_like(100, seed=3).comm.weights("volume")
    D = topo.hop_matrix()
    nodes = np.arange(100)   # len(nodes) == n: no snake pre-truncation
    a = multilevel.multilevel_map(G, nodes, topo.coords_array(), D=D,
                                 rng=np.random.default_rng(0),
                                 coarse_target=160)
    b = mapping.map_graph(G, nodes, topo.coords_array(), D=D,
                          rng=np.random.default_rng(0))
    np.testing.assert_array_equal(a, b)  # coarsening no-op -> bit-identical


@pytest.mark.parametrize("topo,n,n_faulty", [
    (TorusTopology((8, 8, 8)), 256, 0),
    (TorusTopology((8, 8, 8)), 256, 12),
    (TorusTopology((8, 8, 8)), 512, 0),
    (FatTreeTopology(8), 100, 6),
    (FatTreeTopology(8), 128, 0),
])
def test_tofa_ml_within_5pct_of_flat(topo, n, n_faulty):
    p_f = _faults(topo.n_nodes, n_faulty)
    wl = npb_dt_like(n, seed=3)
    req = PlacementRequest(comm=wl.comm, topology=topo, p_f=p_f)
    engine = PlacementEngine()
    flat = engine.place(req, policy="tofa", rng=np.random.default_rng(0))
    ml = engine.place(req, policy="tofa-ml", rng=np.random.default_rng(0))
    assert ml.hop_bytes <= flat.hop_bytes * 1.05


def test_tofa_ml_bit_identical_below_coarse_target():
    topo = TorusTopology((8, 8, 4))
    wl = npb_dt_like(120, seed=3)  # 120 <= COARSE_TARGET=160
    req = PlacementRequest(comm=wl.comm, topology=topo,
                           p_f=_faults(topo.n_nodes, 8))
    engine = PlacementEngine()
    flat = engine.place(req, policy="tofa", rng=np.random.default_rng(0))
    ml = engine.place(req, policy="tofa-ml", rng=np.random.default_rng(0))
    np.testing.assert_array_equal(ml.placement, flat.placement)


def test_hierarchical_select_contract():
    topo = TorusTopology((8, 8, 8))
    p_f = _faults(topo.n_nodes, 20)
    D = topo.lazy_distance(p_f)
    groups = topo.hierarchy_groups(64)
    healthy = p_f == 0
    sel = multilevel.hierarchical_select(D, groups, 100, healthy=healthy)
    assert len(sel) == 100
    assert len(np.unique(sel)) == 100
    assert healthy[sel].all()
    np.testing.assert_array_equal(sel, np.sort(sel))
    # quality: the hierarchical ball's internal cost stays close to the
    # dense full-matrix select_nodes ball's
    Wd = topo.weight_matrix(p_f)
    ref = mapping.select_nodes(
        Wd + 1e9 * ((p_f[:, None] > 0) | (p_f[None, :] > 0)), 100)
    cost = lambda ids: Wd[np.ix_(ids, ids)].sum()
    assert cost(sel) <= cost(ref) * 1.4   # rack-granular ball, bounded loss


# ----------------------------------------------------------- engine, caches
def test_engine_lazy_end_to_end_matches_dense():
    topo = TorusTopology((6, 6, 4))   # 144 nodes
    wl = npb_dt_like(64, seed=3)
    for n_faulty in (0, 8):
        req = PlacementRequest(comm=wl.comm, topology=topo,
                               p_f=_faults(topo.n_nodes, n_faulty))
        dense_eng = PlacementEngine(lazy_threshold=10_000)
        lazy_eng = PlacementEngine(lazy_threshold=100)
        assert not dense_eng._use_lazy(topo)
        assert lazy_eng._use_lazy(topo)
        assert is_lazy(lazy_eng.hops(topo))
        d = dense_eng.place(req, policy="tofa", rng=np.random.default_rng(0))
        l = lazy_eng.place(req, policy="tofa", rng=np.random.default_rng(0))
        assert l.hop_bytes <= d.hop_bytes * 1.05


def test_engine_lazy_threshold_env(monkeypatch):
    monkeypatch.setenv("REPRO_LAZY_THRESHOLD", "123")
    assert PlacementEngine().lazy_threshold == 123
    assert PlacementEngine(lazy_threshold=9).lazy_threshold == 9


def test_engine_lru_topology_eviction():
    engine = PlacementEngine(max_cached_topologies=2)
    wl = npb_dt_like(16, seed=3)
    for dims in [(4, 4), (4, 5), (4, 6), (4, 7)]:
        req = PlacementRequest(comm=wl.comm, topology=TorusTopology(dims))
        engine.place(req, policy="tofa", rng=np.random.default_rng(0))
    stats = engine.cache_stats()
    assert stats["topology_evictions"] >= 2
    assert stats["cached_topologies"] <= 2


def test_engine_lru_weight_eviction():
    topo = TorusTopology((4, 4, 4))
    engine = PlacementEngine(max_cached_weights=1)
    wl = npb_dt_like(16, seed=3)
    for seed in range(3):
        req = PlacementRequest(comm=wl.comm, topology=topo,
                               p_f=_faults(topo.n_nodes, 4, seed=seed))
        engine.place(req, policy="tofa", rng=np.random.default_rng(0))
    stats = engine.cache_stats()
    assert stats["weight_evictions"] >= 1
    assert stats["cached_weight_matrices"] <= 1


# ------------------------------------------------------------- jax implicit
@pytest.mark.skipif(not core_backend.has_jax(), reason="jax not installed")
def test_jax_implicit_matches_dense():
    topo = TorusTopology((6, 6, 4))
    n = 64
    G = npb_dt_like(n, seed=3).comm.weights("volume")
    Dd = topo.hop_matrix()
    Dl = topo.lazy_distance()
    assert Dl.implicit is not None
    rng = np.random.default_rng(0)
    P = np.stack([rng.permutation(topo.n_nodes)[:n] for _ in range(4)])
    hb_np = mapping.hop_bytes_batch(G, Dd, P)
    R_np = mapping.refine_batch(G, Dd, P)
    with core_backend.use("jax"):
        hb_dense = mapping.hop_bytes_batch(G, Dd, P)
        hb_impl = mapping.hop_bytes_batch(G, Dl, P)
        R_dense = mapping.refine_batch(G, Dd, P)
        R_impl = mapping.refine_batch(G, Dl, P)
    np.testing.assert_allclose(hb_dense, hb_np, rtol=1e-9)
    np.testing.assert_allclose(hb_impl, hb_np, rtol=1e-9)
    np.testing.assert_array_equal(R_dense, R_np)
    np.testing.assert_array_equal(R_impl, R_np)


def test_numpy_lazy_refine_matches_dense():
    topo = TorusTopology((6, 6, 4))
    n = 48
    G = npb_dt_like(n, seed=3).comm.weights("volume")
    p_f = _faults(topo.n_nodes, 6)
    Dd = topo.weight_matrix(p_f)
    Dl = topo.lazy_distance(p_f)   # faulty -> no implicit spec, exact lazy
    rng = np.random.default_rng(0)
    P = np.stack([rng.permutation(topo.n_nodes)[:n] for _ in range(3)])
    np.testing.assert_allclose(mapping.hop_bytes_batch(G, Dl, P),
                               mapping.hop_bytes_batch(G, Dd, P), rtol=1e-12)
    np.testing.assert_array_equal(mapping.refine_batch(G, Dl, P),
                                  mapping.refine_batch(G, Dd, P))
