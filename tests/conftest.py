"""Shared test utilities: optional-dependency guards.

JAX is an *optional* dependency of the placement stack (the
``repro[jax]`` extra): the core placement/simulation suites run on a
NumPy-only install, while the accelerator-layer suites (models, kernels,
launch, profiler) need JAX and are skipped wholesale when it is absent —
the CI backend matrix runs both configurations.
"""
import pytest

try:
    import jax  # noqa: F401
except ImportError:
    collect_ignore = [
        "test_dryrun.py",
        "test_kernels.py",
        "test_models_smoke.py",
        "test_profiler.py",
        "test_system.py",
        "test_train_extras.py",
    ]


def hypothesis_or_stubs():
    """Return ``(given, settings, st)``, real or stand-in.

    On a bare environment without ``hypothesis``, the stand-ins mark the
    decorated property tests as skipped while the rest of the module still
    collects and runs — the suite must never error at import time over an
    optional dev dependency.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        skip = pytest.mark.skip(reason="hypothesis not installed")

        def deco(*args, **kwargs):
            return lambda f: skip(f)

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return deco, deco, _Strategies()
