"""Shared test utilities: optional-dependency guards."""
import pytest


def hypothesis_or_stubs():
    """Return ``(given, settings, st)``, real or stand-in.

    On a bare environment without ``hypothesis``, the stand-ins mark the
    decorated property tests as skipped while the rest of the module still
    collects and runs — the suite must never error at import time over an
    optional dev dependency.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        skip = pytest.mark.skip(reason="hypothesis not installed")

        def deco(*args, **kwargs):
            return lambda f: skip(f)

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return deco, deco, _Strategies()
