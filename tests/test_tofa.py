import numpy as np
import pytest

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core.tofa import POLICIES, place, tofa_place
from repro.core.topology import TorusTopology
from repro.workloads.patterns import lammps_like, npb_dt_like


@pytest.fixture(scope="module")
def torus():
    return TorusTopology((8, 8, 8))


def test_tofa_healthy_uses_window(torus):
    wl = lammps_like(64)
    res = tofa_place(wl.comm, torus, None)
    assert res.used_consecutive_window
    assert res.faulty_nodes_used == 0
    assert len(set(res.placement.tolist())) == 64


def test_tofa_avoids_faulty_nodes_when_window_exists(torus):
    wl = npb_dt_like(85)
    rng = np.random.default_rng(3)
    p_f = np.zeros(512)
    p_f[rng.choice(512, 16, replace=False)] = 0.02
    res = tofa_place(wl.comm, torus, p_f)
    assert res.faulty_nodes_used == 0, \
        "TOFA must avoid faulty nodes when enough healthy nodes exist"


def test_tofa_no_window_falls_back_to_weighted_map(torus):
    # poison every 8th node: longest healthy run is 7 < 64 -> step 12 path
    wl = lammps_like(64)
    p_f = np.zeros(512)
    p_f[::8] = 0.05
    res = tofa_place(wl.comm, torus, p_f)
    assert not res.used_consecutive_window
    # 448 healthy nodes remain; weighted selection must still avoid faults
    assert res.faulty_nodes_used == 0
    assert len(set(res.placement.tolist())) == 64


def test_tofa_tolerates_faults_when_unavoidable():
    # 16-node torus, 60% faulty, 10-process job: some faults unavoidable
    t = TorusTopology((4, 4))
    p_f = np.zeros(16)
    p_f[:10] = 0.5  # only 6 healthy nodes
    wl = lammps_like(10)
    res = tofa_place(wl.comm, t, p_f)
    assert len(set(res.placement.tolist())) == 10
    assert res.faulty_nodes_used >= 4  # needs at least 4 faulty


def test_linear_is_default_slurm(torus):
    wl = lammps_like(16)
    res = place("linear", wl.comm, torus)
    assert list(res.placement) == list(range(16))


def test_all_policies_valid(torus):
    wl = npb_dt_like(40)
    for pol in POLICIES:
        res = place(pol, wl.comm, torus, rng=np.random.default_rng(1))
        assert len(res.placement) == 40
        assert len(set(res.placement.tolist())) == 40, pol
        assert res.policy == pol
        assert (res.placement >= 0).all() and (res.placement < 512).all()


def test_tofa_beats_linear_hop_bytes_on_irregular(torus):
    wl = npb_dt_like(85)
    hb = {p: place(p, wl.comm, torus, rng=np.random.default_rng(0)).hop_bytes
          for p in ("linear", "tofa")}
    assert hb["tofa"] < hb["linear"]


def test_too_many_processes_raises():
    t = TorusTopology((2, 2))
    wl = lammps_like(10)
    with pytest.raises(ValueError):
        tofa_place(wl.comm, t, None)


# ---------------------------------------------------------------- property
@settings(max_examples=25, deadline=None)
@given(
    n_faulty=st.integers(0, 40),
    n_procs=st.integers(2, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_tofa_invariants(n_faulty, n_procs, seed):
    """Any fault pattern: placement is injective, in range, and never uses a
    faulty node while >= n_procs healthy nodes exist (Eq. 1's purpose)."""
    rng = np.random.default_rng(seed)
    t = TorusTopology((4, 4, 4))
    p_f = np.zeros(64)
    if n_faulty:
        p_f[rng.choice(64, min(n_faulty, 64), replace=False)] = 0.02
    wl = npb_dt_like(n_procs, seed=seed % 100)
    res = tofa_place(wl.comm, t, p_f, rng=rng)
    pl = res.placement
    assert len(pl) == n_procs
    assert len(set(pl.tolist())) == n_procs
    assert (pl >= 0).all() and (pl < 64).all()
    if (p_f == 0).sum() >= n_procs:
        assert res.faulty_nodes_used == 0
