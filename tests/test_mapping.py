import numpy as np
import pytest

from repro.core.comm_graph import CommGraph
from repro.core.mapping import (bisect_graph, bisect_nodes, greedy_placement,
                                hop_bytes, linear_placement, map_graph,
                                random_placement, select_nodes)
from repro.core.topology import TorusTopology
from repro.workloads.patterns import lammps_like, npb_dt_like


def test_bisect_graph_sizes():
    rng = np.random.default_rng(0)
    W = rng.random((20, 20))
    W = W + W.T
    for s in (1, 7, 10, 19):
        in0 = bisect_graph(W, s)
        assert in0.sum() == s


def test_bisect_graph_finds_planted_partition():
    # two dense blocks weakly connected: bisection must recover them
    n = 16
    W = np.zeros((n, n))
    rng = np.random.default_rng(1)
    for i in range(n):
        for j in range(i + 1, n):
            same = (i < 8) == (j < 8)
            w = 10.0 + rng.random() if same else 0.01 * rng.random()
            W[i, j] = W[j, i] = w
    in0 = bisect_graph(W, 8)
    side = in0[:8]
    assert side.all() or not side.any(), "planted bisection not recovered"


def test_bisect_nodes_geometric_compact():
    t = TorusTopology((4, 8))
    nodes = np.arange(32)
    a, b = bisect_nodes(nodes, t.coords_array(), 16)
    assert len(a) == 16 and len(b) == 16
    # split along dim of span 8: each half spans half the long dimension
    ca = t.coords_array()[a]
    assert ca[:, 1].max() - ca[:, 1].min() <= 3


def test_select_nodes_avoids_expensive():
    t = TorusTopology((8, 8))
    p = np.zeros(64)
    bad = [0, 9, 18, 27]
    p[bad] = 0.5
    W = t.weight_matrix(p)
    chosen = select_nodes(W, 16)
    assert len(chosen) == 16
    assert not set(bad) & set(chosen.tolist())


def test_map_graph_valid_assignment():
    wl = npb_dt_like(40)
    t = TorusTopology((8, 8))
    nodes = np.arange(64)
    pl = map_graph(wl.comm.G_v, nodes, t.coords_array(), D=t.hop_matrix())
    assert len(pl) == 40
    assert len(set(pl.tolist())) == 40, "placement must be injective"
    assert set(pl.tolist()) <= set(nodes.tolist())


@pytest.mark.parametrize("wl_fn,n", [(lammps_like, 64), (npb_dt_like, 85)])
def test_mapper_beats_random_and_linear(wl_fn, n):
    """Fig. 3 property: topology-aware mapping lowers hop-bytes vs baselines."""
    from repro.core.tofa import place
    wl = wl_fn(n)
    t = TorusTopology((8, 8, 8))
    D = t.hop_matrix()
    rng = np.random.default_rng(0)
    mapped = place("topo", wl.comm, t).placement
    lin = linear_placement(n, np.arange(t.n_nodes))
    rand = random_placement(n, np.arange(t.n_nodes), rng)
    hb_map = hop_bytes(wl.comm.G_v, D, mapped)
    hb_lin = hop_bytes(wl.comm.G_v, D, lin)
    hb_rand = hop_bytes(wl.comm.G_v, D, rand)
    assert hb_map < hb_rand, "mapper must beat random placement"
    assert hb_map < hb_lin, "mapper must beat sequential default placement"


def test_mapper_beats_linear_on_irregular():
    """The paper's key contrast: irregular patterns are where linear
    (default-slurm) placement loses most (22% in Fig. 3a)."""
    from repro.core.tofa import place
    wl = npb_dt_like(85)
    t = TorusTopology((8, 8, 8))
    D = t.hop_matrix()
    mapped = place("topo", wl.comm, t).placement
    hb_map = hop_bytes(wl.comm.G_v, D, mapped)
    hb_lin = hop_bytes(wl.comm.G_v, D, linear_placement(85, np.arange(512)))
    assert hb_map < 0.85 * hb_lin, (
        f"expected >15% hop-bytes win on irregular pattern, got "
        f"{1 - hb_map / hb_lin:.1%}")


def test_greedy_places_heaviest_pair_adjacent():
    g = CommGraph(4)
    g.add_p2p(0, 3, 1000.0)
    g.add_p2p(1, 2, 10.0)
    t = TorusTopology((4, 4))
    pl = greedy_placement(g.G_v, np.arange(16), t.hop_matrix())
    assert t.hop_matrix()[pl[0], pl[3]] == 1
    assert len(set(pl.tolist())) == 4


def test_linear_placement_is_identity_prefix():
    pl = linear_placement(5, np.arange(100))
    assert list(pl) == [0, 1, 2, 3, 4]
