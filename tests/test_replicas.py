"""Monte-Carlo replica engine: statistics, determinism, executors.

Covers the three contracts ``benchmarks/clustersim.py --check`` rests on:

* the bootstrap statistics are correct (closed-form checks, degenerate
  inputs, paired comparisons);
* every replica is bit-identical to a standalone ``run_preset`` call
  with the same seed — across all presets, the process-pool executor,
  and the vectorized paper-mode path;
* a fixed-seed :class:`SummaryStats` regression pins the aggregate
  numbers so silent changes to preset RNG streams fail loudly.
"""
import numpy as np
import pytest

from repro.sim.replicas import (
    PairedComparison, ReplicaSet, SummaryStats, bootstrap_ci,
    paired_compare, paper_replica_vector, run_replicas, summarize,
    _flat_policy_rows,
)
from repro.sim.scenarios import SCENARIOS, run_preset

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()


# ------------------------------------------------------------ statistics
def test_bootstrap_ci_matches_normal_theory():
    rng = np.random.default_rng(0)
    x = rng.normal(loc=3.0, scale=2.0, size=400)
    lo, hi = bootstrap_ci(x, B=4000, alpha=0.05, seed=1)
    se = x.std(ddof=1) / np.sqrt(x.size)
    assert lo < x.mean() < hi
    # percentile bootstrap ~ mean +/- 1.96 se for a big normal sample
    assert lo == pytest.approx(x.mean() - 1.96 * se, abs=0.6 * se)
    assert hi == pytest.approx(x.mean() + 1.96 * se, abs=0.6 * se)


def test_bootstrap_ci_level_monotone():
    rng = np.random.default_rng(3)
    x = rng.exponential(size=200)
    lo95, hi95 = bootstrap_ci(x, B=2000, alpha=0.05, seed=2)
    lo50, hi50 = bootstrap_ci(x, B=2000, alpha=0.50, seed=2)
    assert lo95 <= lo50 <= hi50 <= hi95


def test_bootstrap_ci_degenerate_inputs():
    assert bootstrap_ci([4.2]) == (4.2, 4.2)            # single observation
    assert bootstrap_ci([1.5] * 10) == (1.5, 1.5)       # zero variance
    lo, hi = bootstrap_ci([1.0, 2.0], B=200, seed=0)    # tiny n still sane
    assert 1.0 <= lo <= hi <= 2.0
    with pytest.raises(ValueError):
        bootstrap_ci([])
    with pytest.raises(ValueError):
        bootstrap_ci([1.0, 2.0], alpha=1.5)
    with pytest.raises(ValueError):
        bootstrap_ci([1.0, 2.0], B=0)
    with pytest.raises(ValueError):
        bootstrap_ci(np.zeros((2, 2)))


def test_bootstrap_ci_seed_reproducible():
    x = np.random.default_rng(5).normal(size=50)
    assert bootstrap_ci(x, seed=9) == bootstrap_ci(x, seed=9)
    assert bootstrap_ci(x, seed=9) != bootstrap_ci(x, seed=10)


def test_summarize_fields_consistent():
    x = np.random.default_rng(1).normal(size=100)
    s = summarize(x, metric="m", B=500, alpha=0.05, seed=0)
    assert isinstance(s, SummaryStats)
    assert s.metric == "m" and s.n == 100
    assert s.ci_low <= s.mean <= s.ci_high
    assert s.p05 <= s.p50 <= s.p95
    assert s.std == pytest.approx(x.std(ddof=1))
    assert summarize([7.0]).std == 0.0


def test_paired_compare_detects_shift():
    rng = np.random.default_rng(2)
    b = rng.normal(loc=5.0, scale=1.0, size=64)
    a = b - rng.uniform(0.5, 1.5, size=64)     # a strictly smaller
    cmp = paired_compare(a, b, a="tofa", b="linear", B=1000, seed=0)
    assert isinstance(cmp, PairedComparison)
    assert cmp.significant and cmp.delta_ci_low > 0
    assert cmp.win_rate == 1.0
    assert cmp.p_value <= 2 / 1001
    assert cmp.delta == pytest.approx(float((b - a).mean()))


def test_paired_compare_null_not_significant():
    x = np.random.default_rng(4).normal(size=64)
    cmp = paired_compare(x, x, B=500)
    assert cmp.delta == 0.0 and not cmp.significant
    assert cmp.win_rate == 0.0 and cmp.p_value > 0.5
    with pytest.raises(ValueError):
        paired_compare([1.0, 2.0], [1.0])


# ----------------------------------------------------------- determinism
def _strip_wall(rows):
    return {pol: {k: v for k, v in r.items() if k != "place_time_s"}
            for pol, r in rows.items()}


@pytest.mark.parametrize("preset", sorted(SCENARIOS))
def test_replica_bit_identical_to_standalone(preset):
    """run_replicas(seeds=[k]) reproduces run_preset(seed=k) bit-for-bit
    (wall-clock fields excepted) for every registered preset."""
    seed = 11
    rs = run_replicas(preset, seeds=[seed], fast=True)
    ref = _strip_wall(_flat_policy_rows(run_preset(preset, seed=seed,
                                                   fast=True)))
    for pol, row in ref.items():
        for k, v in row.items():
            assert rs.metrics[pol][k][0] == v, (preset, pol, k)


@given(seed=st.integers(min_value=0, max_value=40))
@settings(max_examples=8, deadline=None)
def test_replica_bit_identical_property(seed):
    rs = run_replicas("fat-tree", seeds=[seed], fast=True)
    ref = _strip_wall(_flat_policy_rows(run_preset("fat-tree", seed=seed,
                                                   fast=True)))
    for pol, row in ref.items():
        for k, v in row.items():
            assert rs.metrics[pol][k][0] == v, (seed, pol, k)


def test_process_pool_equals_serial():
    a = run_replicas("fat-tree", n_replicas=4, fast=True, executor="serial")
    b = run_replicas("fat-tree", n_replicas=4, fast=True,
                     executor="process", max_workers=2)
    assert a.seeds == b.seeds and a.policies == b.policies
    for pol in a.metrics:
        for k in a.metrics[pol]:
            if k == "place_time_s":
                continue
            assert np.array_equal(a.metrics[pol][k], b.metrics[pol][k])


def test_vectorized_paper_path_equals_event_path():
    vec = run_replicas("paper-fig4-5", n_replicas=3, fast=True)
    evt = run_replicas("paper-fig4-5", n_replicas=3, fast=True,
                       vectorize="never")
    for pol in vec.metrics:
        for k in vec.metrics[pol]:
            if k == "place_time_s":
                continue
            assert np.array_equal(vec.metrics[pol][k],
                                  evt.metrics[pol][k]), (pol, k)


def test_vectorized_single_replica_matches_standalone():
    ref = _strip_wall(_flat_policy_rows(run_preset("paper-fig4-5", seed=4,
                                                   fast=True)))
    vec = _strip_wall(_flat_policy_rows(paper_replica_vector(seed=4,
                                                             fast=True)))
    assert vec == ref


# ------------------------------------------------------------- aggregate
def test_replicaset_compare_and_summary():
    rs = run_replicas("dragonfly", n_replicas=6, fast=True)
    assert isinstance(rs, ReplicaSet) and rs.n_replicas == 6
    s = rs.summary("tofa")
    assert s.n == 6 and s.ci_low <= s.mean <= s.ci_high
    cmp = rs.compare(B=500)
    assert cmp.a == "tofa" and cmp.b == "linear" and cmp.n == 6
    assert 0.0 <= cmp.win_rate <= 1.0
    assert cmp.delta_ci_low <= cmp.delta <= cmp.delta_ci_high
    with pytest.raises(KeyError):
        rs.samples("no-such-policy")


def test_run_replicas_argument_validation():
    with pytest.raises(KeyError):
        run_replicas("no-such-preset", n_replicas=1)
    with pytest.raises(ValueError):
        run_replicas("fat-tree")                       # neither
    with pytest.raises(ValueError):
        run_replicas("fat-tree", n_replicas=2, seeds=[0, 1])   # both
    with pytest.raises(ValueError):
        run_replicas("fat-tree", n_replicas=0)
    with pytest.raises(ValueError):
        run_replicas("fat-tree", n_replicas=1, executor="threads")
    with pytest.raises(ValueError):
        run_replicas("fat-tree", n_replicas=1, vectorize="always")


def test_summary_stats_regression_fat_tree_32():
    """Fixed-seed pin: fast fat-tree across 32 replicas, B=1000.

    These numbers change only if a preset RNG stream, the placement
    policies, or the simulator semantics change — all of which must be
    deliberate, visible events.
    """
    rs = run_replicas("fat-tree", n_replicas=32, fast=True)
    s_tofa = rs.summary("tofa", B=1000, seed=0)
    s_lin = rs.summary("linear", B=1000, seed=0)
    cmp = rs.compare(B=1000, seed=0)
    assert s_tofa.mean == pytest.approx(PINNED["tofa_mean"], rel=1e-9)
    assert s_tofa.ci_low == pytest.approx(PINNED["tofa_ci_low"], rel=1e-9)
    assert s_tofa.ci_high == pytest.approx(PINNED["tofa_ci_high"], rel=1e-9)
    assert s_lin.mean == pytest.approx(PINNED["linear_mean"], rel=1e-9)
    assert cmp.win_rate == pytest.approx(PINNED["win_rate"], rel=1e-9)
    assert cmp.delta == pytest.approx(PINNED["delta"], rel=1e-9)


PINNED = {
    # regenerate: run_replicas("fat-tree", n_replicas=32, fast=True),
    # summary(B=1000, seed=0) / compare(B=1000, seed=0)
    "tofa_mean": 0.90792345,
    "tofa_ci_low": 0.8037965361979167,
    "tofa_ci_high": 1.02415233,
    "linear_mean": 1.0694688874999998,
    "win_rate": 0.78125,
    "delta": 0.16154543749999997,
}
