"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
shape/dtype sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_tpu
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_tpu
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.ssm import ssd_chunked

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _naive_attention(q, k, v, causal):
    import math
    H, Hkv = q.shape[1], k.shape[1]
    if H != Hkv:
        k = jnp.repeat(k, H // Hkv, axis=1)
        v = jnp.repeat(v, H // Hkv, axis=1)
    s = jnp.einsum("bhsk,bhtk->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        Sq, Sk = q.shape[2], k.shape[2]
        qp = jnp.arange(Sq) + (Sk - Sq)
        kp = jnp.arange(Sk)
        s = jnp.where(kp[None, :] <= qp[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtk->bhsk", p, v.astype(jnp.float32))


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,Sq,Sk,Dh,causal", [
    (1, 2, 2, 64, 64, 32, True),
    (2, 4, 2, 96, 96, 64, True),      # GQA + non-pow2 seq (padding)
    (1, 4, 1, 32, 128, 64, True),     # decode-ish: Sq < Sk, MQA
    (2, 2, 2, 64, 64, 128, False),    # non-causal (cross attention)
    (1, 8, 4, 200, 200, 64, True),    # ragged tail
])
def test_flash_kernel_matches_ref(B, H, Hkv, Sq, Sk, Dh, causal, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, Dh), dtype)
    out_k = flash_attention_tpu(q, k, v, causal=causal, block_q=32,
                                block_k=32, interpret=True)
    out_r = flash_attention_ref(q, k, v, causal=causal, q_block=16,
                                kv_block=32)
    naive = _naive_attention(q, k, v, causal)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(naive), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(out_r, np.float32),
                               np.asarray(naive), atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(
    sq=st.integers(4, 80), dh=st.sampled_from([16, 32, 64]),
    h=st.sampled_from([1, 2, 4]), seed=st.integers(0, 100),
)
def test_flash_kernel_property(sq, dh, h, seed):
    """Any shape: kernel == oracle == naive within fp tolerance."""
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, h, sq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (1, h, sq, dh), jnp.float32)
    v = jax.random.normal(ks[2], (1, h, sq, dh), jnp.float32)
    out_k = flash_attention_tpu(q, k, v, causal=True, block_q=16,
                                block_k=16, interpret=True)
    naive = _naive_attention(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(naive),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------- ssd
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,G,S,P,N,chunk", [
    (1, 2, 1, 64, 16, 16, 16),
    (2, 4, 2, 128, 32, 32, 32),
    (1, 8, 1, 96, 64, 128, 32),   # grouped broadcast, wide state
])
def test_ssd_kernel_matches_ref(B, H, G, S, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(1), 4)
    xdt = jax.random.normal(ks[0], (B, H, S, P), dtype) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.5
    dA = dA.astype(dtype)
    Bm = jax.random.normal(ks[2], (B, G, S, N), dtype) * 0.5
    Cm = jax.random.normal(ks[3], (B, G, S, N), dtype) * 0.5
    y_k, st_k = ssd_scan_tpu(xdt, dA, Bm, Cm, chunk=chunk, interpret=True)
    y_r, st_r = ssd_scan_ref(xdt, dA, Bm, Cm, chunk=chunk)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st_k, np.float32),
                               np.asarray(st_r, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_model_chunked_matches_direct_recurrence():
    """models/ssm.ssd_chunked (used by mamba2/zamba2) == exact recurrence."""
    ks = jax.random.split(jax.random.key(2), 5)
    B, S, H, P, G, N = 2, 64, 4, 16, 1, 32
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y, fin = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)

    from repro.kernels.ssd_scan.ref import _direct
    xdt = jnp.moveaxis(x * dt[..., None], 1, 2)
    dA = jnp.moveaxis(dt * A[None, None, :], 1, 2)
    y_d, fin_d = _direct(xdt, dA, jnp.moveaxis(Bm, 1, 2),
                         jnp.moveaxis(Cm, 1, 2))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.moveaxis(y_d, 1, 2)),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_d),
                               atol=1e-4, rtol=1e-4)


def test_ssd_decode_consistent_with_scan():
    """Running ssd_chunked over S tokens == S single decode steps."""
    from repro.models.ssm import ssd_decode_step
    ks = jax.random.split(jax.random.key(3), 5)
    B, S, H, P, G, N = 1, 8, 2, 8, 1, 16
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y_scan, fin = ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
    state2 = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(S):
        y_t, state2 = ssd_decode_step(state2, x[:, t], dt[:, t], A,
                                      Bm[:, t], Cm[:, t])
        outs.append(y_t)
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(state2),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64), (3, 7, 128), (130, 256)])
def test_rmsnorm_kernel_matches_ref(shape, dtype):
    ks = jax.random.split(jax.random.key(4), 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], shape[-1:], dtype) + 1.0
    out_k = rmsnorm_tpu(x, w, interpret=True, block_rows=8)
    out_r = rmsnorm_ref(x, w)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 64), d=st.sampled_from([32, 128, 512]),
       seed=st.integers(0, 50))
def test_rmsnorm_property(rows, d, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    x = jax.random.normal(ks[0], (rows, d))
    w = jax.random.normal(ks[1], (d,)) + 1.0
    out_k = rmsnorm_tpu(x, w, interpret=True, block_rows=16)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(rmsnorm_ref(x, w)),
                               atol=2e-5, rtol=2e-5)
    # invariance: rmsnorm(c*x) == rmsnorm(x) for any positive scale c
    out_s = rmsnorm_tpu(3.7 * x, w, interpret=True, block_rows=16)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_k),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------- swap_gain
@pytest.mark.parametrize("n,block_rows", [(64, 64), (200, 64), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_swap_gain_kernel_matches_ref(n, block_rows, dtype):
    from repro.kernels.swap_gain.kernel import swap_gain_tpu
    from repro.kernels.swap_gain.ref import swap_gain_ref

    rng = np.random.default_rng(0)
    M = jnp.asarray(rng.random((n, n)), dtype=dtype)
    M = 0.5 * (M + M.T)
    G = jnp.asarray(rng.random((n, n)) * (rng.random((n, n)) < 0.2),
                    dtype=dtype)
    G = 0.5 * (G + G.T)
    contrib = (G * M).sum(1)
    tol = 2e-4 if dtype == jnp.float32 else 1e-9
    for i in (0, n // 2, n - 1):
        ref = swap_gain_ref(M, G, contrib, i)
        out = swap_gain_tpu(M, G, contrib, jnp.int32(i),
                            block_rows=block_rows, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol * float(n))


def _select_oracle(M, G, contrib, i, n_valid):
    """Composed oracle for the fused select: full gains row, mask, argmax,
    accept-or-identity — the exact steps the fused kernel collapses."""
    from repro.kernels.swap_gain.ref import GAIN_EPS, swap_gain_ref

    g = np.asarray(swap_gain_ref(jnp.asarray(M), jnp.asarray(G),
                                 jnp.asarray(contrib), i)).copy()
    g[i] = 0.0
    g[n_valid:] = -np.inf
    j = int(np.argmax(g))
    gain = float(g[j])
    if not (gain > GAIN_EPS and i < n_valid):
        j = i
    return gain, j


@pytest.mark.parametrize("n,n_valid,block_rows", [
    (16, 16, 8),        # single block
    (64, 64, 64),       # block == n
    (200, 180, 64),     # ragged + padded tail beyond n_valid
    (300, 256, 128),    # multi-block with padding
])
def test_swap_select_triad(n, n_valid, block_rows):
    """Fused mover select: ref == Pallas-interpret == composed oracle,
    including first-occurrence argmax ties (integer weights make exact
    duplicate gains common at these sizes)."""
    from repro.kernels.swap_gain.kernel import swap_select_tpu
    from repro.kernels.swap_gain.ref import swap_select_ref

    rng = np.random.default_rng(0)
    A = rng.integers(0, 7, (n, n)).astype(np.float64)
    M = A + A.T
    B = (rng.integers(0, 5, (n, n)) * (rng.random((n, n)) < 0.3))
    G = (B + B.T).astype(np.float64)
    contrib = (G * M).sum(1)
    for i in (0, n // 3, n_valid - 1, n - 1):
        want_gain, want_j = _select_oracle(M, G, contrib, i, n_valid)
        for fn in (
            swap_select_ref,
            lambda *a: swap_select_tpu(*a, block_rows=block_rows,
                                       interpret=True),
        ):
            gain, j = fn(jnp.asarray(M), jnp.asarray(G),
                         jnp.asarray(contrib), jnp.int32(i),
                         jnp.int32(n_valid))
            assert int(j) == want_j, (n, i)
            if want_j != i:            # gain only meaningful on accept
                np.testing.assert_allclose(float(gain), want_gain,
                                           rtol=1e-12)


def test_swap_select_rejects_all_negative():
    """No positive gain anywhere -> j == i (identity swap), every impl."""
    from repro.kernels.swap_gain.kernel import swap_select_tpu
    from repro.kernels.swap_gain.ops import swap_select
    from repro.kernels.swap_gain.ref import swap_select_ref

    n = 32
    # an already-optimal layout: identical processes, so every swap gain
    # is exactly zero (< GAIN_EPS) and the mover must stay put
    M = np.ones((n, n)) - np.eye(n)
    G = np.ones((n, n)) - np.eye(n)
    contrib = (G * M).sum(1)
    args = (jnp.asarray(M), jnp.asarray(G), jnp.asarray(contrib),
            jnp.int32(3), jnp.int32(n))
    for fn in (swap_select_ref, swap_select,
               lambda *a: swap_select_tpu(*a, interpret=True)):
        _, j = fn(*args)
        assert int(j) == 3


def test_swap_gain_ops_dispatch():
    """auto resolves to the jitted ref off-TPU; the dense refine path of
    the jax mapping backend consumes exactly this entry point."""
    from repro.kernels.swap_gain.ops import swap_gain
    from repro.kernels.swap_gain.ref import swap_gain_ref

    rng = np.random.default_rng(1)
    n = 48
    M = jnp.asarray(0.5 * (rng.random((n, n)) + rng.random((n, n)).T))
    G = jnp.asarray(rng.integers(0, 5, (n, n)).astype(np.float64))
    G = 0.5 * (G + G.T)
    contrib = (G * M).sum(1)
    out = swap_gain(M, G, contrib, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(swap_gain_ref(M, G, contrib, 7)),
                               rtol=1e-12)


# ------------------------------------------------------------- hop_dist
@pytest.mark.parametrize("dims,m,k", [
    ((8, 8, 8), 37, 53),       # ragged (padding exercised)
    ((32, 32, 16), 256, 128),  # block-aligned
    ((5, 7), 12, 12),          # 2-D, non-pow2 extents
    ((2, 3, 4, 3), 9, 30),     # 4-D
])
def test_torus_hop_kernel_matches_np(dims, m, k):
    from repro.kernels.hop_dist.kernel import torus_hop_tpu
    from repro.kernels.hop_dist.ops import torus_hop_pairs, torus_hop_pairs_np
    from repro.kernels.hop_dist.ref import torus_hop_pairs_ref

    rng = np.random.default_rng(0)
    cu = np.stack([rng.integers(0, d, m) for d in dims], axis=1)
    cv = np.stack([rng.integers(0, d, k) for d in dims], axis=1)
    want = torus_hop_pairs_np(cu, cv, dims)  # numpy all-pairs oracle
    got_ref = np.asarray(torus_hop_pairs_ref(jnp.asarray(cu),
                                             jnp.asarray(cv), dims))
    got_tpu = np.asarray(torus_hop_tpu(jnp.asarray(cu), jnp.asarray(cv),
                                       dims, interpret=True))
    got_auto = np.asarray(torus_hop_pairs(cu, cv, dims))
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_tpu, want)
    np.testing.assert_array_equal(got_auto, want)


def test_torus_hop_elems_matches_dense_hop_matrix():
    from repro.core.topology import TorusTopology
    from repro.kernels.hop_dist.ops import torus_hop_np
    from repro.kernels.hop_dist.ref import torus_hop_elems_ref

    topo = TorusTopology((6, 5, 4))
    c = topo.coords_array()
    H = topo.hop_matrix()
    u, v = np.meshgrid(np.arange(120), np.arange(120), indexing="ij")
    np.testing.assert_array_equal(
        torus_hop_np(c[u.ravel()], c[v.ravel()],
                     topo.dims).reshape(120, 120), H)
    got = np.asarray(torus_hop_elems_ref(
        jnp.asarray(c[u.ravel()]), jnp.asarray(c[v.ravel()]), topo.dims))
    np.testing.assert_array_equal(got.reshape(120, 120), H)


@pytest.mark.parametrize("k,m,kk", [
    (4, 16, 16),       # tiny pod structure
    (6, 37, 53),       # ragged (padding exercised)
    (8, 128, 100),     # block-aligned rows, ragged cols
])
def test_fattree_hop_triad(k, m, kk):
    """np == jitted ref == Pallas-interpret on the fat-tree metric, all
    checked against the topology's dense hop matrix."""
    from repro.core.fattree import FatTreeTopology
    from repro.kernels.hop_dist.kernel import fattree_hop_tpu
    from repro.kernels.hop_dist.ops import (fattree_hop, fattree_hop_pairs_np)
    from repro.kernels.hop_dist.ref import fattree_hop_pairs_ref

    topo = FatTreeTopology(k)
    c = topo.coords_array().astype(np.float64)
    rng = np.random.default_rng(0)
    u = rng.integers(0, topo.n_nodes, m)
    v = rng.integers(0, topo.n_nodes, kk)
    want = topo.hop_matrix()[np.ix_(u, v)].astype(np.float64)
    np.testing.assert_array_equal(fattree_hop_pairs_np(c[u], c[v]), want)
    np.testing.assert_array_equal(
        np.asarray(fattree_hop_pairs_ref(jnp.asarray(c[u]),
                                         jnp.asarray(c[v]))), want)
    np.testing.assert_array_equal(
        np.asarray(fattree_hop_tpu(jnp.asarray(c[u]), jnp.asarray(c[v]),
                                   interpret=True)), want)
    np.testing.assert_array_equal(np.asarray(fattree_hop(c[u], c[v])), want)


def test_fattree_hop_elems_matches_lazy_adapter():
    """The elementwise form agrees with FatTreeLazyDistance under scale
    and endpoint penalties (the exact metric the jitted refine compiles)."""
    from repro.core.fattree import FatTreeTopology
    from repro.kernels.hop_dist.ops import fattree_hop_np
    from repro.kernels.hop_dist.ref import fattree_hop_elems_ref

    topo = FatTreeTopology(4)
    lazy = topo.lazy_distance(c=2.0)
    c = topo.coords_array()
    n = topo.n_nodes
    u, v = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    u, v = u.ravel(), v.ravel()
    got_np = 2.0 * fattree_hop_np(c[u], c[v])
    got_ref = 2.0 * np.asarray(fattree_hop_elems_ref(
        jnp.asarray(c[u]), jnp.asarray(c[v])))
    np.testing.assert_array_equal(got_np, 2.0 * topo.hop_matrix()[u, v])
    np.testing.assert_array_equal(got_ref, got_np)
    np.testing.assert_array_equal(np.asarray(lazy[u, v]), got_np)


@given(st.integers(2, 16), st.integers(2, 16), st.integers(2, 16),
       st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_torus_hop_property(dx, dy, dz, seed):
    from repro.kernels.hop_dist.ops import torus_hop_np

    dims = (dx, dy, dz)
    rng = np.random.default_rng(seed)
    cu = np.stack([rng.integers(0, d, 8) for d in dims], axis=1)
    cv = np.stack([rng.integers(0, d, 8) for d in dims], axis=1)
    h = torus_hop_np(cu, cv, dims)
    assert (h >= 0).all()
    assert (h <= sum(d // 2 for d in dims)).all()             # diameter
    np.testing.assert_array_equal(h, torus_hop_np(cv, cu, dims))  # symmetry
    assert (torus_hop_np(cu, cu, dims) == 0).all()            # identity
