import numpy as np
import pytest

from repro.cluster.failures import BernoulliPerJob, NoFailures, WeibullArrival
from repro.cluster.heartbeat import EWMA, HeartbeatMonitor, MovingAverage
from repro.cluster.nodes import NodeRegistry, NodeState
from repro.cluster.scheduler import Job, Scheduler
from repro.core.topology import TorusTopology
from repro.workloads.patterns import lammps_like


def test_registry_topology_file_roundtrip():
    t = TorusTopology((4, 4))
    reg = NodeRegistry(t)
    text = reg.topology_file()
    reg2 = NodeRegistry.from_topology_file(text, (4, 4))
    assert len(reg2) == 16


def test_heartbeat_moving_average_converges():
    rng = np.random.default_rng(0)
    mon = HeartbeatMonitor(8, MovingAverage(window=200))
    true_p = np.zeros(8)
    true_p[3] = 0.3
    mon.simulate_rounds(rng, true_p, 400)
    est = mon.outage_probabilities()
    assert est[3] == pytest.approx(0.3, abs=0.08)
    assert est[0] == 0.0


def test_heartbeat_ewma_reacts_to_state_change():
    mon = HeartbeatMonitor(2, EWMA(alpha=0.2))
    rng = np.random.default_rng(1)
    mon.simulate_rounds(rng, np.array([0.0, 0.0]), 50)
    assert mon.outage_probabilities()[1] == 0.0
    mon.simulate_rounds(rng, np.array([0.0, 1.0]), 20)  # node 1 dies
    est = mon.outage_probabilities()
    assert est[1] > 0.9 and est[0] == 0.0


def test_straggler_scores_from_latency():
    mon = HeartbeatMonitor(3)
    rng = np.random.default_rng(2)
    slow = np.array([0.0, 2.0, 0.0])
    mon.simulate_rounds(rng, np.zeros(3), 30, slowdown=slow)
    s = mon.straggler_scores()
    assert s[1] == pytest.approx(2.0, rel=0.2)
    assert s[0] == pytest.approx(0.0, abs=0.1)


def test_bernoulli_failure_model_rate():
    rng = np.random.default_rng(3)
    fm = BernoulliPerJob(np.arange(16), 0.02)
    hits = [len(fm.sample_failed(rng, 1.0)) for _ in range(4000)]
    assert np.mean(hits) == pytest.approx(16 * 0.02, rel=0.15)
    assert fm.outage_vector(64)[:16].sum() == pytest.approx(16 * 0.02)
    assert fm.outage_vector(64)[16:].sum() == 0


def test_no_failures():
    assert len(NoFailures().sample_failed(np.random.default_rng(0), 1.0)) == 0


def test_weibull_scales_with_duration():
    rng = np.random.default_rng(4)
    fm = WeibullArrival(np.arange(32), mtbf=1000.0, shape=1.0)
    short = np.mean([len(fm.sample_failed(rng, 1.0)) for _ in range(2000)])
    long = np.mean([len(fm.sample_failed(rng, 100.0)) for _ in range(2000)])
    assert long > 10 * short


def test_scheduler_drains_flapping_node():
    t = TorusTopology((4, 4))
    sch = Scheduler(t, drain_threshold=0.5)
    replies_bad = np.ones(16, dtype=bool)
    replies_bad[5] = False
    for _ in range(20):
        sch.heartbeat_round(replies_bad)
    assert sch.registry[5].state == NodeState.DRAINED
    assert sch.estimated_outage()[5] == 1.0


def test_scheduler_tofa_avoids_drained_node():
    t = TorusTopology((4, 4))
    sch = Scheduler(t)
    bad = np.ones(16, dtype=bool)
    bad[0] = False
    for _ in range(30):
        sch.heartbeat_round(bad)
    rec = sch.submit(Job(lammps_like(8), distribution="tofa"))
    assert 0 not in set(rec.placement.placement.tolist())
    assert rec.runtime > 0


def test_scheduler_elastic_replacement():
    t = TorusTopology((4, 4))
    sch = Scheduler(t)
    sch.heartbeat_round(np.ones(16, dtype=bool))
    rec = sch.submit(Job(lammps_like(6), distribution="linear"))
    victim = int(rec.placement.placement[0])
    replaced = sch.handle_node_failure([victim])
    assert rec in replaced
    assert rec.restarts == 1
    assert victim not in set(rec.placement.placement.tolist())
    sch.complete(rec.job.job_id)
    assert sch.records[rec.job.job_id].state == "done"
