"""Workload pattern construction: the paper's Fig. 1 traffic shapes.

Pins the structural properties the placement policies and the replica
engine rely on: symmetric volume matrices with zero diagonals, the
banded halo structure of the regular (LAMMPS-style) generators, the
off-diagonal shuffle of the irregular (NPB-DT-style) generator, and
deterministic seeding so replica streams stay reproducible.
"""
import numpy as np
import pytest

from repro.workloads.patterns import (
    WORKLOADS, Workload, _grid3, alltoall_heavy, allreduce_heavy,
    get_workload, halo3d, lammps_like, npb_dt_like,
)


def _check_comm_invariants(wl: Workload):
    G = wl.comm.G_v
    assert G.shape == (wl.n_ranks, wl.n_ranks)
    assert np.array_equal(G, G.T), "volume matrix must be symmetric"
    assert np.all(np.diag(G) == 0), "no self-traffic on the diagonal"
    assert G.sum() > 0
    M = wl.comm.G_m
    assert np.array_equal(M, M.T)
    assert np.all(np.diag(M) == 0)


def test_grid3_factors_cubically():
    assert _grid3(64) == (4, 4, 4)
    assert _grid3(27) == (3, 3, 3)
    assert _grid3(24) == (2, 3, 4)
    assert _grid3(7) == (1, 1, 7)                        # prime: degenerate
    for n in (8, 12, 30, 64, 85):
        a, b, c = _grid3(n)
        assert a * b * c == n and a <= b <= c


def test_lammps_halo_bands():
    wl = lammps_like(64)
    _check_comm_invariants(wl)
    assert wl.pattern == "regular" and wl.name == "lammps"
    G = wl.comm.G_v
    # 4x4x4 rank grid: halo neighbours at rank strides nz=4... actually
    # strides 1 (z), 4 (y), 16 (x); interior pair (21, 22) differs in z
    assert G[21, 22] > 0 and G[21, 25] > 0 and G[21, 37] > 0
    # halo traffic dominates: every rank talks to its 6 halo neighbours
    halo = lammps_like(64, collective_bytes=0.0)
    deg = (halo.comm.G_v > 0).sum(axis=1)
    assert (deg == 6).all()


def test_npb_dt_irregular_and_deterministic():
    wl = npb_dt_like()
    assert wl.n_ranks == 85                              # DT class C
    _check_comm_invariants(wl)
    assert wl.pattern == "irregular"
    same = npb_dt_like()
    assert np.array_equal(wl.comm.G_v, same.comm.G_v)    # seeded
    other = npb_dt_like(seed=99)
    assert not np.array_equal(wl.comm.G_v, other.comm.G_v)
    # the DAG has no dense diagonal band: most adjacent-rank pairs silent
    G = wl.comm.G_v
    adj = np.array([G[i, i + 1] for i in range(84)])
    assert (adj == 0).mean() > 0.5


def test_halo3d_degree_six():
    wl = halo3d((3, 3, 3))
    _check_comm_invariants(wl)
    deg = (wl.comm.G_v > 0).sum(axis=1)
    assert (deg == 6).all()                              # periodic 3D stencil
    wl2 = halo3d((2, 2, 2))                              # size-2 dims: wrap
    _check_comm_invariants(wl2)                          # collapses to 3
    assert ((wl2.comm.G_v > 0).sum(axis=1) == 3).all()


def test_alltoall_uniform():
    wl = alltoall_heavy(16)
    _check_comm_invariants(wl)
    G = wl.comm.G_v
    off = G[~np.eye(16, dtype=bool)]
    assert np.ptp(off) == 0 and off[0] > 0               # flat heatmap


def test_allreduce_ring():
    wl = allreduce_heavy(16)
    _check_comm_invariants(wl)
    deg = (wl.comm.G_v > 0).sum(axis=1)
    assert (deg == 2).all()                              # ring neighbours


def test_registry_round_trip():
    assert set(WORKLOADS) == {"lammps", "npb_dt", "halo3d", "alltoall",
                              "allreduce"}
    for name in WORKLOADS:
        wl = get_workload(name) if name != "halo3d" else get_workload(
            name, dims=(2, 2, 2))
        assert isinstance(wl, Workload)
        assert wl.name == name
        assert wl.flops_per_rank > 0 and wl.rounds > 0
        _check_comm_invariants(wl)
    with pytest.raises(KeyError):
        get_workload("no-such-workload")
