import numpy as np
import pytest

from repro.core.topology import (TorusTopology, arrangements,
                                 find_consecutive_healthy, FAULT_PENALTY)


def test_coords_roundtrip():
    t = TorusTopology((4, 3, 5))
    for n in range(t.n_nodes):
        assert t.node_at(t.coords(n)) == n


def test_coords_array_matches_coords():
    t = TorusTopology((3, 4))
    arr = t.coords_array()
    for n in range(t.n_nodes):
        assert tuple(arr[n]) == t.coords(n)


def test_route_length_equals_hop_distance():
    t = TorusTopology((8, 8, 8))
    rng = np.random.default_rng(0)
    hops = t.hop_matrix()
    for _ in range(50):
        u, v = rng.integers(0, t.n_nodes, 2)
        assert len(t.route(int(u), int(v))) == hops[u, v]


def test_route_wraps_shortest_direction():
    t = TorusTopology((8,))
    # 0 -> 7 should go backwards through the wrap link (1 hop)
    r = t.route(0, 7)
    assert len(r) == 1 and r[0].dst == 7


def test_route_endpoints():
    t = TorusTopology((4, 4))
    r = t.route(0, 15)
    assert r[0].src == 0 and r[-1].dst == 15
    # consecutive links chain
    for a, b in zip(r[:-1], r[1:]):
        assert a.dst == b.src


def test_hop_matrix_symmetric_zero_diag():
    t = TorusTopology((4, 4))
    h = t.hop_matrix()
    assert np.allclose(h, h.T)
    assert np.allclose(np.diag(h), 0)
    # max distance on a 4x4 torus is 2+2
    assert h.max() == 4


def test_weight_matrix_no_faults_is_hops():
    t = TorusTopology((4, 4))
    assert np.allclose(t.weight_matrix(None), t.hop_matrix())
    assert np.allclose(t.weight_matrix(np.zeros(16)), t.hop_matrix())


def test_weight_matrix_fault_penalty_eq1():
    t = TorusTopology((8,))
    p = np.zeros(8)
    p[3] = 0.02
    w = t.weight_matrix(p)
    h = t.hop_matrix()
    # 2 -> 4 routes through 3: two links touch node 3
    assert w[2, 4] == h[2, 4] + 2 * FAULT_PENALTY
    # 2 -> 3: one link (2,3) touches node 3
    assert w[2, 3] == h[2, 3] + FAULT_PENALTY
    # 0 -> 1 avoids node 3 entirely
    assert w[0, 1] == h[0, 1]
    # faulty path strictly worse than longest healthy path (paper rationale)
    assert w[2, 3] > h.max()


def test_weight_matrix_straggler_soft_penalty():
    t = TorusTopology((8,))
    s = np.zeros(8)
    s[3] = 0.5
    w = t.weight_matrix(None, straggler=s)
    h = t.hop_matrix()
    assert w[2, 3] == h[2, 3] + 0.5
    assert w[0, 1] == h[0, 1]


def test_neighbors_torus_degree():
    t = TorusTopology((8, 8, 8))
    assert len(t.neighbors(0)) == 6
    t2 = TorusTopology((16, 16))
    assert len(t2.neighbors(17)) == 4


def test_find_consecutive_healthy():
    p = np.zeros(16)
    p[5] = 0.1
    w = find_consecutive_healthy(p, 8)
    assert w is not None and list(w) == list(range(6, 14))
    assert find_consecutive_healthy(p, 11) is None
    assert find_consecutive_healthy(p, 11, wrap=True) is not None
    assert find_consecutive_healthy(np.zeros(4), 8) is None


def test_arrangements_table1():
    arrs = arrangements(256, 3)
    for a in ((4, 8, 8), (4, 4, 16), (2, 8, 16)):
        assert a in arrs
    assert all(np.prod(a) == 256 for a in arrs)
