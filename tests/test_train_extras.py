"""Training-substrate tests: microbatching, optimizer, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.models import model as M
from repro.train.data import SyntheticDataset
from repro.train.optimizer import AdamW
from repro.train.train_step import cross_entropy, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    params = M.init(cfg, jax.random.key(0))
    ds = SyntheticDataset(cfg.vocab, 16, 8, seed=0)
    return cfg, params, ds


def test_microbatch_accumulation_matches_full_batch(setup):
    """grad accumulation over 4 microbatches == one full-batch step."""
    cfg, params, ds = setup
    opt = AdamW(lr=1e-3, warmup_steps=1)
    batch = ds.batch(0)
    s_full = jax.jit(make_train_step(cfg, opt, microbatches=1))
    s_mb = jax.jit(make_train_step(cfg, opt, microbatches=4))
    p1, st1, m1 = s_full(params, opt.init(params), batch)
    p2, st2, m2 = s_mb(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


def test_optimizer_bf16_state_still_learns(setup):
    cfg, params, ds = setup
    opt = AdamW(lr=1e-2, warmup_steps=1, state_dtype=jnp.bfloat16)
    step = jax.jit(make_train_step(cfg, opt))
    state = opt.init(params)
    assert jax.tree.leaves(state.m)[0].dtype == jnp.bfloat16
    losses = []
    p = params
    for i in range(4):
        p, state, m = step(p, state, ds.batch(0))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, grad_clip=1e-6, warmup_steps=1, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    new, state, gnorm = opt.update(grads, state, params)
    assert float(gnorm) > 1e5
    # clipped step: |delta| <= lr * (1/(sqrt eps-ish)) but finite & small-ish
    assert np.all(np.isfinite(np.asarray(new["w"])))


def test_dataset_deterministic_and_learnable():
    ds = SyntheticDataset(vocab=64, seq_len=32, global_batch=4, seed=9)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(ds.batch(6)["tokens"]),
                              np.asarray(b1["tokens"]))
    # labels are the next-token shift of the same stream
    toks = np.asarray(b1["tokens"])
    labs = np.asarray(b1["labels"])
    np.testing.assert_array_equal(toks[:, 1:], labs[:, :-1])
    # mostly deterministic successor structure (noise = 0.1)
    succ = ds._succ
    match = (succ[toks[:, :-1]] == toks[:, 1:]).mean()
    assert match > 0.8


def test_cross_entropy_matches_manual():
    logits = jnp.array([[[2.0, 0.0, -1.0]]])
    labels = jnp.array([[0]])
    got = float(cross_entropy(logits, labels))
    p = np.exp([2.0, 0.0, -1.0])
    want = -np.log(p[0] / p.sum())
    assert got == pytest.approx(want, rel=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), vocab=st.sampled_from([16, 64]),
       b=st.integers(1, 4))
def test_dataset_tokens_in_range(seed, vocab, b):
    ds = SyntheticDataset(vocab=vocab, seq_len=8, global_batch=b, seed=seed)
    batch = ds.batch(0)
    toks = np.asarray(batch["tokens"])
    assert toks.min() >= 0 and toks.max() < vocab
    assert toks.shape == (b, 8)
