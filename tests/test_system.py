"""End-to-end system tests: the full stack wired together.

Covers: profiler -> TOFA -> device permutation on a real compiled program;
sharded training on a small host-emulated mesh (GSPMD + shard_map MoE);
checkpoint/restart round-trip; paper-claims direction on a small scenario.

Multi-device cases run in a subprocess so the main test process keeps its
single default CPU device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


def _run_py(code: str, devices: int = 8) -> str:
    env = {**ENV,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_profiler_tofa_device_assignment_end_to_end():
    """Compile a sharded program, extract comm graph, permute devices."""
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.profiler import comm_graph_from_hlo
        from repro.core.placement import Fabric, assign_devices
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        def f(w, x):
            return jnp.einsum("bd,df->bf", x, w).sum()
        g = jax.jit(jax.grad(f), in_shardings=(
            NamedSharding(mesh, P("data", "model")),
            NamedSharding(mesh, P("data", None))))
        with mesh:
            comp = g.lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
                           jax.ShapeDtypeStruct((64, 256), jnp.float32)
                           ).compile()
        comm = comm_graph_from_hlo(comp.as_text(), n_devices=8)
        assert comm.total_bytes() > 0, "no collectives found"
        fabric = Fabric(pod_dims=(2, 4), n_pods=1)
        a = assign_devices(comm, fabric, policy="tofa")
        assert sorted(a.permutation.tolist()) == list(range(8))
        assert a.hop_bytes_placed <= a.hop_bytes_linear + 1e-6
        print("OK", comm.total_bytes(), a.improvement)
    """)
    assert "OK" in out


def test_sharded_training_loss_falls_gspmd():
    """4-device mesh, dense arch: sharded train step reduces loss."""
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs.base import reduced
        from repro.configs.registry import get_arch
        from repro.models import model as M
        from repro.parallel.sharding import ShardingCtx
        from repro.train.data import SyntheticDataset
        from repro.train.optimizer import AdamW
        from repro.train.train_step import make_train_step
        mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                    ("data", "model"))
        cfg = reduced(get_arch("smollm-135m"))
        ctx = ShardingCtx(mesh=mesh)
        params = M.init(cfg, jax.random.key(0))
        params = jax.tree.map(jax.device_put, params,
                              ctx.param_shardings(M.schema(cfg)))
        opt = AdamW(lr=1e-2, warmup_steps=1)
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt, ctx))
        ds = SyntheticDataset(cfg.vocab, 32, 8, seed=0)
        losses = []
        with mesh:
            for i in range(5):
                params, state, m = step(params, state, ds.batch(i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("OK", losses)
    """, devices=4)
    assert "OK" in out


def test_sharded_moe_ep_shardmap_matches_local():
    """shard_map EP MoE == single-device local MoE (same params/batch)."""
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs.base import reduced
        from repro.configs.registry import get_arch
        from repro.models import model as M
        from repro.parallel.sharding import ShardingCtx
        from repro.train.data import SyntheticDataset
        cfg = reduced(get_arch("phi3.5-moe-42b"))
        params = M.init(cfg, jax.random.key(0))
        ds = SyntheticDataset(cfg.vocab, 16, 4, seed=0)
        batch = ds.batch(0)
        logits_local = M.forward(cfg, params, batch)  # 1-device reference
        mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                    ("data", "model"))
        ctx = ShardingCtx(mesh=mesh)
        params_s = jax.tree.map(jax.device_put, params,
                                ctx.param_shardings(M.schema(cfg)))
        with mesh:
            logits_ep = jax.jit(
                lambda p, b: M.forward(cfg, p, b, ctx))(params_s, batch)
        err = float(jnp.max(jnp.abs(logits_ep - logits_local)))
        assert err < 2e-3, err
        print("OK", err)
    """, devices=4)
    assert "OK" in out


def test_checkpoint_restart_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro.configs.base import reduced
    from repro.configs.registry import get_arch
    from repro.models import model as M
    from repro.train.checkpoint import (latest_checkpoint,
                                        restore_checkpoint, save_checkpoint)
    from repro.train.optimizer import AdamW

    cfg = reduced(get_arch("smollm-135m"))
    params = M.init(cfg, jax.random.key(0))
    opt = AdamW()
    state = opt.init(params)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, params, state)
    save_checkpoint(d, 20, params, state, keep=2)
    assert latest_checkpoint(d).endswith("step_00000020")
    restored = restore_checkpoint(latest_checkpoint(d), params, state)
    assert restored["step"] == 20
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # corrupted-shape restore must fail loudly
    bad = jax.tree.map(lambda x: jnp.zeros(x.shape + (1,), x.dtype), params)
    with pytest.raises(ValueError):
        restore_checkpoint(latest_checkpoint(d), bad, state)


def test_paper_claims_direction_small():
    """Mini Fig. 4: TOFA beats default placement under failures and the
    irregular workload benefits more than the regular one (paper's core
    qualitative claims)."""
    from repro.sim.batchsim import run_scenario
    from repro.workloads.patterns import lammps_like, npb_dt_like

    kw = dict(dims=(4, 4, 4), n_batches=2, n_instances=30, n_faulty=6,
              p_f=0.05, seed=5)
    dt = run_scenario(lambda: npb_dt_like(40), ("linear", "tofa"), **kw)
    lm = run_scenario(lambda: lammps_like(27), ("linear", "tofa"), **kw)
    imp_dt = dt["tofa"].improvement_over(dt["linear"])
    imp_lm = lm["tofa"].improvement_over(lm["linear"])
    assert imp_dt > 0, f"TOFA must improve irregular batch ({imp_dt:.1%})"
    assert dt["tofa"].mean_abort_ratio <= dt["linear"].mean_abort_ratio
    assert imp_dt > imp_lm, (
        f"irregular should benefit more: DT {imp_dt:.1%} vs LAMMPS "
        f"{imp_lm:.1%}")
