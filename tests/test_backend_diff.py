"""Differential equivalence: numpy backend vs jit-compiled jax backend.

The jax backend (:mod:`repro.core.mapping_jax`) is a decision-identical
port of the vectorized NumPy mapping kernels: at the float64 dtype policy
and with integer-weight guests (every in-tree workload except the
fractional all-reduce edges of ``lammps_like``), float64 arithmetic on
the kernels' integer inputs is exact, so the jitted kernels accept the
same swaps in the same order and placements match **bit for bit** on
torus and fat-tree hosts, healthy and faulty.  Guests with non-dyadic
weights may round differently inside BLAS/XLA reductions, so they are
held to quality tolerance instead.

Also covered: the dtype policy (float64 default, float32 opt-in;
placements integer-exact on every backend), the numpy-only fallback
guarantees, and ``place_many`` ≡ sequential ``place``.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import backend, mapping
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.fattree import FatTreeTopology
from repro.core.topology import TorusTopology
from repro.workloads.patterns import halo3d, lammps_like, npb_dt_like

RTOL = 1e-9


def _hosts():
    return [("torus", TorusTopology((4, 4, 4))),
            ("fattree", FatTreeTopology(8))]


def _weights(topo, faulty: bool, seed: int = 5) -> np.ndarray:
    if not faulty:
        return topo.hop_matrix()
    p_f = np.zeros(topo.n_nodes)
    bad = np.random.default_rng(seed).choice(topo.n_nodes, 6, replace=False)
    p_f[bad] = 0.1
    return topo.weight_matrix(p_f)


def _request(topo, n: int, faulty: bool) -> PlacementRequest:
    wl = npb_dt_like(n)
    p_f = None
    if faulty:
        p_f = np.zeros(topo.n_nodes)
        bad = np.random.default_rng(5).choice(topo.n_nodes, 6,
                                              replace=False)
        p_f[bad] = 0.1
    return PlacementRequest(comm=wl.comm, topology=topo, p_f=p_f)


# ------------------------------------------------------------- hop bytes
@pytest.mark.parametrize("host_name,topo", _hosts())
@pytest.mark.parametrize("faulty", [False, True])
def test_hop_bytes_parity(host_name, topo, faulty):
    wl = npb_dt_like(40)
    D = _weights(topo, faulty)
    rng = np.random.default_rng(0)
    P = np.stack([rng.permutation(topo.n_nodes)[:40] for _ in range(5)])
    ref = mapping.hop_bytes_batch(wl.comm.G_v, D, P)
    with backend.use("jax"):
        out = mapping.hop_bytes_batch(wl.comm.G_v, D, P)
        one = mapping.hop_bytes(wl.comm.G_v, D, P[0])
    np.testing.assert_allclose(out, ref, rtol=RTOL)
    np.testing.assert_allclose(one, ref[0], rtol=RTOL)


# ------------------------------------------------- kernel-level identity
@pytest.mark.parametrize("host_name,topo", _hosts())
@pytest.mark.parametrize("faulty", [False, True])
def test_refine_identical(host_name, topo, faulty):
    wl = npb_dt_like(40)
    D = _weights(topo, faulty)
    rng = np.random.default_rng(1)
    P = np.stack([rng.permutation(topo.n_nodes)[:40] for _ in range(3)])
    ref = mapping.refine_batch(wl.comm.G_v, D, P)
    with backend.use("jax"):
        out = mapping.refine_batch(wl.comm.G_v, D, P)
        single = mapping._pairwise_refine(wl.comm.G_v, D, P[0])
    assert np.array_equal(out, ref), f"{host_name} faulty={faulty}"
    assert np.array_equal(single, ref[0])


@pytest.mark.parametrize("health", ["healthy", "faults", "stragglers", "both"])
def test_fattree_lazy_refine_identical(health):
    """Fat-tree implicit path: the jitted refine computes the endpoint-form
    fat-tree metric in-kernel (coords + penalty gather, never a stored
    matrix) for *every* health state, and stays bit-identical to the NumPy
    kernels running against the lazy adapter's ``__getitem__``."""
    from repro.core import mapping_jax

    topo = FatTreeTopology(8)
    p_f = strag = None
    if health in ("faults", "both"):
        p_f = np.zeros(topo.n_nodes)
        bad = np.random.default_rng(5).choice(topo.n_nodes, 6, replace=False)
        p_f[bad] = 0.1
    if health in ("stragglers", "both"):
        strag = np.zeros(topo.n_nodes)
        slow = np.random.default_rng(9).choice(topo.n_nodes, 5, replace=False)
        strag[slow] = 1.5
    Dl = topo.lazy_distance(p_f, c=2.0, straggler=strag)
    assert mapping_jax.lazy_supported(Dl), health
    wl = npb_dt_like(40)
    rng = np.random.default_rng(1)
    P = np.stack([rng.permutation(topo.n_nodes)[:40] for _ in range(3)])
    ref = mapping.refine_batch(wl.comm.G_v, Dl, P)
    hb_ref = mapping.hop_bytes_batch(wl.comm.G_v, Dl, ref)
    with backend.use("jax"):
        out = mapping.refine_batch(wl.comm.G_v, Dl, P)
        hb = mapping.hop_bytes_batch(wl.comm.G_v, Dl, out)
    assert np.array_equal(out, ref), health
    np.testing.assert_allclose(hb, hb_ref, rtol=RTOL)


@pytest.mark.parametrize("host_name,topo", _hosts())
def test_select_nodes_identical(host_name, topo):
    W = _weights(topo, faulty=True)
    for count in (5, 17, 33):
        ref = mapping.select_nodes(W, count)
        with backend.use("jax"):
            out = mapping.select_nodes(W, count)
        assert np.array_equal(out, ref), count
        with backend.use("jax"):
            seeded = mapping.select_nodes(W, count, seed=3)
        assert np.array_equal(seeded, mapping.select_nodes(W, count, seed=3))


@pytest.mark.parametrize("host_name,topo", _hosts())
@pytest.mark.parametrize("wl_fn", [npb_dt_like, lammps_like])
def test_greedy_placement_identical(host_name, topo, wl_fn):
    wl = wl_fn(24)
    D = topo.hop_matrix()
    ref = mapping.greedy_placement(wl.comm.G_v, np.arange(topo.n_nodes), D)
    with backend.use("jax"):
        out = mapping.greedy_placement(wl.comm.G_v, np.arange(topo.n_nodes),
                                       D)
    assert np.array_equal(out, ref)


# ------------------------------------------------- engine-level identity
@pytest.mark.parametrize("host_name,topo", _hosts())
@pytest.mark.parametrize("faulty", [False, True])
@pytest.mark.parametrize("policy", ["linear", "greedy", "topo", "tofa"])
def test_policy_placements_identical(host_name, topo, faulty, policy):
    """Integer-weight guests: fixed seeds give bit-identical placements."""
    req = _request(topo, 24, faulty)
    ref = PlacementEngine().place(req, policy=policy,
                                  rng=np.random.default_rng(0))
    with backend.use("jax"):
        out = PlacementEngine().place(req, policy=policy,
                                      rng=np.random.default_rng(0))
    assert np.array_equal(out.placement, ref.placement), \
        f"{host_name} faulty={faulty} {policy}"
    assert out.placement.dtype.kind == "i"          # integer-exact
    assert ref.placement.dtype.kind == "i"
    np.testing.assert_allclose(out.hop_bytes, ref.hop_bytes, rtol=RTOL)


def test_fractional_weight_guest_quality():
    """lammps_like carries non-dyadic all-reduce weights: cross-backend
    placements may legally differ (BLAS vs XLA reduction order), but the
    jax backend must stay within quality tolerance of numpy."""
    topo = TorusTopology((4, 4, 4))
    wl = lammps_like(48)
    req = PlacementRequest(comm=wl.comm, topology=topo)
    ref = PlacementEngine().place(req, policy="tofa",
                                  rng=np.random.default_rng(0))
    with backend.use("jax"):
        out = PlacementEngine().place(req, policy="tofa",
                                      rng=np.random.default_rng(0))
    assert out.hop_bytes <= ref.hop_bytes * 1.05
    assert len(set(out.placement.tolist())) == wl.n_ranks


def test_engine_backend_kwarg():
    """PlacementEngine(backend='jax') pins the backend per engine."""
    topo = TorusTopology((4, 4, 4))
    req = _request(topo, 24, faulty=True)
    ref = PlacementEngine().place(req, rng=np.random.default_rng(0))
    out = PlacementEngine(backend="jax").place(req,
                                               rng=np.random.default_rng(0))
    assert np.array_equal(out.placement, ref.placement)
    assert backend.active().name == "numpy"      # scope did not leak


# ------------------------------------------------------------ place_many
@pytest.mark.parametrize("be", ["numpy", "jax"])
def test_place_many_equals_sequential(be):
    topo = TorusTopology((4, 4, 4))
    requests = [_request(topo, n, faulty) for n, faulty in
                [(12, False), (24, True), (18, False), (12, True)]]
    with backend.use(be):
        engine = PlacementEngine()
        seq = [engine.place(r, policy="tofa") for r in requests]
        batch = PlacementEngine().place_many(requests, policy="tofa")
    for s, b in zip(seq, batch):
        assert np.array_equal(s.placement, b.placement)
        assert s.hop_bytes == b.hop_bytes


def test_place_many_exclusive_disjoint():
    topo = TorusTopology((4, 4, 4))
    requests = [_request(topo, 20, False) for _ in range(3)]
    plans = PlacementEngine().place_many(requests, policy="tofa",
                                         exclusive=True)
    used: set[int] = set()
    for p in plans:
        ids = set(int(x) for x in p.placement)
        assert not (ids & used)          # exclusive node allocation
        used |= ids
    with pytest.raises(ValueError):
        PlacementEngine().place_many(
            [_request(topo, 24, False) for _ in range(3)],
            policy="tofa", exclusive=True)   # 72 procs > 64 nodes


def test_place_many_per_request_policies():
    topo = TorusTopology((4, 4, 4))
    requests = [_request(topo, 12, False), _request(topo, 12, False)]
    plans = PlacementEngine().place_many(requests,
                                         policy=["linear", "tofa"])
    assert plans[0].policy == "linear" and plans[1].policy == "tofa"
    with pytest.raises(ValueError):
        PlacementEngine().place_many(requests, policy=["tofa"])


# ------------------------------------------------------------ dtype policy
def test_float32_mode_runs_and_returns_int_placements():
    topo = TorusTopology((4, 4, 4))
    req = _request(topo, 24, faulty=True)
    with backend.use("jax", dtype="float32"):
        assert backend.active().dtype == "float32"
        plan = PlacementEngine().place(req, policy="tofa",
                                       rng=np.random.default_rng(0))
    assert plan.placement.dtype.kind == "i"
    assert len(set(plan.placement.tolist())) == 24
    # float32 quality stays in the same ballpark as the exact float64 run
    ref = PlacementEngine().place(req, policy="tofa",
                                  rng=np.random.default_rng(0))
    assert plan.hop_bytes <= ref.hop_bytes * 1.10


def test_numpy_default_untouched():
    """Importing/using the jax backend must not change the default path."""
    assert backend.active().name == "numpy"
    topo = TorusTopology((4, 4, 4))
    wl = halo3d((2, 3, 4))
    req = PlacementRequest(comm=wl.comm, topology=topo)
    a = PlacementEngine().place(req, rng=np.random.default_rng(0))
    with backend.use("jax"):
        pass
    b = PlacementEngine().place(req, rng=np.random.default_rng(0))
    assert np.array_equal(a.placement, b.placement)


def test_backend_registry_errors():
    with pytest.raises(ValueError):
        backend.get_backend("tensorflow")
    with pytest.raises(ValueError):
        backend.get_backend("jax", dtype="float16")


def test_reference_impl_wins_over_jax_backend():
    """use_reference_impl must run the scalar loops even when the jax
    backend is active — the reference baseline is backend-independent."""
    topo = TorusTopology((4, 4, 4))
    wl = npb_dt_like(24)
    D = topo.hop_matrix()
    P = np.stack([np.random.default_rng(s).permutation(topo.n_nodes)[:24]
                  for s in range(2)])
    with mapping.use_reference_impl():
        ref = mapping.refine_batch(wl.comm.G_v, D, P)
        with backend.use("jax"):
            out = mapping.refine_batch(wl.comm.G_v, D, P)
            assert mapping.greedy_placement is \
                mapping.greedy_placement_reference
    assert np.array_equal(out, ref)
