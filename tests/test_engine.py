"""PlacementEngine API: registry, request validation, caching, replace,
topology protocol, and shim equivalence."""
import numpy as np
import pytest

from repro.core.engine import (PlacementEngine, PlacementRequest, Topology,
                               default_engine)
from repro.core.fattree import FatTreeTopology
from repro.core.placement import Fabric
from repro.core.policies import (DuplicatePolicyError, PolicyOutput,
                                 UnknownPolicyError, available_policies,
                                 get_policy, register_policy,
                                 unregister_policy)
from repro.core.tofa import POLICIES, place
from repro.core.topology import TorusTopology
from repro.workloads.patterns import lammps_like, npb_dt_like


@pytest.fixture()
def engine():
    return PlacementEngine()


@pytest.fixture(scope="module")
def torus():
    return TorusTopology((4, 4, 4))


# ------------------------------------------------------------------ registry
def test_registry_contains_seed_policies():
    assert set(available_policies()) >= {"linear", "random", "greedy",
                                         "topo", "tofa"}
    assert POLICIES == available_policies()


def test_unknown_policy_raises(engine, torus):
    req = PlacementRequest(comm=lammps_like(8).comm, topology=torus)
    with pytest.raises(UnknownPolicyError):
        engine.place(req, policy="definitely-not-registered")
    # legacy callers catch ValueError
    with pytest.raises(ValueError):
        get_policy("definitely-not-registered")


def test_duplicate_registration_raises():
    with pytest.raises(DuplicatePolicyError):
        @register_policy("linear")
        class Dup:                                      # pragma: no cover
            fault_aware = False

            def place(self, ctx):
                return PolicyOutput(np.arange(ctx.n_procs))


def test_third_party_policy_registers_and_runs(engine, torus):
    @register_policy("test-reverse-linear")
    class ReverseLinear:
        fault_aware = False

        def place(self, ctx):
            return PolicyOutput(ctx.available[:ctx.n_procs][::-1].copy())

    try:
        req = PlacementRequest(comm=lammps_like(8).comm, topology=torus)
        plan = engine.place(req, policy="test-reverse-linear")
        assert list(plan.placement) == list(range(8))[::-1]
        assert plan.policy == "test-reverse-linear"
    finally:
        unregister_policy("test-reverse-linear")
    assert "test-reverse-linear" not in available_policies()


# ---------------------------------------------------------------- validation
def test_request_rejects_too_many_processes(torus):
    with pytest.raises(ValueError, match="processes"):
        PlacementRequest(comm=lammps_like(100).comm, topology=torus)


def test_request_rejects_insufficient_available(torus):
    with pytest.raises(ValueError, match="available"):
        PlacementRequest(comm=lammps_like(8).comm, topology=torus,
                         available=np.arange(4))


def test_request_rejects_bad_metric_and_shapes(torus):
    comm = lammps_like(8).comm
    with pytest.raises(ValueError, match="metric"):
        PlacementRequest(comm=comm, topology=torus, metric="latency")
    with pytest.raises(ValueError, match="p_f"):
        PlacementRequest(comm=comm, topology=torus, p_f=np.zeros(7))
    with pytest.raises(ValueError, match="range"):
        PlacementRequest(comm=comm, topology=torus,
                         available=np.arange(60, 70))


# ------------------------------------------------------------------- engine
def test_engine_runs_every_policy(engine, torus):
    req = PlacementRequest(comm=npb_dt_like(20).comm, topology=torus)
    for pol in ("linear", "random", "greedy", "topo", "tofa"):
        plan = engine.place(req, policy=pol, rng=np.random.default_rng(1))
        assert len(plan.placement) == 20
        assert len(set(plan.placement.tolist())) == 20, pol
        assert plan.policy == pol
        assert plan.wall_time_s >= 0
        assert plan.cost_breakdown()["hop_bytes"] == plan.hop_bytes


def test_weight_matrix_cache_hit(engine, torus):
    p_f = np.zeros(64)
    p_f[[3, 17]] = 0.1
    w1 = engine.weights(torus, p_f)
    w2 = engine.weights(torus, p_f.copy())
    assert w1 is w2
    assert engine.cache_stats()["weight_hits"] == 1
    # all-healthy degenerates to the cached hop matrix
    assert engine.weights(torus, np.zeros(64)) is engine.hops(torus)


def test_shim_equivalence_fixed_seed(engine, torus):
    """place() must return the same placement as the engine for all seed
    policies (the shim is a thin wrapper, not a fork)."""
    wl = npb_dt_like(20)
    p_f = np.zeros(64)
    p_f[np.random.default_rng(5).choice(64, 6, replace=False)] = 0.05
    req = PlacementRequest(comm=wl.comm, topology=torus, p_f=p_f)
    for pol in ("linear", "random", "greedy", "topo", "tofa"):
        legacy = place(pol, wl.comm, torus, p_f,
                       rng=np.random.default_rng(0))
        plan = engine.place(req, policy=pol, rng=np.random.default_rng(0))
        assert (legacy.placement == plan.placement).all(), pol
        assert legacy.hop_bytes == plan.hop_bytes


# ------------------------------------------------------------------ replace
def test_replace_avoids_failed_nodes(engine, torus):
    wl = npb_dt_like(20)
    req = PlacementRequest(comm=wl.comm, topology=torus)
    plan = engine.place(req, policy="tofa", rng=np.random.default_rng(0))
    failed = plan.placement[:3].tolist()
    new = engine.replace(plan, failed)
    assert new.provenance == "replace-incremental"
    assert not set(failed) & set(new.placement.tolist())
    assert len(set(new.placement.tolist())) == 20
    assert new.faulty_nodes_used == 0
    # unaffected processes did not move
    moved = np.flatnonzero(plan.placement != new.placement)
    assert set(moved.tolist()) == {0, 1, 2}
    # failed nodes are certain outages in the new request
    assert (new.request.p_f[failed] == 1.0).all()
    assert not np.isin(failed, new.request.available_ids).any()


def test_replace_full_fallback_when_mostly_displaced(engine, torus):
    wl = lammps_like(8)
    plan = engine.place(PlacementRequest(comm=wl.comm, topology=torus),
                        policy="linear")
    new = engine.replace(plan, plan.placement[:6])
    assert new.provenance == "replace-full"
    assert not np.isin(new.placement, plan.placement[:6]).any()


def test_replace_raises_without_capacity():
    t = TorusTopology((2, 2))
    plan = PlacementEngine().place(
        PlacementRequest(comm=lammps_like(4).comm, topology=t),
        policy="linear")
    with pytest.raises(ValueError, match="surviving"):
        PlacementEngine().replace(plan, [0])


# --------------------------------------------------------- topology protocol
def test_topology_protocol_instances(torus):
    for topo in (torus, Fabric(pod_dims=(4, 4), n_pods=2),
                 FatTreeTopology(4)):
        assert isinstance(topo, Topology)


def test_fat_tree_distances():
    ft = FatTreeTopology(4)
    assert ft.n_nodes == 16
    h = ft.hop_matrix()
    assert h[0, 0] == 0          # same host
    assert h[0, 1] == 2          # same edge switch
    assert h[0, 2] == 4          # same pod, different edge
    assert h[0, 4] == 6          # different pod
    assert (h == h.T).all()


def test_fat_tree_tofa_avoids_faulty_hosts():
    ft = FatTreeTopology(8)      # 128 hosts
    wl = npb_dt_like(24)
    p_f = np.zeros(ft.n_nodes)
    p_f[np.random.default_rng(2).choice(ft.n_nodes, 16, replace=False)] = 0.1
    eng = PlacementEngine()
    plan = eng.place(PlacementRequest(comm=wl.comm, topology=ft, p_f=p_f),
                     policy="tofa")
    assert plan.faulty_nodes_used == 0
    assert len(set(plan.placement.tolist())) == 24
    # fault-aware beats linear on the weighted metric under faults
    lin = eng.place(PlacementRequest(comm=wl.comm, topology=ft, p_f=p_f),
                    policy="linear")
    assert plan.hop_bytes_fault_weighted is not None
    assert lin.faulty_nodes_used > 0 or plan.hop_bytes <= lin.hop_bytes


def test_fabric_via_engine_matches_chip_count():
    fab = Fabric(pod_dims=(4, 4), n_pods=2)
    assert fab.n_nodes == fab.n_chips == 32
    eng = PlacementEngine()
    plan = eng.place(PlacementRequest(comm=lammps_like(8).comm, topology=fab),
                     policy="topo")
    assert len(set(plan.placement.tolist())) == 8


def test_default_engine_is_shared():
    assert default_engine() is default_engine()


def test_replace_rejects_out_of_range_node_ids(engine, torus):
    plan = engine.place(PlacementRequest(comm=lammps_like(8).comm,
                                         topology=torus), policy="linear")
    with pytest.raises(ValueError, match="range"):
        engine.replace(plan, [999])


def test_replace_honours_refreshed_availability(engine, torus):
    """The plan's request is a submit-time snapshot; a live scheduler passes
    current p_f/available so re-placement avoids nodes that went down or
    drained after submission, not just the newly failed ones."""
    wl = lammps_like(8)
    plan = engine.place(PlacementRequest(comm=wl.comm, topology=torus),
                        policy="linear")           # nodes 0..7
    died_earlier = [8, 9, 10]                       # down since submit
    now_avail = np.setdiff1d(np.arange(64), died_earlier)
    p_now = np.zeros(64)
    p_now[died_earlier] = 1.0
    new = engine.replace(plan, [int(plan.placement[0])],
                         p_f=p_now, available=now_avail)
    assert int(plan.placement[0]) not in new.placement
    assert not np.isin(new.placement, died_earlier).any()
    assert (new.request.p_f[died_earlier] == 1.0).all()
