"""Belief subsystem: estimators, tracker, calibration, churn, BCa.

Covers the ``repro.beliefs`` contract surface:

* conjugate closed forms against hand analytics;
* Weibull method-of-moments recovery on synthetic lifetimes;
* property tests (hypothesis, skipped when absent): posterior
  concentration and the rack-pooling MSE win on sparse histories;
* tracker event accounting — overlap refcounts, censored exposure,
  rebase — and the ``p_floor`` pattern hygiene;
* the zero-epoch-churn regression: a learned tracker feeding placements
  must keep the engine weight-cache hit rate at the BENCH_state floor;
* BCa bootstrap internals and the percentile-vs-BCa coverage property.
"""
import math

import numpy as np
import pytest

from repro.beliefs import (AdversarialBeliefs, BeliefTracker,
                           ExponentialBayes, HeartbeatBeliefAdapter,
                           LifetimeStats, OracleBeliefs, RackPooledBayes,
                           StaticPrior, WeibullMoM, belief_mse, brier_score,
                           expected_calibration_error, log_loss,
                           pattern_confusion, reliability_diagram,
                           window_outcomes)
from repro.beliefs.estimators import _weibull_shape_from_cv2
from repro.cluster.heartbeat import EWMA, HeartbeatMonitor, MovingAverage
from repro.sim.replicas import _jackknife, _norm_cdf, _norm_ppf, bootstrap_ci

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()


def stats_of(n_failures, exposure, sum_life=None, sum_life_sq=None,
             down=None):
    k = np.asarray(n_failures, dtype=np.float64)
    t = np.asarray(exposure, dtype=np.float64)
    z = np.zeros_like(k)
    return LifetimeStats(
        n_failures=k, exposure=t,
        sum_life=z if sum_life is None else np.asarray(sum_life, float),
        sum_life_sq=(z if sum_life_sq is None
                     else np.asarray(sum_life_sq, float)),
        down=(np.zeros(len(k), dtype=bool) if down is None
              else np.asarray(down, dtype=bool)),
    )


# ---------------------------------------------------------------- conjugate
class TestExponentialBayes:
    def test_closed_form_matches_analytics(self):
        m = ExponentialBayes(prior_events=0.5, prior_exposure=10.0)
        s = stats_of([2.0], [100.0])
        a, b = 2.5, 110.0
        d = 1.0
        expect = 1.0 - (b / (b + d)) ** a
        assert m.p_f(s, d)[0] == pytest.approx(expect, rel=1e-12)
        assert m.posterior_mean_rate(s)[0] == pytest.approx(a / b)

    def test_posterior_predictive_vs_monte_carlo(self):
        # p_f(d) is E_lambda[1 - exp(-lambda d)] under the Gamma posterior
        m = ExponentialBayes(prior_events=1.0, prior_exposure=50.0)
        s = stats_of([3.0], [70.0])
        a, b = m.posterior(s)
        rng = np.random.default_rng(7)
        lam = rng.gamma(a[0], 1.0 / b[0], size=200_000)
        mc = float(np.mean(1.0 - np.exp(-lam * 2.0)))
        assert m.p_f(s, 2.0)[0] == pytest.approx(mc, abs=2e-4)

    def test_prior_only_and_limits(self):
        m = ExponentialBayes()
        s = LifetimeStats.empty(4)
        p = m.p_f(s, 1.0)
        assert np.all(p > 0) and np.all(p < 0.02)     # tiny prior mass
        # long windows -> 1 at the Lomax rate 1 - (b/d)^a
        assert np.all(m.p_f(s, 1e9) > 0.999)
        assert np.all(m.p_f(s, 1e9) < 1.0)

    def test_invalid_prior_rejected(self):
        with pytest.raises(ValueError):
            ExponentialBayes(prior_events=0.0)
        with pytest.raises(ValueError):
            ExponentialBayes(prior_exposure=-1.0)

    @given(k=st.integers(0, 50), extra=st.floats(0.1, 1e4))
    @settings(max_examples=50, deadline=None)
    def test_property_failures_raise_exposure_lowers(self, k, extra):
        m = ExponentialBayes()
        base = stats_of([float(k)], [100.0])
        more_k = stats_of([float(k + 1)], [100.0])
        more_t = stats_of([float(k)], [100.0 + extra])
        assert m.p_f(more_k, 1.0)[0] > m.p_f(base, 1.0)[0]
        assert m.p_f(more_t, 1.0)[0] < m.p_f(base, 1.0)[0]

    @given(scale=st.integers(1, 200))
    @settings(max_examples=30, deadline=None)
    def test_property_posterior_concentrates(self, scale):
        # same empirical rate, `scale`x the evidence: posterior relative
        # sd shrinks ~1/sqrt(scale) and p_f approaches the exact rate-
        # 0.05 exponential answer
        m = ExponentialBayes()
        s = stats_of([5.0 * scale], [100.0 * scale])
        a, b = m.posterior(s)
        rel_sd = 1.0 / math.sqrt(a[0])     # Gamma relative sd
        assert rel_sd <= 1.0 / math.sqrt(5.0 * scale)
        exact = 1.0 - math.exp(-0.05)
        gap = abs(m.p_f(s, 1.0)[0] - exact)
        loose = abs(m.p_f(stats_of([5.0], [100.0]), 1.0)[0] - exact)
        assert gap <= loose + 1e-12


# ------------------------------------------------------------------ weibull
class TestWeibullMoM:
    @staticmethod
    def _stats_from_lifetimes(life: np.ndarray) -> LifetimeStats:
        return stats_of([float(len(life))], [float(life.sum())],
                        [float(life.sum())], [float((life ** 2).sum())])

    def test_shape_from_cv2_identity_points(self):
        # exponential: CV^2 = 1 <-> shape 1; CV < 1 <-> shape > 1
        assert _weibull_shape_from_cv2(np.array([1.0]))[0] == \
            pytest.approx(1.0, abs=1e-6)
        assert _weibull_shape_from_cv2(np.array([0.1]))[0] > 1.0
        assert _weibull_shape_from_cv2(np.array([4.0]))[0] < 1.0

    @pytest.mark.parametrize("shape,scale", [(0.7, 5.0), (1.0, 2.0),
                                             (2.5, 10.0)])
    def test_recovers_known_weibull(self, shape, scale):
        rng = np.random.default_rng(11)
        life = scale * rng.weibull(shape, size=4000)
        got_shape, got_scale, fitted = WeibullMoM().fit(
            self._stats_from_lifetimes(life))
        assert fitted[0]
        assert got_shape[0] == pytest.approx(shape, rel=0.1)
        assert got_scale[0] == pytest.approx(scale, rel=0.1)

    def test_invalid_min_samples_rejected(self):
        with pytest.raises(ValueError):
            WeibullMoM(min_samples=1)

    def test_sparse_history_falls_back_to_conjugate(self):
        m = WeibullMoM(min_samples=3)
        s = stats_of([2.0], [40.0], [30.0], [500.0])
        assert not m.fit(s)[2][0]
        assert m.p_f(s, 1.0)[0] == pytest.approx(
            m.fallback.p_f(s, 1.0)[0])

    def test_infant_mortality_beats_exponential_at_short_horizon(self):
        # shape < 1 with the same mean concentrates failure mass early
        rng = np.random.default_rng(3)
        life = 5.0 * rng.weibull(0.5, size=4000)
        s = self._stats_from_lifetimes(life)
        p_weib = WeibullMoM().p_f(s, 0.1)[0]
        mean = life.mean()
        p_expo = 1.0 - math.exp(-0.1 / mean)
        assert p_weib > p_expo


# ---------------------------------------------------------------- pooling
class TestRackPooledBayes:
    def test_sparse_node_shrinks_toward_rack(self):
        groups = [np.arange(0, 4), np.arange(4, 8)]
        m = RackPooledBayes(groups=groups)
        solo = ExponentialBayes()
        # rack 0 is hot (members saw failures), rack 1 quiet; node 0
        # itself has an empty history
        k = np.array([0.0, 4.0, 4.0, 4.0, 0.0, 0.0, 0.0, 0.0])
        t = np.full(8, 50.0)
        s = stats_of(k, t)
        p = m.p_f(s, 1.0)
        assert p[0] > solo.p_f(s, 1.0)[0]    # pulled up by its rack
        assert p[0] > p[4]                   # hot rack > quiet rack
        assert p[1] > p[0]                   # own failures still dominate

    def test_ungrouped_nodes_use_top_level_prior(self):
        m = RackPooledBayes(groups=[np.arange(0, 2)], strength=2.0,
                            prior_events=0.5, prior_exposure=100.0)
        s = LifetimeStats.empty(4)
        p = m.p_f(s, 1.0)
        lam0 = 0.5 / 100.0
        b = 2.0 / lam0
        expect = 1.0 - (b / (b + 1.0)) ** 2.0
        assert p[2] == pytest.approx(expect, rel=1e-12)

    def test_invalid_strength(self):
        with pytest.raises(ValueError):
            RackPooledBayes(groups=[[0]], strength=0.0)

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_pooling_lowers_mse_on_sparse_histories(self, seed):
        # 8 racks x 8 nodes sharing a per-rack true rate; short exposure
        # so per-node histories are sparse.  Rack pooling must beat the
        # un-pooled conjugate model on mean squared rate error.
        rng = np.random.default_rng(seed)
        n_racks, rack_size, horizon = 8, 8, 25.0
        groups = [np.arange(r * rack_size, (r + 1) * rack_size)
                  for r in range(n_racks)]
        true_rate = np.repeat(rng.uniform(0.005, 0.2, n_racks), rack_size)
        k = rng.poisson(true_rate * horizon).astype(np.float64)
        s = stats_of(k, np.full(n_racks * rack_size, horizon))
        pooled = RackPooledBayes(groups=groups)
        solo = ExponentialBayes(prior_events=pooled.prior_events,
                                prior_exposure=pooled.prior_exposure)
        a_p = pooled.strength + s.n_failures
        lam_pooled = a_p / (pooled.strength / np.repeat(
            (pooled.prior_events + np.add.reduceat(k, [g[0] for g in groups]))
            / (pooled.prior_exposure + rack_size * horizon), rack_size)
            + s.exposure)
        mse_pooled = float(np.mean((lam_pooled - true_rate) ** 2))
        mse_solo = float(np.mean(
            (solo.posterior_mean_rate(s) - true_rate) ** 2))
        assert mse_pooled <= mse_solo * 1.05


# ----------------------------------------------------- reference & adapter
class TestReferenceModels:
    def test_oracle_static_adversarial(self):
        truth = np.array([0.0, 0.3, 0.0, 0.1])
        s = LifetimeStats.empty(4)
        assert np.array_equal(OracleBeliefs(truth).p_f(s, 1.0), truth)
        assert np.all(StaticPrior(0.2).p_f(s, 1.0) == 0.2)
        adv = AdversarialBeliefs(truth).p_f(s, 1.0)
        assert np.array_equal(adv, truth[::-1])
        adv[0] = 9.0                         # must be a private copy
        assert truth[3] == 0.1

    def test_heartbeat_adapter_matches_monitor(self):
        mon = HeartbeatMonitor(5, estimator=MovingAverage(window=50))
        rng = np.random.default_rng(0)
        truth = np.array([0.0, 0.5, 0.0, 0.2, 0.9])
        mon.simulate_rounds(rng, truth, 200)
        adapter = HeartbeatBeliefAdapter(MovingAverage(window=50), mon)
        got = adapter.p_f(LifetimeStats.empty(5), duration=123.0)
        np.testing.assert_allclose(got, mon.outage_probabilities())
        ew = HeartbeatBeliefAdapter(EWMA(alpha=0.1), mon)
        expect = np.array([EWMA(alpha=0.1).estimate(h)
                           for h in mon.history])
        np.testing.assert_allclose(ew.p_f(LifetimeStats.empty(5), 1.0),
                                   expect)


# ------------------------------------------------------------------ tracker
class TestBeliefTracker:
    def test_lifetime_accounting(self):
        tr = BeliefTracker(3, ExponentialBayes())
        tr.observe_failure([0], t=4.0)       # closes a 4s lifetime
        tr.observe_repair([0], t=5.0)
        tr.observe_failure([0], t=9.0)       # closes another 4s
        s = tr.stats(now=10.0)
        assert s.n_failures[0] == 2
        assert s.sum_life[0] == pytest.approx(8.0)
        assert s.sum_life_sq[0] == pytest.approx(32.0)
        assert s.exposure[0] == pytest.approx(8.0)   # down: no censoring
        assert s.down[0] and not s.down[1]
        # node 1 never failed: censored exposure = full clock
        assert s.exposure[1] == pytest.approx(10.0)
        assert s.n_failures[1] == 0

    def test_overlap_refcount(self):
        # a rack event downing an already-down node must not close a
        # second lifetime, and the node stays down until both repairs
        tr = BeliefTracker(4, ExponentialBayes())
        tr.observe_failure([1], t=2.0)
        tr.observe_failure([0, 1, 2], t=3.0)
        s = tr.stats(now=3.0)
        assert s.n_failures[1] == 1          # one up->down transition
        assert s.n_failures[0] == 1 and s.n_failures[2] == 1
        tr.observe_repair([0, 1, 2], t=4.0)
        assert tr.stats(4.0).down[1]         # still down (refcount 1)
        tr.observe_repair([1], t=5.0)
        s = tr.stats(now=7.0)
        assert not s.down[1]
        assert s.exposure[1] == pytest.approx(2.0 + 2.0)  # [0,2] + [5,7]

    def test_repair_without_failure_is_safe(self):
        tr = BeliefTracker(2, ExponentialBayes())
        tr.observe_repair([0], t=1.0)        # refcount clamps at zero
        assert tr.stats(2.0).exposure[0] == pytest.approx(2.0)

    def test_rebase_preserves_statistics(self):
        tr = BeliefTracker(2, ExponentialBayes())
        tr.observe_failure([0], t=50.0)
        tr.observe_repair([0], t=60.0)
        tr.advance(100.0)
        before = tr.stats().n_failures.copy()
        tr.rebase(0.0)
        assert tr.now == 0.0
        s = tr.stats(now=0.0)
        np.testing.assert_array_equal(s.n_failures, before)
        # total accumulated exposure survives the shift: 50s closed
        # lifetime + the 40s censored interval [60, 100)
        assert s.exposure[0] == pytest.approx(90.0)
        assert not s.down.any()              # everyone up at the origin
        # a down node at rebase time restarts its clock at t0
        tr2 = BeliefTracker(1, ExponentialBayes())
        tr2.observe_failure([0], t=5.0)
        tr2.rebase(0.0)
        assert tr2.stats(now=3.0).exposure[0] == pytest.approx(5.0 + 3.0)

    def test_p_floor_zeroes_pattern(self):
        tr = BeliefTracker(3, ExponentialBayes(), p_floor=0.02)
        tr.observe_failure([2], t=1.0)
        for c in range(9):                   # rich failure history on 2
            tr.observe_repair([2], t=2.0 * c + 2.0)
            tr.observe_failure([2], t=2.0 * c + 3.0)
        p = tr.p_f_vector(now=20.0)
        assert p[0] == 0.0 and p[1] == 0.0   # prior mass clamped exactly
        assert p[2] > 0.02
        nofloor = BeliefTracker(3, ExponentialBayes(), p_floor=0.0)
        assert np.all(nofloor.p_f_vector(now=20.0) > 0.0)

    def test_validation(self):
        with pytest.raises(ValueError):
            BeliefTracker(0, ExponentialBayes())
        with pytest.raises(ValueError):
            BeliefTracker(2, ExponentialBayes(), horizon=0.0)


# -------------------------------------------------------------- calibration
class TestCalibration:
    def test_brier_and_log_loss(self):
        y = np.array([1.0, 0.0, 1.0, 0.0])
        assert brier_score(y, y) == 0.0
        assert brier_score(np.full(4, 0.5), y) == pytest.approx(0.25)
        assert log_loss(y, y) == pytest.approx(0.0, abs=1e-10)
        assert log_loss(1.0 - y, y) > 20.0   # confidently wrong, finite
        with pytest.raises(ValueError):
            brier_score(np.array([1.5]), np.array([1.0]))

    def test_reliability_diagram_calibrated_forecaster(self):
        rng = np.random.default_rng(5)
        p = rng.uniform(0, 1, 20_000)
        y = (rng.uniform(0, 1, 20_000) < p).astype(float)
        d = reliability_diagram(p, y, n_bins=10)
        pop = d["count"] > 0
        np.testing.assert_allclose(d["mean_pred"][pop], d["frac_pos"][pop],
                                   atol=0.05)
        assert expected_calibration_error(p, y) < 0.03

    def test_pattern_confusion_conventions(self):
        truth = np.array([0.0, 0.3, 0.3, 0.0])
        perfect = pattern_confusion(np.array([0.0, 0.9, 0.1, 0.0]), truth)
        assert perfect["precision"] == 1.0 and perfect["recall"] == 1.0
        nothing = pattern_confusion(np.zeros(4), truth)
        assert nothing["precision"] == 1.0 and nothing["recall"] == 0.0
        clean = pattern_confusion(np.zeros(4), np.zeros(4))
        assert clean["recall"] == 1.0
        half = pattern_confusion(np.array([0.5, 0.5, 0.0, 0.0]), truth)
        assert half["precision"] == pytest.approx(0.5)
        assert half["recall"] == pytest.approx(0.5)

    def test_window_outcomes(self):
        class Ev:
            def __init__(self, kind, t, nodes):
                self.kind, self.time, self.nodes = kind, t, nodes
        events = [Ev("fail", 0.5, [1]), Ev("recover", 0.9, [1]),
                  Ev("fail", 1.5, [0, 2]), Ev("fail", 99.0, [3])]
        out = window_outcomes(events, n_nodes=4, horizon=3.0, duration=1.0)
        assert out.shape == (3, 4)
        assert out[0, 1] and not out[0, 0]
        assert out[1, 0] and out[1, 2]
        assert not out[:, 3].any()           # outside the horizon


# ------------------------------------------------- scheduler / churn / sweep
class TestSchedulerIntegration:
    def test_learned_mode_reports_belief_metrics(self):
        from repro.sim.scenarios import run_preset
        res = run_preset("correlated-failures", policies=("tofa",),
                         seed=0, fast=True, belief_mode="learned")
        row = res["policies"]["tofa"]
        assert 0.0 <= row["belief_err"] < 0.05
        assert row["belief_pattern_recall"] > 0.5
        assert res["params"]["belief_mode"] == "learned"

    def test_atol_is_placement_invariant(self):
        # Eq. 1 consumers read only the p_f > 0 pattern, so the interning
        # tolerance must not change simulated outcomes at all
        from repro.sim.scenarios import run_preset
        rows = [run_preset("correlated-failures", policies=("tofa",),
                           seed=1, fast=True, belief_mode="learned",
                           p_f_atol=atol)["policies"]["tofa"]
                for atol in (0.05, 0.25)]
        assert rows[0]["mean_completion"] == rows[1]["mean_completion"]

    def test_unknown_belief_mode_raises(self):
        from repro.sim.scenarios import run_preset
        with pytest.raises(ValueError):
            run_preset("correlated-failures", policies=("tofa",),
                       seed=0, fast=True, belief_mode="psychic")

    def test_tracker_churn_keeps_engine_cache_warm(self):
        # the zero-epoch-churn regression: a learned tracker publishing
        # drifting beliefs through the scheduler must keep the engine
        # weight-cache hit rate at the BENCH_state floor — epochs mint
        # only on genuine failures, never on belief jitter
        belief_sweep = pytest.importorskip(
            "benchmarks.belief_sweep",
            reason="benchmarks namespace package needs repo-root cwd")
        row = belief_sweep.tracker_churn_row(fast=True, seed=0,
                                             csv=lambda *_: None)
        assert row["hit_rate"] >= 0.95
        assert row["epochs"] <= row["churn_events"] + 1
        assert row["events_ingested"] >= row["rounds"]


# ------------------------------------------------------------ BCa bootstrap
class TestBCaBootstrap:
    def test_norm_ppf_cdf(self):
        assert _norm_ppf(0.5) == pytest.approx(0.0, abs=1e-9)
        assert _norm_ppf(0.975) == pytest.approx(1.959964, abs=1e-5)
        assert _norm_ppf(0.025) == pytest.approx(-1.959964, abs=1e-5)
        assert _norm_ppf(1e-6) == pytest.approx(-4.753424, abs=1e-4)
        for p in (0.001, 0.1, 0.5, 0.9, 0.999):
            assert _norm_cdf(_norm_ppf(p)) == pytest.approx(p, abs=1e-9)

    def test_jackknife_mean_closed_form(self):
        x = np.array([1.0, 2.0, 4.0, 9.0])
        got = _jackknife(x, np.mean)
        expect = np.array([np.delete(x, i).mean() for i in range(4)])
        np.testing.assert_allclose(got, expect)

    def test_degenerate_and_validation(self):
        assert bootstrap_ci(np.array([3.0]), method="bca") == (3.0, 3.0)
        assert bootstrap_ci(np.full(9, 2.5), method="bca") == (2.5, 2.5)
        with pytest.raises(ValueError):
            bootstrap_ci(np.array([1.0, 2.0]), method="studentized")

    def test_bca_shifts_toward_skew(self):
        # right-skewed sample: the percentile interval is biased low; the
        # BCa correction moves both endpoints right
        rng = np.random.default_rng(12)
        x = rng.exponential(1.0, size=25)
        lo_p, hi_p = bootstrap_ci(x, B=4000, seed=1, method="percentile")
        lo_b, hi_b = bootstrap_ci(x, B=4000, seed=1, method="bca")
        assert lo_b > lo_p
        assert hi_b > hi_p

    def test_bca_coverage_beats_percentile_on_skewed_means(self):
        # the satellite claim: on small exponential samples the BCa
        # interval's coverage of the true mean is no worse than the
        # percentile interval's (deterministic seeds, 150 trials)
        rng = np.random.default_rng(2024)
        n, trials, B = 12, 150, 600
        cover = {"percentile": 0, "bca": 0}
        for t in range(trials):
            x = rng.exponential(1.0, size=n)
            for method in cover:
                lo, hi = bootstrap_ci(x, B=B, seed=t, method=method)
                cover[method] += int(lo <= 1.0 <= hi)
        assert cover["bca"] >= cover["percentile"]
        assert cover["bca"] / trials > 0.82   # sane absolute coverage

    def test_summary_and_compare_plumb_method(self):
        from repro.sim.replicas import paired_compare, summarize
        rng = np.random.default_rng(4)
        a = rng.exponential(1.0, 30)
        s = summarize(a, metric="m", method="bca")
        assert s.method == "bca"
        assert s.ci_low <= s.mean <= s.ci_high
        cmp = paired_compare(a, a + 0.3, metric="m", method="bca")
        assert cmp.method == "bca"
        assert cmp.delta_ci_low > 0.0        # a beats b by a 0.3 shift
