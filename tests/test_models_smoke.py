"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; assert shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced, shape_cells
from repro.configs.registry import ARCHS, all_cells, get_arch
from repro.models import model as M
from repro.serve.decode import decode_step, prefill_cross_cache
from repro.serve.kvcache import init_cache
from repro.train.data import SyntheticDataset, extra_inputs
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step

B, S = 2, 32


def _batch(cfg):
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=S, global_batch=B, seed=0)
    batch = ds.batch(0)
    batch.update(extra_inputs(cfg, B, seq_len=S))
    return batch


@pytest.fixture(params=sorted(ARCHS), scope="module")
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def setup(arch):
    cfg = reduced(get_arch(arch))
    params = M.init(cfg, jax.random.key(0))
    return cfg, params


def test_registry_has_all_ten():
    assert len(ARCHS) == 10
    cells = all_cells()
    # 8 archs x 3 cells + 2 sub-quadratic archs x 4 cells = 32 live cells
    assert len(cells) == 32


def test_forward_shapes_and_finite(setup):
    cfg, params = setup
    batch = _batch(cfg)
    logits = M.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{cfg.name}: non-finite logits"


def test_train_step_reduces_loss(setup):
    cfg, params = setup
    opt = AdamW(lr=1e-2, warmup_steps=1)
    step = jax.jit(make_train_step(cfg, opt))
    state = opt.init(params)
    batch = _batch(cfg)
    losses = []
    p = params
    for i in range(4):
        p, state, metrics = step(p, state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), f"{cfg.name}: loss NaN at step {i}"
    assert losses[-1] < losses[0], \
        f"{cfg.name}: loss did not fall ({losses})"


def test_decode_step_matches_forward(setup):
    """Greedy decode logits at position t must match the forward pass —
    cache correctness across every family."""
    cfg, params = setup
    batch = _batch(cfg)
    tokens = batch["tokens"]
    logits_fwd = M.forward(cfg, params, batch)

    caches = init_cache(cfg, B, S)
    if cfg.family == "vlm":
        caches["cross"] = prefill_cross_cache(cfg, params,
                                              batch["vision_embed"])
    if cfg.family == "encdec":
        # encode once, freeze the cross K/V
        enc = _encode(cfg, params, batch)
        caches["cross"] = prefill_cross_cache(cfg, params, enc,
                                              which="decoder")

    step = jax.jit(lambda c, t, p: decode_step(cfg, params, c, t, p))
    errs = []
    for t in range(min(S, 6)):
        logits_t, caches = step(caches, tokens[:, t:t + 1],
                                jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(
            logits_t[:, 0] - logits_fwd[:, t]))))
    assert max(errs) < 2e-2, f"{cfg.name}: decode/forward drift {errs}"


def _encode(cfg, params, batch):
    """Encoder-only forward for the encdec cross cache (mirrors model.py)."""
    from repro.models.layers import rmsnorm, mlp
    from repro.models.model import _attn_apply, _rope, NULL_CTX
    enc = batch["enc_embed"]
    Se = enc.shape[1]
    cos_e, sin_e = _rope(cfg, Se)

    def enc_body(carry, p):
        a, _ = _attn_apply(p, rmsnorm(carry, p["ln1"]), cfg, cos_e, sin_e,
                           NULL_CTX, causal=False)
        c = carry + a
        c = c + mlp(p, rmsnorm(c, p["ln2"]), cfg.act)
        return c, None
    enc, _ = jax.lax.scan(enc_body, enc, params["encoder"])
    return rmsnorm(enc, params["enc_norm"])


def test_param_count_sane(arch):
    """Full-config analytic parameter count is within 25% of the paper
    numbers implied by the arch names (sanity only; catches schema drift)."""
    expected = {
        "smollm-135m": 135e6, "starcoder2-7b": 7e9,
        "nemotron-4-340b": 340e9, "minicpm3-4b": 4e9,
        "llama-3.2-vision-11b": 9.8e9,  # text backbone + cross layers only
        "phi3.5-moe-42b": 42e9, "deepseek-v2-lite-16b": 16e9,
        "mamba2-2.7b": 2.7e9, "zamba2-7b": 7e9,
        "seamless-m4t-large-v2": 2.3e9,
    }
    cfg = get_arch(arch)
    n = cfg.n_params
    exp = expected[arch]
    assert 0.6 * exp < n < 1.55 * exp, \
        f"{arch}: analytic {n/1e9:.2f}B vs expected {exp/1e9:.2f}B"


def test_moe_active_params_below_total():
    cfg = get_arch("phi3.5-moe-42b")
    assert cfg.n_active_params < 0.3 * cfg.n_params
    dense = get_arch("starcoder2-7b")
    assert dense.n_active_params == dense.n_params


def test_long_context_cells_only_subquadratic():
    for name, cfg in ARCHS.items():
        cells = shape_cells(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in cells, name
        else:
            assert "long_500k" not in cells, name
