"""Differential equivalence: vectorized mapping kernels vs retained loop
reference implementations.

The vectorized hot-path kernels (``_pairwise_refine``, ``bisect_graph``,
``select_nodes``, ``greedy_placement``) must produce placements whose
quality (hop-bytes / cut weight) is equal or better than the scalar-loop
references on seeded random guests, torus and fat-tree hosts, with and
without faults.  ``select_nodes`` and ``greedy_placement`` are
decision-identical by construction, so they are held to exact equality.
"""
import numpy as np
import pytest

from repro.core import mapping as mp
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.fattree import FatTreeTopology
from repro.core.topology import TorusTopology
from repro.workloads.patterns import lammps_like, npb_dt_like

# absorbs float-associativity noise between incremental and re-summed costs
RTOL = 1 + 1e-9


def _random_guest(n: int, seed: int, density: float = 0.3) -> np.ndarray:
    rng = np.random.default_rng(seed)
    W = rng.random((n, n)) * (rng.random((n, n)) < density)
    W = W + W.T
    np.fill_diagonal(W, 0.0)
    return W


def _hosts():
    return [
        ("torus", TorusTopology((4, 4, 4))),
        ("fattree", FatTreeTopology(8)),
    ]


def _weights(topo, seed: int, faulty: bool) -> np.ndarray:
    if not faulty:
        return topo.hop_matrix()
    p_f = np.zeros(topo.n_nodes)
    bad = np.random.default_rng(seed).choice(topo.n_nodes, 6, replace=False)
    p_f[bad] = 0.1
    return topo.weight_matrix(p_f)


@pytest.mark.parametrize("seed", range(6))
def test_bisect_graph_cut_not_worse(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 48))
    W = _random_guest(n, seed + 100)
    size0 = int(rng.integers(1, n))
    vec = mp.bisect_graph(W, size0, rng=np.random.default_rng(1))
    ref = mp.bisect_graph_reference(W, size0, rng=np.random.default_rng(1))
    assert vec.sum() == size0 == ref.sum()
    assert mp.cut_weight(W, vec) <= mp.cut_weight(W, ref) * RTOL


@pytest.mark.parametrize("faulty", [False, True])
@pytest.mark.parametrize("host_name,topo", _hosts())
def test_select_nodes_identical(host_name, topo, faulty):
    D = _weights(topo, seed=11, faulty=faulty)
    for count in (5, 16, 31):
        vec = mp.select_nodes(D, count)
        ref = mp.select_nodes_reference(D, count)
        assert np.array_equal(vec, ref), f"{host_name} count={count}"


@pytest.mark.parametrize("wl_fn,n", [(npb_dt_like, 40), (lammps_like, 27)])
@pytest.mark.parametrize("host_name,topo", _hosts())
def test_greedy_placement_identical(host_name, topo, wl_fn, n):
    wl = wl_fn(n)
    D = topo.hop_matrix()
    vec = mp.greedy_placement(wl.comm.G_v, np.arange(topo.n_nodes), D)
    ref = mp.greedy_placement_reference(wl.comm.G_v, np.arange(topo.n_nodes), D)
    assert np.array_equal(vec, ref)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("faulty", [False, True])
@pytest.mark.parametrize("host_name,topo", _hosts())
def test_refine_hop_bytes_not_worse(host_name, topo, faulty, seed):
    n = 48
    G = _random_guest(n, seed)
    D = _weights(topo, seed=seed + 50, faulty=faulty)
    start = np.random.default_rng(seed).choice(topo.n_nodes, n, replace=False)
    vec = mp._pairwise_refine(G, D, start)
    ref = mp._pairwise_refine_reference(G, D, start)
    hb_vec = mp.hop_bytes(G, D, vec)
    hb_ref = mp.hop_bytes(G, D, ref)
    # the refiner only accepts improving swaps: never worse than its input
    assert hb_vec <= mp.hop_bytes(G, D, start) * RTOL
    assert hb_vec <= hb_ref * RTOL, f"{host_name} faulty={faulty} seed={seed}"
    # a swap-refined placement stays a valid assignment
    assert len(set(vec.tolist())) == n


@pytest.mark.parametrize("wl_fn,n", [(npb_dt_like, 40), (lammps_like, 27)])
@pytest.mark.parametrize("faulty", [False, True])
@pytest.mark.parametrize("host_name,topo", _hosts())
def test_map_graph_end_to_end_not_worse(host_name, topo, wl_fn, n, faulty):
    """Full-pipeline differential: vectorized map_graph vs the loop stack."""
    wl = wl_fn(n)
    D = _weights(topo, seed=9, faulty=faulty)
    coords = topo.coords_array()
    nodes = np.arange(topo.n_nodes)
    vec = mp.map_graph(wl.comm.G_v, nodes, coords, D=D,
                       rng=np.random.default_rng(0))
    with mp.use_reference_impl():
        ref = mp.map_graph(wl.comm.G_v, nodes, coords, D=D,
                           rng=np.random.default_rng(0))
    hb_vec = mp.hop_bytes(wl.comm.G_v, D, vec)
    hb_ref = mp.hop_bytes(wl.comm.G_v, D, ref)
    assert len(set(vec.tolist())) == n
    assert hb_vec <= hb_ref * RTOL, (
        f"{host_name} {wl_fn.__name__} faulty={faulty}: "
        f"{hb_vec:.6e} > {hb_ref:.6e}")


@pytest.mark.parametrize("faulty", [False, True])
def test_tofa_policy_end_to_end_not_worse(faulty):
    """Engine-level differential: the full TOFA pipeline, Eq. 1 weighted."""
    topo = TorusTopology((4, 4, 4))
    wl = npb_dt_like(24, seed=5)
    p_f = None
    if faulty:
        p_f = np.zeros(topo.n_nodes)
        p_f[np.random.default_rng(3).choice(topo.n_nodes, 6,
                                            replace=False)] = 0.05
    req = PlacementRequest(comm=wl.comm, topology=topo, p_f=p_f)
    vec = PlacementEngine().place(req, policy="tofa",
                                  rng=np.random.default_rng(0))
    with mp.use_reference_impl():
        ref = PlacementEngine().place(req, policy="tofa",
                                      rng=np.random.default_rng(0))
    assert vec.hop_bytes <= ref.hop_bytes * RTOL


def test_use_reference_impl_restores():
    vec_fns = {name: getattr(mp, name) for name in mp._VECTORIZED_IMPL}
    with mp.use_reference_impl():
        assert mp.bisect_graph is mp.bisect_graph_reference
        assert mp.select_nodes is mp.select_nodes_reference
        assert mp.greedy_placement is mp.greedy_placement_reference
        assert mp._pairwise_refine is mp._pairwise_refine_reference
    for name, fn in vec_fns.items():
        assert getattr(mp, name) is fn


def test_hop_bytes_batch_matches_scalar():
    rng = np.random.default_rng(0)
    topo = TorusTopology((4, 4))
    D = topo.hop_matrix()
    G = _random_guest(10, 1)
    P = np.stack([rng.choice(16, 10, replace=False) for _ in range(5)])
    batch = mp.hop_bytes_batch(G, D, P)
    scalar = [mp.hop_bytes(G, D, p) for p in P]
    np.testing.assert_allclose(batch, scalar, rtol=1e-12)
    # blocked path (tiny block budget forces multiple gathers)
    blocked = mp.hop_bytes_batch(G, D, P, max_block_elems=120)
    np.testing.assert_allclose(blocked, scalar, rtol=1e-12)


def test_comm_graph_builders_match_loop_semantics():
    """Vectorized scatter accumulation == sequential add_p2p loops."""
    from repro.core.comm_graph import CommGraph, _ring_pairs

    ranks = [3, 0, 7, 5, 2]
    g = len(ranks)
    vec = CommGraph(8)
    vec.add_all_reduce(ranks, 640.0, repeats=2.0)
    vec.add_all_reduce(ranks, 64.0, algorithm="recursive_doubling")
    vec.add_all_gather(ranks, 100.0)
    vec.add_reduce_scatter(ranks, 500.0)
    vec.add_all_to_all(ranks, 500.0, repeats=3.0)
    vec.add_broadcast(ranks, 80.0, root=2)
    vec.add_collective_permute([(0, 1), (1, 0), (5, 2)], 50.0)

    ref = CommGraph(8)
    per_pair = 2.0 * (g - 1) / g * 640.0
    for a, b in _ring_pairs(ranks):
        ref.add_p2p(a, b, per_pair * 2.0, 2 * (g - 1) * 2.0)
    k = 1
    while k < g:
        for idx, r in enumerate(ranks):
            peer = idx ^ k
            if peer < g and idx < peer:
                ref.add_p2p(r, ranks[peer], 64.0, 1.0)
        k <<= 1
    for a, b in _ring_pairs(ranks):
        ref.add_p2p(a, b, (g - 1) * 100.0, g - 1)
    for a, b in _ring_pairs(ranks):
        ref.add_p2p(a, b, (g - 1) / g * 500.0, g - 1)
    chunk = 500.0 / g
    for i in range(g):
        for j in range(i + 1, g):
            ref.add_p2p(ranks[i], ranks[j], 2 * chunk * 3.0, 2 * 3.0)
    order = list(range(g))
    order[0], order[2] = order[2], order[0]
    k = 1
    while k < g:
        for idx in range(k):
            peer = idx + k
            if peer < g:
                ref.add_p2p(ranks[order[idx]], ranks[order[peer]], 80.0, 1.0)
        k <<= 1
    for s, d in [(0, 1), (1, 0), (5, 2)]:
        ref.add_p2p(s, d, 50.0, 1.0)

    np.testing.assert_allclose(vec.G_v, ref.G_v, rtol=1e-12)
    np.testing.assert_allclose(vec.G_m, ref.G_m, rtol=1e-12)


def test_comm_graph_two_rank_ring_duplicate_pairs():
    """g=2 ring: the two directed ring edges hit the same unordered pair —
    np.add.at must accumulate both, like two sequential add_p2p calls."""
    from repro.core.comm_graph import CommGraph
    vec = CommGraph(4)
    vec.add_all_reduce([1, 3], 100.0)
    per_pair = 2.0 * 1 / 2 * 100.0
    assert vec.G_v[1, 3] == vec.G_v[3, 1] == 2 * per_pair


def test_heatmap_binning_matches_dense_scatter():
    wl = lammps_like(64)
    m = wl.comm.G_v
    n, bins = 64, 32
    idx = np.arange(n) * bins // n
    dense = np.zeros((bins, bins))
    np.add.at(dense, (idx[:, None].repeat(n, 1), idx[None, :].repeat(n, 0)), m)
    sparse = np.zeros((bins, bins))
    i, j = np.nonzero(m)
    np.add.at(sparse, (idx[i], idx[j]), m[i, j])
    np.testing.assert_allclose(sparse, dense)
    hm = wl.comm.heatmap(width=bins)
    assert len(hm.splitlines()) == bins


def test_engine_shared_cache_reuses_tofa_candidates():
    topo = TorusTopology((4, 4, 4))
    p_f = np.zeros(topo.n_nodes)
    p_f[[0, 5]] = 0.1
    engine = PlacementEngine()
    wl = npb_dt_like(20, seed=2)
    req = PlacementRequest(comm=wl.comm, topology=topo, p_f=p_f)
    engine.place(req, policy="tofa")
    assert engine.stats["shared_misses"] == 1
    engine.place(req, policy="tofa")
    stats = engine.cache_stats()
    assert stats["shared_hits"] >= 1
    # a different health snapshot must not reuse the memo
    p2 = p_f.copy()
    p2[9] = 0.2
    req2 = PlacementRequest(comm=wl.comm, topology=topo, p_f=p2)
    engine.place(req2, policy="tofa")
    assert engine.cache_stats()["shared_misses"] == 2
