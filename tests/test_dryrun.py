"""Dry-run integration test: one real (arch x shape x mesh) cell through
the production launcher in a subprocess (512 host-emulated devices)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape,extra", [
    ("smollm-135m", "decode_32k", []),
    ("mamba2-2.7b", "long_500k", []),
])
def test_dryrun_cell_compiles(arch, shape, extra, tmp_path):
    out = tmp_path / "cell.jsonl"
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", str(out), *extra],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rows = [json.loads(l) for l in open(out)]
    assert len(rows) == 1 and rows[0]["ok"]
    row = rows[0]
    assert row["devices"] == 256
    assert row["compute_s"] >= 0 and row["memory_s"] > 0
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["fits_hbm"] in (True, False)
    # placement analysis present with both policies
    assert "placement" in row
    assert {"linear", "tofa"} <= set(row["placement"])


def test_dryrun_skips_dead_cells():
    """Dead cells (long_500k x full-attention) are excluded by design."""
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    r = subprocess.run(
        [sys.executable, "-c",
         "from repro.configs.base import shape_cells;"
         "from repro.configs.registry import get_arch;"
         "assert 'long_500k' not in shape_cells(get_arch('starcoder2-7b'));"
         "assert 'long_500k' in shape_cells(get_arch('zamba2-7b'));"
         "print('OK')"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=120)
    assert "OK" in r.stdout
