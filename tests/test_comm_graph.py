import numpy as np
import pytest

from repro.core.comm_graph import CommGraph, _ring_pairs


def test_p2p_symmetric():
    g = CommGraph(4)
    g.add_p2p(0, 2, 100.0, 3)
    assert g.G_v[0, 2] == g.G_v[2, 0] == 100.0
    assert g.G_m[0, 2] == g.G_m[2, 0] == 3
    assert np.allclose(g.G_v, g.G_v.T)


def test_self_traffic_ignored():
    g = CommGraph(4)
    g.add_p2p(1, 1, 100.0)
    assert g.G_v.sum() == 0


def test_ring_allreduce_bytes_conservation():
    # ring all-reduce of S bytes over g ranks: each rank sends 2(g-1)/g*S
    g = CommGraph(8)
    S = 800.0
    g.add_all_reduce(list(range(8)), S)
    per_rank_sent = 2 * (8 - 1) / 8 * S
    # symmetric convention: total matrix sum = 2 * total bytes on the wire
    assert np.isclose(g.G_v.sum() / 2, 8 * per_rank_sent)
    # traffic only on ring edges
    assert g.G_v[0, 1] > 0 and g.G_v[0, 2] == 0 and g.G_v[0, 7] > 0


def test_allgather_reduce_scatter():
    g = CommGraph(4)
    g.add_all_gather([0, 1, 2, 3], 100.0)  # shard bytes
    assert np.isclose(g.G_v.sum() / 2, 4 * 3 * 100.0)
    g2 = CommGraph(4)
    g2.add_reduce_scatter([0, 1, 2, 3], 400.0)  # full bytes
    assert np.isclose(g2.G_v.sum() / 2, 4 * 3 / 4 * 400.0)
    # ring AR == RS + AG of matching sizes (bytes identity)
    g3 = CommGraph(4)
    g3.add_all_reduce([0, 1, 2, 3], 400.0)
    assert np.isclose(g3.G_v.sum(), g2.G_v.sum() + g.G_v.sum())


def test_alltoall_uniform_pairs():
    g = CommGraph(4)
    g.add_all_to_all([0, 1, 2, 3], 400.0)
    off = g.G_v[~np.eye(4, dtype=bool)]
    assert np.allclose(off, off[0]) and off[0] > 0
    # each rank sends (g-1)/g * local = 300 bytes
    assert np.isclose(g.G_v.sum() / 2, 4 * 300.0)


def test_recursive_doubling_touches_power2_distances():
    g = CommGraph(8)
    g.add_all_reduce(list(range(8)), 100.0, algorithm="recursive_doubling")
    assert g.G_v[0, 1] > 0 and g.G_v[0, 2] > 0 and g.G_v[0, 4] > 0
    assert g.G_v[0, 3] == 0


def test_broadcast_tree_reaches_everyone():
    g = CommGraph(7)
    g.add_broadcast(list(range(7)), 100.0)
    reached = {0}
    frontier = True
    # every rank must be connected to the root component
    import networkx as nx
    G = nx.from_numpy_array(g.G_v)
    assert nx.is_connected(G)


def test_collective_permute():
    g = CommGraph(4)
    g.add_collective_permute([(0, 1), (1, 2), (2, 3), (3, 0)], 50.0)
    assert g.G_v[0, 1] == 50.0 and g.G_v[3, 0] == 50.0


def test_merge_scale():
    a = CommGraph(4)
    a.add_p2p(0, 1, 10)
    b = CommGraph(4)
    b.add_p2p(1, 2, 20)
    m = a.merged(b).scaled(2.0)
    assert m.G_v[0, 1] == 20 and m.G_v[1, 2] == 40


def test_regularity_metric():
    from repro.workloads.patterns import lammps_like, npb_dt_like
    reg = lammps_like(64).comm.regularity()
    irr = npb_dt_like(85).comm.regularity()
    assert reg > 0.5, f"multi-band 3D-halo pattern should be regular, got {reg}"
    assert irr < 0.3, f"DT-like pattern should be irregular, got {irr}"
    assert reg > 2 * irr, "regular/irregular contrast must be preserved"


def test_heatmap_renders():
    from repro.workloads.patterns import lammps_like
    hm = lammps_like(64).comm.heatmap(width=32)
    lines = hm.splitlines()
    assert len(lines) == 32 and all(len(l) == 32 for l in lines)
    assert any(ch != " " for l in lines for ch in l)


def test_weights_metric_choice():
    g = CommGraph(3)
    g.add_p2p(0, 1, 1000.0, 1)
    g.add_p2p(1, 2, 10.0, 99)
    assert g.weights("volume")[0, 1] > g.weights("volume")[1, 2]
    assert g.weights("messages")[1, 2] > g.weights("messages")[0, 1]
    with pytest.raises(ValueError):
        g.weights("nope")
