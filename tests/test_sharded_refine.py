"""Sharded candidate-stack refine: device axis + single-vs-sharded identity.

The real assertions run in a subprocess because the XLA host-device count
is frozen the moment jax initialises — ``--xla_force_host_platform_device_
count=8`` must be in ``XLA_FLAGS`` *before* the first jax import, which a
test process that already imported jax (conftest, earlier tests) cannot
undo.  The child script exercises:

* ``backend.use("jax")`` sees 8 devices, ``devices=1`` pins the
  single-device vmap path, ``REPRO_JAX_DEVICES`` caps it;
* sharded ``refine_many`` (shard_map over the candidate axis) returns
  placements **bit-identical** to the single-device vmap dispatch — on
  dense, implicit-torus, and implicit-fat-tree distances — including
  ragged stacks that need edge-padding to a device multiple;
* the ``sharded_dispatches`` stat increments only on the sharded path.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytest.importorskip("jax")

REPO = Path(__file__).resolve().parents[1]

CHILD = r"""
import numpy as np
from repro.core import backend, mapping_jax
from repro.core.fattree import FatTreeTopology
from repro.core.topology import TorusTopology
from repro.workloads.patterns import npb_dt_like

be = backend.get_backend("jax")
assert be.device_count == 8, be.device_count
with backend.use("jax", devices=1) as b1:
    assert b1.device_count == 1
with backend.use("jax", devices=3) as b3:
    assert b3.device_count == 3

wl = npb_dt_like(40)
G = wl.comm.G_v
rng = np.random.default_rng(0)

torus = TorusTopology((4, 4, 4))
ft = FatTreeTopology(8)
p_f = np.zeros(ft.n_nodes)
p_f[rng.choice(ft.n_nodes, 6, replace=False)] = 0.1
cases = [
    ("dense", torus.hop_matrix(), torus.n_nodes),
    ("implicit-torus", torus.lazy_distance(), torus.n_nodes),
    ("implicit-fattree", ft.lazy_distance(p_f, c=2.0), ft.n_nodes),
]
for b in (3, 8, 16):     # ragged (pad to device multiple), 1/lane, 2/lane
    for name, D, n_nodes in cases:
        P = np.stack([rng.permutation(n_nodes)[:40] for _ in range(b)])
        with backend.use("jax", devices=1):
            single = mapping_jax.refine_many(G, D, P)
        with backend.use("jax") as bj:
            before = bj.stats["sharded_dispatches"]
            sharded = mapping_jax.refine_many(G, D, P)
            assert bj.stats["sharded_dispatches"] == before + 1, name
        assert sharded.shape == P.shape, (name, b)
        assert np.array_equal(single, sharded), (name, b)
print("OK")
"""


def test_sharded_refine_bit_identical():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env.pop("REPRO_JAX_DEVICES", None)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", CHILD], cwd=REPO, env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.strip().endswith("OK")


def test_devices_cap_env(monkeypatch):
    """REPRO_JAX_DEVICES caps the dispatch without an explicit argument
    (resolved per backend construction, not frozen at import)."""
    from repro.core import backend

    monkeypatch.setenv("REPRO_JAX_DEVICES", "1")
    be = backend.get_backend("jax")
    assert be.devices == 1
    assert be.device_count == 1
    monkeypatch.delenv("REPRO_JAX_DEVICES")
    assert backend.get_backend("jax").devices == 0
