"""Tests for the online placement service (repro.service) and its
satellite changes (arrival validation, scheduler admission counters)."""
import math

import numpy as np
import pytest

from repro.cluster.scheduler import Job, Scheduler
from repro.core.state import NodeHealth
from repro.core.topology import TorusTopology
from repro.service import (AdmissionQueue, LatencyHistogram,
                           PlacementService, ReplicaSpec, SLOClass,
                           elastic_request, kv_shard_bytes,
                           replica_request)
from repro.workloads.arrivals import (burst_stream, mixed_size_factory,
                                      poisson_stream, serial_stream)
from repro.workloads.patterns import halo3d, npb_dt_like


def small_service(seed=0, dims=(3, 3, 3), **kw):
    return PlacementService(TorusTopology(dims), seed=seed,
                            drain_interval=0.25, restart_delay=0.5, **kw)


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

class TestRequests:
    def test_replica_workload_layout(self):
        spec = ReplicaSpec(shards_per_replica=3, shard_bytes=1e8)
        wl = spec.workload(2)
        assert spec.ranks_per_replica == 4
        assert wl.n_ranks == 8
        G = wl.comm.G_v
        # engine<->shard edges are the heavy ones inside each replica
        assert G[0, 1] > 0 and G[4, 5] > 0
        # engine-engine sync exists but is far lighter than KV traffic
        assert 0 < G[0, 4] < G[0, 1]
        # no traffic between different replicas' shards
        assert G[1, 5] == 0

    def test_kv_shard_bytes_scaling(self):
        from repro.configs.registry import get_arch
        cfg = get_arch("smollm-135m")
        one = kv_shard_bytes(cfg, batch=8, max_seq=4096, shards=1)
        four = kv_shard_bytes(cfg, batch=8, max_seq=4096, shards=4)
        assert one > 0 and one / four == pytest.approx(4.0)
        # GQA cache: k+v, each (L, B, Hkv, S, hd) at bf16 (2 bytes)
        assert one == pytest.approx(
            2 * cfg.n_layers * 8 * cfg.n_kv_heads * 4096
            * cfg.head_dim_ * 2, rel=0.01)

    def test_request_validation(self):
        wl = npb_dt_like(8)
        with pytest.raises(ValueError, match="deadline"):
            elastic_request(wl, submit_time=5.0, deadline=1.0)
        with pytest.raises(ValueError, match="hold_time"):
            elastic_request(wl, hold_time=0.0)
        with pytest.raises(ValueError, match="shards"):
            kv_shard_bytes(None, 1, 1, shards=0)

    def test_req_ids_unique(self):
        a = elastic_request(npb_dt_like(8))
        b = elastic_request(npb_dt_like(8))
        assert a.req_id != b.req_id


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

class TestAdmissionQueue:
    def test_slo_lane_ordering(self):
        q = AdmissionQueue()
        wl = npb_dt_like(8)
        be = elastic_request(wl, slo=SLOClass.BEST_EFFORT)
        st = elastic_request(wl, slo=SLOClass.STANDARD)
        ia = elastic_request(wl, slo=SLOClass.INTERACTIVE)
        for r in (be, st, ia):
            assert q.push(r, now=0.0)
        batch = q.drain(now=0.0, capacity=100)
        assert [r.req_id for r in batch] == [ia.req_id, st.req_id,
                                             be.req_id]

    def test_edf_within_lane(self):
        q = AdmissionQueue()
        wl = npb_dt_like(8)
        late = elastic_request(wl, slo=SLOClass.STANDARD, deadline=50.0)
        soon = elastic_request(wl, slo=SLOClass.STANDARD, deadline=10.0)
        q.push(late, 0.0)
        q.push(soon, 0.0)
        assert q.head(SLOClass.STANDARD).req_id == soon.req_id
        assert [r.req_id for r in q.drain(0.0, 100)] == [soon.req_id,
                                                         late.req_id]

    def test_deadline_shedding(self):
        q = AdmissionQueue()
        wl = npb_dt_like(8)
        r1 = elastic_request(wl, deadline=5.0)
        r2 = elastic_request(wl, deadline=50.0)
        q.push(r1, 0.0)
        q.push(r2, 0.0)
        shed = q.shed_expired(now=10.0)
        assert [r.req_id for r in shed] == [r1.req_id]
        assert q.depth == 1
        # an already-expired request is never admitted
        assert not q.push(elastic_request(wl, deadline=15.0), now=15.0)

    def test_bounded_depth_rejects(self):
        q = AdmissionQueue(max_depth=1)
        wl = npb_dt_like(8)
        assert q.push(elastic_request(wl), 0.0)
        assert not q.push(elastic_request(wl), 0.0)
        assert q.peak_depth == 1
        with pytest.raises(ValueError):
            AdmissionQueue(max_depth=0)

    def test_capacity_backfill(self):
        q = AdmissionQueue()
        wide = elastic_request(npb_dt_like(16), slo=SLOClass.STANDARD)
        narrow = elastic_request(npb_dt_like(4), slo=SLOClass.BEST_EFFORT)
        q.push(wide, 0.0)
        q.push(narrow, 0.0)
        batch = q.drain(0.0, capacity=8)   # wide blocked, narrow slips by
        assert [r.req_id for r in batch] == [narrow.req_id]
        assert q.depth == 1
        assert q.head(SLOClass.STANDARD).req_id == wide.req_id

    def test_remove(self):
        q = AdmissionQueue()
        r = elastic_request(npb_dt_like(8))
        q.push(r, 0.0)
        assert q.remove(r.req_id) is r
        assert q.remove(r.req_id) is None
        assert q.depth == 0


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_histogram_percentiles(self):
        h = LatencyHistogram()
        vals = np.linspace(0.01, 1.0, 100)
        for v in vals:
            h.observe(float(v))
        assert h.p50 == pytest.approx(float(np.percentile(vals, 50)))
        assert h.p99 <= h.max == pytest.approx(1.0)
        assert len(h) == 100
        with pytest.raises(ValueError):
            h.observe(-1.0)

    def test_empty_histogram(self):
        h = LatencyHistogram()
        assert h.p50 == -1.0 and h.p99 == -1.0 and h.mean == -1.0
        assert h.to_dict()["n"] == 0


# ---------------------------------------------------------------------------
# service behavior
# ---------------------------------------------------------------------------

class TestService:
    def test_place_and_complete(self):
        svc = small_service()
        req = elastic_request(npb_dt_like(8), hold_time=2.0)
        res = svc.run([req])
        reply = res.replies[req.req_id]
        assert reply.status == "completed"
        assert reply.admission_latency == pytest.approx(0.25)
        assert len(reply.nodes) == 8
        assert res.metrics.placed == 1 and res.metrics.completed == 1

    def test_service_deadline_shed(self):
        svc = small_service()
        # deadline tighter than the drain interval: queued, then shed
        req = elastic_request(npb_dt_like(8), deadline=0.1, hold_time=1.0)
        res = svc.run([req])
        assert res.replies[req.req_id].status == "shed"
        assert res.metrics.shed == 1 and res.metrics.placed == 0

    def test_preemption_under_pressure(self):
        svc = small_service()   # 27 nodes
        fillers = [elastic_request(halo3d((2, 2, 2)),
                                   slo=SLOClass.BEST_EFFORT,
                                   submit_time=0.0, hold_time=100.0)
                   for _ in range(3)]           # 24 of 27 nodes held
        urgent = replica_request(shard_bytes=1e8, n_replicas=2,
                                 shards_per_replica=3,
                                 slo=SLOClass.INTERACTIVE,
                                 submit_time=1.0, hold_time=1.0)
        res = svc.run(fillers + [urgent], horizon=10.0)
        assert res.replies[urgent.req_id].status == "completed"
        assert res.metrics.preempted >= 1
        preempted = [r for r in res.replies.values() if r.preemptions]
        assert preempted and all(r.slo == SLOClass.BEST_EFFORT
                                 for r in preempted)
        # the victim went back to its lane rather than dying
        assert res.metrics.requeued >= 1

    def test_standard_does_not_preempt(self):
        svc = small_service()
        fillers = [elastic_request(halo3d((2, 2, 2)),
                                   slo=SLOClass.BEST_EFFORT,
                                   submit_time=0.0, hold_time=5.0)
                   for _ in range(3)]
        std = elastic_request(npb_dt_like(8), slo=SLOClass.STANDARD,
                              submit_time=1.0, hold_time=1.0)
        res = svc.run(fillers + [std])
        assert res.metrics.preempted == 0
        # it still completes — but only after a filler finishes
        assert res.replies[std.req_id].status == "completed"
        assert res.replies[std.req_id].admission_latency > 1.0

    def test_resize_grow_and_shrink(self):
        svc = small_service(dims=(4, 4, 4))
        req = replica_request(shard_bytes=1e8, n_replicas=2,
                              shards_per_replica=3, hold_time=50.0)
        svc.submit(req, now=0.0)
        svc.tick(0.25)
        lease = svc.leases[req.req_id]
        orig = lease.nodes.copy()
        assert len(orig) == 8
        grown = svc.resize(req.req_id, 3, now=1.0)
        assert len(grown.nodes) == 12
        # existing replicas stay put; the new block lands on free nodes
        assert np.array_equal(grown.nodes[:8], orig)
        assert not np.isin(grown.nodes[8:], orig).any()
        assert grown.workload.n_ranks == 12
        shrunk = svc.resize(req.req_id, 1, now=2.0)
        assert np.array_equal(shrunk.nodes, orig[:4])
        assert svc.metrics.resized == 2
        # freed nodes are allocatable again
        assert svc.free_capacity() == 64 - 4
        with pytest.raises(ValueError):
            svc.resize(req.req_id, 0, now=3.0)
        with pytest.raises(KeyError):
            svc.resize(999999, 2, now=3.0)

    def test_failure_replacement_parity_with_engine(self):
        # one shared request: req_id seeds the per-request placement, so
        # both services see identical inputs
        req = elastic_request(npb_dt_like(8), hold_time=100.0)

        def setup():
            svc = small_service(seed=3, dims=(4, 4, 4))
            svc.submit(req, now=0.0)
            svc.tick(0.25)
            return svc
        a_svc = setup()
        b_svc = setup()
        assert np.array_equal(a_svc.leases[req.req_id].nodes,
                              b_svc.leases[req.req_id].nodes)
        victim = [int(a_svc.leases[req.req_id].nodes[0])]
        # A: the service's failure path
        touched = a_svc.handle_failure(victim, now=1.0)
        assert touched == [req.req_id]
        # B: the same call made directly against the engine
        b_svc.state = b_svc.state.with_health(victim, NodeHealth.DOWN)
        lease = b_svc.leases[req.req_id]
        plan = b_svc.engine.replace(
            lease.plan, victim,
            state=b_svc.busy_view(exclude=req.req_id), rng=b_svc.rng)
        assert plan.provenance == "replace-incremental"
        assert np.array_equal(a_svc.leases[req.req_id].nodes,
                              plan.placement)
        assert a_svc.metrics.replaced == 1
        assert a_svc.replies[req.req_id].replacements == 1

    def test_failure_requeues_when_no_capacity(self):
        svc = small_service()   # 27 nodes
        req = elastic_request(halo3d((3, 3, 3)), hold_time=100.0)  # all 27
        svc.submit(req, now=0.0)
        svc.tick(0.25)
        svc.handle_failure([0], now=1.0)
        # 26 survivors cannot hold 27 ranks: back to the queue
        assert req.req_id not in svc.leases
        assert svc.replies[req.req_id].status == "queued"
        assert svc.metrics.requeued == 1

    def test_failure_untouched_lease_fast_path(self):
        svc = small_service(dims=(4, 4, 4))
        req = elastic_request(npb_dt_like(8), hold_time=100.0)
        svc.submit(req, now=0.0)
        svc.tick(0.25)
        used = set(int(x) for x in svc.leases[req.req_id].nodes)
        spare = next(i for i in range(64) if i not in used)
        touched = svc.handle_failure([spare], now=1.0)
        assert touched == []
        assert svc.metrics.replace_skipped == 1
        assert svc.metrics.replaced == 0

    def test_recovery_restores_capacity(self):
        svc = small_service()
        svc.handle_failure([0, 1], now=0.0)
        assert svc.free_capacity() == 25
        svc.handle_recover([0, 1], now=1.0)
        assert svc.free_capacity() == 27

    def test_determinism_same_seed_same_log(self):
        # the SAME request objects through two fresh services: equal
        # seeds and inputs must give bit-identical placement logs
        rng = np.random.default_rng(11)
        reqs, t = [], 0.0
        for i in range(30):
            t += float(rng.exponential(0.2))
            reqs.append(elastic_request(npb_dt_like(8),
                                        slo=SLOClass(i % 3),
                                        submit_time=t, hold_time=1.0))
        belief = np.where(np.arange(64) % 7 == 0, 0.2, 0.0)

        def storm():
            svc = small_service(seed=5, dims=(4, 4, 4))
            res = svc.run(reqs, failures=[(2.0, [5]), (4.0, [9])],
                          heartbeat_interval=0.5, belief=belief,
                          belief_jitter=0.2)
            assert res.metrics.completed == 30
            return res.placement_log
        assert storm() == storm()

    def test_busy_view_keeps_route_key_warm(self):
        svc = small_service(dims=(4, 4, 4))
        base_key = svc.state.key
        for _ in range(3):
            svc.submit(elastic_request(npb_dt_like(8), hold_time=50.0),
                       now=0.0)
        svc.tick(0.25)
        view = svc.busy_view()
        assert view.is_overlay and view.route_key == base_key
        # belief jitter within atol never mints an epoch
        svc.heartbeat(np.zeros(64), now=0.5)
        assert svc.state.key == base_key

    def test_storm_cache_hit_rate(self):
        # miniature of the benchmarks/serve_storm.py gate
        svc = small_service(seed=0, dims=(4, 4, 4))
        rng = np.random.default_rng(1)
        reqs, t = [], 0.0
        for _ in range(120):
            t += float(rng.exponential(0.2))
            reqs.append(elastic_request(npb_dt_like(8), submit_time=t,
                                        hold_time=1.0))
        belief = np.zeros(64)
        belief[[3, 9, 17]] = 0.3
        res = svc.run(reqs, failures=[(6.0, [3]), (14.0, [9])],
                      recoveries=[(20.0, [3, 9])],
                      heartbeat_interval=0.5, belief=belief,
                      belief_jitter=0.3)
        assert res.metrics.completed == 120
        assert res.hit_rate >= 0.90

    def test_invalid_drain_interval(self):
        with pytest.raises(ValueError):
            PlacementService(TorusTopology((3, 3, 3)), drain_interval=0.0)


# ---------------------------------------------------------------------------
# satellite: arrival validation + duration cap
# ---------------------------------------------------------------------------

class TestArrivalValidation:
    def test_poisson_rejects_bad_inputs(self):
        f = mixed_size_factory((8,))
        rng = np.random.default_rng(0)
        for bad in (0.0, -1.0, math.inf, math.nan):
            with pytest.raises(ValueError, match="rate"):
                poisson_stream(f, bad, 5, rng)
        with pytest.raises(ValueError, match="n_jobs"):
            poisson_stream(f, 1.0, 0, rng)
        with pytest.raises(ValueError, match="max_duration"):
            poisson_stream(f, 1.0, 5, rng, max_duration=0.0)

    def test_poisson_duration_cap(self):
        f = mixed_size_factory((8,))
        specs = poisson_stream(f, rate=10.0, n_jobs=500,
                               rng=np.random.default_rng(0),
                               max_duration=5.0)
        assert 0 < len(specs) < 500
        assert all(s.submit_time <= 5.0 for s in specs)
        # same seed without the cap: identical prefix
        full = poisson_stream(f, rate=10.0, n_jobs=500,
                              rng=np.random.default_rng(0))
        assert [s.submit_time for s in specs] == \
            [s.submit_time for s in full[:len(specs)]]

    def test_empty_stream_errors(self):
        with pytest.raises(ValueError, match="at least one"):
            serial_stream([])
        with pytest.raises(ValueError, match="at least one"):
            burst_stream([])
        with pytest.raises(ValueError, match="instant"):
            burst_stream([npb_dt_like(4)], at=-1.0)
        with pytest.raises(ValueError, match="at least one size"):
            mixed_size_factory(())
        with pytest.raises(ValueError, match="weights"):
            mixed_size_factory((4, 8), weights=(1.0,))


# ---------------------------------------------------------------------------
# satellite: scheduler admission counters
# ---------------------------------------------------------------------------

class TestSchedulerStats:
    def test_admission_counters(self):
        topo = TorusTopology((2, 2, 2))     # 8 nodes
        sch = Scheduler(topo, seed=0)
        sch.clock = 0.0
        first = sch.submit(Job(npb_dt_like(8)))   # takes the whole machine
        assert first.state == "running" and first.start_time == 0.0
        sch.clock = 1.0
        second = sch.submit(Job(npb_dt_like(4)))  # must wait
        s = sch.stats()
        assert s["queue_depth"] == 1 and s["peak_queue_depth"] == 1
        assert s["n_enqueued"] == 2 and s["n_started"] == 1
        sch.clock = 3.0
        sch.complete(first.job.job_id)
        s = sch.stats()
        assert second.state == "running"
        assert second.enqueue_time == 1.0 and second.start_time == 3.0
        assert s["queue_depth"] == 0 and s["n_started"] == 2
        assert s["admission_wait_max_s"] == pytest.approx(2.0)
        assert s["admission_wait_mean_s"] == pytest.approx(1.0)

    def test_clustersim_drives_clock(self):
        from repro.sim.clustersim import ClusterSim, SimConfig
        from repro.workloads.arrivals import JobSpec
        topo = TorusTopology((3, 3, 3))
        sch = Scheduler(topo, seed=0)
        jobs = [JobSpec(npb_dt_like(8), submit_time=float(i))
                for i in range(4)]
        ClusterSim(sch, jobs, config=SimConfig()).run()
        s = sch.stats()
        assert s["n_enqueued"] == 4 and s["n_started"] == 4
        assert s["admission_wait_total_s"] >= 0.0
        assert sch.clock > 0.0
