"""E8 (beyond paper) — event-driven cluster-simulator scenario sweep.

Runs the scenario presets (``repro.sim.scenarios``) per policy and emits
one CSV row per (scenario, policy) with mean job completion, makespan,
abort and event counts, and the scheduler's aggregate ``place_time_s``
(mapper wall-clock across batched ``place_many`` queue drains and
fault-driven re-placements — the number the batched drain shrinks).  ``--write --label <name>`` appends a point to
the committed ``benchmarks/BENCH_clustersim.json`` trajectory;
``--check`` exits non-zero when tofa does not beat linear on mean
completion in the gated presets (``saturated-queue``,
``correlated-failures``) — the CI smoke gate, bounded by fixed seeds and
each preset's ``fast`` event budget.

    PYTHONPATH=src python -m benchmarks.clustersim [--fast] [--check]
    PYTHONPATH=src python -m benchmarks.clustersim --write --label pr3
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

from repro.sim.scenarios import run_preset

BENCH_PATH = pathlib.Path(__file__).parent / "BENCH_clustersim.json"
GATED = ("saturated-queue", "correlated-failures", "degraded-drain")
PRESETS = ("paper-fig4-5", "saturated-queue", "mixed-stream", "fat-tree",
           "correlated-failures", "drain-sweep", "degraded-drain")


def _flat_rows(name: str, out: dict) -> list[dict]:
    """Flatten a preset result into per-(policy[, threshold]) rows."""
    rows = []
    for pol, row in out["policies"].items():
        if "mean_completion" in row:
            rows.append(dict(
                scenario=name, policy=pol,
                mean_completion=row["mean_completion"],
                makespan=row.get("makespan", row["mean_completion"]),
                aborted_attempts=row["aborted_attempts"],
                n_events=row["n_events"],
                truncated=row.get("truncated", False),
                place_time_s=row.get("place_time_s", 0.0)))
        else:   # drain-sweep: one row per threshold
            for th, r in row.items():
                rows.append(dict(scenario=f"{name}/th={th}", policy=pol,
                                 mean_completion=r["mean_completion"],
                                 makespan=r["makespan"],
                                 aborted_attempts=r["aborted_attempts"],
                                 n_events=r["n_events"],
                                 truncated=r.get("truncated", False),
                                 place_time_s=r.get("place_time_s", 0.0)))
    return rows


def run(csv=print, fast: bool | None = None, seed: int = 0) -> dict:
    if fast is None:
        fast = bool(int(os.environ.get("FAST", "0")))
    all_rows: list[dict] = []
    summary: dict = {}
    for name in PRESETS:
        t0 = time.perf_counter()
        out = run_preset(name, seed=seed, fast=fast)
        wall = time.perf_counter() - t0
        rows = _flat_rows(name, out)
        all_rows += rows
        summary[name] = out
        for r in rows:
            csv(f"clustersim,{r['scenario']},{r['policy']},"
                f"{r['mean_completion']:.4f},s_mean_completion,"
                f"makespan={r['makespan']:.4f},"
                f"aborts={r['aborted_attempts']},events={r['n_events']},"
                f"place_time_s={r['place_time_s']:.4f}")
        csv(f"clustersim,{name},wall_time,{wall:.1f},s")
    for name in GATED:
        pols = summary[name]["policies"]
        imp = 1.0 - (pols["tofa"]["mean_completion"]
                     / pols["linear"]["mean_completion"])
        csv(f"clustersim,{name},tofa_improvement,{imp:.3f},frac")
    summary["_rows"] = all_rows
    return summary


def check(summary: dict) -> int:
    """CI gate: tofa must beat linear on mean completion where gated."""
    rc = 0
    for name in GATED:
        pols = summary[name]["policies"]
        tofa, lin = (pols["tofa"]["mean_completion"],
                     pols["linear"]["mean_completion"])
        ok = tofa < lin
        print(f"GATE {name}: tofa={tofa:.4f} linear={lin:.4f} "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            rc = 1
        if pols["tofa"].get("truncated") or pols["linear"].get("truncated"):
            print(f"GATE {name}: FAIL (hit max_events budget)")
            rc = 1
    return rc


def write_trajectory(rows: list[dict], label: str, fast: bool) -> None:
    doc = {"schema": 1, "trajectory": []}
    if BENCH_PATH.exists():
        doc = json.loads(BENCH_PATH.read_text())
    doc["trajectory"].append(
        {"label": label, "fast": fast, "scenarios": rows})
    BENCH_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"appended trajectory point {label!r} to {BENCH_PATH}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless tofa beats linear on the "
                         "gated presets")
    ap.add_argument("--write", action="store_true",
                    help="append a point to BENCH_clustersim.json")
    ap.add_argument("--label", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    summary = run(fast=args.fast or None, seed=args.seed)
    if args.write:
        write_trajectory(summary["_rows"], args.label or "unlabeled",
                         bool(args.fast))
    return check(summary) if args.check else 0


if __name__ == "__main__":
    sys.exit(main())
