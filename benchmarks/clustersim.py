"""E8 (beyond paper) — event-driven cluster-simulator scenario sweep.

Runs the scenario presets (``repro.sim.scenarios``) per policy and emits
one CSV row per (scenario, policy) with mean job completion, makespan,
abort and event counts, and the scheduler's aggregate ``place_time_s``
(mapper wall-clock across batched ``place_many`` queue drains and
fault-driven re-placements — the number the batched drain shrinks).
``--write --label <name>`` appends a point to the committed
``benchmarks/BENCH_clustersim.json`` trajectory.

``--check`` is a *statistical* gate: each gated preset is executed across
``--replicas`` independent seeds (default 16; the committed trajectory
carries >= 1000-replica points) through :mod:`repro.sim.replicas`, and
the gate passes only when the 95% percentile-bootstrap CI of the paired
per-seed delta ``mean_completion(linear) - mean_completion(tofa)`` lies
strictly above zero.  Single-seed point comparisons were retired after a
64-seed audit (see ``SEED_AUDIT``) showed ``saturated-queue`` and
``correlated-failures`` flip their tofa<linear verdict on a minority of
seeds — the paired CI is stable where the anecdote is not.  Replica rows
grow additive ``n_replicas``/``ci_low``/``ci_high``/``win_rate`` keys
next to the existing schema.

    PYTHONPATH=src python -m benchmarks.clustersim [--fast] [--check]
    PYTHONPATH=src python -m benchmarks.clustersim --fast --check \
        --replicas 16 --presets cascading-racks,maintenance-burst --skip-sweep
    PYTHONPATH=src python -m benchmarks.clustersim --fast --write \
        --label pr8 --replicas 1000
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

from repro.sim.replicas import run_replicas
from repro.sim.scenarios import run_preset

BENCH_PATH = pathlib.Path(__file__).parent / "BENCH_clustersim.json"
GATED = ("saturated-queue", "correlated-failures", "degraded-drain",
         "cascading-racks", "maintenance-burst")
PRESETS = ("paper-fig4-5", "saturated-queue", "mixed-stream", "fat-tree",
           "correlated-failures", "drain-sweep", "degraded-drain",
           "dragonfly", "cascading-racks", "maintenance-burst")

# 64-seed fast-mode audit (seed 0..63, single-seed tofa<linear verdicts):
# presets with nonzero flips were migrated from the old point-estimate
# gate to the bootstrap-CI gate; counts are committed with each replica
# trajectory point so the migration rationale travels with the data.
SEED_AUDIT = {
    "saturated-queue": {"n_seeds": 64, "verdict_flips": 6},
    "correlated-failures": {"n_seeds": 64, "verdict_flips": 2},
    "degraded-drain": {"n_seeds": 64, "verdict_flips": 0},
}


def _flat_rows(name: str, out: dict) -> list[dict]:
    """Flatten a preset result into per-(policy[, threshold]) rows."""
    rows = []
    for pol, row in out["policies"].items():
        if "mean_completion" in row:
            rows.append(dict(
                scenario=name, policy=pol,
                mean_completion=row["mean_completion"],
                makespan=row.get("makespan", row["mean_completion"]),
                aborted_attempts=row["aborted_attempts"],
                n_events=row["n_events"],
                truncated=row.get("truncated", False),
                place_time_s=row.get("place_time_s", 0.0)))
        else:   # drain-sweep: one row per threshold
            for th, r in row.items():
                rows.append(dict(scenario=f"{name}/th={th}", policy=pol,
                                 mean_completion=r["mean_completion"],
                                 makespan=r["makespan"],
                                 aborted_attempts=r["aborted_attempts"],
                                 n_events=r["n_events"],
                                 truncated=r.get("truncated", False),
                                 place_time_s=r.get("place_time_s", 0.0)))
    return rows


def run(csv=print, fast: bool | None = None, seed: int = 0) -> dict:
    if fast is None:
        fast = bool(int(os.environ.get("FAST", "0")))
    all_rows: list[dict] = []
    summary: dict = {}
    for name in PRESETS:
        t0 = time.perf_counter()
        out = run_preset(name, seed=seed, fast=fast)
        wall = time.perf_counter() - t0
        rows = _flat_rows(name, out)
        all_rows += rows
        summary[name] = out
        for r in rows:
            csv(f"clustersim,{r['scenario']},{r['policy']},"
                f"{r['mean_completion']:.4f},s_mean_completion,"
                f"makespan={r['makespan']:.4f},"
                f"aborts={r['aborted_attempts']},events={r['n_events']},"
                f"place_time_s={r['place_time_s']:.4f}")
        csv(f"clustersim,{name},wall_time,{wall:.1f},s")
    for name in GATED:
        pols = summary[name]["policies"]
        imp = 1.0 - (pols["tofa"]["mean_completion"]
                     / pols["linear"]["mean_completion"])
        csv(f"clustersim,{name},tofa_improvement,{imp:.3f},frac")
    summary["_rows"] = all_rows
    return summary


def run_replica_rows(presets, n_replicas: int, *, fast: bool,
                     base_seed: int = 0, B: int = 2000,
                     alpha: float = 0.05, executor: str = "auto",
                     max_workers=None, csv=print) -> tuple[list[dict], dict]:
    """Replica-mode sweep: per-policy bootstrap rows + paired comparisons.

    Returns (rows, comparisons): rows use the single-seed schema plus the
    additive ``n_replicas``/``ci_low``/``ci_high``/``win_rate`` keys
    (win_rate only on the non-baseline policy row); comparisons maps
    preset name -> :class:`repro.sim.replicas.PairedComparison`.
    """
    rows: list[dict] = []
    comparisons: dict = {}
    for name in presets:
        t0 = time.perf_counter()
        rs = run_replicas(name, n_replicas=n_replicas, base_seed=base_seed,
                          fast=fast, executor=executor,
                          max_workers=max_workers)
        wall = time.perf_counter() - t0
        cmp = rs.compare(B=B, alpha=alpha)
        comparisons[name] = cmp
        for pol in rs.policies:
            s = rs.summary(pol, B=B, alpha=alpha)
            mk = rs.metrics[pol].get("makespan",
                                     rs.metrics[pol]["mean_completion"])
            trunc = rs.metrics[pol].get("truncated")
            rows.append(dict(
                scenario=name, policy=pol,
                mean_completion=s.mean,
                makespan=float(mk.mean()),
                aborted_attempts=float(
                    rs.metrics[pol]["aborted_attempts"].mean()),
                n_events=float(rs.metrics[pol]["n_events"].mean()),
                truncated=bool(trunc is not None and trunc.any()),
                place_time_s=float(
                    rs.metrics[pol].get("place_time_s",
                                        mk * 0.0).mean()),
                n_replicas=rs.n_replicas,
                ci_low=s.ci_low, ci_high=s.ci_high,
                win_rate=cmp.win_rate if pol == cmp.a else None))
            csv(f"clustersim,{name},{pol},{s.mean:.4f},"
                f"s_mean_completion,n_replicas={rs.n_replicas},"
                f"ci=[{s.ci_low:.4f},{s.ci_high:.4f}]")
        csv(f"clustersim,{name},delta,{cmp.delta:.4f},s,"
            f"ci=[{cmp.delta_ci_low:.4f},{cmp.delta_ci_high:.4f}],"
            f"win_rate={cmp.win_rate:.3f},p={cmp.p_value:.4g},"
            f"wall={wall:.1f}s")
    return rows, comparisons


def check_replicas(comparisons: dict, rows: list[dict]) -> int:
    """Statistical CI gate: paired delta CI above zero, no truncation."""
    rc = 0
    truncated = {r["scenario"] for r in rows
                 if r.get("n_replicas") and r["truncated"]}
    for name, cmp in comparisons.items():
        ok = cmp.significant
        print(f"GATE {name}: n={cmp.n} tofa={cmp.mean_a:.4f} "
              f"linear={cmp.mean_b:.4f} "
              f"delta={cmp.delta:.4f} "
              f"ci=[{cmp.delta_ci_low:.4f},{cmp.delta_ci_high:.4f}] "
              f"win_rate={cmp.win_rate:.3f} p={cmp.p_value:.4g} "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            rc = 1
        if name in truncated:
            print(f"GATE {name}: FAIL (a replica hit max_events budget)")
            rc = 1
    return rc


def write_trajectory(rows: list[dict], label: str, fast: bool,
                     n_replicas: int | None = None) -> None:
    doc = {"schema": 1, "trajectory": []}
    if BENCH_PATH.exists():
        doc = json.loads(BENCH_PATH.read_text())
    point = {"label": label, "fast": fast, "scenarios": rows}
    if n_replicas:
        point["n_replicas"] = n_replicas
        point["seed_audit"] = SEED_AUDIT
    doc["trajectory"].append(point)
    BENCH_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"appended trajectory point {label!r} to {BENCH_PATH}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the paired bootstrap CI of "
                         "linear-minus-tofa is above zero on every gated "
                         "preset")
    ap.add_argument("--write", action="store_true",
                    help="append a point to BENCH_clustersim.json")
    ap.add_argument("--label", default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="single-seed sweep seed / replica base seed")
    ap.add_argument("--replicas", type=int, default=None,
                    help="Monte-Carlo replicas per gated preset "
                         "(--check defaults to 16)")
    ap.add_argument("--presets", default=None,
                    help="comma list restricting the replica sweep "
                         "(default: the gated presets)")
    ap.add_argument("--bootstrap", type=int, default=2000,
                    help="bootstrap resamples B")
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "serial", "process"))
    ap.add_argument("--workers", "--jobs", dest="workers", type=int,
                    default=None,
                    help="process-pool workers for the replica sweep; "
                         "0 (or omitted) auto-detects os.cpu_count()")
    ap.add_argument("--skip-sweep", action="store_true",
                    help="skip the single-seed CSV sweep (replica-only run)")
    args = ap.parse_args()
    if args.check and args.replicas is None:
        args.replicas = 16
    rows: list[dict] = []
    if not args.skip_sweep:
        rows += run(fast=args.fast or None, seed=args.seed)["_rows"]
    comparisons: dict = {}
    if args.replicas:
        presets = (tuple(p for p in args.presets.split(",") if p)
                   if args.presets else GATED)
        rep_rows, comparisons = run_replica_rows(
            presets, args.replicas, fast=bool(args.fast),
            base_seed=args.seed, B=args.bootstrap, alpha=args.alpha,
            executor=args.executor, max_workers=args.workers)
        rows += rep_rows
    if args.write:
        write_trajectory(rows, args.label or "unlabeled", bool(args.fast),
                         n_replicas=args.replicas)
    if args.check:
        return check_replicas(comparisons, rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
