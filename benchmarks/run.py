"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4_5] [--fast]

Prints ``name,metric,value,unit[,extras]`` CSV lines.  The roofline table
reads the dry-run JSONL (see benchmarks/roofline.py docstring) — run
``python -m repro.launch.dryrun --all`` first for fresh numbers.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

BENCHES = ("fig3", "table1", "fig4_5", "mapping_scale", "fault_ablation",
           "refine_scale", "clustersim", "belief_sweep", "serve_storm",
           "roofline")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    ap.add_argument("--fast", action="store_true",
                    help="smaller batches for smoke runs")
    args, _ = ap.parse_known_args()
    if args.fast:
        os.environ["FAST"] = "1"
    names = args.only.split(",") if args.only else list(BENCHES)

    print("bench,metric,value,unit_or_notes")
    rc = 0
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            mod.run(csv=lambda line: print(line, flush=True))
            print(f"{name},wall_time,{time.perf_counter()-t0:.1f},s")
        except Exception as e:  # pragma: no cover
            rc = 1
            print(f"{name},ERROR,{e},exception", file=sys.stderr)
            import traceback
            traceback.print_exc()
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
