"""E9 (beyond paper) — epoch-keyed engine caching under state churn.

The ROADMAP north-star (serve placement at high request rates) lives or
dies on one property: a placement against a *slowly-drifting* cluster
must hit warm engine caches, paying matrix derivation only when health
actually changes.  This benchmark drives the drain-sweep cluster (the
flaky-node configuration of ``sim/scenarios.py``'s ``drain-sweep``
preset) through a serving loop — every round one heartbeat poll (with
real estimator jitter) and one placement — while genuine node failures
arrive every ``churn_every`` rounds, and reports:

* ``hit_rate``     — warm fraction of engine weight + memo lookups
                     (``PlacementEngine.cache_hit_rate``); before the
                     versioned-ClusterState API the estimator jitter
                     alone forced a cold derivation *every round*;
* ``epochs``       — distinct state versions minted (should track the
                     churn events, not the heartbeat rate);
* ``place_warm_ms`` / ``place_cold_ms`` — median warm vs post-churn
                     placement latency (delta weight refreshes keep even
                     the cold ones cheap);
* ``weight_delta_updates`` — how many cold derivations took the row-wise
                     refresh path instead of a full re-derivation.

``--check`` is the CI gate: ``hit_rate`` must stay >= the committed
floor (0.95) on the drain-sweep preset.  ``--write --label <name>``
appends a trajectory point to ``benchmarks/BENCH_state.json``.

    PYTHONPATH=src python -m benchmarks.state_churn [--fast] [--check]
    PYTHONPATH=src python -m benchmarks.state_churn --write --label pr5
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.cluster.scheduler import Job, Scheduler
from repro.core.engine import PlacementEngine
from repro.core.topology import TorusTopology
from repro.workloads.patterns import npb_dt_like

BENCH_PATH = pathlib.Path(__file__).parent / "BENCH_state.json"
MIN_HIT_RATE = 0.95


def run_churn(fast: bool = False, seed: int = 0) -> dict:
    """The drain-sweep serving loop; returns one benchmark row."""
    dims = (4, 4, 4) if fast else (6, 6, 6)
    n_flaky = 12 if fast else 40
    rounds = 120 if fast else 250
    churn_every = 30 if fast else 25
    topo = TorusTopology(dims)
    engine = PlacementEngine()
    sch = Scheduler(topo, engine=engine, seed=seed, drain_threshold=0.6)
    rng0 = np.random.default_rng(seed * 401 + 19)       # drain-sweep flavor
    flaky = rng0.choice(topo.n_nodes, n_flaky, replace=False)
    truth = np.zeros(topo.n_nodes)
    truth[flaky] = 0.3
    sch.registry.set_outage_probabilities(flaky, 0.3)
    sch.monitor.simulate_rounds(np.random.default_rng(seed ^ 0x5eed),
                                truth, 400)
    reply_rng = np.random.default_rng(seed * 77 + 5)
    wl = npb_dt_like(12 if fast else 16)
    # churn alternates flaky victims (pattern-preserving: the weight
    # matrix is literally unchanged, only the epoch moves) and healthy
    # victims (pattern flip: exercises the row-wise delta refresh)
    healthy = np.setdiff1d(np.arange(topo.n_nodes), flaky)
    victims = np.empty(2 * min(len(flaky), len(healthy)), dtype=np.int64)
    victims[0::2] = flaky[:len(victims) // 2]
    victims[1::2] = healthy[:len(victims) // 2]
    down: list[int] = []
    epochs = set()
    warm_s: list[float] = []
    cold_s: list[float] = []
    churned = False
    for r in range(rounds):
        alive = np.ones(topo.n_nodes, dtype=bool)
        alive[down] = False
        replies = alive & (reply_rng.random(topo.n_nodes) >= truth)
        sch.heartbeat_round(replies)
        if (r + 1) % churn_every == 0 and len(down) < len(victims):
            victim = int(victims[len(down)])
            down.append(victim)
            sch.handle_node_failure([victim])
            churned = True
        t0 = time.perf_counter()
        rec = sch.submit(Job(wl, distribution="tofa"))
        dt = time.perf_counter() - t0
        (cold_s if churned else warm_s).append(dt)
        churned = False
        assert rec.state == "running"
        sch.complete(rec.job.job_id)
        epochs.add(sch.cluster_state().epoch)
    stats = engine.cache_stats()
    return {
        "preset": "drain-sweep",
        "dims": list(dims),
        "rounds": rounds,
        "churn_events": len(down),
        "placements": rounds,
        "epochs": len(epochs),
        "hit_rate": engine.cache_hit_rate(),
        "place_warm_ms": 1e3 * float(np.median(warm_s)),
        "place_cold_ms": (1e3 * float(np.median(cold_s))
                          if cold_s else None),
        "weight_misses": stats["weight_misses"],
        "weight_hits": stats["weight_hits"],
        "shared_misses": stats["shared_misses"],
        "shared_hits": stats["shared_hits"],
        "weight_delta_updates": stats["weight_delta_updates"],
        "place_time_s": sch.place_time_s,
    }


def run(csv=print, fast: bool = False, seed: int = 0) -> dict:
    t0 = time.perf_counter()
    row = run_churn(fast=fast, seed=seed)
    wall = time.perf_counter() - t0
    csv(f"state_churn,{row['preset']},hit_rate,{row['hit_rate']:.4f},frac,"
        f"epochs={row['epochs']},churn={row['churn_events']},"
        f"placements={row['placements']},"
        f"delta_updates={row['weight_delta_updates']}")
    cold = (f"{row['place_cold_ms']:.1f}" if row['place_cold_ms'] is not None
            else "n/a")
    csv(f"state_churn,{row['preset']},place_warm_ms,"
        f"{row['place_warm_ms']:.1f},ms,cold_ms={cold}")
    csv(f"state_churn,{row['preset']},wall_time,{wall:.1f},s")
    return row


def check(row: dict) -> int:
    ok = row["hit_rate"] >= MIN_HIT_RATE
    print(f"GATE drain-sweep churn: hit_rate={row['hit_rate']:.4f} "
          f"(floor {MIN_HIT_RATE}) {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def write_trajectory(row: dict, label: str, fast: bool) -> None:
    doc = {"schema": 1,
           "gate": {"preset": "drain-sweep", "min_hit_rate": MIN_HIT_RATE},
           "trajectory": []}
    if BENCH_PATH.exists():
        doc = json.loads(BENCH_PATH.read_text())
    doc["trajectory"].append({"label": label, "fast": fast, "presets": [row]})
    BENCH_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"appended trajectory point {label!r} to {BENCH_PATH}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the engine cache hit rate "
                         "falls below the committed floor")
    ap.add_argument("--write", action="store_true",
                    help="append a point to BENCH_state.json")
    ap.add_argument("--label", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    row = run(fast=args.fast, seed=args.seed)
    if args.write:
        write_trajectory(row, args.label or "unlabeled", bool(args.fast))
    return check(row) if args.check else 0


if __name__ == "__main__":
    sys.exit(main())
