"""E3 / paper Figs. 4-5 — batch completion time + abort ratio under
failures.  The headline experiment:

  Fig. 4:  NPB-DT 85, 16 faulty nodes @ p_f=2%   (paper: TOFA -31%,
           abort 7.4% -> 2%)
  Fig. 5a: LAMMPS 64,  8 faulty nodes @ p_f=2%   (paper: TOFA -17.5%,
           abort -> 0 for TOFA)
  Fig. 5b: LAMMPS 64, 16 faulty nodes @ p_f=2%   (paper: TOFA -18.9%,
           abort 4.0% -> 1.1%)

10 batches x 100 instances each, paired N_f per batch, 8x8x8 torus with the
paper's platform constants.  Use --fast (or FAST=1) for a 3x30 smoke run.
"""
from __future__ import annotations

import os

from repro.sim.batchsim import run_scenario
from repro.workloads.patterns import lammps_like, npb_dt_like

PAPER = {
    "fig4_npb_dt_16f": (0.31, 0.074, 0.02),
    "fig5a_lammps_8f": (0.175, None, 0.0),
    "fig5b_lammps_16f": (0.189, 0.04, 0.011),
}


def run(csv=print, fast: bool | None = None) -> dict:
    if fast is None:
        fast = bool(int(os.environ.get("FAST", "0")))
    nb, ni = (3, 30) if fast else (10, 100)
    scenarios = [
        ("fig4_npb_dt_16f", lambda: npb_dt_like(85), 16),
        ("fig5a_lammps_8f", lambda: lammps_like(64), 8),
        ("fig5b_lammps_16f", lambda: lammps_like(64), 16),
    ]
    out = {}
    for name, wl_fn, n_faulty in scenarios:
        res = run_scenario(wl_fn, ("linear", "tofa"), dims=(8, 8, 8),
                           n_batches=nb, n_instances=ni,
                           n_faulty=n_faulty, p_f=0.02, seed=0)
        lin, tofa = res["linear"], res["tofa"]
        imp = tofa.improvement_over(lin)
        ref_imp, ref_ab_lin, ref_ab_tofa = PAPER[name]
        csv(f"{name},batch_completion_linear,"
            f"{lin.mean_completion:.2f},s")
        csv(f"{name},batch_completion_tofa,{tofa.mean_completion:.2f},s")
        csv(f"{name},improvement,{imp:.3f},frac  # paper: {ref_imp}")
        csv(f"{name},abort_ratio_linear,{lin.mean_abort_ratio:.3f},frac"
            f"  # paper: {ref_ab_lin}")
        csv(f"{name},abort_ratio_tofa,{tofa.mean_abort_ratio:.3f},frac"
            f"  # paper: {ref_ab_tofa}")
        out[name] = {"improvement": imp,
                     "abort_linear": lin.mean_abort_ratio,
                     "abort_tofa": tofa.mean_abort_ratio}
    return out


if __name__ == "__main__":
    run()
