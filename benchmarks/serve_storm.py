"""E10 (beyond paper) — online placement service under a request storm.

Drives :class:`repro.service.service.PlacementService` with an open
Poisson arrival stream (built on :func:`repro.workloads.arrivals.
poisson_stream`'s arrival discipline): a mix of interactive inference
replica sets (KV-shard affinity graphs, admission deadlines), standard
jobs, and best-effort elastic fillers — while a flaky-node churn process
takes nodes down mid-run (and repairs them) and heartbeats republish a
jittered outage belief every poll.  Reported per policy:

* ``placements_per_sec``  — sustained engine throughput over the wall
                            clock actually spent placing (first
                            placements + failure re-placements);
* ``admission_p50_s`` / ``admission_p99_s`` — simulated seconds from
                            submit to first placement (queue wait +
                            drain-tick latency);
* ``completion_p99_s``    — submit-to-completion sojourn including
                            re-placement restarts, the number fault
                            awareness must protect under churn;
* ``hit_rate``            — engine weight/memo cache hit rate; the
                            busy-overlay route keying must keep this
                            warm even though every drain tick has a
                            different lease set.

``--check`` is the CI gate, three conditions: ``tofa`` sustains at least
``MIN_PLACEMENTS_PER_SEC``, its cache hit rate stays >=
``MIN_HIT_RATE``, and its p99 completion under churn beats ``linear``
(same arrivals, same churn, same seeds).  ``--write --label <name>``
appends a trajectory point to ``benchmarks/BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.serve_storm [--fast] [--check]
    PYTHONPATH=src python -m benchmarks.serve_storm --write --label pr6
"""
from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import sys
import time

import numpy as np

from repro.core.topology import TorusTopology
from repro.service import (PlacementService, SLOClass, elastic_request,
                           replica_request)
from repro.workloads.arrivals import mixed_size_factory, poisson_stream

BENCH_PATH = pathlib.Path(__file__).parent / "BENCH_serve.json"
MIN_PLACEMENTS_PER_SEC = 50.0
MIN_HIT_RATE = 0.90
POLICIES = ("tofa", "linear")


def build_stream(n_req: int, rate: float, seed: int,
                 deadline_slack: float = 60.0) -> list:
    """The storm: Poisson arrivals, one third interactive replica sets
    (deadline-bounded), one third standard jobs, one third best-effort
    fillers (the preemption victim pool).  Rebuilding with one seed gives
    byte-identical workloads and arrival times across policies."""
    rng = np.random.default_rng(seed)
    specs = poisson_stream(mixed_size_factory((8, 12, 18)), rate, n_req,
                           rng, max_duration=None)
    reqs = []
    for i, spec in enumerate(specs):
        t = spec.submit_time
        if i % 3 == 0:
            reqs.append(replica_request(
                shard_bytes=2e8, n_replicas=2, shards_per_replica=3,
                slo=SLOClass.INTERACTIVE, submit_time=t,
                deadline=t + deadline_slack))
        elif i % 3 == 1:
            reqs.append(elastic_request(spec.workload,
                                        slo=SLOClass.STANDARD,
                                        submit_time=t))
        else:
            reqs.append(elastic_request(spec.workload,
                                        slo=SLOClass.BEST_EFFORT,
                                        submit_time=t))
    return reqs


def build_churn(topo, n_flaky: int, seed: int, horizon: float,
                churn_every: float, repair_after: float,
                per_event: int = 1):
    """Flaky node set (elevated heartbeat belief) and the failure /
    recovery schedule drawn from it — the adversarial case fault-aware
    placement is supposed to win: churn strikes exactly the nodes the
    belief flags.  ``per_event`` nodes go down together at each event
    (one epoch mint per event either way).

    The flaky nodes are drawn from the busiest *half* of the id range:
    churn on nodes no placement ever uses distinguishes nothing, so the
    bad region sits where allocator traffic actually lands — a
    fault-blind packer walks straight into it, a fault-aware one reads
    the belief and steers around it."""
    rng = np.random.default_rng(seed * 211 + 7)
    flaky = np.sort(rng.choice(topo.n_nodes // 2, n_flaky, replace=False))
    belief = np.zeros(topo.n_nodes)
    belief[flaky] = 0.3
    failures, recoveries = [], []
    t = churn_every
    k = 0
    while t < horizon:
        victims = [int(flaky[(k + j) % len(flaky)])
                   for j in range(per_event)]
        failures.append((t, victims))
        recoveries.append((t + repair_after, victims))
        t += churn_every
        k += per_event
    return flaky, belief, failures, recoveries


def run_storm(fast: bool = False, seed: int = 0) -> dict:
    """One storm per policy on identical streams; returns the bench row."""
    dims = (4, 4, 4) if fast else (6, 6, 6)
    n_req = 150 if fast else 600
    rate = 10.0 if not fast else 5.0
    horizon_guess = n_req / rate + 60.0
    topo = TorusTopology(dims)
    flaky, belief, failures, recoveries = build_churn(
        topo, n_flaky=8 if fast else 24, seed=seed,
        horizon=horizon_guess, churn_every=5.0, repair_after=15.0,
        per_event=1 if fast else 4)
    rows = {}
    for policy in POLICIES:
        svc = PlacementService(topo, policy=policy, seed=seed,
                               drain_interval=0.25, restart_delay=1.0)
        reqs = build_stream(n_req, rate, seed)
        res = svc.run(reqs, failures=failures, recoveries=recoveries,
                      heartbeat_interval=0.5, belief=belief,
                      belief_jitter=0.3)
        rows[policy] = dict(res.row, policy=policy)
    return {
        "dims": list(dims),
        "n_requests": n_req,
        "rate_jobs_per_s": rate,
        "n_flaky": int(len(flaky)),
        "churn_events": len(failures),
        "policies": rows,
    }


def run(csv=print, fast: bool | None = None, seed: int = 0) -> dict:
    if fast is None:        # benchmarks.run harness passes --fast via env
        fast = bool(int(os.environ.get("FAST", "0")))
    t0 = time.perf_counter()
    row = run_storm(fast=fast, seed=seed)
    wall = time.perf_counter() - t0
    for policy, r in row["policies"].items():
        csv(f"serve_storm,{policy},placements_per_sec,"
            f"{r['placements_per_sec']:.1f},1/s,"
            f"placed={r['placed']},replaced={r['replaced']},"
            f"hit_rate={r['hit_rate']:.4f}")
        csv(f"serve_storm,{policy},admission_p99_s,"
            f"{r['admission_p99_s']:.3f},s,p50={r['admission_p50_s']:.3f}")
        csv(f"serve_storm,{policy},completion_p99_s,"
            f"{r['completion_p99_s']:.2f},s,p50={r['completion_p50_s']:.2f},"
            f"completed={r['completed']},shed={r['shed']},"
            f"preempted={r['preempted']}")
    csv(f"serve_storm,storm,wall_time,{wall:.1f},s,"
        f"n_requests={row['n_requests']},churn={row['churn_events']}")
    return row


def check(row: dict) -> int:
    tofa = row["policies"]["tofa"]
    linear = row["policies"]["linear"]
    rc = 0
    pps = tofa["placements_per_sec"]
    ok = pps >= MIN_PLACEMENTS_PER_SEC
    print(f"GATE serve_storm throughput: placements_per_sec={pps:.1f} "
          f"(floor {MIN_PLACEMENTS_PER_SEC}) {'OK' if ok else 'FAIL'}")
    rc |= 0 if ok else 1
    hr = tofa["hit_rate"]
    ok = hr >= MIN_HIT_RATE
    print(f"GATE serve_storm cache: hit_rate={hr:.4f} "
          f"(floor {MIN_HIT_RATE}) {'OK' if ok else 'FAIL'}")
    rc |= 0 if ok else 1
    tp, lp = tofa["completion_p99_s"], linear["completion_p99_s"]
    ok = math.isfinite(tp) and tp > 0 and tp < lp
    print(f"GATE serve_storm churn resilience: tofa p99 completion "
          f"{tp:.2f}s vs linear {lp:.2f}s {'OK' if ok else 'FAIL'}")
    rc |= 0 if ok else 1
    return rc


def write_trajectory(row: dict, label: str, fast: bool) -> None:
    doc = {"schema": 1,
           "gate": {"min_placements_per_sec": MIN_PLACEMENTS_PER_SEC,
                    "min_hit_rate": MIN_HIT_RATE,
                    "p99_completion": "tofa < linear"},
           "trajectory": []}
    if BENCH_PATH.exists():
        doc = json.loads(BENCH_PATH.read_text())
    doc["trajectory"].append({"label": label, "fast": fast, "storm": row})
    BENCH_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"appended trajectory point {label!r} to {BENCH_PATH}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when a storm gate fails "
                         "(throughput floor, cache hit rate, tofa p99 "
                         "completion beating linear)")
    ap.add_argument("--write", action="store_true",
                    help="append a point to BENCH_serve.json")
    ap.add_argument("--label", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    row = run(fast=bool(args.fast), seed=args.seed)
    if args.write:
        write_trajectory(row, args.label or "unlabeled", bool(args.fast))
    return check(row) if args.check else 0


if __name__ == "__main__":
    sys.exit(main())
